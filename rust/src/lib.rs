//! # tiledbits — Tiled Bit Networks (CIKM 2024) in Rust + JAX + Pallas
//!
//! A three-layer reproduction of *"Tiled Bit Networks: Sub-Bit Neural Network
//! Compression Through Reuse of Learnable Binary Vectors"* (Gorbett, Shirazi,
//! Ray — CIKM 2024):
//!
//! * **Layer 1 (Pallas)** — tile-reusing matmul + tile-construction kernels,
//!   authored in `python/compile/kernels/` and AOT-lowered to HLO text.
//! * **Layer 2 (JAX)** — the model zoo and train/eval graphs, lowered once by
//!   `python/compile/aot.py` into `artifacts/`.
//! * **Layer 3 (this crate)** — everything that runs: the PJRT runtime, the
//!   training coordinator, the native sub-bit inference engine (the paper's
//!   Algorithm 1 plus the bit-packed XNOR-popcount fast path), the TBNZ
//!   model format, dataset substrates, the serving stack, and the benchmark
//!   harness that regenerates every table and figure in the paper.
//!
//! Python never runs on the request path: after `make artifacts` the `tbn`
//! binary is self-contained.
//!
//! ## Inference architecture
//!
//! The native engine is a **layer DAG**: `nn::Engine` executes an
//! `nn::Graph` of typed nodes (`nn::layers::Node`) — `Fc`, `Conv2d` (im2col
//! over the same bit kernels as FC, incl. grouped/depthwise), `Pool2d`,
//! `GlobalPool`, `Flatten`, the transformer plumbing `LayerNorm` /
//! `TokenMeanPool` / `Transpose` / `PosEmbedAdd`, plus the join nodes
//! `Add` (residual skip), `MatMulFeature` (PointNet T-Net feature
//! transform) and `Attention` (multi-head self-attention over Q/K/V slots,
//! max-subtracted softmax in f32) — with a value-table walker: activations
//! are addressable by node id and freed after their last consumer.
//! `nn::lower_arch_spec` turns `arch::models` specs into runnable graphs:
//! sequential CNN stacks (`vgg_small_cifar`, `convmixer_cifar`, the minis,
//! PointNet-style shared-MLP token convs) *and* the annotated branching
//! architectures per the `arch::BlockRole` block-boundary annotations —
//! `resnet18_cifar` / `resnet50_cifar` residual graphs (identity +
//! 1x1-projection skips, ReLU after the join), `pointnet_cls` T-Nets
//! (transform subgraph → `MatMulFeature` apply), and the transformer
//! encoders: `vit_cifar` / `vit_small_imagenet` / `tst_electricity` /
//! `tst_weather` lower to pre-LN attention + MLP residual blocks (Q/K/V/O
//! and MLP projections run as tiled token-FCs through the batched
//! tile-resident row kernel) and `mlpmixer_cifar` runs its token-mixing
//! MLPs between `Transpose` pairs, closing the paper's full architecture
//! coverage (Swin/MobileViT attention variants are rejected with errors
//! naming the construct).  `nn::MlpEngine` wraps an FC-chain `Engine`
//! built from a TBNZ model and keeps the original deployable-runner API.
//!
//! Every engine runs one of four `nn::EnginePath`s:
//!
//! * `Reference` — f32 Algorithm 1 (tile reuse, never expands weights); the
//!   oracle for everything else.
//! * `Packed` — the deployment fast path: hidden activations (FC vectors
//!   and conv im2col patches alike) sign-binarized with an XNOR-Net scale,
//!   weight layers computed as XNOR + popcount with per-run alpha rescaling
//!   (`nn::packed`).  Tiled layers default to the **tile-resident** layout
//!   (`nn::PackedLayout::TileResident`): exactly one packed `q`-bit tile
//!   plus its alphas stays resident per layer — `O(q)` weight residency,
//!   the paper's "single tile per layer in memory" inference kernel — and
//!   row dots walk constant-alpha runs as offsets into the tile through
//!   shift-stitched u128-lane popcount kernels (`tbn::bitops`).  The
//!   expanded `O(m·n)` row layout stays available behind
//!   `PackedLayout::Expanded` for A/B measurement, and batched forwards
//!   walk each row's weight state once across the whole batch.
//!   `serve::Server::start_pool` shares one packed model across N batching
//!   workers behind a bounded queue (`serve::ServePolicy`: reject-or-block
//!   backpressure, per-worker counters, nearest-rank p50/p95/p99 latency
//!   report).  The pools serve real traffic through the network front end
//!   (`tbn serve --listen`): `serve::NetServer` speaks minimal HTTP/1.1
//!   over `std::net` (no HTTP crate) in front of a `serve::ModelRegistry`
//!   holding many named models in one process — `O(q)` tile residency is
//!   what makes multi-model serving cheap — with `Arc`-swap hot model
//!   replacement (`POST /reload`; in-flight requests finish on the model
//!   they resolved), load shedding as `503` under `OverflowPolicy::Reject`,
//!   and graceful drain on SIGTERM/shutdown (stop accepting, complete
//!   every accepted request, emit final per-model stats).  Connections are
//!   multiplexed (`serve::NetModel`, CLI `--net-model`): the default `mux`
//!   model runs every connection as a nonblocking state machine on one
//!   epoll-driven event loop (raw `epoll`/`poll(2)` FFI, no async runtime),
//!   dispatching parsed requests to the worker pools off-loop and resuming
//!   partial writes on readiness — thread count stays bounded at any
//!   connection count, idle keep-alive clients cost a table entry instead
//!   of a parked thread, and accepts beyond `--max-conns` shed with `503`;
//!   the `threads` model keeps the handler-thread-per-connection baseline
//!   for A/B, and both share one request handler + response renderer, so
//!   wire behavior is byte-identical.  Connection counters
//!   (accepted/open/stalls/shed) surface on `GET /stats` and a periodic
//!   stats line.  `serve::loadgen`
//!   (`tbn loadgen`, `benches/table_serve.rs`) drives it open-loop with
//!   Poisson arrivals, measuring p50/p95/p99/p99.9 from the scheduled
//!   arrival time (coordinated-omission-free), saturation throughput, and
//!   latency across a `--conns` connection ladder, A/B per net model
//!   (`BENCH_serve.json`); `tests/net_serving.rs` pins wire parity —
//!   an HTTP answer is bit-identical to `Engine::forward` — plus
//!   shedding, torn-model-free swaps, drain completeness, and the
//!   connection state machine (slowloris dribble, pipelined bursts,
//!   multi-MB partial-write resume, idle-conn drain) on both net models.
//!   Both packed paths also thread *within* one forward:
//!   `Engine::with_threads` (CLI `--threads`, env `TBN_THREADS`) splits the
//!   independent output rows / conv positions of each packed kernel across
//!   scoped std threads writing disjoint output slices, leaving every
//!   per-element reduction order untouched — so threaded forwards are
//!   **bit-exact** against single-threaded ones at any thread count, and
//!   intra-op threads compose multiplicatively with serve workers.
//!   Inside each thread the XNOR-popcount word loops run on a
//!   runtime-dispatched SIMD backend (`nn::SimdBackend`, resolved once per
//!   process via `OnceLock`): AVX2 Harley–Seal kernels where the CPU has
//!   them, portable u128 / four-lane u64 / scalar generations everywhere
//!   else — selected by `Engine::with_simd` (CLI `--simd`, env `TBN_SIMD`,
//!   mirroring the layout/thread switches) and also **bit-exact** across
//!   every backend at every width, offset phase, and thread count (the
//!   safety argument for the `unsafe` intrinsics lives in `tbn::bitops`).
//! * `PackedInt8` — `Packed` with the first weight layer's input quantized
//!   to 8-bit integers (the paper's microcontroller input packing) instead
//!   of running layer 0 in f32; parity-gated by the quantization bound in
//!   `tests/conv_parity.rs`.
//! * `PackedInt` — the threshold-folded fully-integer hidden pipeline: a
//!   hidden FC feeding only packed FCs never materializes f32 — each row's
//!   sign test collapses into a precomputed integer popcount threshold
//!   (`nn::IntThresholds`; negative-alpha rows flip the comparison, ReLU
//!   folds in for free) and the row kernel writes the next layer's packed
//!   bit-words directly, composing with both weight layouts, `--threads`
//!   and every SIMD backend.  f32 boundaries (entry layer, convs, joins,
//!   the output layer) emit with a per-layer constant gamma calibrated by
//!   `Engine::calibrate_int_gammas`, so `Packed` stays the exact
//!   data-dependent-gamma baseline; bit-exactness against a plain-Rust
//!   integer oracle is pinned by `tests/int_pipeline_parity.rs`.
//!
//! ## Test tiers
//!
//! * **Artifact-free** (always run, what CI gates on — once per packed
//!   weight layout via the `TBN_LAYOUT` env override, crossed with
//!   single-/multi-threaded kernels via `TBN_THREADS`): unit tests, property
//!   tests (`tests/properties.rs`), packed/reference parity
//!   (`tests/packed_parity.rs`), conv parity + CNN graph smoke tests
//!   (`tests/conv_parity.rs`), branching-graph parity
//!   (`tests/graph_parity.rs`), transformer parity
//!   (`tests/transformer_parity.rs`), serving-pool tests, format/config
//!   tests.
//!   CI also compiles every bench binary (`cargo bench --no-run`) and runs
//!   the release-mode `--ignored` tier.
//! * **Artifact-dependent** (`tests/native_parity.rs`, runtime/pipeline
//!   integration, the trained halves of the benches): need `make artifacts`
//!   and a real PJRT runtime; they skip with a notice when either is
//!   missing.  The vendored `xla` crate in `rust/vendor/` is an offline
//!   stub — host-side literal ops are real, graph execution reports
//!   unavailable — so a bare checkout still builds and tests everywhere.

pub mod arch;
pub mod baselines;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod tbn;
pub mod tensor;
pub mod train;
pub mod util;

/// Repo-relative default artifact directory (override with `--artifacts`).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
/// Repo-relative default experiment config (single source of truth with aot.py).
pub const DEFAULT_CONFIG: &str = "configs/experiments.json";
/// Where the coordinator records completed runs.
pub const DEFAULT_RUNS_DIR: &str = "runs";
