//! Figure 2: conv vs fully-connected composition of popular DNNs — the
//! motivation figure for tiling FC layers. Pure analytic, over every
//! architecture spec in `arch::all_archs()`.

use tiledbits::arch;
use tiledbits::bench_util::header;
use tiledbits::coordinator::report;

fn main() {
    header("Figure 2: composition of popular DNNs (conv vs FC params)");
    print!("{}", report::composition_table().render());

    // the figure's qualitative claim, checked numerically
    let conv_heavy = ["resnet18_cifar", "resnet34_imagenet", "resnet50_cifar",
                      "convmixer_cifar"];
    let fc_heavy = ["vit_cifar", "swin_t", "pointnet_cls", "mlpmixer_cifar",
                    "tst_electricity"];
    let mut ok = true;
    for name in conv_heavy {
        let a = arch::arch_by_name(name).unwrap();
        if a.fc_fraction() > 0.2 {
            println!("UNEXPECTED: {name} fc fraction {:.2}", a.fc_fraction());
            ok = false;
        }
    }
    for name in fc_heavy {
        let a = arch::arch_by_name(name).unwrap();
        if a.fc_fraction() < 0.8 {
            println!("UNEXPECTED: {name} fc fraction {:.2}", a.fc_fraction());
            ok = false;
        }
    }
    println!("\nshape check ({}): ResNets conv-dominated; Transformers/MLPs/PointNet",
             if ok { "PASS" } else { "FAIL" });
    println!("FC-dominated — the populations the paper's FC tiling unlocks.");
}
