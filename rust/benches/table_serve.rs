//! Serving bench: open-loop load against the network front end.
//!
//! Boots the full production serving path in-process — `ModelRegistry` +
//! `NetServer` on `127.0.0.1:0` over a packed micro-MLP worker pool — and
//! drives it with the in-crate Poisson load generator, once per net model
//! (`mux` event loop vs `threads` per-connection baseline).  Each model
//! gets a rate ladder at a fixed connection count *and* a latency-vs-#conns
//! ladder (1/64/512 keep-alive connections at a fixed rate) — the mux
//! model's whole point is holding the 512-connection rung with bounded
//! threads.  Reports completed/rejected counts, p50/p95/p99/p99.9 latency
//! (measured from the scheduled arrival, so client-side queueing under
//! overload is charged to the server), and per-model saturation
//! throughput.  `--json` writes the machine-readable `BENCH_serve.json`
//! with `net_model`-tagged rows (grep-gated in CI next to
//! `BENCH_table2/table6`).
//!
//! Artifact-free and short: the model is seeded like the engine unit
//! tests, rates/durations are sized for a CI smoke run
//! (`cargo bench --bench table_serve`), not a steady-state soak.

use std::sync::Arc;
use std::time::Duration;

use tiledbits::bench_util::header;
use tiledbits::nn::{EnginePath, MlpEngine, Nonlin, SimdBackend};
use tiledbits::serve::{loadgen, BatchPolicy, LoadgenConfig, LoadgenReport, ModelRegistry,
                       NetConfig, NetModel, NetServer, OverflowPolicy, ServePolicy,
                       Server};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::util::Rng;

/// The deployment micro MLP (256 -> 128 -> 10), fully tiled at p=4.
fn micro_model() -> TbnzModel {
    let p = 4usize;
    let mut r = Rng::new(42);
    let mk = |name: &str, m: usize, n: usize, r: &mut Rng| {
        let w: Vec<f32> = r.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        }
    };
    TbnzModel { layers: vec![mk("fc0", 128, 256, &mut r), mk("head", 10, 128, &mut r)] }
}

const WORKERS: usize = 2;
const MAX_CONNS: usize = 2048;

/// Boot one fresh micro-MLP pool behind a front end running `model`.
fn boot(simd: SimdBackend, model: NetModel) -> NetServer {
    let engine =
        MlpEngine::with_path(micro_model(), Nonlin::Relu, EnginePath::Packed).unwrap();
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 32, window: Duration::from_micros(200) },
        queue_cap: 256,
        // shed under overload so the saturation sweep measures the server,
        // not a convoy of blocked submitters
        on_full: OverflowPolicy::Reject,
        kernel_threads: 1,
        simd,
        engine: EnginePath::Packed,
    };
    let registry = Arc::new(ModelRegistry::new());
    registry.register("micro", Server::start_pool_with(Arc::new(engine), policy, WORKERS));
    NetServer::start_with(
        registry,
        "127.0.0.1:0",
        None,
        NetConfig { model, max_conns: MAX_CONNS, dispatch_threads: 16 },
    )
    .expect("bind loopback")
}

fn print_table(title: &str, reports: &[LoadgenReport]) {
    println!("\n{title}");
    println!("{:>12} {:>6} {:>8} {:>10} {:>10} {:>12} {:>9} {:>9} {:>9} {:>9}",
             "offered_rps", "conns", "sent", "completed", "rejected", "achieved_rps",
             "p50_us", "p95_us", "p99_us", "p999_us");
    for r in reports {
        println!("{:>12.0} {:>6} {:>8} {:>10} {:>10} {:>12.1} {:>9} {:>9} {:>9} {:>9}",
                 r.offered_rps, r.conns, r.sent, r.completed, r.rejected,
                 r.achieved_rps, r.p50_us, r.p95_us, r.p99_us, r.p999_us);
    }
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let simd = SimdBackend::default();
    header("Serving: open-loop load vs the network front end (micro MLP)");
    println!("packed kernels run the {simd} xnor-popcount backend");

    let net_models: &[NetModel] = if cfg!(unix) {
        &[NetModel::Mux, NetModel::Threads]
    } else {
        &[NetModel::Threads]
    };
    let rates = [500.0, 2000.0, 8000.0];
    let conns_ladder = [1usize, 64, 512];
    let mut groups: Vec<(String, Vec<LoadgenReport>)> = Vec::new();

    for &model in net_models {
        let net = boot(simd, model);
        let addr = net.addr().to_string();
        println!("\n== net model {model} ==");
        println!("serving micro on {addr} ({WORKERS} workers, queue cap 256, reject, \
                  max {MAX_CONNS} conns)");

        let base = LoadgenConfig {
            addr,
            model: "micro".into(),
            duration: Duration::from_millis(600),
            conns: 4,
            seed: 9,
            ..LoadgenConfig::default()
        };
        // rate ladder at a fixed connection count: the saturation sweep
        let rate_reports = loadgen::sweep_grid(&base, &rates, &[4]).expect("rate sweep");
        print_table(&format!("[{model}] rate ladder at 4 conns"), &rate_reports);
        let saturation = loadgen::saturation_rps(&rate_reports);
        println!("[{model}] saturation throughput: {saturation:.1} req/s (max achieved \
                  across the sweep)");

        // connection ladder at a fixed rate: latency vs #conns — where the
        // threads model pays a thread per idle client and mux does not
        let conn_reports =
            loadgen::sweep_grid(&base, &[2000.0], &conns_ladder).expect("conns sweep");
        print_table(&format!("[{model}] latency vs #conns at 2000 req/s"), &conn_reports);

        let ns = net.net_stats();
        println!("[{model}] net counters: accepted={} closed={} read_stalls={} \
                  write_stalls={} shed_at_accept={}",
                 ns.accepted, ns.closed, ns.read_stalls, ns.write_stalls,
                 ns.shed_at_accept);

        let mut all = rate_reports;
        all.extend(conn_reports);
        groups.push((model.as_str().to_string(), all));

        // graceful drain: every accepted request completed before this returns
        for (name, generation, s) in net.shutdown() {
            println!("final model={name} generation={generation} served={} rejected={}",
                     s.served, s.rejected);
        }
    }

    if json_mode {
        let doc = loadgen::grid_to_json(&groups);
        let path = "BENCH_serve.json";
        std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_serve.json");
        println!("wrote {path}");
    }
    println!("drain: complete");
}
