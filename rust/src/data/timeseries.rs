//! Synthetic multivariate series (ECL / Weather stand-ins): seasonal + AR(2)
//! + cross-channel mixing + noise.  The forecasting target is the next step
//! of every channel.

use crate::util::Rng;
use super::{Dataset, Task};

/// Generate `n` windows of shape [seq, channels] with next-step targets.
///
/// One long latent series is synthesized and windows are sliced from it (so
/// neighbouring windows share dynamics, like real load/weather data).
/// `noise` controls the irreducible target noise (ECL noisier than Weather).
pub fn synth_series(input: &[usize], n: usize, rng: &mut Rng, noise: f32) -> Dataset {
    assert_eq!(input.len(), 2, "series wants [seq, channels]");
    let (seq, ch) = (input[0], input[1]);
    let total = n + seq + 1;

    // latent drivers: a few seasonal components + AR(2)
    let n_latent = 4.min(ch);
    let mut latents = vec![vec![0.0f32; total]; n_latent];
    for (li, lat) in latents.iter_mut().enumerate() {
        let period = 12.0 + 10.0 * li as f32 + 6.0 * rng.next_f32();
        let phase = std::f32::consts::TAU * rng.next_f32();
        let (a1, a2) = (0.6 + 0.2 * rng.next_f32(), -0.3 + 0.1 * rng.next_f32());
        let mut e1 = 0.0f32;
        let mut e2 = 0.0f32;
        for (t, v) in lat.iter_mut().enumerate() {
            let season = (std::f32::consts::TAU * t as f32 / period + phase).sin();
            let ar = a1 * e1 + a2 * e2 + 0.3 * rng.gauss_f32();
            e2 = e1;
            e1 = ar;
            *v = season + ar;
        }
    }

    // channel mixing: each channel is a sparse combination of latents
    let mix: Vec<Vec<f32>> = (0..ch)
        .map(|_| (0..n_latent).map(|_| rng.gauss_f32() * 0.8).collect())
        .collect();
    let mut series = vec![0.0f32; total * ch];
    for t in 0..total {
        for c in 0..ch {
            let mut v = 0.0;
            for l in 0..n_latent {
                v += mix[c][l] * latents[l][t];
            }
            series[t * ch + c] = v + noise * rng.gauss_f32();
        }
    }

    let mut x = Vec::with_capacity(n * seq * ch);
    let mut y = Vec::with_capacity(n * ch);
    for w in 0..n {
        let start = w; // sliding windows, stride 1
        x.extend_from_slice(&series[start * ch..(start + seq) * ch]);
        y.extend_from_slice(&series[(start + seq) * ch..(start + seq + 1) * ch]);
    }
    Dataset { n, x_elems: seq * ch, x, y_int: vec![], y_float: y, y_elems: ch,
              y_int_elems: 0, task: Task::Forecast }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_shapes() {
        let mut rng = Rng::new(6);
        let d = synth_series(&[48, 32], 10, &mut rng, 0.25);
        assert_eq!(d.x.len(), 10 * 48 * 32);
        assert_eq!(d.y_float.len(), 10 * 32);
        assert_eq!(d.task, Task::Forecast);
    }

    #[test]
    fn target_is_next_step_of_window() {
        // window w+1's last row equals window w's target when stride is 1:
        let mut rng = Rng::new(7);
        let (seq, ch) = (16usize, 4usize);
        let d = synth_series(&[seq, ch], 5, &mut rng, 0.1);
        for w in 0..4 {
            let y_w = &d.y_float[w * ch..(w + 1) * ch];
            let next_last = &d.x[((w + 1) * seq * ch + (seq - 1) * ch)..((w + 1) * seq * ch + seq * ch)];
            assert_eq!(y_w, next_last, "window {w}");
        }
    }

    #[test]
    fn persistence_beats_nothing_autocorrelated() {
        // series must be autocorrelated: last-value persistence predicts the
        // target much better than the series variance (else forecasting is
        // unlearnable noise)
        let mut rng = Rng::new(8);
        let d = synth_series(&[48, 8], 200, &mut rng, 0.1);
        let ch = 8;
        let mut mse_persist = 0.0f64;
        let mut var = 0.0f64;
        let mean: f64 = d.y_float.iter().map(|v| *v as f64).sum::<f64>()
            / d.y_float.len() as f64;
        for w in 0..d.n {
            for c in 0..ch {
                let last = d.x[w * d.x_elems + 47 * ch + c] as f64;
                let y = d.y_float[w * ch + c] as f64;
                mse_persist += (y - last) * (y - last);
                var += (y - mean) * (y - mean);
            }
        }
        assert!(mse_persist < 0.5 * var, "persistence {mse_persist} vs var {var}");
    }
}
