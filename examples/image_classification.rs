//! Image classification at three precisions — a miniature of the paper's
//! Table 1 experiment: train ResNet-mini on SynthCIFAR as full-precision,
//! BWNN (1-bit) and TBN_4 (sub-bit), then print the comparison, including
//! the analytic columns on the *full-size* ResNet18.
//!
//! `TBN_STEPS` scales the run (default 200; the EXPERIMENTS.md numbers use
//! the configured 500).

use anyhow::{anyhow, Result};
use tiledbits::arch;
use tiledbits::config::Manifest;
use tiledbits::coordinator::{report, run_or_load};
use tiledbits::runtime::Runtime;
use tiledbits::tbn::{compress, TilingPolicy};
use tiledbits::train::TrainOptions;

fn main() -> Result<()> {
    let artifacts = std::env::var("TBN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("TBN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(200);
    let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
    let rt = Runtime::new(&artifacts)?;
    let opts = TrainOptions { steps: Some(steps), eval_every: 0, log_every: 50, seed: None };

    println!("== image classification: FP vs BWNN vs TBN (ResNet-mini / SynthCIFAR) ==\n");
    let ids = ["resnet_mini_fp", "resnet_mini_bwnn", "resnet_mini_tbn4",
               "resnet_mini_tbn8", "resnet_mini_tbn16"];
    let mut runs = Vec::new();
    for id in ids {
        let rec = run_or_load(&rt, &manifest, id, &opts, "runs")?;
        println!("{:24} acc {:>5.1}%  bit-width {:>6.3}  storage {:>9} bits",
                 id, 100.0 * rec.metric, rec.bit_width, rec.storage_bits);
        runs.push((id, rec));
    }

    println!("\n-- analytic columns on the full-size ResNet18 (paper Table 1) --");
    let a = arch::resnet18_cifar();
    for (label, pol) in [
        ("Full-Precision", TilingPolicy::fp()),
        ("BWNN (1-bit)", TilingPolicy::bwnn(0)),
        ("TBN_4", TilingPolicy::tbn(4, 64_000)),
        ("TBN_8", TilingPolicy::tbn(8, 64_000)),
        ("TBN_16", TilingPolicy::tbn(16, 64_000)),
    ] {
        let (bw, mbit, sav) = compress::table_row(&a, &pol);
        println!("{label:16} bit-width {bw:>6.3}  #params {mbit:>8.2} M-bit  savings {sav:>5.1}x");
    }

    let table = report::accuracy_table(
        "Table 1 (ResNet18 CIFAR): published vs measured-mini",
        "resnet18_cifar", "T1",
        &runs.iter().map(|(l, r)| (*l, r)).collect::<Vec<_>>());
    println!("\n{}", table.render());
    Ok(())
}
