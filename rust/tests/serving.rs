//! Serving-stack integration, in two tiers:
//!
//! * **artifact-free** (always run): the multi-worker pool over synthetic
//!   engines — request conservation, batch-size bounds, stats consistency,
//!   packed-path serving;
//! * **artifact-dependent** (skip cleanly when `artifacts/` is absent or the
//!   PJRT runtime is unavailable): the batcher fed from a real trained +
//!   exported model.

use std::sync::Arc;
use std::time::Duration;

use tiledbits::arch;
use tiledbits::config::Manifest;
use tiledbits::nn::{lower_arch_spec, Engine, EnginePath, LowerOptions, MlpEngine,
                    Nonlin, PackedLayout};
use tiledbits::runtime::Runtime;
use tiledbits::serve::{BatchPolicy, Server};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::tensor::BitVec;
use tiledbits::train::{export, metrics, Trainer, TrainOptions};
use tiledbits::util::{locate_upwards, Rng};

fn trained_engine() -> Option<(MlpEngine, Vec<Vec<f32>>, Vec<i32>)> {
    let Some(artifacts) = locate_upwards("artifacts") else {
        eprintln!("skipping serving tests: artifacts/ not built");
        return None;
    };
    let manifest = match Manifest::load(&artifacts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping serving tests: {e}");
            return None;
        }
    };
    let rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping serving tests: {e:#}");
            return None;
        }
    };
    let exp = manifest.by_id("mlp_micro_tbn4").unwrap();
    let trainer = Trainer::new(&rt, exp).unwrap();
    let (_, model) = trainer
        .run(&TrainOptions { steps: Some(120), eval_every: 0, log_every: 10_000, seed: Some(4) })
        .unwrap();
    let tbnz = export::to_tbnz(exp, &model).unwrap();
    let engine = MlpEngine::new(tbnz, Nonlin::Relu).unwrap();
    let d = trainer.test_ds.x_elems;
    let n = 128.min(trainer.test_ds.n);
    let idxs: Vec<usize> = (0..n).collect();
    let (x, y, _) = trainer.test_ds.gather(&idxs);
    let xs = (0..n).map(|i| x[i * d..(i + 1) * d].to_vec()).collect();
    Some((engine, xs, y))
}

#[test]
fn served_accuracy_matches_direct_inference() {
    let Some((engine, xs, labels)) = trained_engine() else { return };
    let direct: Vec<i32> = engine.classify_batch(&xs).iter().map(|&i| i as i32).collect();
    let direct_acc = metrics::accuracy(&direct, &labels);
    assert!(direct_acc > 0.4, "trained TBN should beat chance, got {direct_acc}");

    let server = Arc::new(Server::start(engine, BatchPolicy {
        max_batch: 16,
        window: Duration::from_micros(300),
    }));
    // concurrent clients
    let mut handles = Vec::new();
    for t in 0..4 {
        let s = server.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let mut preds = Vec::new();
            for i in (t..xs.len()).step_by(4) {
                let r = s.infer(xs[i].clone()).unwrap();
                let arg = r.y.iter().enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k as i32).unwrap();
                preds.push((i, arg));
            }
            preds
        }));
    }
    let mut served = vec![0i32; xs.len()];
    let mut count = 0;
    for h in handles {
        for (i, p) in h.join().unwrap() {
            served[i] = p;
            count += 1;
        }
    }
    assert_eq!(count, xs.len(), "no request may be dropped");
    assert_eq!(served, direct, "served predictions must equal direct inference");

    let stats = server.stats();
    assert_eq!(stats.served, xs.len());
    assert!(stats.mean_batch() >= 1.0);
    assert!(stats.mean_latency_us() > 0.0);
}

#[test]
fn throughput_improves_with_batching_pressure() {
    let Some((engine, xs, _)) = trained_engine() else { return };
    let server = Arc::new(Server::start(engine, BatchPolicy {
        max_batch: 32,
        window: Duration::from_micros(500),
    }));
    // flood the queue, then drain
    let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone()).unwrap()).collect();
    let mut max_batch_seen = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        max_batch_seen = max_batch_seen.max(resp.batch_size);
    }
    assert!(max_batch_seen >= 2, "burst traffic should form batches, saw {max_batch_seen}");
}

// ---------------------------------------------------------------------------
// Artifact-free tier: multi-worker pool over synthetic engines
// ---------------------------------------------------------------------------

/// Deployment-shaped synthetic model (64 -> 48 tiled, 48 -> 32 tiled,
/// 32 -> 10 bwnn; the middle layer runs packed-tiled), deterministic in
/// `seed` — the same construction the engine unit tests use.
fn synthetic_model(seed: u64) -> TbnzModel {
    let mut r = Rng::new(seed);
    let w1: Vec<f32> = r.normal_vec(48 * 64, 1.0);
    let w2: Vec<f32> = r.normal_vec(32 * 48, 1.0);
    let w3: Vec<f32> = r.normal_vec(10 * 32, 1.0);
    TbnzModel {
        layers: vec![
            LayerRecord {
                name: "fc0".into(),
                shape: vec![48, 64],
                payload: WeightPayload::Tiled {
                    p: 4,
                    tile: tile_from_weights(&w1, 4),
                    alphas: alphas_from(&w1, 4, AlphaMode::PerTile),
                },
            },
            LayerRecord {
                name: "fc1".into(),
                shape: vec![32, 48],
                payload: WeightPayload::Tiled {
                    p: 4,
                    tile: tile_from_weights(&w2, 4),
                    alphas: alphas_from(&w2, 4, AlphaMode::PerTile),
                },
            },
            LayerRecord {
                name: "head".into(),
                shape: vec![10, 32],
                payload: WeightPayload::Bwnn {
                    bits: BitVec::from_signs(&w3),
                    alpha: w3.iter().map(|x| x.abs()).sum::<f32>() / w3.len() as f32,
                },
            },
        ],
    }
}

fn synthetic_engine(seed: u64, path: EnginePath) -> MlpEngine {
    MlpEngine::with_path(synthetic_model(seed), Nonlin::Relu, path).unwrap()
}

#[test]
fn multi_worker_pool_answers_every_request_exactly_once() {
    let engine = Arc::new(synthetic_engine(11, EnginePath::Packed));
    let direct: Vec<Vec<f32>> = {
        let mut r = Rng::new(99);
        let xs: Vec<Vec<f32>> = (0..160).map(|_| r.normal_vec(64, 1.0)).collect();
        engine.forward_batch(&xs)
    };
    let mut r = Rng::new(99);
    let xs: Vec<Vec<f32>> = (0..160).map(|_| r.normal_vec(64, 1.0)).collect();

    let max_batch = 8;
    let server = Arc::new(Server::start_pool(
        engine,
        BatchPolicy { max_batch, window: Duration::from_micros(300) },
        4,
    ));
    assert_eq!(server.stats().workers, 4);

    // 8 concurrent senders, striped over the request set
    let mut handles = Vec::new();
    for t in 0..8usize {
        let s = server.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for i in (t..xs.len()).step_by(8) {
                let resp = s.infer(xs[i].clone()).unwrap();
                assert!(resp.batch_size >= 1 && resp.batch_size <= max_batch,
                        "batch size {} out of bounds", resp.batch_size);
                assert!(resp.total_us >= resp.queue_us);
                out.push((i, resp.y));
            }
            out
        }));
    }
    let mut answered = vec![false; xs.len()];
    for h in handles {
        for (i, y) in h.join().unwrap() {
            assert!(!answered[i], "request {i} answered twice");
            answered[i] = true;
            assert_eq!(y, direct[i], "served output {i} must equal direct inference");
        }
    }
    assert!(answered.iter().all(|&a| a), "every request must be answered");

    let stats = server.stats();
    assert_eq!(stats.served, xs.len());
    assert_eq!(stats.batch_size_sum, xs.len(),
               "every request is in exactly one batch");
    assert!(stats.batches >= xs.len() / max_batch);
    assert!(stats.batches <= xs.len());
    assert!(stats.mean_batch() >= 1.0 && stats.mean_batch() <= max_batch as f64);
    assert!(stats.mean_latency_us() > 0.0);
    assert!(stats.max_latency_us as f64 >= stats.mean_latency_us());
}

#[test]
fn pool_serves_packed_and_reference_paths_consistently() {
    // same weights behind both paths; each server must reproduce its own
    // engine's direct outputs exactly
    for path in [EnginePath::Reference, EnginePath::Packed] {
        let engine = Arc::new(synthetic_engine(5, path));
        let mut r = Rng::new(123);
        let xs: Vec<Vec<f32>> = (0..24).map(|_| r.normal_vec(64, 1.0)).collect();
        let direct: Vec<Vec<f32>> = xs.iter().map(|x| engine.forward(x)).collect();
        let server = Server::start_pool(
            engine,
            BatchPolicy { max_batch: 4, window: Duration::from_micros(200) },
            3,
        );
        for (x, want) in xs.iter().zip(&direct) {
            let got = server.infer(x.clone()).unwrap();
            assert_eq!(&got.y, want, "path {path:?}");
        }
        let stats = server.stats();
        assert_eq!(stats.served, xs.len());
        assert_eq!(stats.workers, 3);
    }
}

#[test]
fn serving_reports_latency_percentiles() {
    let engine = Arc::new(synthetic_engine(13, EnginePath::Packed));
    let server = Server::start_pool(
        engine,
        BatchPolicy { max_batch: 8, window: Duration::from_micros(200) },
        2,
    );
    let mut r = Rng::new(21);
    let rxs: Vec<_> = (0..50)
        .map(|_| server.submit(r.normal_vec(64, 1.0)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let stats = server.stats();
    let p = stats.latency_percentiles().expect("50 served requests -> report");
    assert_eq!(p.samples, 50);
    assert!(p.p50_us <= p.p95_us && p.p95_us <= p.p99_us,
            "tail ordering violated: {p:?}");
    assert!(p.p99_us <= stats.max_latency_us);
}

/// A branching layer-graph engine (residual joins) serves directly behind
/// the pool: lowered ResNet-style graphs answer bit-identically to direct
/// batched inference on the packed path.
#[test]
fn pool_serves_branching_graph_engine() {
    let spec = arch::resnet_micro();
    let lopts = LowerOptions {
        input: (3, 7, 7),
        p: 4,
        alpha_mode: AlphaMode::PerTile,
        seed: 31,
    };
    let graph = lower_arch_spec(&spec, &lopts).unwrap();
    // default layout through the TBN_LAYOUT env hook, so the CI expanded
    // leg serves a branching graph under the expanded layout too
    let engine = Arc::new(
        Engine::with_layout_graph(graph, Nonlin::Relu, EnginePath::Packed,
                                  PackedLayout::from_env())
            .unwrap());
    let mut r = Rng::new(32);
    let xs: Vec<Vec<f32>> = (0..24).map(|_| r.normal_vec(3 * 7 * 7, 1.0)).collect();
    let direct: Vec<Vec<f32>> = xs.iter().map(|x| engine.forward(x)).collect();
    let server = Server::start_pool(
        engine,
        BatchPolicy { max_batch: 4, window: Duration::from_micros(200) },
        2,
    );
    for (x, want) in xs.iter().zip(&direct) {
        let got = server.infer(x.clone()).unwrap();
        assert_eq!(&got.y, want, "served branching graph must equal direct forward");
    }
    assert_eq!(server.stats().served, xs.len());
}

/// A lowered transformer graph (attention joins, layer norms, pos-embed,
/// mean-pool head) serves behind the batching pool bit-identically to
/// direct batched inference — the `tbn serve --arch vit_micro` path.
#[test]
fn pool_serves_transformer_graph_engine() {
    let spec = arch::vit_micro();
    let lopts = LowerOptions {
        input: spec.native_input().expect("vit_micro input shape"),
        p: 4,
        alpha_mode: AlphaMode::PerTile,
        seed: 41,
    };
    let graph = lower_arch_spec(&spec, &lopts).unwrap();
    // default layout through the TBN_LAYOUT env hook, so the CI expanded
    // leg serves a transformer graph under the expanded layout too
    let engine = Arc::new(
        Engine::with_layout_graph(graph, Nonlin::Relu, EnginePath::Packed,
                                  PackedLayout::from_env())
            .unwrap());
    let d = engine.in_len();
    let mut r = Rng::new(42);
    let xs: Vec<Vec<f32>> = (0..24).map(|_| r.normal_vec(d, 1.0)).collect();
    let direct = engine.forward_batch(&xs);
    let server = Server::start_pool(
        engine,
        BatchPolicy { max_batch: 4, window: Duration::from_micros(200) },
        2,
    );
    for (x, want) in xs.iter().zip(&direct) {
        let got = server.infer(x.clone()).unwrap();
        assert_eq!(&got.y, want, "served transformer graph must equal direct forward");
        assert_eq!(got.y.len(), 6);
    }
    assert_eq!(server.stats().served, xs.len());
}

/// The serve stack returns identical outputs under both packed weight
/// layouts (the tile-resident layout is bit-exact vs expanded), while the
/// tile-resident engine keeps strictly fewer weight bytes resident.
#[test]
fn pool_serves_identically_across_weight_layouts() {
    let model = synthetic_model(5);
    let tile = Arc::new(MlpEngine::with_path_layout(
        model.clone(), Nonlin::Relu, EnginePath::Packed,
        PackedLayout::TileResident).unwrap());
    let expanded = Arc::new(MlpEngine::with_path_layout(
        model, Nonlin::Relu, EnginePath::Packed, PackedLayout::Expanded).unwrap());
    assert!(tile.resident_weight_bytes() < expanded.resident_weight_bytes(),
            "tile {} vs expanded {}", tile.resident_weight_bytes(),
            expanded.resident_weight_bytes());
    let mut r = Rng::new(77);
    let xs: Vec<Vec<f32>> = (0..16).map(|_| r.normal_vec(64, 1.0)).collect();
    let policy = BatchPolicy { max_batch: 4, window: Duration::from_micros(150) };
    let srv_tile = Server::start_pool(tile, policy.clone(), 2);
    let srv_exp = Server::start_pool(expanded, policy, 2);
    for x in &xs {
        let a = srv_tile.infer(x.clone()).unwrap();
        let b = srv_exp.infer(x.clone()).unwrap();
        assert_eq!(a.y, b.y, "layouts must serve bit-identical outputs");
    }
}

#[test]
fn pool_drains_queue_on_shutdown() {
    // flood, then drop the server handle from this thread after collecting
    // receivers: every accepted request must still be answered
    let engine = Arc::new(synthetic_engine(7, EnginePath::Packed));
    let server = Server::start_pool(
        engine,
        BatchPolicy { max_batch: 16, window: Duration::from_micros(100) },
        2,
    );
    let mut r = Rng::new(8);
    let rxs: Vec<_> = (0..64)
        .map(|_| server.submit(r.normal_vec(64, 1.0)).unwrap())
        .collect();
    drop(server); // close + join: workers drain the queue first
    for rx in rxs {
        let resp = rx.recv().expect("accepted request dropped at shutdown");
        assert_eq!(resp.y.len(), 10);
    }
}
