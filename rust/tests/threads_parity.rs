//! Threaded-vs-single-threaded bit-exactness (artifact-free).
//!
//! The intra-op threading contract (`nn` module docs): a threaded packed
//! forward splits only *independent output elements* across scoped std
//! threads — every element is computed wholly by one thread with the serial
//! per-element expression — so the result is **bit-exact** against the
//! single-threaded kernel at any thread count.  These tests sweep
//! `Packed`/`PackedInt8` × tile-resident/expanded layouts × FC chains and
//! conv graphs, with the awkward shapes on purpose: ragged widths
//! (`n % 64 != 0`), batch sizes that do not divide the thread count, and
//! fewer output rows than threads.
//!
//! A NaN/±inf regression rides along: `binarize_activations_into` guards its
//! XNOR-Net gamma against non-finite activations (as `quantize_input_i8`
//! always did), so poisoned inputs yield finite outputs on every engine
//! path instead of NaN-poisoning downstream layers.
//!
//! Engines built "at the default" go through `PackedLayout::from_env()` /
//! `threads_from_env()`, so the CI matrix re-runs this suite under
//! `TBN_LAYOUT=expanded` and `TBN_THREADS=4`.

use tiledbits::arch;
use tiledbits::nn::{lower_arch_spec, threads_from_env, Engine, EnginePath,
                    LowerOptions, MlpEngine, Nonlin, PackedLayout};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::util::Rng;

fn tiled_layer(rng: &mut Rng, name: &str, m: usize, n: usize, p: usize) -> LayerRecord {
    let w = rng.normal_vec(m * n, 1.0);
    assert_eq!((m * n) % p, 0, "{name}: p must divide the layer");
    LayerRecord {
        name: name.into(),
        shape: vec![m, n],
        payload: WeightPayload::Tiled {
            p,
            tile: tile_from_weights(&w, p),
            alphas: alphas_from(&w, p, AlphaMode::PerTile),
        },
    }
}

/// Ragged 70 -> 65 -> 33 -> 3 tiled chain: no width is a multiple of 64,
/// alpha runs split mid-row, and the 3-row head has fewer rows than any
/// multi-thread sweep point.
fn ragged_model() -> TbnzModel {
    let mut rng = Rng::new(0x7EAD5);
    TbnzModel {
        layers: vec![
            tiled_layer(&mut rng, "fc0", 65, 70, 5),
            tiled_layer(&mut rng, "fc1", 33, 65, 5),
            tiled_layer(&mut rng, "head", 3, 33, 3),
        ],
    }
}

const THREAD_SWEEP: [usize; 3] = [2, 4, 8];

#[test]
fn threaded_fc_chain_is_bit_exact_on_every_path_and_layout() {
    let model = ragged_model();
    let mut rng = Rng::new(51);
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(70, 1.0)).collect();
    for path in [EnginePath::Packed, EnginePath::PackedInt8] {
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let serial = MlpEngine::with_path_layout(
                model.clone(), Nonlin::Relu, path, layout).unwrap().with_threads(1);
            let singles: Vec<Vec<f32>> = xs.iter().map(|x| serial.forward(x)).collect();
            let batch = serial.forward_batch(&xs);
            for t in THREAD_SWEEP {
                let threaded = MlpEngine::with_path_layout(
                    model.clone(), Nonlin::Relu, path, layout).unwrap().with_threads(t);
                for (s, x) in xs.iter().enumerate() {
                    assert_eq!(threaded.forward(x), singles[s],
                               "{path:?} {layout:?} threads={t} sample {s}");
                }
                // batch of 5 with threads in {2, 4, 8}: none divides evenly
                assert_eq!(threaded.forward_batch(&xs), batch,
                           "{path:?} {layout:?} threads={t} batched");
            }
        }
    }
}

/// Batched and single-sample forwards must stay bit-identical to each other
/// *under* threading, not just each to their serial counterparts.
#[test]
fn batch_equals_single_under_threads() {
    let model = ragged_model();
    let mut rng = Rng::new(52);
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(70, 1.0)).collect();
    for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
        let engine = MlpEngine::with_path_layout(
            model.clone(), Nonlin::Relu, EnginePath::Packed, layout)
            .unwrap()
            .with_threads(4);
        let batch = engine.forward_batch(&xs);
        for (s, x) in xs.iter().enumerate() {
            assert_eq!(batch[s], engine.forward(x), "{layout:?} sample {s}");
        }
    }
}

#[test]
fn threaded_conv_graph_is_bit_exact_on_every_path_and_layout() {
    let spec = arch::cnn_micro();
    let opts = LowerOptions {
        input: (3, 16, 16),
        p: 4,
        alpha_mode: AlphaMode::PerTile,
        seed: 7,
    };
    let graph = lower_arch_spec(&spec, &opts).unwrap();
    let mut rng = Rng::new(53);
    let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(3 * 16 * 16, 1.0)).collect();
    for path in [EnginePath::Packed, EnginePath::PackedInt8] {
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let serial = Engine::with_layout_graph(
                graph.clone(), Nonlin::Relu, path, layout).unwrap().with_threads(1);
            let singles: Vec<Vec<f32>> = xs.iter().map(|x| serial.forward(x)).collect();
            for t in THREAD_SWEEP {
                let threaded = Engine::with_layout_graph(
                    graph.clone(), Nonlin::Relu, path, layout).unwrap().with_threads(t);
                for (s, x) in xs.iter().enumerate() {
                    assert_eq!(threaded.forward(x), singles[s],
                               "{path:?} {layout:?} threads={t} sample {s}");
                }
            }
        }
    }
}

/// NaN/±inf regression: non-finite activations must not poison the XNOR-Net
/// gamma.  Poisoned inputs yield finite outputs on the Packed and PackedInt8
/// paths (bit-equal across layouts and thread counts like any other input),
/// and on the Reference path's quantized oracle.
#[test]
fn non_finite_inputs_stay_finite_on_all_paths() {
    let model = ragged_model();
    let mut rng = Rng::new(54);
    let mut x = rng.normal_vec(70, 1.0);
    x[0] = f32::NAN;
    x[13] = f32::INFINITY;
    x[27] = f32::NEG_INFINITY;
    x[64] = f32::NAN; // past the first packed word on ragged widths

    let reference = MlpEngine::with_path(
        model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let y_ref = reference.forward_quantized(&x);
    assert!(y_ref.iter().all(|v| v.is_finite()),
            "Reference quantized oracle produced non-finite outputs: {y_ref:?}");

    for path in [EnginePath::Packed, EnginePath::PackedInt8] {
        let mut per_layout = Vec::new();
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let engine = MlpEngine::with_path_layout(
                model.clone(), Nonlin::Relu, path, layout).unwrap();
            let y = engine.forward(&x);
            assert!(y.iter().all(|v| v.is_finite()),
                    "{path:?} {layout:?} produced non-finite outputs: {y:?}");
            let threaded = MlpEngine::with_path_layout(
                model.clone(), Nonlin::Relu, path, layout).unwrap().with_threads(4);
            assert_eq!(threaded.forward(&x), y,
                       "{path:?} {layout:?}: threading must not change poisoned-input \
                        handling");
            per_layout.push(y);
        }
        assert_eq!(per_layout[0], per_layout[1],
                   "{path:?}: layouts must agree bit-exactly on poisoned inputs");
    }
}

/// The env default (`TBN_THREADS`, the CI matrix hook) must agree with the
/// explicit setter — whatever the matrix leg, engines built "at the default"
/// compute the same bits as `with_threads(1)`.
#[test]
fn env_default_threads_match_explicit_serial() {
    let model = ragged_model();
    let mut rng = Rng::new(55);
    let x = rng.normal_vec(70, 1.0);
    let default_engine = MlpEngine::with_path_layout(
        model.clone(), Nonlin::Relu, EnginePath::Packed, PackedLayout::from_env())
        .unwrap();
    assert_eq!(default_engine.engine().threads(), threads_from_env());
    let serial = MlpEngine::with_path_layout(
        model, Nonlin::Relu, EnginePath::Packed, PackedLayout::from_env())
        .unwrap()
        .with_threads(1);
    assert_eq!(default_engine.forward(&x), serial.forward(&x));
}
