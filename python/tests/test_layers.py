"""Unit tests for compile.layers: tiling decisions, init, STE weights, norms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.layers import (ModelBind, ParamSpec, SpecBuilder, TilingConfig,
                            accuracy, dense, effective_weight, init_params,
                            inference_weight_arrays, mse, softmax_xent)


class TestSpecBuilderDecisions:
    def test_fp_mode_never_quantizes(self):
        b = SpecBuilder(TilingConfig(mode="fp"))
        s = b.weight("w", (512, 512))
        assert s.quant == "fp"

    def test_tbn_tiles_large_divisible_layers(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=4, lam=1000))
        s = b.weight("w", (64, 64))  # N=4096 >= 1000, divisible by 4
        assert s.quant == "tiled" and s.p == 4 and s.q == 1024

    def test_lambda_small_falls_back_to_binary(self):
        # untiled layers in a TBN are stored 1-bit (paper Table 6: the
        # untiled classification head is binary)
        b = SpecBuilder(TilingConfig(mode="tbn", p=4, lam=10_000))
        s = b.weight("w", (64, 64))
        assert s.quant == "bwnn"

    def test_indivisible_layer_falls_back_to_binary(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=4, lam=1))
        s = b.weight("w", (3, 9))  # 27 not divisible by 4
        assert s.quant == "bwnn"

    def test_alpha_src_A_adds_sibling_param(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=2, lam=1, alpha_src="A"))
        b.weight("w", (4, 4))
        names = [s.name for s in b.specs]
        assert names == ["w", "w.A"]
        assert b.specs[1].role == "alpha_src"

    def test_alpha_src_W_adds_nothing(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=2, lam=1, alpha_src="W"))
        b.weight("w", (4, 4))
        assert [s.name for s in b.specs] == ["w"]

    def test_single_alpha_mode(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=4, lam=1, alpha="single"))
        assert b.weight("w", (4, 4)).n_alphas == 1

    def test_bwnn_binarizes_everything(self):
        b = SpecBuilder(TilingConfig(mode="bwnn", lam=100))
        big = b.weight("big", (32, 32))
        small = b.weight("small", (4, 4))
        assert big.quant == "bwnn" and small.quant == "bwnn"

    def test_duplicate_name_rejected(self):
        b = SpecBuilder(TilingConfig())
        b.weight("w", (2, 2))
        with pytest.raises(AssertionError):
            b.weight("w", (2, 2))


class TestInit:
    def test_deterministic(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=2, lam=1))
        b.weight("w", (8, 8))
        p1 = init_params(jnp.asarray(7, jnp.int32), b.specs)
        p2 = init_params(jnp.asarray(7, jnp.int32), b.specs)
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))

    def test_seed_changes_values(self):
        b = SpecBuilder(TilingConfig())
        b.weight("w", (8, 8))
        p1 = init_params(jnp.asarray(1, jnp.int32), b.specs)
        p2 = init_params(jnp.asarray(2, jnp.int32), b.specs)
        assert not np.array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))

    def test_A_differs_from_W(self):
        b = SpecBuilder(TilingConfig(mode="tbn", p=2, lam=1, alpha_src="A"))
        b.weight("w", (8, 8))
        p = init_params(jnp.asarray(1, jnp.int32), b.specs)
        assert not np.array_equal(np.asarray(p["w"]), np.asarray(p["w.A"]))

    def test_kaiming_scale(self):
        b = SpecBuilder(TilingConfig())
        b.weight("w", (256, 512))
        p = init_params(jnp.asarray(0, jnp.int32), b.specs)
        std = float(np.asarray(p["w"]).std())
        assert std == pytest.approx((2.0 / 512) ** 0.5, rel=0.15)


class TestEffectiveWeight:
    def test_tiled_matches_ref_pipeline(self):
        spec = ParamSpec("w", (8, 16), "kaiming", "weight", "tiled",
                         p=4, n_alphas=4, alpha_src="A")
        r = np.random.default_rng(0)
        w = jnp.asarray(r.standard_normal((8, 16)), jnp.float32)
        a = jnp.asarray(r.standard_normal((8, 16)), jnp.float32)
        got = effective_weight({"w": w, "w.A": a}, spec)
        t = ref.tile_from_weights(w, 4)
        al = ref.alphas_from(a, 4, per_tile=True)
        want = ref.expand_tile(t, al, (8, 16))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_bwnn_matches_ref(self):
        spec = ParamSpec("w", (8, 8), "kaiming", "weight", "bwnn")
        w = jnp.asarray(np.random.default_rng(1).standard_normal((8, 8)),
                        jnp.float32)
        got = effective_weight({"w": w}, spec)
        b, alpha = ref.binarize_bwnn(w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(b * alpha),
                                   rtol=1e-6)

    def test_fp_identity(self):
        spec = ParamSpec("w", (4, 4), "kaiming", "weight", "fp")
        w = jnp.ones((4, 4))
        np.testing.assert_array_equal(
            np.asarray(effective_weight({"w": w}, spec)), np.asarray(w))

    def test_tiled_weight_has_p_identical_slices(self):
        """Paper §4.1: tiling creates replicated channel groups."""
        spec = ParamSpec("w", (8, 4), "kaiming", "weight", "tiled",
                         p=4, n_alphas=1, alpha_src="W")
        w = jnp.asarray(np.random.default_rng(2).standard_normal((8, 4)),
                        jnp.float32)
        bhat = np.asarray(effective_weight({"w": w}, spec)).reshape(4, -1)
        for i in range(1, 4):
            np.testing.assert_allclose(bhat[i], bhat[0])


class TestInferenceExport:
    def test_tiled_export_shapes(self):
        spec = ParamSpec("w", (8, 16), "kaiming", "weight", "tiled",
                         p=4, n_alphas=4, alpha_src="A")
        w = jnp.ones((8, 16))
        a = jnp.full((8, 16), 0.5)
        arrs = inference_weight_arrays(w, a, spec)
        assert arrs["tile"].shape == (32,)
        assert arrs["alphas"].shape == (4,)
        np.testing.assert_allclose(np.asarray(arrs["alphas"]), 0.5)

    def test_forward_dispatch_tile_params(self):
        """dense() with .tile params must equal the training-path weight."""
        spec = ParamSpec("w", (8, 16), "kaiming", "weight", "tiled",
                         p=4, n_alphas=4, alpha_src="W")
        r = np.random.default_rng(3)
        w = jnp.asarray(r.standard_normal((8, 16)), jnp.float32)
        x = jnp.asarray(r.standard_normal((5, 16)), jnp.float32)
        train_y = dense({"w": w}, spec, x)
        arrs = inference_weight_arrays(w, None, spec)
        infer_y = dense({"w.tile": arrs["tile"], "w.alphas": arrs["alphas"]},
                        spec, x)
        np.testing.assert_allclose(np.asarray(train_y), np.asarray(infer_y),
                                   rtol=2e-4, atol=2e-4)


class TestLossesMetrics:
    def test_xent_uniform_logits(self):
        logits = jnp.zeros((4, 10))
        labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
        assert float(softmax_xent(logits, labels)) == pytest.approx(np.log(10), rel=1e-5)

    def test_label_smoothing_increases_loss_at_certainty(self):
        logits = jnp.asarray([[100.0, 0.0]])
        labels = jnp.asarray([0], jnp.int32)
        plain = float(softmax_xent(logits, labels, 0.0))
        smooth = float(softmax_xent(logits, labels, 0.1))
        assert smooth > plain

    def test_accuracy(self):
        logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
        labels = jnp.asarray([0, 1, 1], jnp.int32)
        assert float(accuracy(logits, labels)) == pytest.approx(2 / 3)

    def test_mse(self):
        assert float(mse(jnp.asarray([1.0, 3.0]), jnp.asarray([0.0, 0.0]))) == 5.0
