//! Network front-end integration: real TCP loopback traffic through
//! `NetServer` + `ModelRegistry` over the bounded-queue worker pools.
//!
//! All artifact-free.  The load-bearing properties:
//!
//! * **parity** — a `POST /infer` answer is bit-identical to calling
//!   `Engine::forward` directly (f32 survives the JSON wire exactly:
//!   f32 -> f64 is exact, the writer prints shortest-round-trip f64, and
//!   the parse + `as f32` narrowing recovers the original bits);
//! * **load shedding** — a full queue under `OverflowPolicy::Reject`
//!   answers `503` and never deadlocks the connection handlers;
//! * **hot swap** — `POST /reload` mid-traffic never serves a torn model:
//!   every answer is self-consistent and its `generation` matches its
//!   values;
//! * **drain** — shutdown completes in-flight requests before returning,
//!   and idle connections never stall it;
//! * **robustness** — malformed bodies/framing, slowloris dribble,
//!   pipelined bursts, multi-MB responses against slow readers, and the
//!   `max_conns` admission limit all get correct answers and leave the
//!   server serving.
//!
//! The connection-state-machine tests run on **both** net models
//! (`--net-model mux|threads`); the rest run on the default model (mux on
//! unix), which is how the acceptance bar "the existing suite passes
//! against the mux server" is held.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use tiledbits::nn::{EnginePath, MlpEngine, Nonlin};
use tiledbits::serve::{loadgen, BatchModel, BatchPolicy, ModelBuilder, ModelRegistry,
                       NetConfig, NetModel, NetServer, OverflowPolicy, ServePolicy,
                       Server};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::util::{Json, Rng};

// ---------------------------------------------------------------------------
// Minimal blocking HTTP client for the tests
// ---------------------------------------------------------------------------

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(body.as_bytes()).unwrap();
}

/// Read one `Content-Length`-framed response; returns (status, body).
fn read_response(stream: &mut TcpStream, buf: &mut Vec<u8>) -> (u16, Json) {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(h) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let text = std::str::from_utf8(&buf[..h]).unwrap();
            let status: u16 = text
                .split_whitespace()
                .nth(1)
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| panic!("bad status line in {text:?}"));
            let len: usize = text
                .split("\r\n")
                .find_map(|l| {
                    let (k, v) = l.split_once(':')?;
                    k.eq_ignore_ascii_case("content-length")
                        .then(|| v.trim().parse().unwrap())
                })
                .expect("content-length header");
            let total = h + 4 + len;
            while buf.len() < total {
                let n = stream.read(&mut tmp).unwrap();
                assert!(n > 0, "connection closed mid-response");
                buf.extend_from_slice(&tmp[..n]);
            }
            let json = Json::parse(std::str::from_utf8(&buf[h + 4..total]).unwrap())
                .expect("response body is JSON");
            buf.drain(..total);
            return (status, json);
        }
        let n = stream.read(&mut tmp).unwrap();
        assert!(n > 0, "connection closed before response");
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// One-shot round trip on a fresh connection.
fn roundtrip(addr: &str, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    send_request(&mut stream, method, path, body);
    read_response(&mut stream, &mut Vec::new())
}

fn infer_body(model: &str, x: &[f32]) -> String {
    Json::obj(vec![
        ("model", Json::Str(model.to_string())),
        ("x", Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())),
    ])
    .to_string()
}

fn y_f32(resp: &Json) -> Vec<f32> {
    resp.get("y")
        .and_then(Json::as_arr)
        .expect("y array")
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect()
}

// ---------------------------------------------------------------------------
// Models
// ---------------------------------------------------------------------------

/// The deployment micro MLP (256 -> 128 -> 10), fully tiled at p=4.
fn micro_engine() -> MlpEngine {
    let p = 4usize;
    let mut r = Rng::new(42);
    let mk = |name: &str, m: usize, n: usize, r: &mut Rng| {
        let w: Vec<f32> = r.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        }
    };
    let model = TbnzModel {
        layers: vec![mk("fc0", 128, 256, &mut r), mk("head", 10, 128, &mut r)],
    };
    MlpEngine::with_path(model, Nonlin::Relu, EnginePath::Packed).unwrap()
}

/// Constant-output model: every answer is `[v, v, v]` — any mix of values
/// within one response would be a torn model.
struct ConstModel {
    v: f32,
}

impl BatchModel for ConstModel {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|_| vec![self.v; 3]).collect()
    }

    fn in_dim(&self) -> usize {
        2
    }
}

/// Slow model for overload/drain: sleeps per batch, counts invocations.
struct SlowModel {
    delay: Duration,
    calls: Arc<AtomicUsize>,
}

impl BatchModel for SlowModel {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        thread::sleep(self.delay);
        xs.iter().map(|x| vec![x.iter().sum()]).collect()
    }

    fn in_dim(&self) -> usize {
        1
    }
}

fn pool<M: BatchModel + Sync>(model: M, queue_cap: usize, on_full: OverflowPolicy,
                              max_batch: usize, workers: usize) -> Server {
    Server::start_pool_with(
        Arc::new(model),
        ServePolicy {
            batch: BatchPolicy { max_batch, window: Duration::from_micros(100) },
            queue_cap,
            on_full,
            ..ServePolicy::default()
        },
        workers,
    )
}

/// Huge-output model for partial-write coverage: the response JSON is
/// several MB, far beyond loopback socket buffers.
struct WideModel {
    n: usize,
}

impl BatchModel for WideModel {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|_| vec![0.125f32; self.n]).collect()
    }

    fn in_dim(&self) -> usize {
        1
    }
}

fn serve_one(name: &str, server: Server, builder: Option<ModelBuilder>)
             -> (NetServer, String) {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, server);
    let net = NetServer::start(registry, "127.0.0.1:0", builder).unwrap();
    let addr = net.addr().to_string();
    (net, addr)
}

/// [`serve_one`] with an explicit net model and connection limit.
fn serve_one_with(name: &str, server: Server, builder: Option<ModelBuilder>,
                  model: NetModel, max_conns: usize) -> (NetServer, String) {
    let registry = Arc::new(ModelRegistry::new());
    registry.register(name, server);
    let net = NetServer::start_with(
        registry,
        "127.0.0.1:0",
        builder,
        NetConfig { model, max_conns, ..NetConfig::default() },
    )
    .unwrap();
    let addr = net.addr().to_string();
    (net, addr)
}

/// Every net model this target can run (the state-machine tests cover all).
fn net_models() -> Vec<NetModel> {
    if cfg!(unix) {
        vec![NetModel::Mux, NetModel::Threads]
    } else {
        vec![NetModel::Threads]
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn loopback_roundtrip_is_bit_identical_to_direct_forward() {
    let engine = Arc::new(micro_engine());
    let direct = engine.clone();
    let server = Server::start_pool_with(engine, ServePolicy::default(), 2);
    let (net, addr) = serve_one("micro", server, None);

    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut buf = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..8 {
        let x = rng.normal_vec(256, 1.0);
        send_request(&mut stream, "POST", "/infer", &infer_body("micro", &x));
        let (status, resp) = read_response(&mut stream, &mut buf);
        assert_eq!(status, 200, "request {i}: {resp:?}");
        let want = direct.forward(&x);
        let got = y_f32(&resp);
        assert_eq!(got.len(), want.len());
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(),
                       "request {i} output {j}: {g} != {w} after the JSON wire");
        }
    }
    net.shutdown();
}

#[test]
fn concurrent_clients_are_all_served() {
    let (net, addr) = serve_one(
        "c",
        pool(ConstModel { v: 1.5 }, 64, OverflowPolicy::Block, 8, 2),
        None,
    );
    let clients = 4usize;
    let per_client = 25usize;
    let mut handles = Vec::new();
    for _ in 0..clients {
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let mut stream = TcpStream::connect(&addr).unwrap();
            let mut buf = Vec::new();
            for _ in 0..per_client {
                send_request(&mut stream, "POST", "/infer",
                             &infer_body("c", &[0.0, 0.0]));
                let (status, resp) = read_response(&mut stream, &mut buf);
                assert_eq!(status, 200);
                assert_eq!(y_f32(&resp), vec![1.5; 3]);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = net.shutdown();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].2.served, clients * per_client);
    assert_eq!(stats[0].2.rejected, 0);
}

#[test]
fn overload_returns_503_without_deadlock() {
    // one worker, queue of 1, no batching, 30ms/request: a concurrent burst
    // must shed most requests as 503 and still answer every connection
    let calls = Arc::new(AtomicUsize::new(0));
    let (net, addr) = serve_one(
        "s",
        pool(SlowModel { delay: Duration::from_millis(30), calls }, 1,
             OverflowPolicy::Reject, 1, 1),
        None,
    );
    // pre-connect, then release the whole burst at once: with a 30ms
    // model, queue cap 1, and one worker, most of 8 simultaneous requests
    // must shed
    let barrier = Arc::new(std::sync::Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let addr = addr.clone();
        let barrier = barrier.clone();
        handles.push(thread::spawn(move || {
            let mut stream = TcpStream::connect(&addr).unwrap();
            barrier.wait();
            send_request(&mut stream, "POST", "/infer", &infer_body("s", &[1.0]));
            read_response(&mut stream, &mut Vec::new()).0
        }));
    }
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(statuses.iter().all(|s| *s == 200 || *s == 503),
            "only 200/503 expected: {statuses:?}");
    assert!(statuses.contains(&200), "someone must get served: {statuses:?}");
    assert!(statuses.contains(&503), "a synchronized burst must shed: {statuses:?}");
    let stats = net.shutdown();
    let s = &stats[0].2;
    assert_eq!(s.rejected, statuses.iter().filter(|x| **x == 503).count());
    assert_eq!(s.served + s.rejected, 8, "every request served or shed: {s:?}");
}

#[test]
fn hot_swap_mid_traffic_never_serves_a_torn_model() {
    // builder: seed n -> a ConstModel answering [n, n, n] at generation n
    let builder: ModelBuilder = Arc::new(|_name: &str, seed: u64| {
        Ok(pool(ConstModel { v: seed as f32 }, 64, OverflowPolicy::Block, 8, 2))
    });
    let (net, addr) = serve_one(
        "m",
        pool(ConstModel { v: 0.0 }, 64, OverflowPolicy::Block, 8, 2),
        Some(builder),
    );
    let mut handles = Vec::new();
    for _ in 0..3 {
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let mut stream = TcpStream::connect(&addr).unwrap();
            let mut buf = Vec::new();
            for _ in 0..60 {
                send_request(&mut stream, "POST", "/infer",
                             &infer_body("m", &[0.0, 0.0]));
                let (status, resp) = read_response(&mut stream, &mut buf);
                assert_eq!(status, 200);
                let y = y_f32(&resp);
                let generation = resp.usize_or("generation", usize::MAX);
                // never torn: all outputs agree, and they name the exact
                // generation that produced them
                assert!(y.iter().all(|v| *v == y[0]), "torn response {y:?}");
                assert_eq!(y[0] as usize, generation,
                           "y {y:?} from generation {generation}");
            }
        }));
    }
    // swap generations 1..=4 into place while the clients hammer /infer
    for seed in 1..=4u64 {
        thread::sleep(Duration::from_millis(10));
        let body = Json::obj(vec![
            ("model", Json::Str("m".into())),
            ("seed", Json::Num(seed as f64)),
        ])
        .to_string();
        let (status, resp) = roundtrip(&addr, "POST", "/reload", &body);
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(resp.usize_or("generation", 0), seed as usize);
    }
    for h in handles {
        h.join().unwrap();
    }
    net.shutdown();
}

#[test]
fn drain_completes_in_flight_requests() {
    let calls = Arc::new(AtomicUsize::new(0));
    let (net, addr) = serve_one(
        "d",
        pool(SlowModel { delay: Duration::from_millis(120), calls }, 4,
             OverflowPolicy::Block, 1, 1),
        None,
    );
    let client = {
        let addr = addr.clone();
        thread::spawn(move || roundtrip(&addr, "POST", "/infer", &infer_body("d", &[2.0])))
    };
    // let the request reach the pool, then drain while it is in flight
    thread::sleep(Duration::from_millis(40));
    let stats = net.shutdown();
    let (status, resp) = client.join().unwrap();
    assert_eq!(status, 200, "in-flight request must complete through drain");
    assert_eq!(y_f32(&resp), vec![2.0]);
    assert_eq!(stats[0].2.served, 1);
}

#[test]
fn malformed_bodies_get_errors_and_the_connection_survives() {
    let (net, addr) = serve_one(
        "e",
        pool(ConstModel { v: 3.0 }, 16, OverflowPolicy::Block, 4, 1),
        None,
    );
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut buf = Vec::new();
    // bad JSON -> 400, same connection keeps working
    send_request(&mut stream, "POST", "/infer", "this is not json");
    let (status, resp) = read_response(&mut stream, &mut buf);
    assert_eq!(status, 400);
    assert!(resp.str_or("error", "").contains("bad JSON"));
    // wrong input width -> 400
    send_request(&mut stream, "POST", "/infer", &infer_body("e", &[1.0]));
    let (status, resp) = read_response(&mut stream, &mut buf);
    assert_eq!(status, 400, "{resp:?}");
    // missing x -> 400
    send_request(&mut stream, "POST", "/infer", r#"{"model":"e"}"#);
    let (status, _) = read_response(&mut stream, &mut buf);
    assert_eq!(status, 400);
    // unknown path -> 404, unknown method -> 405
    send_request(&mut stream, "POST", "/nope", "{}");
    assert_eq!(read_response(&mut stream, &mut buf).0, 404);
    send_request(&mut stream, "DELETE", "/infer", "");
    assert_eq!(read_response(&mut stream, &mut buf).0, 405);
    // and after all that abuse, a well-formed request still answers
    send_request(&mut stream, "POST", "/infer", &infer_body("e", &[0.0, 0.0]));
    let (status, resp) = read_response(&mut stream, &mut buf);
    assert_eq!(status, 200);
    assert_eq!(y_f32(&resp), vec![3.0; 3]);
    // unparseable framing: 400 answer, then the server closes the socket
    let mut broken = TcpStream::connect(&addr).unwrap();
    broken.write_all(b"totally wrong\r\n\r\n").unwrap();
    let mut raw = Vec::new();
    broken.read_to_end(&mut raw).unwrap(); // EOF proves the close
    let text = String::from_utf8_lossy(&raw);
    assert!(text.starts_with("HTTP/1.1 400"), "got {text:?}");
    net.shutdown();
}

#[test]
fn models_listing_and_loadgen_probe_agree() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", pool(ConstModel { v: 1.0 }, 16, OverflowPolicy::Block, 4, 1));
    registry.register("b", pool(SlowModel {
        delay: Duration::ZERO,
        calls: Arc::new(AtomicUsize::new(0)),
    }, 16, OverflowPolicy::Block, 4, 1));
    let net = NetServer::start(registry, "127.0.0.1:0", None).unwrap();
    let addr = net.addr().to_string();
    let models = loadgen::probe_models(&addr).unwrap();
    assert_eq!(models, vec![("a".to_string(), 2), ("b".to_string(), 1)]);
    // /stats and /healthz answer too
    let (status, resp) = roundtrip(&addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert_eq!(resp.get("models").and_then(Json::as_arr).unwrap().len(), 2);
    let (status, resp) = roundtrip(&addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    net.shutdown();
}

#[test]
fn slowloris_headers_are_served_and_counted_on_both_net_models() {
    for model in net_models() {
        let (net, addr) = serve_one_with(
            "sl",
            pool(ConstModel { v: 2.0 }, 16, OverflowPolicy::Block, 4, 1),
            None,
            model,
            64,
        );
        let mut stream = TcpStream::connect(&addr).unwrap();
        let body = infer_body("sl", &[0.0, 0.0]);
        let head = format!(
            "POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        let bytes = [head.as_bytes(), body.as_bytes()].concat();
        // dribble the first half byte-at-a-time, park mid-request longer
        // than the threads model's poll tick, then finish the request
        let half = bytes.len() / 2;
        for b in &bytes[..half] {
            stream.write_all(std::slice::from_ref(b)).unwrap();
            thread::sleep(Duration::from_millis(1));
        }
        thread::sleep(Duration::from_millis(250));
        stream.write_all(&bytes[half..]).unwrap();
        let (status, resp) = read_response(&mut stream, &mut Vec::new());
        assert_eq!(status, 200, "[{model}] {resp:?}");
        assert_eq!(y_f32(&resp), vec![2.0; 3], "[{model}]");
        assert!(net.net_stats().read_stalls > 0,
                "[{model}] a dribbled request must count read stalls");
        net.shutdown();
    }
}

#[test]
fn pipelined_requests_answer_in_order_on_both_net_models() {
    for model in net_models() {
        let calls = Arc::new(AtomicUsize::new(0));
        let (net, addr) = serve_one_with(
            "p",
            pool(SlowModel { delay: Duration::ZERO, calls }, 16,
                 OverflowPolicy::Block, 1, 1),
            None,
            model,
            64,
        );
        let mut stream = TcpStream::connect(&addr).unwrap();
        // three complete requests in one burst: the server must answer
        // them one at a time, in order, on the same connection
        let mut wire = Vec::new();
        for i in 1..=3 {
            let body = infer_body("p", &[i as f32]);
            wire.extend_from_slice(
                format!("POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                        body.len())
                .as_bytes(),
            );
        }
        stream.write_all(&wire).unwrap();
        let mut buf = Vec::new();
        for i in 1..=3 {
            let (status, resp) = read_response(&mut stream, &mut buf);
            assert_eq!(status, 200, "[{model}] pipelined request {i}");
            assert_eq!(y_f32(&resp), vec![i as f32],
                       "[{model}] answers must come back in request order");
        }
        net.shutdown();
    }
}

#[test]
fn huge_responses_survive_slow_readers_on_both_net_models() {
    for model in net_models() {
        // ~2.8 MB of JSON per response: far beyond loopback socket buffers,
        // so the writer must stall and resume
        let n = 400_000usize;
        let (net, addr) = serve_one_with(
            "w",
            pool(WideModel { n }, 16, OverflowPolicy::Block, 1, 1),
            None,
            model,
            64,
        );
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        for round in 0..2 {
            send_request(&mut stream, "POST", "/infer", &infer_body("w", &[1.0]));
            // let the server hit a full socket buffer before we start reading
            thread::sleep(Duration::from_millis(300));
            let (status, resp) = read_response(&mut stream, &mut buf);
            assert_eq!(status, 200, "[{model}] round {round}");
            let y = y_f32(&resp);
            assert_eq!(y.len(), n, "[{model}] round {round}");
            assert!(y.iter().all(|v| *v == 0.125), "[{model}] round {round}");
        }
        if model == NetModel::Mux {
            assert!(net.net_stats().write_stalls > 0,
                    "[mux] a multi-MB response must stall the nonblocking writer");
        }
        net.shutdown();
    }
}

#[test]
fn idle_connections_do_not_stall_drain_on_both_net_models() {
    for model in net_models() {
        let calls = Arc::new(AtomicUsize::new(0));
        let (net, addr) = serve_one_with(
            "i",
            pool(SlowModel { delay: Duration::from_millis(120), calls }, 4,
                 OverflowPolicy::Block, 1, 1),
            None,
            model,
            64,
        );
        // park 32 idle keep-alive connections, then put one request in flight
        let idle: Vec<TcpStream> =
            (0..32).map(|_| TcpStream::connect(&addr).unwrap()).collect();
        let client = {
            let addr = addr.clone();
            thread::spawn(move || roundtrip(&addr, "POST", "/infer",
                                            &infer_body("i", &[2.0])))
        };
        thread::sleep(Duration::from_millis(40));
        let t0 = Instant::now();
        let stats = net.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5),
                "[{model}] drain must not wait on idle connections");
        let (status, resp) = client.join().unwrap();
        assert_eq!(status, 200, "[{model}] the in-flight request must complete");
        assert_eq!(y_f32(&resp), vec![2.0], "[{model}]");
        assert_eq!(stats[0].2.served, 1, "[{model}]");
        drop(idle);
    }
}

#[test]
fn connection_limit_sheds_at_accept_on_both_net_models() {
    for model in net_models() {
        let (net, addr) = serve_one_with(
            "l",
            pool(ConstModel { v: 1.0 }, 16, OverflowPolicy::Block, 4, 1),
            None,
            model,
            2,
        );
        let body = infer_body("l", &[0.0, 0.0]);
        let mut c1 = TcpStream::connect(&addr).unwrap();
        let mut b1 = Vec::new();
        send_request(&mut c1, "POST", "/infer", &body);
        assert_eq!(read_response(&mut c1, &mut b1).0, 200, "[{model}]");
        let mut c2 = TcpStream::connect(&addr).unwrap();
        let mut b2 = Vec::new();
        send_request(&mut c2, "POST", "/infer", &body);
        assert_eq!(read_response(&mut c2, &mut b2).0, 200, "[{model}]");
        // the table is full: the third accept is shed with 503 and closed
        let mut c3 = TcpStream::connect(&addr).unwrap();
        let mut raw = Vec::new();
        c3.read_to_end(&mut raw).unwrap(); // EOF proves the close
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 503"), "[{model}] got {text:?}");
        let ns = net.net_stats();
        assert_eq!(ns.shed_at_accept, 1, "[{model}]");
        assert_eq!(ns.accepted, 2, "[{model}] shed accepts must not count as admitted");
        // closing an admitted connection frees its slot for a new client
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(5);
        let admitted = loop {
            let mut c = TcpStream::connect(&addr).unwrap();
            // a shed connection answers 503-and-close (or resets the socket
            // if the race loses the bytes); an admitted one answers 200 —
            // so every io error here just means "retry"
            let head = format!(
                "POST /infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
                body.len()
            );
            let sent = c
                .write_all(head.as_bytes())
                .and_then(|()| c.write_all(body.as_bytes()));
            let mut first = [0u8; 12];
            if sent.is_ok() && c.read_exact(&mut first).is_ok()
                && &first[..] == b"HTTP/1.1 200"
            {
                break true;
            }
            if Instant::now() > deadline {
                break false;
            }
            thread::sleep(Duration::from_millis(50));
        };
        assert!(admitted, "[{model}] a freed slot must admit a new connection");
        net.shutdown();
    }
}

#[test]
fn malformed_framing_closes_with_400_on_both_net_models() {
    for model in net_models() {
        let (net, addr) = serve_one_with(
            "mf",
            pool(ConstModel { v: 3.0 }, 16, OverflowPolicy::Block, 4, 1),
            None,
            model,
            64,
        );
        // bad JSON answers 400 and the connection survives
        let mut stream = TcpStream::connect(&addr).unwrap();
        let mut buf = Vec::new();
        send_request(&mut stream, "POST", "/infer", "this is not json");
        assert_eq!(read_response(&mut stream, &mut buf).0, 400, "[{model}]");
        send_request(&mut stream, "POST", "/infer", &infer_body("mf", &[0.0, 0.0]));
        let (status, resp) = read_response(&mut stream, &mut buf);
        assert_eq!(status, 200, "[{model}]");
        assert_eq!(y_f32(&resp), vec![3.0; 3], "[{model}]");
        // unparseable framing: 400 answer, then the server closes the socket
        let mut broken = TcpStream::connect(&addr).unwrap();
        broken.write_all(b"totally wrong\r\n\r\n").unwrap();
        let mut raw = Vec::new();
        broken.read_to_end(&mut raw).unwrap();
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 400"), "[{model}] got {text:?}");
        // truncated request (EOF mid-header) answers 400 and closes too
        let mut trunc = TcpStream::connect(&addr).unwrap();
        trunc.write_all(b"POST /infer HT").unwrap();
        trunc.shutdown(std::net::Shutdown::Write).unwrap();
        let mut raw = Vec::new();
        trunc.read_to_end(&mut raw).unwrap();
        let text = String::from_utf8_lossy(&raw);
        assert!(text.starts_with("HTTP/1.1 400"), "[{model}] got {text:?}");
        net.shutdown();
    }
}

#[test]
fn stats_endpoint_reports_net_counters_on_both_net_models() {
    for model in net_models() {
        let (net, addr) = serve_one_with(
            "st",
            pool(ConstModel { v: 1.0 }, 16, OverflowPolicy::Block, 4, 1),
            None,
            model,
            64,
        );
        let (status, resp) = roundtrip(&addr, "GET", "/stats", "");
        assert_eq!(status, 200, "[{model}]");
        let netj = resp.get("net").expect("stats must carry the net object");
        assert_eq!(netj.str_or("model", ""), model.as_str(), "[{model}]");
        assert!(netj.usize_or("accepted", 0) >= 1, "[{model}]");
        assert!(netj.usize_or("open", 0) >= 1,
                "[{model}] the requesting connection itself is open");
        net.shutdown();
    }
}
