//! Minimal leveled logger writing to stderr, controlled by `TBN_LOG`
//! (error|warn|info|debug; default info). No env_logger in the vendor set.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let from_env = match std::env::var("TBN_LOG").as_deref() {
        Ok("error") => ERROR,
        Ok("warn") => WARN,
        Ok("debug") => DEBUG,
        _ => INFO,
    };
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

pub fn set_level(l: u8) {
    LEVEL.store(l, Ordering::Relaxed);
}

pub fn enabled(l: u8) -> bool {
    l <= level()
}

pub fn log(l: u8, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let name = ["ERROR", "WARN", "INFO", "DEBUG"][l as usize];
    eprintln!("[{:>10}.{:03} {name:5} {target}] {msg}", t.as_secs(), t.subsec_millis());
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::INFO, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::WARN, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::DEBUG, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(WARN);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(!enabled(INFO));
        set_level(INFO);
        assert!(enabled(INFO));
        assert!(!enabled(DEBUG));
    }
}
