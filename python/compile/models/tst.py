"""Time-series Transformer encoder for Table 5 (Zerveas-style).

Single-step multivariate forecasting: the encoder reads a (seq, channels)
window, and a linear head on the last token predicts the next step's values
for all channels.  MSE loss, matching the paper's ECL/Weather setup.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..layers import ModelBind, ModelDef, SpecBuilder, TilingConfig, declare_layernorm
from .vit import declare_encoder_block, encoder_block


def build(cfg: dict, tiling: TilingConfig) -> ModelDef:
    dim = int(cfg["dim"])
    depth = int(cfg["depth"])
    heads = int(cfg["heads"])
    mlp_dim = int(cfg["mlp_dim"])
    seq = int(cfg["seq"])
    channels = int(cfg["channels"])

    b = SpecBuilder(tiling)
    b.weight("in_proj", (dim, channels))
    b.other("pos_embed", (seq, dim), "normal")
    for d in range(depth):
        declare_encoder_block(b, f"blk{d}", dim, mlp_dim)
    declare_layernorm(b, "final", dim)
    b.weight("head", (channels, dim))
    specs = b.specs

    def apply(params, x):
        # x: (batch, seq, channels) -> (batch, channels) next-step forecast
        m = ModelBind(specs, params)
        h = m.dense("in_proj", x) + m.p("pos_embed")
        for d in range(depth):
            h = encoder_block(m, f"blk{d}", h, heads)
        h = m.ln("final", h)[:, -1, :]  # last-token representation
        return m.dense("head", h)

    return ModelDef(specs, apply)
