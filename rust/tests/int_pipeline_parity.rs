//! `PackedInt` pipeline parity (artifact-free).
//!
//! The threshold-folded integer path (`EnginePath::PackedInt`) replaces
//! every hidden FC -> FC edge with packed sign bits: a row's sign test
//! collapses into an integer popcount threshold (`nn::IntThresholds`), so
//! the kernel never materializes f32 between binarized FC layers.  These
//! tests pin that path three ways:
//!
//! 1. **Bit-exactness vs a plain-Rust integer oracle** on a ragged-width
//!    FC chain (every width `% 64 != 0`): the oracle composes
//!    `FcLayer::forward_int_oracle` / `forward_int_oracle_f32` — scalar
//!    bit reads, no packed words, no SIMD, no threads — and the engine
//!    must match it exactly on both weight layouts, every `SimdBackend`,
//!    and every thread count, single-sample and batched alike.
//! 2. **Edge-case rules pinned at the engine level**: a layer whose alphas
//!    are all negative classifies every row `Neg` (flipped comparison) and
//!    one with alpha 0 classifies `Zero` (constant-0 bits), both still
//!    bit-exact against the oracle, with the microcontroller `export_i32`
//!    encodings checked alongside.
//! 3. **Argmax agreement vs `Packed`** on the lowered `cnn_micro` conv
//!    graph and the `vit_micro` transformer with calibrated gammas: conv /
//!    attention boundaries genuinely move (a per-layer constant replaces
//!    the data-dependent XNOR-Net scale), so the gate is prediction
//!    agreement, not bit equality.
//!
//! `SimdBackend::Avx2` is safe to list everywhere: `with_simd` clamps to
//! the detected best off-AVX2 hosts (see `tests/simd_parity.rs`).

use tiledbits::arch;
use tiledbits::nn::{lower_arch_spec, Engine, EnginePath, IntRowRule, LowerOptions,
                    MlpEngine, Node, Nonlin, PackedLayout, SimdBackend};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::util::Rng;

const ALL_BACKENDS: [SimdBackend; 4] = [SimdBackend::Scalar, SimdBackend::U64x4,
                                        SimdBackend::U128, SimdBackend::Avx2];
const LAYOUTS: [PackedLayout; 2] = [PackedLayout::TileResident,
                                    PackedLayout::Expanded];
const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn tiled_layer(rng: &mut Rng, name: &str, m: usize, n: usize, p: usize)
               -> LayerRecord {
    let w = rng.normal_vec(m * n, 1.0);
    LayerRecord {
        name: name.into(),
        shape: vec![m, n],
        payload: WeightPayload::Tiled {
            p,
            tile: tile_from_weights(&w, p),
            alphas: alphas_from(&w, p, AlphaMode::PerTile),
        },
    }
}

/// A tiled layer with caller-pinned alphas (a single alpha covers the whole
/// layer) — how the negative- and zero-scale rule classes are forced.
fn tiled_layer_alpha(rng: &mut Rng, name: &str, m: usize, n: usize, p: usize,
                     alpha: f32) -> LayerRecord {
    let w = rng.normal_vec(m * n, 1.0);
    LayerRecord {
        name: name.into(),
        shape: vec![m, n],
        payload: WeightPayload::Tiled {
            p,
            tile: tile_from_weights(&w, p),
            alphas: vec![alpha],
        },
    }
}

/// Ragged FC chain (70 -> 90 -> 70 -> 33 -> 3): every width `% 64 != 0`, so
/// each bit buffer carries a partial tail word, and the 70-row hidden layer
/// spans two output words (the word-split threading engages).
fn ragged_model() -> TbnzModel {
    let mut rng = Rng::new(0x1A7B);
    TbnzModel {
        layers: vec![
            tiled_layer(&mut rng, "fc0", 90, 70, 5),
            tiled_layer(&mut rng, "fc1", 70, 90, 5),
            tiled_layer(&mut rng, "fc2", 33, 70, 3),
            tiled_layer(&mut rng, "head", 3, 33, 3),
        ],
    }
}

/// Plain-Rust composition of the integer pipeline over an FC chain: the
/// entry layer runs the f32 reference, hidden packed layers run the scalar
/// threshold oracle over sign bools, f32 boundaries emit `gamma * acc` —
/// no packed words anywhere.  Thresholds and gammas are read back from the
/// engine so a calibrated engine is compared against its own constants.
fn oracle_chain(engine: &Engine, x: &[f32]) -> Vec<f32> {
    enum Val {
        F32(Vec<f32>),
        Bits(Vec<bool>),
    }
    let n = engine.graph().len();
    let mut cur = Val::F32(x.to_vec());
    for idx in 0..n {
        let Node::Fc(fc) = engine.node(idx) else {
            panic!("oracle_chain only walks FC chains")
        };
        let relu = idx + 1 < n; // Nonlin::Relu everywhere but the head
        cur = match (engine.packed_layer(idx), engine.int_thresholds(idx)) {
            (Some(p), Some(thr)) => {
                let x_pos: Vec<bool> = match &cur {
                    Val::F32(h) => h.iter().map(|&v| v > 0.0).collect(),
                    Val::Bits(b) => b.clone(),
                };
                if engine.emits_bits(idx) {
                    Val::Bits(fc.forward_int_oracle(p, thr, &x_pos))
                } else {
                    Val::F32(fc.forward_int_oracle_f32(p, thr, &x_pos, relu))
                }
            }
            _ => {
                let Val::F32(h) = &cur else {
                    panic!("bits never flow into a non-packed node")
                };
                Val::F32(fc.forward_reference(h, relu))
            }
        };
    }
    match cur {
        Val::F32(y) => y,
        Val::Bits(_) => panic!("the output node never emits bits"),
    }
}

fn argmax(y: &[f32]) -> usize {
    y.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// The integer path is bit-exact against the plain-Rust oracle on the
/// ragged chain: both layouts, every SIMD backend, every thread count,
/// per-sample and batched.  Also pins the bit-edge plan the constructor
/// derived: hidden packed FCs emit bits, the entry layer and head do not.
#[test]
fn int_path_bit_exact_vs_integer_oracle_on_ragged_chain() {
    let model = ragged_model();
    let mut rng = Rng::new(0x515);
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(70, 1.0)).collect();
    for layout in LAYOUTS {
        let base = MlpEngine::with_path_layout(model.clone(), Nonlin::Relu,
                                               EnginePath::PackedInt, layout)
            .unwrap();
        let e = base.engine();
        assert!(!e.emits_bits(0), "{layout:?}: the entry layer is f32");
        assert!(e.emits_bits(1) && e.emits_bits(2),
                "{layout:?}: hidden packed FCs must emit bits");
        assert!(!e.emits_bits(3), "{layout:?}: the head emits logits");
        let want: Vec<Vec<f32>> = xs.iter().map(|x| oracle_chain(e, x)).collect();
        for backend in ALL_BACKENDS {
            for threads in THREAD_SWEEP {
                let engine = MlpEngine::with_path_layout(
                    model.clone(), Nonlin::Relu, EnginePath::PackedInt, layout)
                    .unwrap()
                    .with_threads(threads)
                    .with_simd(backend);
                for (s, x) in xs.iter().enumerate() {
                    assert_eq!(engine.forward(x), want[s],
                               "{layout:?} {backend} threads={threads} sample {s}");
                }
                assert_eq!(engine.forward_batch(&xs), want,
                           "{layout:?} {backend} threads={threads} batched");
            }
        }
    }
}

/// Calibration only moves f32 boundaries: hidden bits are invariant under
/// any positive constant gamma, so a calibrated engine still matches the
/// oracle (which reads the calibrated constants back from the engine).
#[test]
fn calibrated_engine_still_matches_oracle() {
    let model = ragged_model();
    let mut rng = Rng::new(0x516);
    let xs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(70, 1.0)).collect();
    for layout in LAYOUTS {
        let engine = MlpEngine::with_path_layout(model.clone(), Nonlin::Relu,
                                                 EnginePath::PackedInt, layout)
            .unwrap()
            .calibrate_int_gammas(&xs);
        let e = engine.engine();
        let head = e.graph().len() - 1;
        let thr = e.int_thresholds(head).unwrap();
        assert!(thr.gamma.is_finite() && thr.gamma > 0.0 && thr.gamma != 1.0,
                "{layout:?}: calibration must move the head gamma (got {})",
                thr.gamma);
        for (s, x) in xs.iter().enumerate() {
            assert_eq!(engine.forward(x), oracle_chain(e, x),
                       "{layout:?} calibrated sample {s}");
        }
    }
}

/// Negative- and zero-scale layers at the engine level: every row of the
/// all-negative layer folds to `Neg` (flipped comparison), every row of
/// the zero-alpha layer folds to `Zero` (constant-0 bits), the `export_i32`
/// encodings match the documented scheme, and the whole chain stays
/// bit-exact against the oracle on both layouts at several thread counts.
#[test]
fn negative_and_zero_scale_rows_pinned() {
    let mut rng = Rng::new(0xA1FA);
    let model = TbnzModel {
        layers: vec![
            tiled_layer(&mut rng, "fc0", 48, 40, 4),
            tiled_layer_alpha(&mut rng, "neg", 72, 48, 4, -0.5),
            tiled_layer_alpha(&mut rng, "zero", 40, 72, 4, 0.0),
            tiled_layer(&mut rng, "head", 3, 40, 4),
        ],
    };
    let mut xrng = Rng::new(0xA1FB);
    let xs: Vec<Vec<f32>> = (0..4).map(|_| xrng.normal_vec(40, 1.0)).collect();
    for layout in LAYOUTS {
        let base = MlpEngine::with_path_layout(model.clone(), Nonlin::Relu,
                                               EnginePath::PackedInt, layout)
            .unwrap();
        let e = base.engine();
        assert!(e.emits_bits(1) && e.emits_bits(2),
                "{layout:?}: both interior layers feed packed FCs");
        let neg = e.int_thresholds(1).unwrap();
        assert!(neg.rules.iter().all(|r| matches!(r, IntRowRule::Neg { .. })),
                "{layout:?}: uniform negative alpha must fold every row Neg");
        assert!(neg.export_i32().iter().all(|&v| v <= -1),
                "{layout:?}: Neg rows export as -t-1 <= -1");
        let zero = e.int_thresholds(2).unwrap();
        assert!(zero.rules.iter().all(|r| matches!(r, IntRowRule::Zero)),
                "{layout:?}: alpha 0 must fold every row Zero");
        assert!(zero.export_i32().iter().all(|&v| v == i32::MAX),
                "{layout:?}: Zero rows export the unreachable i32::MAX");
        // Zero rows emit constant-0 bits: the oracle sees the head reading
        // an all-false sign vector, and the engine must agree exactly.
        for threads in THREAD_SWEEP {
            let engine = MlpEngine::with_path_layout(
                model.clone(), Nonlin::Relu, EnginePath::PackedInt, layout)
                .unwrap()
                .with_threads(threads);
            for (s, x) in xs.iter().enumerate() {
                assert_eq!(engine.forward(x), oracle_chain(e, x),
                           "{layout:?} threads={threads} sample {s}");
            }
            assert_eq!(engine.forward_batch(&xs),
                       xs.iter().map(|x| oracle_chain(e, x)).collect::<Vec<_>>(),
                       "{layout:?} threads={threads} batched");
        }
    }
}

fn lowered(name: &str) -> (tiledbits::nn::Graph, usize) {
    let (spec, input) = match name {
        "cnn_micro" => (arch::cnn_micro(), (3usize, 16usize, 16usize)),
        "vit_micro" => {
            let s = arch::vit_micro();
            let input = s.native_input().expect("vit_micro input shape");
            (s, input)
        }
        other => panic!("unknown spec {other}"),
    };
    let opts = LowerOptions { input, p: 4, alpha_mode: AlphaMode::PerTile, seed: 7 };
    let graph = lower_arch_spec(&spec, &opts).unwrap();
    (graph, input.0 * input.1 * input.2)
}

/// Argmax-agreement sweep vs `Packed` on the lowered `cnn_micro` conv graph
/// and the `vit_micro` transformer, gammas calibrated on the eval samples.
/// Conv and attention boundaries replace data-dependent per-patch /
/// per-token gammas with one calibrated constant per layer, so logits move;
/// predictions must still agree on at least 70% of samples (the same gate
/// the int8 entry path uses).  Calibration itself must have engaged: at
/// least one packed layer's gamma moved off the 1.0 default, and every
/// gamma stays finite and positive.
#[test]
fn argmax_agreement_on_cnn_and_vit_micro() {
    for name in ["cnn_micro", "vit_micro"] {
        let (graph, in_len) = lowered(name);
        let mut rng = Rng::new(61);
        let xs: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(in_len, 1.0)).collect();
        let packed = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                               EnginePath::Packed,
                                               PackedLayout::TileResident)
            .unwrap();
        let int = Engine::with_layout_graph(graph, Nonlin::Relu,
                                            EnginePath::PackedInt,
                                            PackedLayout::TileResident)
            .unwrap()
            .calibrate_int_gammas(&xs);
        let gammas: Vec<f32> = (0..int.graph().len())
            .filter_map(|i| int.int_thresholds(i))
            .map(|thr| thr.gamma)
            .collect();
        assert!(!gammas.is_empty(), "{name}: expected packed layers");
        assert!(gammas.iter().all(|g| g.is_finite() && *g > 0.0),
                "{name}: calibrated gammas must stay finite and positive \
                 ({gammas:?})");
        assert!(gammas.iter().any(|g| *g != 1.0),
                "{name}: calibration must move at least one gamma off the \
                 1.0 default ({gammas:?})");
        let n = xs.len();
        let agree = xs
            .iter()
            .filter(|x| argmax(&packed.forward(x)) == argmax(&int.forward(x)))
            .count();
        assert!(agree * 10 >= n * 7, "{name}: argmax agreement {agree}/{n}");
    }
}
