//! Bit operations: the Table 2 accounting model *and* the measured kernels
//! it models — word-level XNOR + popcount dot products over `u64`-packed
//! sign vectors, the arithmetic the `nn::packed` fast path runs on.
//!
//! Unit convention (standard in the BNN literature and consistent with the
//! paper's numbers — FP/IR-Net = 64x exactly): one full-precision MAC costs
//! 64 bit-ops; one binary (XNOR+popcount) MAC costs 1 bit-op.
//!
//! TBN reduction model (paper §4.1): with default training (single tile per
//! layer) a tiled conv layer's output channels replicate in groups of p, so
//! only one channel per group is computed — a p-fold reduction.  In addition,
//! when the *previous* layer was tiled, this layer's input channels arrive in
//! p identical groups, so the inner reduction folds weight sums per group —
//! a further p-fold reduction where applicable.  This yields the >p overall
//! savings the paper reports (6.7x at p=4 on ResNet18).

use crate::arch::{ArchSpec, Kind};
use super::policy::{decide, Quant, TilingPolicy};

// ---------------------------------------------------------------------------
// Word-level XNOR-popcount kernels
// ---------------------------------------------------------------------------
//
// Layout convention is `tensor::BitVec`'s: bit k of a packed slice lives in
// word k / 64 at position k % 64 (LSB-first); bit = 1 encodes +1.

/// Low `count` bits set (`count` in `0..=64`).
#[inline]
fn mask_low(count: usize) -> u64 {
    if count >= 64 {
        u64::MAX
    } else {
        (1u64 << count) - 1
    }
}

/// XNOR-popcount dot product over the bit range `[start, start + len)` of
/// two packed sign slices: returns `sum_i a_i * b_i` over that range, i.e.
/// `2 * agreements - len`.
///
/// This is the one bit-op the whole packed inference path reduces to; the
/// per-layer alpha scaling happens outside, once per constant-alpha run.
///
/// The interior full words run through two `u128` lanes (four `u64` words
/// per iteration, two independent popcount chains the CPU can retire in
/// parallel); only the boundary words pay the masking.
/// `benches/table2_bitops.rs` reports the words-per-second delta against
/// [`xnor_dot_words_range_u64x4`] (the previous 4-wide scalar unroll) and
/// [`xnor_dot_words_range_scalar`].
#[inline]
pub fn xnor_dot_words_range(a: &[u64], b: &[u64], start: usize, len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    let end = start + len;
    debug_assert!(end <= a.len() * 64 && end <= b.len() * 64);
    let first_w = start / 64;
    let last_w = (end - 1) / 64;
    // whole range inside one word: mask both ends at once
    if first_w == last_w {
        let mut mask = u64::MAX << (start % 64);
        let valid = end - last_w * 64; // 1..=64 bits of this word are in range
        if valid < 64 {
            mask &= (1u64 << valid) - 1;
        }
        let same = ((!(a[first_w] ^ b[first_w])) & mask).count_ones() as i64;
        return 2 * same - len as i64;
    }
    let mut same: u64 = 0;
    let mut w = first_w;
    if start % 64 != 0 {
        // leading partial word
        let mask = u64::MAX << (start % 64);
        same += ((!(a[w] ^ b[w])) & mask).count_ones() as u64;
        w += 1;
    }
    // full words: [w, full_end), two u128 lanes at a time
    let full_end = if end % 64 == 0 { last_w + 1 } else { last_w };
    let (mut s0, mut s1) = (0u64, 0u64);
    while w + 4 <= full_end {
        let a01 = a[w] as u128 | ((a[w + 1] as u128) << 64);
        let b01 = b[w] as u128 | ((b[w + 1] as u128) << 64);
        let a23 = a[w + 2] as u128 | ((a[w + 3] as u128) << 64);
        let b23 = b[w + 2] as u128 | ((b[w + 3] as u128) << 64);
        s0 += (!(a01 ^ b01)).count_ones() as u64;
        s1 += (!(a23 ^ b23)).count_ones() as u64;
        w += 4;
    }
    same += s0 + s1;
    while w < full_end {
        same += (!(a[w] ^ b[w])).count_ones() as u64;
        w += 1;
    }
    if end % 64 != 0 {
        // trailing partial word
        let valid = end - last_w * 64;
        let mask = (1u64 << valid) - 1;
        same += ((!(a[last_w] ^ b[last_w])) & mask).count_ones() as u64;
    }
    2 * same as i64 - len as i64
}

/// The pre-u128 inner loop: a 4-wide unrolled scalar `count_ones`
/// accumulation over `u64` words.  Kept as the bench baseline for the
/// u128-lane widening (`benches/table2_bitops.rs`) and as a third oracle
/// for the property tests.
#[inline]
pub fn xnor_dot_words_range_u64x4(a: &[u64], b: &[u64], start: usize, len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    let end = start + len;
    debug_assert!(end <= a.len() * 64 && end <= b.len() * 64);
    let first_w = start / 64;
    let last_w = (end - 1) / 64;
    if first_w == last_w {
        let mut mask = u64::MAX << (start % 64);
        let valid = end - last_w * 64;
        if valid < 64 {
            mask &= (1u64 << valid) - 1;
        }
        let same = ((!(a[first_w] ^ b[first_w])) & mask).count_ones() as i64;
        return 2 * same - len as i64;
    }
    let mut same: u64 = 0;
    let mut w = first_w;
    if start % 64 != 0 {
        let mask = u64::MAX << (start % 64);
        same += ((!(a[w] ^ b[w])) & mask).count_ones() as u64;
        w += 1;
    }
    let full_end = if end % 64 == 0 { last_w + 1 } else { last_w };
    let (mut s0, mut s1, mut s2, mut s3) = (0u64, 0u64, 0u64, 0u64);
    while w + 4 <= full_end {
        s0 += (!(a[w] ^ b[w])).count_ones() as u64;
        s1 += (!(a[w + 1] ^ b[w + 1])).count_ones() as u64;
        s2 += (!(a[w + 2] ^ b[w + 2])).count_ones() as u64;
        s3 += (!(a[w + 3] ^ b[w + 3])).count_ones() as u64;
        w += 4;
    }
    same += s0 + s1 + s2 + s3;
    while w < full_end {
        same += (!(a[w] ^ b[w])).count_ones() as u64;
        w += 1;
    }
    if end % 64 != 0 {
        let valid = end - last_w * 64;
        let mask = (1u64 << valid) - 1;
        same += ((!(a[last_w] ^ b[last_w])) & mask).count_ones() as u64;
    }
    2 * same as i64 - len as i64
}

/// Read `count` (1..=64) bits at `[start, start + count)` from a packed
/// slice into the low bits.  Caller guarantees
/// `start + count <= a.len() * 64`.
#[inline]
fn fetch_bits(a: &[u64], start: usize, count: usize) -> u64 {
    debug_assert!(count >= 1 && count <= 64);
    let wi = start / 64;
    let off = start % 64;
    let in_word = 64 - off; // bits available from word wi
    let v = if count <= in_word {
        a[wi] >> off
    } else {
        (a[wi] >> off) | (a[wi + 1] << in_word)
    };
    v & mask_low(count)
}

/// XNOR-popcount dot of two bit ranges at **independent offsets**:
/// `sum_k a[a_start + k] * b[b_start + k]` for `k in 0..len`, with both
/// slices packed LSB-first.
///
/// This is the tile-resident inner loop: the tile keeps exactly `q` bits
/// resident and every row of the expanded matrix is a window into the
/// repeated tile stream, so row dots need dots at a tile phase that
/// generally differs from the activation's word phase.  When the two phases
/// agree mod 64 this delegates to the aligned kernel over shifted word
/// views; otherwise the `a` side is shift-stitched to `b`'s word grid with
/// the previous high word carried across iterations — one fresh load plus
/// two shifts per 64 bits of `a`.
#[inline]
pub fn xnor_dot_words_offset(a: &[u64], a_start: usize, b: &[u64], b_start: usize,
                             len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    debug_assert!(a_start + len <= a.len() * 64);
    debug_assert!(b_start + len <= b.len() * 64);
    if a_start % 64 == b_start % 64 {
        // congruent phases: one aligned walk over word-shifted views
        return xnor_dot_words_range(&a[a_start / 64..], &b[b_start / 64..],
                                    a_start % 64, len);
    }
    let mut same: u64 = 0;
    let mut done = 0usize;
    // leading partial: advance to b's next word boundary
    let b_off = b_start % 64;
    if b_off != 0 {
        let take = (64 - b_off).min(len);
        let av = fetch_bits(a, a_start, take);
        let bv = (b[b_start / 64] >> b_off) & mask_low(take);
        same += ((!(av ^ bv)) & mask_low(take)).count_ones() as u64;
        done = take;
    }
    // full b words: carried-word stitch of a onto b's grid.  Once b is
    // word-aligned, a's in-word offset is constant — and nonzero, because
    // the congruent case was handled above.
    let mut bw = (b_start + done) / 64;
    if done + 64 <= len {
        let off = (a_start + done) % 64;
        debug_assert!(off != 0, "congruent phases must take the aligned path");
        let mut wi = (a_start + done) / 64;
        let mut lo = a[wi] >> off;
        while done + 64 <= len {
            let hi = a[wi + 1];
            let av = lo | (hi << (64 - off));
            same += (!(av ^ b[bw])).count_ones() as u64;
            lo = hi >> off;
            wi += 1;
            bw += 1;
            done += 64;
        }
    }
    if done < len {
        let take = len - done;
        let av = fetch_bits(a, a_start + done, take);
        let bv = b[bw] & mask_low(take);
        same += ((!(av ^ bv)) & mask_low(take)).count_ones() as u64;
    }
    2 * same as i64 - len as i64
}

/// Scalar (one-word-at-a-time) form of [`xnor_dot_words_range`] — the
/// pre-unroll baseline, kept for the before/after words-per-second
/// comparison in `benches/table2_bitops.rs` and as a second oracle for the
/// property tests.
#[inline]
pub fn xnor_dot_words_range_scalar(a: &[u64], b: &[u64], start: usize, len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    let end = start + len;
    debug_assert!(end <= a.len() * 64 && end <= b.len() * 64);
    let first_w = start / 64;
    let last_w = (end - 1) / 64;
    let mut same: i64 = 0;
    for w in first_w..=last_w {
        let mut mask = u64::MAX;
        if w == first_w {
            mask &= u64::MAX << (start % 64);
        }
        if w == last_w {
            let valid = end - w * 64; // 1..=64 bits of this word are in range
            if valid < 64 {
                mask &= (1u64 << valid) - 1;
            }
        }
        same += ((!(a[w] ^ b[w])) & mask).count_ones() as i64;
    }
    2 * same - len as i64
}

/// XNOR-popcount dot over the first `bits` bits of two packed sign slices.
#[inline]
pub fn xnor_dot_words(a: &[u64], b: &[u64], bits: usize) -> i64 {
    xnor_dot_words_range(a, b, 0, bits)
}

/// Bit-ops per fp MAC.
pub const FP_MAC_BITOPS: f64 = 64.0;
/// Bit-ops per binary MAC (XNOR + popcount, amortized per the BNN convention).
pub const BIN_MAC_BITOPS: f64 = 1.0;

/// Total bit-ops for a full-precision model.
pub fn fp_bitops(arch: &ArchSpec) -> f64 {
    arch.total_macs() as f64 * FP_MAC_BITOPS
}

/// Binary-weight model (IR-Net-style): every conv/FC MAC becomes binary.
pub fn bwnn_bitops(arch: &ArchSpec, policy: &TilingPolicy) -> f64 {
    arch.layers
        .iter()
        .map(|l| {
            let quantized = matches!(l.kind, Kind::Conv { .. } | Kind::Fc { .. })
                && decide(policy, l.params) != Quant::Fp;
            l.macs as f64 * if quantized { BIN_MAC_BITOPS } else { FP_MAC_BITOPS }
        })
        .sum()
}

/// TBN model: binary MACs with the replication reductions described above.
///
/// A tiled layer gets the output-replication p-fold reduction only when its
/// tile length is a multiple of the per-output-channel weight count (so whole
/// channels replicate — true for the paper's default configs); the input-fold
/// reduction applies when the producing layer was tiled.
pub fn tbn_bitops(arch: &ArchSpec, policy: &TilingPolicy) -> f64 {
    let mut total = 0.0;
    let mut prev_tiled_p: usize = 1;
    for l in &arch.layers {
        if !matches!(l.kind, Kind::Conv { .. } | Kind::Fc { .. }) {
            continue;
        }
        let quant = decide(policy, l.params);
        // input folding: if the producing layer's output channels replicate
        // in groups of p, any consumer can pre-sum weights per group
        let in_red = prev_tiled_p as f64;
        let cost = match quant {
            Quant::Fp => l.macs as f64 * FP_MAC_BITOPS,
            Quant::Bwnn => l.macs as f64 * BIN_MAC_BITOPS / in_red,
            Quant::Tiled { p } => {
                let q = l.params / p;
                // output replication: whole channels replicate iff q is a
                // multiple of the per-channel weight count
                let out_red = if q % l.per_channel() == 0 { p as f64 } else { 1.0 };
                l.macs as f64 * BIN_MAC_BITOPS / (out_red * in_red)
            }
        };
        total += cost;
        prev_tiled_p = match quant {
            Quant::Tiled { p } => {
                let q = l.params / p;
                if q % l.per_channel() == 0 { p } else { 1 }
            }
            _ => 1,
        };
    }
    total
}

/// One Table 2 row: (fp, bwnn, tbn) in G bit-ops plus the savings factor.
pub fn table2_row(arch: &ArchSpec, p: usize, lambda: usize) -> (f64, f64, f64, f64) {
    let tbn_pol = TilingPolicy::tbn(p, lambda);
    let bw_pol = TilingPolicy::bwnn(lambda);
    let fp = fp_bitops(arch) / 1e9;
    let bw = bwnn_bitops(arch, &bw_pol) / 1e9;
    let tb = tbn_bitops(arch, &tbn_pol) / 1e9;
    (fp, bw, tb, bw / tb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::tensor::BitVec;
    use crate::util::Rng;

    fn naive_sign_dot(a: &BitVec, b: &BitVec, start: usize, len: usize) -> i64 {
        (start..start + len)
            .map(|i| if a.get_bit(i) == b.get_bit(i) { 1i64 } else { -1i64 })
            .sum()
    }

    #[test]
    fn xnor_words_matches_naive_full_width() {
        let mut r = Rng::new(21);
        for len in [1usize, 5, 63, 64, 65, 128, 130, 200] {
            let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
            let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
            assert_eq!(
                xnor_dot_words(a.words(), b.words(), len),
                naive_sign_dot(&a, &b, 0, len),
                "len={len}"
            );
            assert_eq!(xnor_dot_words(a.words(), b.words(), len), a.xnor_dot(&b));
        }
    }

    #[test]
    fn xnor_words_range_matches_naive_subranges() {
        let mut r = Rng::new(22);
        let len = 300;
        let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
        let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
        for _ in 0..200 {
            let start = r.below(len);
            let l = 1 + r.below(len - start);
            assert_eq!(
                xnor_dot_words_range(a.words(), b.words(), start, l),
                naive_sign_dot(&a, &b, start, l),
                "start={start} len={l}"
            );
        }
        assert_eq!(xnor_dot_words_range(a.words(), b.words(), 17, 0), 0);
    }

    /// The u128-lane kernel, the 4-wide u64 unroll and the scalar baseline
    /// are the same function — over long word runs (where the wide bodies
    /// engage), ragged boundaries and sub-word ranges.
    #[test]
    fn unrolled_matches_scalar_baseline() {
        let mut r = Rng::new(23);
        let len = 64 * 40 + 17; // > wide-lane body plus ragged tail
        let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
        let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
        for _ in 0..300 {
            let start = r.below(len);
            let l = 1 + r.below(len - start);
            let scalar = xnor_dot_words_range_scalar(a.words(), b.words(), start, l);
            assert_eq!(xnor_dot_words_range(a.words(), b.words(), start, l), scalar,
                       "u128 lanes, start={start} len={l}");
            assert_eq!(xnor_dot_words_range_u64x4(a.words(), b.words(), start, l), scalar,
                       "u64x4, start={start} len={l}");
        }
        // word-aligned full-width run (pure wide-lane body)
        assert_eq!(
            xnor_dot_words_range(a.words(), b.words(), 0, 64 * 40),
            xnor_dot_words_range_scalar(a.words(), b.words(), 0, 64 * 40),
        );
    }

    /// The misaligned-offset kernel must agree with the naive per-bit dot
    /// for arbitrary (a_start, b_start, len) triples — including congruent
    /// phases (the aligned delegation) and sub-word ranges.
    #[test]
    fn offset_kernel_matches_naive_at_all_phases() {
        let mut r = Rng::new(24);
        let (alen, blen) = (5 * 64 + 23, 7 * 64 + 41);
        let a = BitVec::from_signs(&r.normal_vec(alen, 1.0));
        let b = BitVec::from_signs(&r.normal_vec(blen, 1.0));
        let naive = |a_start: usize, b_start: usize, len: usize| -> i64 {
            (0..len)
                .map(|k| {
                    if a.get_bit(a_start + k) == b.get_bit(b_start + k) { 1i64 } else { -1 }
                })
                .sum()
        };
        for _ in 0..400 {
            let a_start = r.below(alen);
            let b_start = r.below(blen);
            let l = 1 + r.below((alen - a_start).min(blen - b_start));
            assert_eq!(
                xnor_dot_words_offset(a.words(), a_start, b.words(), b_start, l),
                naive(a_start, b_start, l),
                "a_start={a_start} b_start={b_start} len={l}"
            );
        }
        // forced congruent-phase cases exercise the aligned delegation
        for phase in [0usize, 1, 17, 63] {
            let l = 200.min(alen - (64 + phase)).min(blen - (128 + phase));
            assert_eq!(
                xnor_dot_words_offset(a.words(), 64 + phase, b.words(), 128 + phase, l),
                naive(64 + phase, 128 + phase, l),
                "congruent phase {phase}"
            );
        }
        assert_eq!(xnor_dot_words_offset(a.words(), 9, b.words(), 70, 0), 0);
    }

    /// A tile window that wraps nowhere: dotting the repeated-tile stream
    /// window `[s, s+len)` against an aligned activation equals expanding
    /// the window first — the identity the tile-resident packed layer rests
    /// on.
    #[test]
    fn offset_kernel_reads_tile_windows_exactly() {
        let mut r = Rng::new(25);
        let q = 3 * 64 + 9;
        let tile = BitVec::from_signs(&r.normal_vec(q, 1.0));
        let n = 100;
        let x = BitVec::from_signs(&r.normal_vec(n, 1.0));
        for s in [0usize, 1, 63, 64, 65, q - n] {
            let len = n.min(q - s);
            // expanded window, re-packed at offset 0
            let window: Vec<f32> =
                (0..len).map(|k| if tile.get_bit(s + k) { 1.0 } else { -1.0 }).collect();
            let wv = BitVec::from_signs(&window);
            let want = xnor_dot_words_range(wv.words(), x.words(), 0, len);
            assert_eq!(
                xnor_dot_words_offset(tile.words(), s, x.words(), 0, len),
                want,
                "tile offset {s}"
            );
        }
    }

    #[test]
    fn xnor_words_single_word_masks() {
        // start and end inside the same word
        let a = BitVec::from_signs(&[1.0; 10]);
        let b = BitVec::from_signs(&[-1.0; 10]);
        assert_eq!(xnor_dot_words_range(a.words(), b.words(), 3, 5), -5);
        let b2 = BitVec::from_signs(&[1.0; 10]);
        assert_eq!(xnor_dot_words_range(a.words(), b2.words(), 3, 5), 5);
    }

    #[test]
    fn fp_to_bwnn_is_64x() {
        // the paper's FP/IR-Net ratio is exactly 64 (35.03 / 0.547)
        let a = arch::resnet18_cifar();
        let fp = fp_bitops(&a);
        let bw = bwnn_bitops(&a, &TilingPolicy::bwnn(0));
        assert!((fp / bw - 64.0).abs() < 1e-9);
    }

    #[test]
    fn tbn_beats_bwnn_substantially_on_resnet18() {
        // Table 2: IR-Net 0.547 -> TBN 0.082 is 6.7x at p=4.  Our accounting
        // model (output replication x input folding, residual/downsample
        // layers unfolded) lands in the same regime; the exact factor depends
        // on how aggressively the folded small-int MACs are costed.
        let (fp, bw, tb, factor) = table2_row(&arch::resnet18_cifar(), 4, 64_000);
        assert!(fp > bw && bw > tb);
        assert!((fp / bw - 64.0).abs() < 1e-9, "fp/bwnn must be 64x");
        assert!(factor > 2.0, "expected substantial reduction, got {factor:.2}");
        assert!(factor < 16.0, "reduction cannot exceed p^2, got {factor:.2}");
    }

    #[test]
    fn resnet50_reduction_larger_than_resnet18() {
        // Paper: 6.7x (ResNet18) vs 7.9x (ResNet50)
        let (_, _, _, f18) = table2_row(&arch::resnet18_cifar(), 4, 64_000);
        let (_, _, _, f50) = table2_row(&arch::resnet50_cifar(), 4, 64_000);
        assert!(f50 > f18 * 0.7, "f18={f18:.2} f50={f50:.2}");
    }

    #[test]
    fn imagenet_tbn2_reduction_reasonable() {
        // Paper: FP 225.66 / IR-Net 3.526 / TBN 0.58 (6.1x) at p=2
        let (fp, bw, tb, factor) = table2_row(&arch::resnet34_imagenet(), 2, 150_000);
        assert!(fp > 200.0 && fp < 260.0, "fp G bitops = {fp}"); // paper: 225.66
        assert!(bw > 3.0 && bw < 4.1, "bw = {bw}"); // paper: 3.526
        assert!(tb < bw / 1.5, "tb = {tb}");
        assert!(factor >= 1.5 && factor <= 4.0, "factor = {factor}");
    }

    #[test]
    fn nothing_tiled_degenerates_to_bwnn() {
        let a = arch::resnet18_cifar();
        // lambda so high nothing tiles: every layer falls back to 1-bit,
        // so tbn cost == bwnn cost
        let pol = TilingPolicy::tbn(4, usize::MAX);
        let bw_pol = TilingPolicy::bwnn(0);
        assert!((tbn_bitops(&a, &pol) - bwnn_bitops(&a, &bw_pol)).abs() < 1e-6);
    }
}
