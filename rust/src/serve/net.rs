//! Network front end: a `std::net` TCP listener speaking minimal HTTP/1.1
//! over the bounded-queue worker pools in a [`ModelRegistry`].
//!
//! No HTTP crate is vendored, so the framing is hand-rolled and deliberately
//! small: request line + headers + `Content-Length` body, keep-alive by
//! default, single-line JSON bodies (the `util::Json` writer emits no
//! newlines in compact mode).  Endpoints:
//!
//! * `POST /infer` — body `{"model": "<name>", "x": [f32, ...]}` (the
//!   `model` field may be omitted on single-model servers).  `200` answers
//!   carry `y`, the model `generation`, and the pool's timing breakdown.
//!   A full queue under `OverflowPolicy::Reject` sheds the request with a
//!   `503 Service Unavailable` (the HTTP face of load shedding — the pool's
//!   `rejected` counter has already recorded it); an unknown model is
//!   `404`; a malformed body or wrong input width is `400` — the
//!   connection handler answers and keeps the connection alive rather than
//!   dying with the request.
//! * `POST /reload` — body `{"model": "<name>", "seed": n}`: rebuild the
//!   named model through the server's [`ModelBuilder`] and hot-swap it into
//!   the registry (`Arc` swap; in-flight requests finish on the old pool).
//!   `501` when the server was started without a builder.
//! * `GET /models` — registry listing (name, input dim, generation).
//! * `GET /stats` — per-model serving stats incl. nearest-rank p50/p95/p99.
//! * `GET /healthz` — liveness probe.
//!
//! **Graceful drain** ([`NetServer::shutdown`], also wired to
//! SIGTERM/SIGINT via [`install_shutdown_flag`]): stop accepting (the
//! listener is woken and dropped, so new connects are refused), let every
//! connection handler finish the request it is serving (handlers poll the
//! closing flag on a short read timeout), join them all, and return the
//! final per-model stats.  Because handlers block in `Server::infer` until
//! the pool answers, joining them proves every accepted network request was
//! completed — nothing accepted is dropped.
//!
//! Concurrency model: one accept thread + one handler thread per
//! connection (clients are expected to keep connections alive and pipeline
//! serially; the load generator and tests do).  Handler threads are
//! tracked and reaped so the handle list stays bounded.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::util::Json;

use super::registry::ModelRegistry;
use super::{Server, ServerStats};

/// Upper bound on one request's header block.
const MAX_HEADER_BYTES: usize = 64 * 1024;
/// Upper bound on one request's body (a 1M-float input is ~8 MB of JSON).
const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;
/// Read-timeout granularity at which idle handlers poll the closing flag.
const POLL_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// Rebuilds a model by name for `POST /reload` hot swaps: `(name, seed)`
/// -> a fresh worker pool over the rebuilt engine.
pub type ModelBuilder = Arc<dyn Fn(&str, u64) -> Result<Server, String> + Send + Sync>;

/// Tracked connection-handler threads (joined at drain).
type ConnHandles = Arc<Mutex<Vec<thread::JoinHandle<()>>>>;

/// A parsed HTTP request (the subset this server speaks).
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
    keep_alive: bool,
}

enum ReqRead {
    Request(HttpRequest),
    /// Clean EOF between requests, a broken connection, or drain.
    Closed,
    /// Unparseable framing: answer 400 and close.
    Malformed(String),
}

/// Read one HTTP request from `stream` into/out of `buf` (which carries
/// pipelined leftovers between keep-alive requests).  Returns `Closed` when
/// the peer hangs up cleanly or `closing` is raised while idle.
fn read_request(stream: &mut TcpStream, buf: &mut Vec<u8>, closing: &AtomicBool) -> ReqRead {
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(h) = find_header_end(buf) {
            let (method, path, content_length, keep_alive) = match parse_header(&buf[..h]) {
                Ok(p) => p,
                Err(e) => return ReqRead::Malformed(e),
            };
            if content_length > MAX_BODY_BYTES {
                return ReqRead::Malformed(format!(
                    "content-length {content_length} exceeds {MAX_BODY_BYTES}"
                ));
            }
            let total = h + 4 + content_length;
            while buf.len() < total {
                match stream.read(&mut tmp) {
                    Ok(0) => return ReqRead::Malformed("truncated body".into()),
                    Ok(n) => buf.extend_from_slice(&tmp[..n]),
                    Err(e) if would_block(&e) => {
                        if closing.load(Ordering::SeqCst) {
                            // mid-request at drain: the framing is incomplete
                            // and the client is gone from our perspective
                            return ReqRead::Closed;
                        }
                    }
                    Err(_) => return ReqRead::Closed,
                }
            }
            let body = buf[h + 4..total].to_vec();
            buf.drain(..total);
            return ReqRead::Request(HttpRequest { method, path, body, keep_alive });
        }
        if buf.len() > MAX_HEADER_BYTES {
            return ReqRead::Malformed("header block too large".into());
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return if buf.is_empty() {
                    ReqRead::Closed
                } else {
                    ReqRead::Malformed("truncated request".into())
                };
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if would_block(&e) => {
                if closing.load(Ordering::SeqCst) {
                    return ReqRead::Closed;
                }
            }
            Err(_) => return ReqRead::Closed,
        }
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse the header block (without the trailing blank line): request line
/// + the two headers we honor (`Content-Length`, `Connection`).
fn parse_header(block: &[u8]) -> Result<(String, String, usize, bool), String> {
    let text = std::str::from_utf8(block).map_err(|_| "non-utf8 header".to_string())?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(format!("bad request line {request_line:?}"));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true; // HTTP/1.1 default
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .parse::<usize>()
                .map_err(|_| format!("bad content-length {value:?}"))?;
        } else if name.eq_ignore_ascii_case("connection")
            && value.eq_ignore_ascii_case("close")
        {
            keep_alive = false;
        }
    }
    Ok((method, path, content_length, keep_alive))
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    body: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = body.to_string();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

/// Dispatch one parsed request against the registry; returns
/// `(status line, body)`.
fn handle(registry: &ModelRegistry, builder: Option<&ModelBuilder>, req: &HttpRequest)
          -> (&'static str, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/infer") => handle_infer(registry, &req.body),
        ("POST", "/reload") => handle_reload(registry, builder, &req.body),
        ("GET", "/models") => {
            let models: Vec<Json> = registry
                .infos()
                .into_iter()
                .map(|i| {
                    Json::obj(vec![
                        ("name", Json::Str(i.name)),
                        ("in_dim", Json::Num(i.in_dim as f64)),
                        ("generation", Json::Num(i.generation as f64)),
                    ])
                })
                .collect();
            ("200 OK", Json::obj(vec![("models", Json::Arr(models))]))
        }
        ("GET", "/stats") => {
            let rows: Vec<Json> = registry
                .stats()
                .into_iter()
                .map(|(name, generation, s)| stats_json(&name, generation, &s))
                .collect();
            ("200 OK", Json::obj(vec![("models", Json::Arr(rows))]))
        }
        ("GET", "/healthz") => ("200 OK", Json::obj(vec![("ok", Json::Bool(true))])),
        ("POST", _) | ("GET", _) => ("404 Not Found", err_json("unknown path")),
        _ => ("405 Method Not Allowed", err_json("method not allowed")),
    }
}

fn handle_infer(registry: &ModelRegistry, body: &[u8]) -> (&'static str, Json) {
    let parsed = match std::str::from_utf8(body)
        .map_err(|_| "non-utf8 body".to_string())
        .and_then(Json::parse)
    {
        Ok(j) => j,
        Err(e) => return ("400 Bad Request", err_json(&format!("bad JSON body: {e}"))),
    };
    let name = parsed.str_or("model", "");
    let resolved = if name.is_empty() {
        registry.sole().ok_or_else(|| {
            "missing \"model\" field (required with multiple models)".to_string()
        })
    } else {
        registry
            .get(name)
            .map(|(s, g)| (name.to_string(), s, g))
            .ok_or_else(|| format!("unknown model {name:?}"))
    };
    let (name, server, generation) = match resolved {
        Ok(r) => r,
        Err(e) => {
            let status = if name.is_empty() { "400 Bad Request" } else { "404 Not Found" };
            return (status, err_json(&e));
        }
    };
    let Some(xs) = parsed.get("x").and_then(Json::as_arr) else {
        return ("400 Bad Request", err_json("missing \"x\" array"));
    };
    let mut x = Vec::with_capacity(xs.len());
    for v in xs {
        match v.as_f64() {
            Some(f) => x.push(f as f32),
            None => return ("400 Bad Request", err_json("\"x\" must be numbers")),
        }
    }
    match server.infer(x) {
        Ok(r) => (
            "200 OK",
            Json::obj(vec![
                ("model", Json::Str(name)),
                ("generation", Json::Num(generation as f64)),
                ("y", Json::Arr(r.y.iter().map(|&v| Json::Num(v as f64)).collect())),
                ("queue_us", Json::Num(r.queue_us as f64)),
                ("total_us", Json::Num(r.total_us as f64)),
                ("batch_size", Json::Num(r.batch_size as f64)),
            ]),
        ),
        // load shedding: the pool's Reject policy refused the request and
        // counted it — surface the 503 equivalent to the client
        Err(e) if e.contains("queue full") => ("503 Service Unavailable", err_json(&e)),
        Err(e) if e.contains("input dim") => ("400 Bad Request", err_json(&e)),
        Err(e) => ("503 Service Unavailable", err_json(&e)),
    }
}

fn handle_reload(registry: &ModelRegistry, builder: Option<&ModelBuilder>, body: &[u8])
                 -> (&'static str, Json) {
    let Some(builder) = builder else {
        return ("501 Not Implemented", err_json("server started without a model builder"));
    };
    let parsed = match std::str::from_utf8(body)
        .map_err(|_| "non-utf8 body".to_string())
        .and_then(Json::parse)
    {
        Ok(j) => j,
        Err(e) => return ("400 Bad Request", err_json(&format!("bad JSON body: {e}"))),
    };
    let name = parsed.str_or("model", "");
    if name.is_empty() {
        return ("400 Bad Request", err_json("missing \"model\" field"));
    }
    let seed = parsed.usize_or("seed", 0) as u64;
    match builder(name, seed).and_then(|server| registry.swap(name, server)) {
        Ok(generation) => (
            "200 OK",
            Json::obj(vec![
                ("model", Json::Str(name.to_string())),
                ("generation", Json::Num(generation as f64)),
            ]),
        ),
        Err(e) => ("400 Bad Request", err_json(&e)),
    }
}

fn stats_json(name: &str, generation: usize, s: &ServerStats) -> Json {
    let mut row = Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("generation", Json::Num(generation as f64)),
        ("served", Json::Num(s.served as f64)),
        ("rejected", Json::Num(s.rejected as f64)),
        ("batches", Json::Num(s.batches as f64)),
        ("mean_batch", Json::Num(s.mean_batch())),
        ("mean_latency_us", Json::Num(s.mean_latency_us())),
        ("workers", Json::Num(s.workers as f64)),
        ("kernel_threads", Json::Num(s.kernel_threads as f64)),
        ("engine", Json::Str(format!("{:?}", s.engine))),
    ]);
    if let Some(p) = s.latency_percentiles() {
        row.set("p50_us", Json::Num(p.p50_us as f64));
        row.set("p95_us", Json::Num(p.p95_us as f64));
        row.set("p99_us", Json::Num(p.p99_us as f64));
    }
    row
}

/// One connection's serve loop: read request, answer, repeat until the
/// peer closes, a framing error forces a close, or drain begins.  A
/// malformed request gets a `400` answer and (for body/framing breakage)
/// a close — it never kills the thread with a panic.
fn connection_loop(
    mut stream: TcpStream,
    registry: Arc<ModelRegistry>,
    builder: Option<ModelBuilder>,
    closing: Arc<AtomicBool>,
) {
    // short read timeout so an idle handler notices drain promptly
    let _ = stream.set_read_timeout(Some(POLL_READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut buf = Vec::new();
    loop {
        match read_request(&mut stream, &mut buf, &closing) {
            ReqRead::Request(req) => {
                let (status, body) = handle(&registry, builder.as_ref(), &req);
                let keep = req.keep_alive && !closing.load(Ordering::SeqCst);
                if write_response(&mut stream, status, &body, keep).is_err() || !keep {
                    return;
                }
            }
            ReqRead::Malformed(e) => {
                let _ = write_response(&mut stream, "400 Bad Request", &err_json(&e), false);
                return;
            }
            ReqRead::Closed => return,
        }
    }
}

/// A running network front end.  Dropping it without calling
/// [`shutdown`](NetServer::shutdown) still drains (Drop delegates).
pub struct NetServer {
    addr: SocketAddr,
    closing: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
    conns: ConnHandles,
    registry: Arc<ModelRegistry>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting.  `builder` enables `POST /reload` hot swaps.
    pub fn start(
        registry: Arc<ModelRegistry>,
        addr: &str,
        builder: Option<ModelBuilder>,
    ) -> Result<NetServer, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        let closing = Arc::new(AtomicBool::new(false));
        let conns: ConnHandles = Arc::new(Mutex::new(Vec::new()));
        let accept_handle = {
            let registry = registry.clone();
            let closing = closing.clone();
            let conns = conns.clone();
            thread::spawn(move || {
                for stream in listener.incoming() {
                    if closing.load(Ordering::SeqCst) {
                        // the shutdown self-connect (or any racer) lands
                        // here: refuse and stop accepting
                        return;
                    }
                    let Ok(stream) = stream else { continue };
                    let registry = registry.clone();
                    let builder = builder.clone();
                    let closing = closing.clone();
                    let handle = thread::spawn(move || {
                        connection_loop(stream, registry, builder, closing)
                    });
                    let mut c = conns.lock().unwrap();
                    // reap finished handlers so the list stays bounded
                    let mut live = Vec::new();
                    for h in c.drain(..) {
                        if h.is_finished() {
                            let _ = h.join();
                        } else {
                            live.push(h);
                        }
                    }
                    *c = live;
                    c.push(handle);
                }
            })
        };
        Ok(NetServer {
            addr: local,
            closing,
            accept_handle: Some(accept_handle),
            conns,
            registry,
        })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Graceful drain: stop accepting, finish every in-flight request,
    /// join all connection handlers, and return the final per-model stats.
    pub fn shutdown(mut self) -> Vec<(String, usize, ServerStats)> {
        self.drain();
        self.registry.stats()
    }

    fn drain(&mut self) {
        if self.closing.swap(true, Ordering::SeqCst) {
            return; // already drained
        }
        // wake the accept loop so it observes the flag and exits
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // the listener is dropped: new connects are refused from here on;
        // join every handler — each finishes its in-flight request first
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.drain();
    }
}

// ---------------------------------------------------------------------------
// SIGTERM / SIGINT -> process-wide shutdown flag
// ---------------------------------------------------------------------------

static SHUTDOWN_FLAG: AtomicBool = AtomicBool::new(false);

/// Install SIGTERM/SIGINT handlers that raise a process-wide flag, and
/// return the flag.  `tbn serve --listen` polls it and drains when raised,
/// so `kill -TERM` is a graceful drain, not an abort.  Raw `signal(2)` FFI
/// against the platform libc — the vendor set has no signal crate; the
/// handler only stores an atomic, which is async-signal-safe.  On non-unix
/// targets the flag exists but is never raised by a signal.
#[cfg(unix)]
pub fn install_shutdown_flag() -> &'static AtomicBool {
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN_FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
    &SHUTDOWN_FLAG
}

#[cfg(not(unix))]
pub fn install_shutdown_flag() -> &'static AtomicBool {
    &SHUTDOWN_FLAG
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_parser_accepts_minimal_requests() {
        let block = b"POST /infer HTTP/1.1\r\nHost: x\r\nContent-Length: 12";
        let (method, path, len, keep) = parse_header(block).unwrap();
        assert_eq!(method, "POST");
        assert_eq!(path, "/infer");
        assert_eq!(len, 12);
        assert!(keep);
        let block = b"GET /models HTTP/1.1\r\nConnection: close";
        let (_, _, len, keep) = parse_header(block).unwrap();
        assert_eq!(len, 0);
        assert!(!keep);
    }

    #[test]
    fn header_parser_rejects_garbage() {
        assert!(parse_header(b"nonsense").is_err());
        assert!(parse_header(b"POST /x SPDY/3").is_err());
        assert!(parse_header(b"POST /x HTTP/1.1\r\nContent-Length: tweleve").is_err());
    }

    #[test]
    fn find_header_end_locates_terminator() {
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(14));
        assert_eq!(find_header_end(b"partial"), None);
    }

    #[test]
    fn infer_handler_reports_client_errors() {
        let reg = ModelRegistry::new();
        let (status, body) = handle_infer(&reg, b"not json");
        assert_eq!(status, "400 Bad Request");
        assert!(body.str_or("error", "").contains("bad JSON"));
        let (status, _) = handle_infer(&reg, br#"{"model":"nope","x":[1]}"#);
        assert_eq!(status, "404 Not Found");
        // empty registry, no model field -> 400 (no sole default)
        let (status, _) = handle_infer(&reg, br#"{"x":[1]}"#);
        assert_eq!(status, "400 Bad Request");
    }

    #[test]
    fn shutdown_flag_is_stable() {
        // the handler install must not fire the flag by itself
        let flag = install_shutdown_flag();
        assert!(!flag.load(Ordering::SeqCst) || cfg!(not(unix)));
    }
}
