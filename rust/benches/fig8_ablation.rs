//! Figure 8: test loss across tiling configurations on the ResNet (the
//! paper's appendix ablation) — same four configs as Fig 7 but tracked in
//! *loss* space, on the ResNet-mini.

use tiledbits::bench_util::{bench_dirs, bench_steps, header};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_or_load;
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;

fn main() {
    header("Figure 8: ResNet tiling-configuration test loss");
    let (artifacts, runs) = bench_dirs();
    let steps = bench_steps(80);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("(artifacts not built; skipping)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");
    let opts = TrainOptions {
        steps: Some(steps),
        eval_every: (steps / 4).max(1),
        log_every: 10_000,
        seed: None,
    };

    let variants = [
        ("resnet_mini_tbn4", "lambda + W+A + multi-alpha (best)"),
        ("resnet_mini_tbn4_global", "global tiling"),
        ("resnet_mini_tbn4_wonly", "W-only alphas"),
        ("resnet_mini_tbn4_single_alpha", "single alpha"),
    ];
    let mut losses = Vec::new();
    for (id, label) in variants {
        match run_or_load(&rt, &manifest, id, &opts, &runs) {
            Ok(rec) => {
                let curve: Vec<String> = rec.eval_curve.iter()
                    .map(|(s, l, _)| format!("{s}:{l:.3}")).collect();
                println!("{label:36} final loss {:.4}  [{}]",
                         rec.loss, curve.join(" "));
                losses.push((label, rec.loss));
            }
            Err(e) => println!("{label:36} FAILED: {e:#}"),
        }
    }
    if let (Some(best), Some(global)) = (
        losses.iter().find(|(l, _)| l.contains("best")),
        losses.iter().find(|(l, _)| l.contains("global")),
    ) {
        println!("\nshape check: global-tiling loss {:.4} vs default {:.4} — the paper's",
                 global.1, best.1);
        println!("only clear Fig-8 separation is global tiling being worst{}",
                 if global.1 >= best.1 { " (holds)" } else { " (NOT holding at this scale)" });
    }
}
