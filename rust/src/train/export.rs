//! Exporter: trained parameters → sub-bit inference artifacts.
//!
//! Two consumers:
//! * `to_tbnz` builds the TBNZ serialized model (native engine / deployment);
//! * `forward_inputs` builds the positional literal list for the AOT
//!   `forward` graph (PJRT serving path — tiled FC layers run through the
//!   Pallas tile-reuse kernel lowered into that graph).
//!
//! Both derive tiles and alphas natively in Rust (`tbn::tile` / `tbn::alpha`),
//! exercising the same math the Python oracle pins down; parity is asserted
//! in `rust/tests/native_parity.rs`.

use anyhow::{anyhow, Result};

use crate::config::Experiment;
use crate::runtime;
use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                 TbnzModel, WeightPayload};
use crate::tensor::{BitVec, Tensor};
use super::TrainedModel;

fn alpha_mode(n_alphas: usize) -> AlphaMode {
    if n_alphas <= 1 { AlphaMode::Single } else { AlphaMode::PerTile }
}

/// Find the alpha-source tensor for a tiled weight: the sibling `<name>.A`
/// when the experiment trains an independent A, otherwise the weight itself.
fn alpha_source<'m>(exp: &Experiment, model: &'m TrainedModel, name: &str,
                    w: &'m Tensor, alpha_src: &str) -> &'m Tensor {
    if alpha_src == "A" {
        if let Some(a) = model.param(exp, &format!("{name}.A")) {
            return a;
        }
    }
    w
}

/// Serialize a trained model to the TBNZ sub-bit format.
///
/// Weight layers are stored per their manifest quant decision; `other`
/// params (norms, embeddings) are stored full-precision; the alpha source A
/// never ships (it only exists to compute alphas).
pub fn to_tbnz(exp: &Experiment, model: &TrainedModel) -> Result<TbnzModel> {
    let mut layers = Vec::new();
    for (info, tensor) in exp.params.iter().zip(&model.params) {
        if info.role == "alpha_src" {
            continue;
        }
        let payload = match info.quant.as_str() {
            "tiled" => {
                let tile = tile_from_weights(&tensor.data, info.p);
                let src = alpha_source(exp, model, &info.name, tensor, &info.alpha_src);
                let alphas = alphas_from(&src.data, info.p, alpha_mode(info.n_alphas));
                WeightPayload::Tiled { p: info.p, tile, alphas }
            }
            "bwnn" => WeightPayload::Bwnn {
                bits: BitVec::from_signs(&tensor.data),
                alpha: tensor.mean_abs(),
            },
            _ => WeightPayload::Fp(tensor.data.clone()),
        };
        layers.push(LayerRecord {
            name: info.name.clone(),
            shape: info.shape.clone(),
            payload,
        });
    }
    Ok(TbnzModel { layers })
}

/// Build the forward graph's positional inputs (after `x`) from trained
/// parameters, in the manifest's `infer_params` order.
pub fn forward_inputs(exp: &Experiment, model: &TrainedModel) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(exp.infer_params.len());
    for ip in &exp.infer_params {
        let src_info = exp
            .params
            .iter()
            .position(|p| p.name == ip.source)
            .ok_or_else(|| anyhow!("infer param {} has unknown source {}", ip.name, ip.source))?;
        let w = &model.params[src_info];
        let info = &exp.params[src_info];
        let lit = match ip.kind.as_str() {
            "tile" => {
                let tile = tile_from_weights(&w.data, info.p);
                runtime::literal_f32(&Tensor::new(vec![tile.len()], tile.to_signs()))?
            }
            "alphas" => {
                let src = alpha_source(exp, model, &info.name, w, &info.alpha_src);
                let alphas = alphas_from(&src.data, info.p, alpha_mode(info.n_alphas));
                runtime::literal_f32(&Tensor::new(vec![alphas.len()], alphas))?
            }
            "bwnn_bin" => {
                let signs = BitVec::from_signs(&w.data).to_signs();
                runtime::literal_f32(&Tensor::new(info.shape.clone(), signs))?
            }
            "bwnn_alpha" => {
                runtime::literal_f32(&Tensor::new(vec![1], vec![w.mean_abs()]))?
            }
            "fp" => runtime::literal_f32(w)?,
            k => return Err(anyhow!("unknown infer param kind {k:?}")),
        };
        out.push(lit);
    }
    Ok(out)
}

/// Summarize the exported model: (params, storage bits, bit-width).
pub fn export_summary(model: &TbnzModel) -> (usize, usize, f64) {
    (model.total_params(), model.storage_bits(), model.bit_width())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Experiment, InferParamInfo, IoInfo, ParamInfo};
    use crate::tbn::TilingPolicy;
    use crate::util::Rng;

    fn mini_exp() -> Experiment {
        Experiment {
            id: "t".into(),
            tables: vec![],
            model_family: "mlp".into(),
            dataset_kind: "synth_mnist".into(),
            dataset_classes: 10,
            dataset_n_train: 64,
            dataset_n_test: 64,
            tiling: TilingPolicy::tbn(4, 0),
            opt_kind: "sgd".into(),
            opt_slots: 1,
            train_steps: 1,
            lr: 0.1,
            warmup: 0,
            schedule: "constant".into(),
            seed: 1,
            params: vec![
                ParamInfo { name: "fc".into(), shape: vec![8, 8], role: "weight".into(),
                            quant: "tiled".into(), p: 4, q: 16, n_alphas: 4,
                            alpha_src: "A".into() },
                ParamInfo { name: "fc.A".into(), shape: vec![8, 8],
                            role: "alpha_src".into(), quant: "aux".into(),
                            p: 1, q: 0, n_alphas: 0, alpha_src: "".into() },
                ParamInfo { name: "head".into(), shape: vec![2, 8], role: "weight".into(),
                            quant: "fp".into(), p: 1, q: 0, n_alphas: 0,
                            alpha_src: "".into() },
            ],
            infer_params: vec![
                InferParamInfo { name: "fc.tile".into(), kind: "tile".into(),
                                 shape: vec![16], source: "fc".into() },
                InferParamInfo { name: "fc.alphas".into(), kind: "alphas".into(),
                                 shape: vec![4], source: "fc".into() },
                InferParamInfo { name: "head".into(), kind: "fp".into(),
                                 shape: vec![2, 8], source: "head".into() },
            ],
            io: IoInfo { task: "cls".into(), train_batch: 4, eval_batch: 4,
                         serve_batch: 4, x: vec![8], y_train: vec![4],
                         y_eval: vec![4], y_is_int: true },
            graph_files: vec![],
        }
    }

    fn mini_model() -> TrainedModel {
        let mut r = Rng::new(3);
        TrainedModel {
            id: "t".into(),
            params: vec![
                Tensor::new(vec![8, 8], r.normal_vec(64, 1.0)),
                Tensor::new(vec![8, 8], r.normal_vec(64, 1.0)),
                Tensor::new(vec![2, 8], r.normal_vec(16, 1.0)),
            ],
        }
    }

    #[test]
    fn tbnz_skips_alpha_source_and_tiles() {
        let exp = mini_exp();
        let model = mini_model();
        let tbnz = to_tbnz(&exp, &model).unwrap();
        assert_eq!(tbnz.layers.len(), 2);
        assert!(matches!(tbnz.layers[0].payload, WeightPayload::Tiled { p: 4, .. }));
        assert!(matches!(tbnz.layers[1].payload, WeightPayload::Fp(_)));
    }

    #[test]
    fn tbnz_alphas_come_from_a() {
        let exp = mini_exp();
        let model = mini_model();
        let tbnz = to_tbnz(&exp, &model).unwrap();
        if let WeightPayload::Tiled { alphas, .. } = &tbnz.layers[0].payload {
            let want = alphas_from(&model.params[1].data, 4, AlphaMode::PerTile);
            assert_eq!(alphas, &want);
        } else {
            panic!("not tiled");
        }
    }

    #[test]
    fn forward_inputs_positional() {
        let exp = mini_exp();
        let model = mini_model();
        let lits = forward_inputs(&exp, &model).unwrap();
        assert_eq!(lits.len(), 3);
        assert_eq!(lits[0].element_count(), 16); // tile
        assert_eq!(lits[1].element_count(), 4); // alphas
        assert_eq!(lits[2].element_count(), 16); // fp head
    }

    #[test]
    fn tile_values_are_signs() {
        let exp = mini_exp();
        let model = mini_model();
        let lits = forward_inputs(&exp, &model).unwrap();
        let v = lits[0].to_vec::<f32>().unwrap();
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
    }

    #[test]
    fn summary_subbit() {
        let exp = mini_exp();
        let model = mini_model();
        let tbnz = to_tbnz(&exp, &model).unwrap();
        let (params, bits, bw) = export_summary(&tbnz);
        assert_eq!(params, 64 + 16);
        assert_eq!(bits, (16 + 4 * 32) + 32 * 16);
        assert!(bw < 32.0);
    }
}
