//! Figure 6: effect of layer size — accuracy vs compression rate for
//! ConvMixer and MLPMixer. ConvMixer's small layers make it degrade fast;
//! MLPMixer's larger channel-MLPs degrade gracefully.

use tiledbits::bench_util::{bench_dirs, bench_steps, header};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_or_load;
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;

fn main() {
    header("Figure 6: accuracy vs compression (ConvMixer / MLPMixer)");
    let (artifacts, runs) = bench_dirs();
    let steps = bench_steps(60);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("(artifacts not built; skipping)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");
    let opts = TrainOptions { steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None };

    for (family, ps) in [("mlpmixer", vec![2usize, 4, 8, 16, 32]),
                         ("convmixer", vec![2, 4, 8, 16])] {
        println!("\n-- {family} ({steps} steps) --");
        let fp_id = format!("{family}_fp");
        let fp_acc = match run_or_load(&rt, &manifest, &fp_id, &opts, &runs) {
            Ok(rec) => {
                println!("{fp_id:20} acc {:5.1}%  (baseline)", 100.0 * rec.metric);
                rec.metric
            }
            Err(e) => {
                println!("{fp_id:20} FAILED: {e:#}");
                continue;
            }
        };
        for p in ps {
            let id = format!("{family}_tbn{p}");
            if manifest.by_id(&id).is_none() {
                continue;
            }
            match run_or_load(&rt, &manifest, &id, &opts, &runs) {
                Ok(rec) => println!(
                    "{id:20} acc {:5.1}%  ({:+5.1} vs fp)  bit-width {:.3}",
                    100.0 * rec.metric, 100.0 * (rec.metric - fp_acc), rec.bit_width),
                Err(e) => println!("{id:20} FAILED: {e:#}"),
            }
        }
    }
    println!("\nshape check (paper Fig 6): both near-FP at p=4; ConvMixer degrades");
    println!("faster at high p than MLPMixer (its largest layer is 4x smaller).");
}
