//! TBNZ — the sub-bit serialized model format.
//!
//! What the paper stores after training ("we save a vector of size q for
//! each layer along with full-precision scalars"), made concrete:
//!
//! ```text
//! magic   b"TBNZ"            4 bytes
//! version u32 = 1
//! n_layers u32
//! per layer:
//!   name     u16 len + utf8 bytes
//!   kind     u8   (0 = fp, 1 = bwnn, 2 = tiled)
//!   rank     u8, dims u32 x rank
//!   tiled:   u32 p, u32 q, u32 n_alphas, f32 alphas[n_alphas],
//!            tile bits ceil(q/8) bytes (LSB-first, bit=1 -> +1)
//!   bwnn:    f32 alpha, packed sign bits ceil(N/8) bytes
//!   fp:      f32 data[N]
//! ```
//!
//! All integers little-endian. The format is self-describing: loading
//! requires no manifest.

use crate::tensor::BitVec;

/// In-memory weight payload of one layer.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightPayload {
    Fp(Vec<f32>),
    Bwnn { bits: BitVec, alpha: f32 },
    Tiled { p: usize, tile: BitVec, alphas: Vec<f32> },
}

/// One serialized layer: a name, a logical shape and a payload.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRecord {
    pub name: String,
    pub shape: Vec<usize>,
    pub payload: WeightPayload,
}

impl LayerRecord {
    pub fn n(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bits this layer occupies on disk/in weight memory (excluding name).
    pub fn storage_bits(&self) -> usize {
        match &self.payload {
            WeightPayload::Fp(v) => 32 * v.len(),
            WeightPayload::Bwnn { bits, .. } => bits.len() + 32,
            WeightPayload::Tiled { tile, alphas, .. } => tile.len() + 32 * alphas.len(),
        }
    }

    /// Reconstruct the full f32 weight vector (reference path; the native
    /// engine avoids this and reuses the tile directly).
    pub fn expand(&self) -> Vec<f32> {
        match &self.payload {
            WeightPayload::Fp(v) => v.clone(),
            WeightPayload::Bwnn { bits, alpha } => {
                bits.to_signs().iter().map(|s| s * alpha).collect()
            }
            WeightPayload::Tiled { tile, alphas, .. } => {
                super::tile::expand_tile(tile, alphas, self.n())
            }
        }
    }
}

/// A whole serialized model.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TbnzModel {
    pub layers: Vec<LayerRecord>,
}

const MAGIC: &[u8; 4] = b"TBNZ";
const VERSION: u32 = 1;

impl TbnzModel {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.n()).sum()
    }

    pub fn storage_bits(&self) -> usize {
        self.layers.iter().map(|l| l.storage_bits()).sum()
    }

    pub fn storage_bytes(&self) -> usize {
        self.storage_bits().div_ceil(8)
    }

    /// Bits per model parameter (the paper's "Bit-Width" column).
    pub fn bit_width(&self) -> f64 {
        self.storage_bits() as f64 / self.total_params().max(1) as f64
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.layers.len() as u32).to_le_bytes());
        for layer in &self.layers {
            let nb = layer.name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            let kind: u8 = match &layer.payload {
                WeightPayload::Fp(_) => 0,
                WeightPayload::Bwnn { .. } => 1,
                WeightPayload::Tiled { .. } => 2,
            };
            out.push(kind);
            out.push(layer.shape.len() as u8);
            for &d in &layer.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match &layer.payload {
                WeightPayload::Fp(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                WeightPayload::Bwnn { bits, alpha } => {
                    out.extend_from_slice(&alpha.to_le_bytes());
                    out.extend_from_slice(&bits.to_bytes());
                }
                WeightPayload::Tiled { p, tile, alphas } => {
                    out.extend_from_slice(&(*p as u32).to_le_bytes());
                    out.extend_from_slice(&(tile.len() as u32).to_le_bytes());
                    out.extend_from_slice(&(alphas.len() as u32).to_le_bytes());
                    for a in alphas {
                        out.extend_from_slice(&a.to_le_bytes());
                    }
                    out.extend_from_slice(&tile.to_bytes());
                }
            }
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<TbnzModel, String> {
        let mut r = Reader { b, i: 0 };
        if r.take(4)? != MAGIC {
            return Err("bad magic".into());
        }
        let version = r.u32()?;
        if version != VERSION {
            return Err(format!("unsupported version {version}"));
        }
        let n_layers = r.u32()? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|e| e.to_string())?;
            let kind = r.u8()?;
            let rank = r.u8()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u32()? as usize);
            }
            let n: usize = shape.iter().product();
            let payload = match kind {
                0 => {
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(r.f32()?);
                    }
                    WeightPayload::Fp(v)
                }
                1 => {
                    let alpha = r.f32()?;
                    let bytes = r.take(n.div_ceil(8))?;
                    WeightPayload::Bwnn { bits: BitVec::from_bytes(bytes, n), alpha }
                }
                2 => {
                    let p = r.u32()? as usize;
                    let q = r.u32()? as usize;
                    let n_alphas = r.u32()? as usize;
                    let mut alphas = Vec::with_capacity(n_alphas);
                    for _ in 0..n_alphas {
                        alphas.push(r.f32()?);
                    }
                    let bytes = r.take(q.div_ceil(8))?;
                    if p * q != n {
                        return Err(format!("{name}: p*q = {} != N = {n}", p * q));
                    }
                    WeightPayload::Tiled { p, tile: BitVec::from_bytes(bytes, q), alphas }
                }
                k => return Err(format!("unknown layer kind {k}")),
            };
            layers.push(LayerRecord { name, shape, payload });
        }
        Ok(TbnzModel { layers })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_bytes())
    }

    pub fn load(path: &str) -> Result<TbnzModel, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
        TbnzModel::from_bytes(&bytes)
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err(format!("truncated at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_model() -> TbnzModel {
        let mut r = Rng::new(1);
        let w: Vec<f32> = (0..64).map(|_| r.gauss_f32()).collect();
        let tile = super::super::tile::tile_from_weights(&w, 4);
        TbnzModel {
            layers: vec![
                LayerRecord {
                    name: "fc0".into(),
                    shape: vec![8, 8],
                    payload: WeightPayload::Tiled { p: 4, tile, alphas: vec![0.5, 0.6, 0.7, 0.8] },
                },
                LayerRecord {
                    name: "bw".into(),
                    shape: vec![4, 4],
                    payload: WeightPayload::Bwnn {
                        bits: BitVec::from_signs(&r.normal_vec(16, 1.0)),
                        alpha: 0.33,
                    },
                },
                LayerRecord {
                    name: "head".into(),
                    shape: vec![2, 3],
                    payload: WeightPayload::Fp(vec![1.0, -2.0, 3.0, -4.0, 5.0, -6.0]),
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let m = sample_model();
        let m2 = TbnzModel::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn storage_accounting() {
        let m = sample_model();
        // tiled: q=16 bits + 4 alphas*32; bwnn: 16 bits + 32; fp: 6*32
        assert_eq!(m.layers[0].storage_bits(), 16 + 128);
        assert_eq!(m.layers[1].storage_bits(), 16 + 32);
        assert_eq!(m.layers[2].storage_bits(), 192);
        assert_eq!(m.total_params(), 64 + 16 + 6);
    }

    #[test]
    fn sub_bit_width_for_tiled_layer() {
        let m = sample_model();
        let l = &m.layers[0];
        // 144 bits over 64 params = 2.25 (alphas dominate at this tiny size);
        // at realistic sizes the tile term dominates: check the tile-only ratio.
        assert!(l.storage_bits() < 32 * l.n());
        let tile_bits = 16.0;
        assert!(tile_bits / l.n() as f64 == 0.25); // 1/p of a bit per param
    }

    #[test]
    fn expand_tiled_layer() {
        let m = sample_model();
        let w = m.layers[0].expand();
        assert_eq!(w.len(), 64);
        // block i scaled by alphas[i]
        for (i, a) in [0.5f32, 0.6, 0.7, 0.8].iter().enumerate() {
            for j in 0..16 {
                assert!((w[i * 16 + j].abs() - a).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn corrupted_rejected() {
        let m = sample_model();
        let mut b = m.to_bytes();
        b[0] = b'X';
        assert!(TbnzModel::from_bytes(&b).is_err());
        let b2 = m.to_bytes();
        assert!(TbnzModel::from_bytes(&b2[..b2.len() - 3]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let m = sample_model();
        let path = std::env::temp_dir().join("tbnz_test.tbnz");
        let path = path.to_str().unwrap();
        m.save(path).unwrap();
        assert_eq!(TbnzModel::load(path).unwrap(), m);
        let _ = std::fs::remove_file(path);
    }
}
