//! Packed-vs-reference engine parity (artifact-free).
//!
//! The packed path computes the quantized deployment forward with XNOR +
//! popcount; the reference path computes the *same math* in plain f32
//! (`MlpEngine::forward_quantized` on a `Reference` engine).  These tests
//! pin the two against each other across randomized model configurations:
//! tile sizes, layer widths including non-multiple-of-64 values, alpha
//! modes, and mixed tiled/bwnn/fp chains — and pin the **tile-resident**
//! weight layout (one `q`-bit tile resident per layer, row dots as
//! shift-stitched offsets into it) bit-exactly against the **expanded**
//! layout across the same configurations, batched and single-sample.
//!
//! Tolerance vs the oracle: the packed path accumulates exact integer dots
//! per alpha run while the oracle accumulates elementwise f32, so values
//! differ by f32 rounding.  A sign tie-break (an activation within rounding
//! distance of zero binarizing differently) can additionally knock out
//! individual outputs, so a small outlier budget is allowed per
//! configuration.  The two packed layouts accumulate identical exact dots
//! in identical order, so their comparison is `assert_eq!` — no tolerance.
//!
//! Packed engines built "at the default layout" go through
//! `PackedLayout::from_env()`, so the CI matrix re-runs this suite under
//! `TBN_LAYOUT=expanded` to gate both layouts end to end.

use tiledbits::nn::{EnginePath, MlpEngine, Nonlin, PackedLayout};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::tensor::BitVec;
use tiledbits::util::Rng;

/// Layer widths drawn from a pool that straddles the 64-bit word size.
const DIMS: [usize; 9] = [5, 17, 33, 48, 64, 65, 100, 128, 130];

fn random_layer(rng: &mut Rng, name: &str, m: usize, n: usize) -> LayerRecord {
    let w = rng.normal_vec(m * n, 1.0);
    let payload = match rng.below(4) {
        // tiled dominates the draw: it is the payload under test
        0 | 1 => {
            let total = m * n;
            let mut p = [2usize, 4, 8][rng.below(3)];
            while total % p != 0 && p > 1 {
                p /= 2;
            }
            let mode = if rng.below(2) == 0 { AlphaMode::Single } else { AlphaMode::PerTile };
            WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, mode),
            }
        }
        2 => WeightPayload::Bwnn {
            bits: BitVec::from_signs(&w),
            alpha: 0.05 + rng.next_f32(),
        },
        _ => WeightPayload::Fp(w),
    };
    LayerRecord { name: name.into(), shape: vec![m, n], payload }
}

fn random_model(rng: &mut Rng) -> TbnzModel {
    let n_layers = 1 + rng.below(4);
    let mut dims = Vec::with_capacity(n_layers + 1);
    for _ in 0..=n_layers {
        dims.push(DIMS[rng.below(DIMS.len())]);
    }
    let layers = (0..n_layers)
        .map(|i| random_layer(rng, &format!("l{i}"), dims[i + 1], dims[i]))
        .collect();
    TbnzModel { layers }
}

/// Compare outputs with an f32 tolerance and a small sign-tie outlier budget.
fn assert_close(a: &[f32], b: &[f32], allowed_outliers: usize, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    let scale = a
        .iter()
        .chain(b.iter())
        .fold(1.0f32, |m, v| m.max(v.abs()));
    let tol = 1e-3 * scale;
    let bad: Vec<String> = (0..a.len())
        .filter(|&i| (a[i] - b[i]).abs() > tol)
        .map(|i| format!("[{i}] {} vs {}", a[i], b[i]))
        .collect();
    assert!(bad.len() <= allowed_outliers,
            "{ctx}: {}/{} outputs beyond tol {tol}: {}",
            bad.len(), a.len(), bad.join(", "));
}

#[test]
fn packed_matches_reference_across_random_configs() {
    let mut configs = 0usize;
    for case in 0..24u64 {
        let mut rng = Rng::new(0xA11CE ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let model = random_model(&mut rng);
        let ctx = format!(
            "case {case}: dims {:?}",
            model.layers.iter().map(|l| l.shape.clone()).collect::<Vec<_>>()
        );
        let reference =
            MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
        let packed = MlpEngine::with_path_layout(model, Nonlin::Relu, EnginePath::Packed,
                                                 PackedLayout::from_env())
            .unwrap();
        let out_budget = 1 + packed.out_dim() / 50; // sign-tie outlier budget
        for s in 0..4 {
            let x = rng.normal_vec(reference.in_dim(), 1.0);
            let a = reference.forward_quantized(&x);
            let b = packed.forward(&x);
            assert_close(&a, &b, out_budget, &format!("{ctx} sample {s}"));
        }
        configs += 1;
    }
    assert!(configs >= 20, "parity must cover at least 20 configurations");
}

#[test]
fn packed_matches_reference_without_relu() {
    for case in 0..6u64 {
        let mut rng = Rng::new(0xBEE5 ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let model = random_model(&mut rng);
        let reference =
            MlpEngine::with_path(model.clone(), Nonlin::None, EnginePath::Reference).unwrap();
        let packed = MlpEngine::with_path_layout(model, Nonlin::None, EnginePath::Packed,
                                                 PackedLayout::from_env())
            .unwrap();
        let x = rng.normal_vec(reference.in_dim(), 1.0);
        let budget = 1 + packed.out_dim() / 50;
        assert_close(&reference.forward_quantized(&x), &packed.forward(&x), budget,
                     &format!("nonlin-none case {case}"));
    }
}

/// Non-multiple-of-64 widths, q not a multiple of n: alpha runs split
/// mid-row and the last packed word is partial — the two hard layout cases.
#[test]
fn packed_handles_ragged_widths_and_split_alpha_runs() {
    let mut rng = Rng::new(4242);
    // m*n = 70*33 = 2310 = 2 * 3 * 5 * 7 * 11; p = 2 gives q = 1155 (q % 33 = 0
    // is false for p = 5: q = 462, 462 % 33 = 0 ... choose p values that
    // divide the layer but leave q % n != 0)
    let w = rng.normal_vec(70 * 33, 1.0);
    let layer0 = LayerRecord {
        name: "fc0".into(),
        shape: vec![70, 33],
        payload: WeightPayload::Tiled {
            p: 7,
            tile: tile_from_weights(&w, 7), // q = 330, 330 % 33 == 0? 330/33=10 — yes;
            // mid-row splits still occur on rows whose start is not q-aligned
            alphas: alphas_from(&w, 7, AlphaMode::PerTile),
        },
    };
    let w1 = rng.normal_vec(13 * 70, 1.0);
    let layer1 = LayerRecord {
        name: "head".into(),
        shape: vec![13, 70],
        payload: WeightPayload::Tiled {
            p: 5,
            tile: tile_from_weights(&w1, 5), // q = 182, 182 % 70 = 42 -> splits
            alphas: alphas_from(&w1, 5, AlphaMode::PerTile),
        },
    };
    let model = TbnzModel { layers: vec![layer0, layer1] };
    let reference =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let packed = MlpEngine::with_path_layout(model, Nonlin::Relu, EnginePath::Packed,
                                             PackedLayout::from_env())
        .unwrap();
    for s in 0..8 {
        let mut r = Rng::new(900 + s);
        let x = r.normal_vec(33, 1.0);
        assert_close(&reference.forward_quantized(&x), &packed.forward(&x), 1,
                     &format!("ragged sample {s}"));
    }
}

#[test]
fn packed_batch_equals_packed_single() {
    let mut rng = Rng::new(77);
    let model = random_model(&mut rng);
    for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
        let packed = MlpEngine::with_path_layout(
            model.clone(), Nonlin::Relu, EnginePath::Packed, layout).unwrap();
        let xs: Vec<Vec<f32>> =
            (0..7).map(|_| rng.normal_vec(packed.in_dim(), 1.0)).collect();
        let batch = packed.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&packed.forward(x), y,
                       "{layout:?}: batch and single-sample paths must be bit-equal");
        }
    }
}

/// The tile-resident layout is bit-exact against the expanded layout across
/// randomized (m, n, q) model configurations — both walk the same
/// constant-alpha runs and accumulate the same exact integer dots in the
/// same order — for single samples and batches alike.
#[test]
fn tile_resident_layout_matches_expanded_across_random_configs() {
    let mut configs = 0usize;
    for case in 0..16u64 {
        let mut rng = Rng::new(0x711E ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let model = random_model(&mut rng);
        let ctx = format!(
            "case {case}: dims {:?}",
            model.layers.iter().map(|l| l.shape.clone()).collect::<Vec<_>>()
        );
        let tile = MlpEngine::with_path_layout(
            model.clone(), Nonlin::Relu, EnginePath::Packed,
            PackedLayout::TileResident).unwrap();
        let expanded = MlpEngine::with_path_layout(
            model, Nonlin::Relu, EnginePath::Packed, PackedLayout::Expanded).unwrap();
        // a tiled layer after the first makes the layouts differ in state;
        // either way the outputs must agree exactly
        assert!(tile.resident_weight_bytes() <= expanded.resident_weight_bytes(),
                "{ctx}: tile residency above expanded");
        for s in 0..3 {
            let x = rng.normal_vec(tile.in_dim(), 1.0);
            assert_eq!(tile.forward(&x), expanded.forward(&x), "{ctx} sample {s}");
        }
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| rng.normal_vec(tile.in_dim(), 1.0)).collect();
        assert_eq!(tile.forward_batch(&xs), expanded.forward_batch(&xs),
                   "{ctx} batched");
        configs += 1;
    }
    assert!(configs >= 16);
}

/// Shift-stitched hard case: ragged widths (n % 64 != 0) with tile lengths
/// that are not multiples of 64 either, so every row dot on the
/// tile-resident layout runs at a misaligned tile phase.
#[test]
fn tile_resident_handles_shift_stitched_phases() {
    let mut rng = Rng::new(9191);
    let w0 = rng.normal_vec(54 * 70, 1.0);
    let w1 = rng.normal_vec(27 * 54, 1.0);
    let model = TbnzModel {
        layers: vec![
            LayerRecord {
                name: "fc0".into(),
                shape: vec![54, 70],
                payload: WeightPayload::Tiled {
                    p: 4, // q = 945, 945 % 64 = 49
                    tile: tile_from_weights(&w0, 4),
                    alphas: alphas_from(&w0, 4, AlphaMode::PerTile),
                },
            },
            LayerRecord {
                name: "head".into(),
                shape: vec![27, 54],
                payload: WeightPayload::Tiled {
                    p: 6, // q = 243, 243 % 54 = 27 -> mid-row alpha splits
                    tile: tile_from_weights(&w1, 6),
                    alphas: alphas_from(&w1, 6, AlphaMode::PerTile),
                },
            },
        ],
    };
    let reference =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let tile = MlpEngine::with_path_layout(
        model.clone(), Nonlin::Relu, EnginePath::Packed,
        PackedLayout::TileResident).unwrap();
    let expanded = MlpEngine::with_path_layout(
        model, Nonlin::Relu, EnginePath::Packed, PackedLayout::Expanded).unwrap();
    for s in 0..8 {
        let mut r = Rng::new(3300 + s);
        let x = r.normal_vec(70, 1.0);
        assert_eq!(tile.forward(&x), expanded.forward(&x), "layout sample {s}");
        assert_close(&reference.forward_quantized(&x), &tile.forward(&x), 1,
                     &format!("oracle sample {s}"));
    }
}

#[test]
fn classify_agrees_between_paths_on_separable_inputs() {
    // On a trained-looking model with clear margins, the quantized forward's
    // argmax should agree between paths for nearly every sample.
    let mut rng = Rng::new(31337);
    let model = TbnzModel {
        layers: vec![
            random_layer(&mut rng, "fc0", 64, 100),
            random_layer(&mut rng, "fc1", 48, 64),
            random_layer(&mut rng, "head", 10, 48),
        ],
    };
    let reference =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let packed = MlpEngine::with_path_layout(model, Nonlin::Relu, EnginePath::Packed,
                                             PackedLayout::from_env())
        .unwrap();
    let n = 64;
    let mut agree = 0usize;
    for _ in 0..n {
        let x = rng.normal_vec(100, 1.0);
        let a = reference.forward_quantized(&x);
        let b = packed.forward(&x);
        let am = a.iter().enumerate().max_by(|u, v| u.1.partial_cmp(v.1).unwrap()).unwrap().0;
        let bm = b.iter().enumerate().max_by(|u, v| u.1.partial_cmp(v.1).unwrap()).unwrap().0;
        if am == bm {
            agree += 1;
        }
    }
    assert!(agree as f64 / n as f64 >= 0.95, "argmax agreement {agree}/{n}");
}
