//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repository builds in has no crates.io access, so the
//! workspace vendors the small subset of `anyhow` it actually uses: the
//! string-backed [`Error`] type, the [`Result`] alias, the [`anyhow!`] /
//! [`bail!`] macros, and the [`Context`] extension trait for `Result` and
//! `Option`.  Semantics match the real crate for this subset, except that
//! source-error chains are flattened into the message eagerly.

use std::fmt;

/// A string-backed error. Like `anyhow::Error`, it deliberately does *not*
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`] but still allows
/// `Result<T, OtherError>` spellings.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error (`anyhow::Context` subset).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or a format
/// string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let owned = String::from("owned message");
        let b = anyhow!(owned);
        assert_eq!(b.to_string(), "owned message");
        let c = anyhow!("x = {}, y = {y}", 1, y = 2);
        assert_eq!(c.to_string(), "x = 1, y = 2");
    }

    #[test]
    fn bail_returns_err() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");

        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn debug_and_alternate_display() {
        let e = anyhow!("msg");
        assert_eq!(format!("{e:?}"), "msg");
        assert_eq!(format!("{e:#}"), "msg");
    }
}
