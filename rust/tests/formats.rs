//! Format/contract tests: the real experiment config parses and is
//! internally consistent with the Rust-side policy mirror; TBNZ files
//! survive disk round-trips; run records round-trip.

use tiledbits::config::Manifest;
use tiledbits::coordinator::RunRecord;
use tiledbits::tbn::{decide, Quant, TilingPolicy};
use tiledbits::util::{locate_upwards, Json};

/// The experiment grid is committed at the repository root; tests run with
/// the crate root as cwd, so resolve it upward.
fn config_path() -> String {
    locate_upwards("configs/experiments.json")
        .expect("configs/experiments.json must exist (committed config)")
}

#[test]
fn experiments_config_parses() {
    let j = Json::parse_file(&config_path()).expect("configs/experiments.json must parse");
    let exps = j.get("experiments").and_then(Json::as_arr).expect("experiments array");
    assert!(exps.len() >= 40, "expected a full experiment grid, got {}", exps.len());
    let mut ids = std::collections::HashSet::new();
    for e in exps {
        let id = e.str_or("id", "");
        assert!(!id.is_empty());
        assert!(ids.insert(id.to_string()), "duplicate id {id}");
        assert!(e.get("tables").is_some(), "{id}: unmapped to any table");
        let tiling = e.get("tiling").expect("tiling");
        let mode = tiling.str_or("mode", "");
        assert!(["fp", "bwnn", "tbn"].contains(&mode), "{id}: bad mode {mode}");
        if mode == "tbn" {
            assert!(tiling.usize_or("p", 0) >= 2, "{id}: tbn needs p >= 2");
        }
    }
}

#[test]
fn config_covers_every_table_and_figure() {
    let j = Json::parse_file(&config_path()).unwrap();
    let exps = j.get("experiments").and_then(Json::as_arr).unwrap();
    let mut covered = std::collections::HashSet::new();
    for e in exps {
        for t in e.get("tables").and_then(Json::as_arr).unwrap_or(&[]) {
            covered.insert(t.as_str().unwrap_or("").to_string());
        }
    }
    // tables with trained experiments behind them (T2/T7/F2/F5 are analytic)
    for t in ["T1", "T3", "T4", "T5", "T6", "F6", "F7", "F8"] {
        assert!(covered.contains(t), "no experiment covers {t}");
    }
}

#[test]
fn manifest_matches_config_when_built() {
    let Some(artifacts) = locate_upwards("artifacts") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let Ok(manifest) = Manifest::load(&artifacts) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let j = Json::parse_file(&config_path()).unwrap();
    let exps = j.get("experiments").and_then(Json::as_arr).unwrap();
    assert_eq!(manifest.experiments.len(), exps.len());
    for e in &manifest.experiments {
        // every graph file must exist
        for (name, file) in &e.graph_files {
            let path = format!("{artifacts}/{file}");
            assert!(std::path::Path::new(&path).exists(), "{}: missing {name} ({path})", e.id);
        }
        // param table consistency
        for p in &e.params {
            if p.quant == "tiled" {
                assert_eq!(p.p * p.q, p.n(), "{}: {}", e.id, p.name);
                assert!(p.n_alphas == 1 || p.n_alphas == p.p);
            }
        }
        // Rust policy mirror agrees with the Python-decided quant for
        // weight params
        for p in e.params.iter().filter(|p| p.role == "weight") {
            let want = match p.quant.as_str() {
                "tiled" => Quant::Tiled { p: e.tiling.p },
                "bwnn" => Quant::Bwnn,
                _ => Quant::Fp,
            };
            assert_eq!(decide(&e.tiling, p.n()), want,
                       "{}: {} ({} elems)", e.id, p.name, p.n());
        }
        // infer params: A never ships; every tile has alphas
        let names: Vec<&str> = e.infer_params.iter().map(|ip| ip.name.as_str()).collect();
        assert!(!names.iter().any(|n| n.ends_with(".A")), "{}: A leaked", e.id);
        for ip in &e.infer_params {
            if ip.kind == "tile" {
                let alpha_name = format!("{}.alphas", ip.source);
                assert!(names.contains(&alpha_name.as_str()), "{}: {} missing alphas",
                        e.id, ip.source);
            }
        }
    }
}

#[test]
fn policy_decisions_cover_config_lambdas() {
    // every tbn config in the file produces at least one tiled decision on
    // a layer the size of its model family's biggest layer
    let j = Json::parse_file(&config_path()).unwrap();
    for e in j.get("experiments").and_then(Json::as_arr).unwrap() {
        let t = e.get("tiling").unwrap();
        if t.str_or("mode", "") != "tbn" {
            continue;
        }
        let policy = TilingPolicy::tbn(t.usize_or("p", 4), t.usize_or("lambda", 0));
        // a comfortably-large layer must tile
        let big = (policy.lambda.max(1)) * policy.p;
        assert_eq!(decide(&policy, big * policy.p), Quant::Tiled { p: policy.p },
                   "{}", e.str_or("id", "?"));
    }
}

#[test]
fn run_record_roundtrip() {
    let rec = RunRecord {
        id: "x".into(),
        steps: 100,
        loss: 0.5,
        metric: 0.91,
        class_iou: Some(0.4),
        instance_iou: None,
        bit_width: 0.26,
        storage_bits: 1234,
        total_params: 4000,
        duration_s: 1.5,
        forward_agreement: 0.99,
        eval_curve: vec![(50, 0.7, 0.8), (100, 0.5, 0.91)],
        train_curve: vec![(0, 2.3), (50, 1.0)],
    };
    let dir = std::env::temp_dir().join("tbn_fmt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("x.json");
    rec.save(path.to_str().unwrap()).unwrap();
    let rt = RunRecord::load(path.to_str().unwrap()).unwrap();
    assert_eq!(rt.id, "x");
    assert_eq!(rt.steps, 100);
    assert!((rt.metric - 0.91).abs() < 1e-9);
    assert_eq!(rt.class_iou, Some(0.4));
    assert_eq!(rt.instance_iou, None);
    assert_eq!(rt.eval_curve.len(), 2);
    assert_eq!(rt.train_curve[1], (50, 1.0));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tbnz_file_roundtrip_through_disk() {
    use tiledbits::tbn::{tile_from_weights, LayerRecord, TbnzModel, WeightPayload};
    use tiledbits::util::Rng;
    let mut rng = Rng::new(77);
    let w = rng.normal_vec(256, 1.0);
    let model = TbnzModel {
        layers: vec![LayerRecord {
            name: "only".into(),
            shape: vec![16, 16],
            payload: WeightPayload::Tiled {
                p: 4,
                tile: tile_from_weights(&w, 4),
                alphas: vec![0.1, 0.2, 0.3, 0.4],
            },
        }],
    };
    let path = std::env::temp_dir().join("fmt_roundtrip.tbnz");
    let path = path.to_str().unwrap();
    model.save(path).unwrap();
    assert_eq!(TbnzModel::load(path).unwrap(), model);
    let _ = std::fs::remove_file(path);
}
