//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Pipeline exercised (no Python anywhere on this path):
//!   1. load the AOT artifacts (Layer 2 JAX graphs with the Layer 1 Pallas
//!      tile-reuse kernel lowered into the forward graph);
//!   2. train a Tiled Bit Network on a synthetic classification set, with
//!      the Rust coordinator driving the PJRT train_step graph;
//!   3. evaluate, export the sub-bit TBNZ model, and verify the exported
//!      tiles through the forward graph;
//!   4. run the native Algorithm 1 engine on the same model and serve a few
//!      requests through the dynamic batcher.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use anyhow::{anyhow, Result};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_experiment;
use tiledbits::nn::{MlpEngine, Nonlin};
use tiledbits::runtime::Runtime;
use tiledbits::serve::{BatchPolicy, Server};
use tiledbits::train::{export, Trainer, TrainOptions};
use tiledbits::util::human_bytes;

fn main() -> Result<()> {
    let artifacts = std::env::var("TBN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("TBN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(300);

    println!("== tiledbits quickstart ==");
    let manifest = Manifest::load(&artifacts)
        .map_err(|e| anyhow!("{e}\nhint: run `make artifacts` first"))?;
    let rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());

    // ---- 1+2: train a TBN (p=4) on the synthetic MNIST stand-in ----------
    let id = "mlp_micro_tbn4";
    let exp = manifest.by_id(id).ok_or_else(|| anyhow!("missing {id}"))?;
    println!("\n[1/4] training {id} for {steps} steps (p={}, lambda={})",
             exp.tiling.p, exp.tiling.lambda);
    let opts = TrainOptions { steps: Some(steps), eval_every: steps / 4,
                              log_every: 50, seed: None };
    let rec = run_experiment(&rt, exp, &opts)?;
    println!("      final test accuracy {:.2}%  (loss {:.4})",
             100.0 * rec.metric, rec.loss);
    println!("      forward-graph verification: {:.1}% prediction agreement",
             100.0 * rec.forward_agreement);

    // ---- 3: export the sub-bit model --------------------------------------
    println!("\n[2/4] exporting TBNZ (sub-bit serialized model)");
    let trainer = Trainer::new(&rt, exp)?;
    let (_, model) = trainer.run(&TrainOptions {
        steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None })?;
    let tbnz = export::to_tbnz(exp, &model)?;
    let (params, bits, bw) = export::export_summary(&tbnz);
    println!("      {params} params -> {} on disk ({bw:.3} bits/param, {:.1}x vs 1-bit)",
             human_bytes(bits as f64 / 8.0), 1.0 / bw);
    let out = "runs/quickstart.tbnz";
    std::fs::create_dir_all("runs").ok();
    tbnz.save(out)?;
    println!("      wrote {out}");

    // ---- 4a: native engine (Algorithm 1) ----------------------------------
    println!("\n[3/4] native Algorithm 1 engine");
    let engine = MlpEngine::new(tbnz, Nonlin::Relu).map_err(|e| anyhow!(e))?;
    println!("      peak memory {}  storage {}",
             human_bytes(engine.peak_memory_bytes() as f64),
             human_bytes(engine.storage_bytes() as f64));
    let d = trainer.test_ds.x_elems;
    let fps = engine.measure_fps(&trainer.test_ds.x[..d], 500);
    println!("      {fps:.0} frames/sec (single core)");

    // ---- 4b: serving stack -------------------------------------------------
    println!("\n[4/4] serving through the dynamic batcher");
    let server = Server::start(engine, BatchPolicy::default());
    let n = 64;
    for i in 0..n {
        let x = trainer.test_ds.x[i * d..(i + 1) * d].to_vec();
        server.infer(x).map_err(|e| anyhow!(e))?;
    }
    let stats = server.stats();
    println!("      served {} requests, mean latency {:.0}us, mean batch {:.2}",
             stats.served, stats.mean_latency_us(), stats.mean_batch());
    println!("\nquickstart OK");
    Ok(())
}
