//! Figure 7: hyperparameter configurations — test accuracy across training
//! for (a) global tiling vs lambda, (b) W vs W+A alpha source, (c) single vs
//! per-tile alphas, on both ResNet-mini and MLPMixer-mini.

use tiledbits::bench_util::{bench_dirs, bench_steps, header};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_or_load;
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;

fn curve(rec: &tiledbits::coordinator::RunRecord) -> String {
    rec.eval_curve
        .iter()
        .map(|(s, _, m)| format!("{s}:{:.2}", m))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    header("Figure 7: hyperparameter configurations across training");
    let (artifacts, runs) = bench_dirs();
    let steps = bench_steps(80);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("(artifacts not built; skipping)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");
    let opts = TrainOptions {
        steps: Some(steps),
        eval_every: (steps / 4).max(1),
        log_every: 10_000,
        seed: None,
    };

    for family in ["resnet_mini", "mlpmixer"] {
        println!("\n-- {family} ({steps} steps; eval curve as step:acc) --");
        let variants = [
            ("tbn4", "default (lambda, W+A, multi-alpha)"),
            ("tbn4_global", "global tiling (lambda=0)"),
            ("tbn4_wonly", "W for alphas (no A)"),
            ("tbn4_single_alpha", "single alpha per layer"),
        ];
        for (suffix, label) in variants {
            let id = format!("{family}_{suffix}");
            if manifest.by_id(&id).is_none() {
                continue;
            }
            match run_or_load(&rt, &manifest, &id, &opts, &runs) {
                Ok(rec) => println!("{label:36} final {:5.1}%  [{}]",
                                    100.0 * rec.metric, curve(&rec)),
                Err(e) => println!("{label:36} FAILED: {e:#}"),
            }
        }
    }
    println!("\nshape check (paper Fig 7/8): global tiling is the clear loser;");
    println!("W+A and multi-alpha give small gains over W-only / single-alpha.");
}
