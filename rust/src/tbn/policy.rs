//! The λ minimum-layer-size tiling policy (paper §3, Hyperparameter Settings)
//! — the Rust mirror of `compile.layers.SpecBuilder`'s decision rule.

use super::alpha::AlphaMode;

/// Per-layer quantization decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Quant {
    /// Tiled with compression factor p (sub-bit).
    Tiled { p: usize },
    /// 1-bit binary weights with a single alpha (BWNN baseline).
    Bwnn,
    /// Full precision (layer too small, indivisible, or fp mode).
    Fp,
}

/// Experiment-wide tiling policy.
#[derive(Debug, Clone)]
pub struct TilingPolicy {
    pub mode: String, // "fp" | "bwnn" | "tbn"
    pub p: usize,
    pub lambda: usize,
    pub alpha: AlphaMode,
    pub alpha_src_a: bool, // true: independent A; false: reuse W
}

impl TilingPolicy {
    pub fn fp() -> TilingPolicy {
        TilingPolicy { mode: "fp".into(), p: 1, lambda: 0,
                       alpha: AlphaMode::Single, alpha_src_a: false }
    }

    pub fn tbn(p: usize, lambda: usize) -> TilingPolicy {
        TilingPolicy { mode: "tbn".into(), p, lambda,
                       alpha: AlphaMode::PerTile, alpha_src_a: true }
    }

    pub fn bwnn(lambda: usize) -> TilingPolicy {
        TilingPolicy { mode: "bwnn".into(), p: 1, lambda,
                       alpha: AlphaMode::Single, alpha_src_a: false }
    }
}

/// Decide the quantization of a weight layer with `n` elements.
///
/// Identical to the Python SpecBuilder: in tbn mode a layer tiles iff
/// `n >= lambda` and `p | n`, and otherwise falls back to **1-bit binary**
/// (TBNs are built on binary-weight models — the paper's Table 6 stores the
/// untiled classification head at 1 bit, and the Table 1/4 bit-width columns
/// only reproduce under this rule).  bwnn mode binarizes every weight layer.
pub fn decide(policy: &TilingPolicy, n: usize) -> Quant {
    match policy.mode.as_str() {
        "tbn" if n >= policy.lambda && policy.p > 0 && n % policy.p == 0 => {
            Quant::Tiled { p: policy.p }
        }
        "tbn" | "bwnn" => Quant::Bwnn,
        _ => Quant::Fp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tbn_tiles_large_divisible() {
        let p = TilingPolicy::tbn(4, 1000);
        assert_eq!(decide(&p, 4096), Quant::Tiled { p: 4 });
    }

    #[test]
    fn lambda_small_falls_back_to_binary() {
        let p = TilingPolicy::tbn(4, 10_000);
        assert_eq!(decide(&p, 4096), Quant::Bwnn);
    }

    #[test]
    fn indivisible_falls_back_to_binary() {
        let p = TilingPolicy::tbn(4, 1);
        assert_eq!(decide(&p, 27), Quant::Bwnn);
    }

    #[test]
    fn global_tiling_lambda_zero() {
        let p = TilingPolicy::tbn(4, 0);
        assert_eq!(decide(&p, 8), Quant::Tiled { p: 4 });
    }

    #[test]
    fn bwnn_binarizes_everything() {
        let p = TilingPolicy::bwnn(100);
        assert_eq!(decide(&p, 1024), Quant::Bwnn);
        assert_eq!(decide(&p, 16), Quant::Bwnn);
    }

    #[test]
    fn fp_mode_never_quantizes() {
        let p = TilingPolicy::fp();
        assert_eq!(decide(&p, 1 << 20), Quant::Fp);
    }
}
