//! Synthetic dataset substrates (DESIGN.md §7 substitutions).
//!
//! The paper evaluates on CIFAR-10/ImageNet, ModelNet40/ShapeNet/S3DIS and
//! the ECL/Weather series — none of which are available offline.  Each
//! generator below produces a *class-structured* synthetic stand-in that
//! exercises the identical train/compress path: tunable separability so the
//! FP ≥ TBN_p ordering and the degradation-with-p trends are observable.
//!
//! Generation is fully deterministic in (kind, seed); train/test splits use
//! disjoint RNG streams of the same distribution.

mod images;
mod pointcloud;
mod timeseries;

use crate::util::Rng;

/// Task family (decides which label buffer is populated).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Cls,
    Seg,
    Forecast,
}

/// An in-memory dataset: flattened row-major samples plus labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    /// Per-sample input element count (prod of the input shape).
    pub x_elems: usize,
    /// Flattened inputs, length n * x_elems.
    pub x: Vec<f32>,
    /// Integer labels: len n (cls) or n * points (seg); empty for forecast.
    pub y_int: Vec<i32>,
    /// Float targets: len n * channels for forecasting; empty otherwise.
    pub y_float: Vec<f32>,
    /// Per-sample float-target width (forecast channels), 0 otherwise.
    pub y_elems: usize,
    /// Per-sample int-label width (1 for cls, points for seg).
    pub y_int_elems: usize,
    pub task: Task,
}

impl Dataset {
    /// Gather a batch by indices into contiguous buffers.
    pub fn gather(&self, idxs: &[usize]) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut x = Vec::with_capacity(idxs.len() * self.x_elems);
        let mut yi = Vec::with_capacity(idxs.len() * self.y_int_elems);
        let mut yf = Vec::with_capacity(idxs.len() * self.y_elems);
        for &i in idxs {
            debug_assert!(i < self.n);
            x.extend_from_slice(&self.x[i * self.x_elems..(i + 1) * self.x_elems]);
            if self.y_int_elems > 0 && !self.y_int.is_empty() {
                yi.extend_from_slice(
                    &self.y_int[i * self.y_int_elems..(i + 1) * self.y_int_elems]);
            }
            if self.y_elems > 0 && !self.y_float.is_empty() {
                yf.extend_from_slice(&self.y_float[i * self.y_elems..(i + 1) * self.y_elems]);
            }
        }
        (x, yi, yf)
    }
}

/// Deterministic epoch shuffler: yields batches of exactly `batch` indices
/// (the trailing partial batch is dropped — graphs have static batch dims).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> BatchIter {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        BatchIter { order, batch, pos: 0 }
    }
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let b = self.order[self.pos..self.pos + self.batch].to_vec();
        self.pos += self.batch;
        Some(b)
    }
}

/// Generate a dataset by config kind. `input` is the per-sample shape from
/// the manifest (e.g. [3,16,16], [128,3], [48,32]).
pub fn generate(kind: &str, input: &[usize], classes: usize, n: usize,
                seed: u64) -> Result<Dataset, String> {
    let mut rng = Rng::new(seed ^ 0xD47A5E7);
    match kind {
        "synth_mnist" => Ok(images::synth_mnist(input, classes, n, &mut rng)),
        "synth_cifar" => Ok(images::synth_cifar(input, classes, n, &mut rng)),
        "synth_modelnet" => Ok(pointcloud::synth_modelnet(input, classes, n, &mut rng)),
        "synth_shapenet" => Ok(pointcloud::synth_shapenet(input, classes, n, &mut rng)),
        "synth_electricity" => Ok(timeseries::synth_series(input, n, &mut rng, 0.25)),
        "synth_weather" => Ok(timeseries::synth_series(input, n, &mut rng, 0.1)),
        k => Err(format!("unknown dataset kind {k:?}")),
    }
}

/// Train/test pair with disjoint streams.
pub fn generate_split(kind: &str, input: &[usize], classes: usize,
                      n_train: usize, n_test: usize, seed: u64)
                      -> Result<(Dataset, Dataset), String> {
    let train = generate(kind, input, classes, n_train, seed.wrapping_mul(2).wrapping_add(1))?;
    let test = generate(kind, input, classes, n_test, seed.wrapping_mul(2).wrapping_add(2))?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kinds_generate() {
        let cases: [(&str, Vec<usize>, usize); 6] = [
            ("synth_mnist", vec![256], 10),
            ("synth_cifar", vec![3, 16, 16], 10),
            ("synth_modelnet", vec![64, 3], 8),
            ("synth_shapenet", vec![64, 3], 4),
            ("synth_electricity", vec![48, 32], 0),
            ("synth_weather", vec![48, 8], 0),
        ];
        for (kind, input, classes) in cases {
            let d = generate(kind, &input, classes, 32, 7).unwrap();
            assert_eq!(d.n, 32, "{kind}");
            assert_eq!(d.x.len(), 32 * d.x_elems, "{kind}");
            assert!(d.x.iter().all(|v| v.is_finite()), "{kind}");
            match d.task {
                Task::Cls => {
                    assert_eq!(d.y_int.len(), 32);
                    assert!(d.y_int.iter().all(|&y| (y as usize) < classes));
                }
                Task::Seg => {
                    assert_eq!(d.y_int.len(), 32 * d.y_int_elems);
                    assert!(d.y_int.iter().all(|&y| (y as usize) < classes));
                }
                Task::Forecast => {
                    assert_eq!(d.y_float.len(), 32 * d.y_elems);
                }
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate("synth_cifar", &[3, 16, 16], 10, 16, 5).unwrap();
        let b = generate("synth_cifar", &[3, 16, 16], 10, 16, 5).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y_int, b.y_int);
        let c = generate("synth_cifar", &[3, 16, 16], 10, 16, 6).unwrap();
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn split_streams_disjoint() {
        let (tr, te) = generate_split("synth_mnist", &[256], 10, 64, 32, 1).unwrap();
        assert_eq!(tr.n, 64);
        assert_eq!(te.n, 32);
        assert_ne!(&tr.x[..256], &te.x[..256]);
    }

    #[test]
    fn classes_are_balancedish() {
        let d = generate("synth_cifar", &[3, 16, 16], 10, 1000, 3).unwrap();
        let mut counts = [0usize; 10];
        for &y in &d.y_int {
            counts[y as usize] += 1;
        }
        for c in counts {
            assert!(c > 50, "class count {c} too low: {counts:?}");
        }
    }

    #[test]
    fn batch_iter_exact_batches_no_dups() {
        let mut rng = Rng::new(1);
        let it = BatchIter::new(100, 32, &mut rng);
        let batches: Vec<_> = it.collect();
        assert_eq!(batches.len(), 3); // 100/32 -> 3 full batches
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            assert_eq!(b.len(), 32);
            for &i in b {
                assert!(seen.insert(i), "duplicate index {i}");
            }
        }
    }

    #[test]
    fn gather_layout() {
        let d = generate("synth_mnist", &[256], 10, 8, 2).unwrap();
        let (x, yi, _) = d.gather(&[3, 1]);
        assert_eq!(x.len(), 2 * 256);
        assert_eq!(&x[..256], &d.x[3 * 256..4 * 256]);
        assert_eq!(yi, vec![d.y_int[3], d.y_int[1]]);
    }

    /// Separability sanity: a nearest-class-mean classifier must beat chance
    /// comfortably on the classification sets (they're meant to be learnable).
    #[test]
    fn images_are_separable() {
        for kind in ["synth_mnist", "synth_cifar"] {
            let input: Vec<usize> = if kind == "synth_mnist" { vec![256] } else { vec![3, 16, 16] };
            let (tr, te) = generate_split(kind, &input, 10, 512, 256, 9).unwrap();
            let d = tr.x_elems;
            let mut means = vec![vec![0.0f64; d]; 10];
            let mut counts = [0usize; 10];
            for i in 0..tr.n {
                let c = tr.y_int[i] as usize;
                counts[c] += 1;
                for j in 0..d {
                    means[c][j] += tr.x[i * d + j] as f64;
                }
            }
            for c in 0..10 {
                for j in 0..d {
                    means[c][j] /= counts[c].max(1) as f64;
                }
            }
            let mut correct = 0;
            for i in 0..te.n {
                let xs = &te.x[i * d..(i + 1) * d];
                let best = (0..10)
                    .min_by(|&a, &b| {
                        let da: f64 = xs.iter().zip(&means[a])
                            .map(|(x, m)| (*x as f64 - m).powi(2)).sum();
                        let db: f64 = xs.iter().zip(&means[b])
                            .map(|(x, m)| (*x as f64 - m).powi(2)).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                if best == te.y_int[i] as usize {
                    correct += 1;
                }
            }
            let acc = correct as f64 / te.n as f64;
            assert!(acc > 0.5, "{kind}: NCM accuracy {acc} too low");
        }
    }
}
