//! Core TBN library: the paper's method (Eqs. 1-9) as host-side Rust, plus
//! the sub-bit model format, compression/bit-ops accounting and the
//! inference memory model.
//!
//! Semantics are byte-for-byte aligned with `python/compile/kernels/ref.py`
//! (the canonical oracle) and verified against it through the exported-model
//! parity tests in `rust/tests/native_parity.rs`.

pub mod alpha;
pub mod bitops;
pub mod compress;
pub mod format;
pub mod memory;
pub mod policy;
pub mod tile;

pub use alpha::{alphas_from, AlphaMode};
pub use format::{LayerRecord, TbnzModel, WeightPayload};
pub use policy::{decide, Quant, TilingPolicy};
pub use tile::{expand_tile, tile_from_weights};
