"""L1 compute kernels: Pallas tile-reuse kernels + the pure-jnp oracle."""

from . import ref
from .tile_construct import tile_alphas, tile_construct
from .tiled_matmul import tiled_matmul, vmem_bytes_tiled

__all__ = ["ref", "tiled_matmul", "tile_construct", "tile_alphas", "vmem_bytes_tiled"]
