//! Serving-stack integration: the native sub-bit engine behind the dynamic
//! batcher, fed from a real trained + exported model.

use std::sync::Arc;
use std::time::Duration;

use tiledbits::config::Manifest;
use tiledbits::nn::{MlpEngine, Nonlin};
use tiledbits::runtime::Runtime;
use tiledbits::serve::{BatchPolicy, Server};
use tiledbits::train::{export, metrics, Trainer, TrainOptions};

fn trained_engine() -> Option<(MlpEngine, Vec<Vec<f32>>, Vec<i32>)> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping serving tests: {e}");
            return None;
        }
    };
    let rt = Runtime::new("artifacts").unwrap();
    let exp = manifest.by_id("mlp_micro_tbn4").unwrap();
    let trainer = Trainer::new(&rt, exp).unwrap();
    let (_, model) = trainer
        .run(&TrainOptions { steps: Some(120), eval_every: 0, log_every: 10_000, seed: Some(4) })
        .unwrap();
    let tbnz = export::to_tbnz(exp, &model).unwrap();
    let engine = MlpEngine::new(tbnz, Nonlin::Relu).unwrap();
    let d = trainer.test_ds.x_elems;
    let n = 128.min(trainer.test_ds.n);
    let idxs: Vec<usize> = (0..n).collect();
    let (x, y, _) = trainer.test_ds.gather(&idxs);
    let xs = (0..n).map(|i| x[i * d..(i + 1) * d].to_vec()).collect();
    Some((engine, xs, y))
}

#[test]
fn served_accuracy_matches_direct_inference() {
    let Some((engine, xs, labels)) = trained_engine() else { return };
    let direct: Vec<i32> = engine.classify_batch(&xs).iter().map(|&i| i as i32).collect();
    let direct_acc = metrics::accuracy(&direct, &labels);
    assert!(direct_acc > 0.4, "trained TBN should beat chance, got {direct_acc}");

    let server = Arc::new(Server::start(engine, BatchPolicy {
        max_batch: 16,
        window: Duration::from_micros(300),
    }));
    // concurrent clients
    let mut handles = Vec::new();
    for t in 0..4 {
        let s = server.clone();
        let xs = xs.clone();
        handles.push(std::thread::spawn(move || {
            let mut preds = Vec::new();
            for i in (t..xs.len()).step_by(4) {
                let r = s.infer(xs[i].clone()).unwrap();
                let arg = r.y.iter().enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k as i32).unwrap();
                preds.push((i, arg));
            }
            preds
        }));
    }
    let mut served = vec![0i32; xs.len()];
    let mut count = 0;
    for h in handles {
        for (i, p) in h.join().unwrap() {
            served[i] = p;
            count += 1;
        }
    }
    assert_eq!(count, xs.len(), "no request may be dropped");
    assert_eq!(served, direct, "served predictions must equal direct inference");

    let stats = server.stats();
    assert_eq!(stats.served, xs.len());
    assert!(stats.mean_batch() >= 1.0);
    assert!(stats.mean_latency_us() > 0.0);
}

#[test]
fn throughput_improves_with_batching_pressure() {
    let Some((engine, xs, _)) = trained_engine() else { return };
    let server = Arc::new(Server::start(engine, BatchPolicy {
        max_batch: 32,
        window: Duration::from_micros(500),
    }));
    // flood the queue, then drain
    let rxs: Vec<_> = xs.iter().map(|x| server.submit(x.clone()).unwrap()).collect();
    let mut max_batch_seen = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        max_batch_seen = max_batch_seen.max(resp.batch_size);
    }
    assert!(max_batch_seen >= 2, "burst traffic should form batches, saw {max_batch_seen}");
}
