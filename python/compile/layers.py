"""Layer-2 building blocks: tiled/binary/full-precision layers with STE.

This module implements the paper's Equations 1-9 as *differentiable training
ops* (straight-through estimation) plus the standard NN primitives needed by
the model zoo in ``compile.models``.  Semantics of the tiling math are pinned
by ``compile.kernels.ref`` (the pure-jnp oracle) and the hypothesis suite.

Parameter bookkeeping
---------------------
Models are pure functions over an ordered dict of named arrays.  Every
parameter is declared with a :class:`ParamSpec`; the tiling *decision* (tile /
binarize / keep fp) is made once at model-build time from the experiment's
``tiling`` config (mode, p, lambda, alpha mode, alpha source) and recorded on
the spec so that

* the AOT compiler (``compile.aot``) can emit a manifest describing exactly
  which parameters are tiles/alphas/weights, and
* the Rust coordinator can reconstruct inference parameters natively.

Straight-through estimation
---------------------------
``ste_sign(s) = s + stop_grad(sign(s) - s)`` — forward is the hard sign of
Eq. 3, backward is identity, so gradients flow through the reshape+sum of
Eqs. 1-2 into W exactly as the paper's Eq. 6 prescribes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Tiling configuration + parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TilingConfig:
    """Experiment-wide tiling policy (paper section 3, Hyperparameter Settings).

    mode: "fp" (no quantization), "bwnn" (1-bit XNOR-style), "tbn" (tiled).
    p: compression factor (tiles per layer).
    lam: minimum layer size N for tiling/binarization (paper's lambda).
    alpha: "single" (Eq. 7) or "per_tile" (Eq. 9).
    alpha_src: "W" (reuse the weight) or "A" (independent parameter).
    """

    mode: str = "fp"
    p: int = 4
    lam: int = 64_000
    alpha: str = "per_tile"
    alpha_src: str = "A"

    @staticmethod
    def from_json(d: dict) -> "TilingConfig":
        return TilingConfig(
            mode=d.get("mode", "fp"),
            p=int(d.get("p", 4)),
            lam=int(d.get("lambda", 64_000)),
            alpha=d.get("alpha", "per_tile"),
            alpha_src=d.get("alpha_src", "A"),
        )


@dataclasses.dataclass
class ParamSpec:
    """One named parameter of a model, with its tiling decision.

    quant is one of:
      "tiled"  — weight trained full-precision, tiled at inference (Eqs. 1-5);
      "bwnn"   — binarized with a single mean-|w| alpha (XNOR-Net style);
      "fp"     — left full precision (layer below lambda, or fp mode);
      "aux"    — non-weight parameter (norm scales, embeddings, ...), never
                 quantized; also used for the independent alpha source A.
    """

    name: str
    shape: Tuple[int, ...]
    init: str  # "kaiming" | "zeros" | "ones" | "normal" | "trunc_normal"
    role: str  # "weight" | "alpha_src" | "other"
    quant: str = "fp"
    p: int = 1
    n_alphas: int = 1
    alpha_src: str = "W"
    fan_in: Optional[int] = None  # overrides kaiming fan-in when set

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    @property
    def q(self) -> int:
        return self.size // self.p


class ModelDef:
    """A model = ordered parameter specs + an apply function.

    ``apply(params, x) -> logits`` runs the *training-path* forward (tiling
    via STE from W).  ``specs`` drive init, the optimizer, the AOT manifest
    and the Rust-side export.
    """

    def __init__(self, specs: List[ParamSpec], apply: Callable[[Params, jnp.ndarray], jnp.ndarray]):
        self.specs = specs
        self.apply = apply

    def spec(self, name: str) -> ParamSpec:
        for s in self.specs:
            if s.name == name:
                return s
        raise KeyError(name)


class SpecBuilder:
    """Collects ParamSpecs while a model function declares its layers.

    The builder applies the experiment's TilingConfig to every weight
    declaration: a weight of size N is tiled iff mode=="tbn", N >= lambda and
    p divides N; binarized iff mode=="bwnn" and N >= lambda.  Tiled weights
    with alpha_src=="A" get a sibling parameter "<name>.A".
    """

    def __init__(self, tiling: TilingConfig):
        self.tiling = tiling
        self.specs: List[ParamSpec] = []
        self._names: set = set()

    def _add(self, spec: ParamSpec) -> ParamSpec:
        assert spec.name not in self._names, f"duplicate param {spec.name}"
        self._names.add(spec.name)
        self.specs.append(spec)
        return spec

    def weight(self, name: str, shape: Sequence[int], init: str = "kaiming",
               fan_in: Optional[int] = None) -> ParamSpec:
        shape = tuple(int(d) for d in shape)
        n = int(math.prod(shape))
        t = self.tiling
        if t.mode == "tbn" and n >= t.lam and n % t.p == 0:
            n_alphas = t.p if t.alpha == "per_tile" else 1
            spec = self._add(ParamSpec(name, shape, init, "weight", "tiled",
                                       p=t.p, n_alphas=n_alphas,
                                       alpha_src=t.alpha_src, fan_in=fan_in))
            if t.alpha_src == "A":
                self._add(ParamSpec(name + ".A", shape, init, "alpha_src",
                                    "aux", fan_in=fan_in))
            return spec
        if t.mode in ("tbn", "bwnn"):
            # TBNs are built on binary-weight models: every weight layer that
            # is not tiled (below lambda, or indivisible by p) is stored at
            # 1 bit, XNOR-Net style.  This matches the paper's accounting
            # (e.g. Table 6: the untiled classification head is 1-bit) and
            # its bit-width columns.
            return self._add(ParamSpec(name, shape, init, "weight", "bwnn",
                                       fan_in=fan_in))
        return self._add(ParamSpec(name, shape, init, "weight", "fp", fan_in=fan_in))

    def other(self, name: str, shape: Sequence[int], init: str) -> ParamSpec:
        return self._add(ParamSpec(name, tuple(int(d) for d in shape), init,
                                   "other", "aux"))


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _fan_in(spec: ParamSpec) -> int:
    if spec.fan_in is not None:
        return spec.fan_in
    if len(spec.shape) == 2:  # (out, in)
        return spec.shape[1]
    if len(spec.shape) == 4:  # (out_c, in_c, kh, kw)
        return spec.shape[1] * spec.shape[2] * spec.shape[3]
    return max(1, spec.size // max(1, spec.shape[0]))


def init_param(key: jax.Array, spec: ParamSpec) -> jnp.ndarray:
    """Kaiming-normal (scale-fan, per the paper's appendix) and friends."""
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.init == "ones":
        return jnp.ones(spec.shape, jnp.float32)
    if spec.init == "normal":
        return 0.02 * jax.random.normal(key, spec.shape, jnp.float32)
    if spec.init == "trunc_normal":
        return 0.02 * jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    # kaiming normal with fan-in scaling (He init, gain for ReLU)
    std = math.sqrt(2.0 / _fan_in(spec))
    return std * jax.random.normal(key, spec.shape, jnp.float32)


def init_params(seed: jnp.ndarray, specs: List[ParamSpec]) -> Params:
    """Deterministically initialize every parameter from an i32 seed scalar.

    The independent alpha source A is initialized from a different fold of
    the key than its W (the paper seeds W and A differently).
    """
    key = jax.random.PRNGKey(seed)
    out: Params = {}
    for i, spec in enumerate(specs):
        sub = jax.random.fold_in(key, i)
        if spec.role == "alpha_src":
            sub = jax.random.fold_in(sub, 0x5EED)
        out[spec.name] = init_param(sub, spec)
    return out


# ---------------------------------------------------------------------------
# Straight-through tiling (training path)
# ---------------------------------------------------------------------------


def ste_sign(s: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 forward (sign with 0 -> -1), identity backward."""
    hard = jnp.where(s > 0, 1.0, -1.0).astype(s.dtype)
    return s + jax.lax.stop_gradient(hard - s)


def effective_weight(params: Params, spec: ParamSpec) -> jnp.ndarray:
    """The weight actually used by the layer, per the spec's quant decision.

    tiled: Eqs. 1-5 with STE + alpha scaling (Eqs. 7/9) from W or A.
    bwnn:  alpha * ste_sign(W)  (XNOR-Net binary-weight baseline).
    fp:    W unchanged.
    """
    w = params[spec.name]
    if spec.quant == "fp" or spec.quant == "aux":
        return w
    if spec.quant == "bwnn":
        alpha = jnp.mean(jnp.abs(w))
        return alpha * ste_sign(w)
    assert spec.quant == "tiled"
    p, q = spec.p, spec.q
    s = w.reshape(p, q).sum(axis=0)  # Eqs. 1-2
    t = ste_sign(s)  # Eq. 3
    a_src = params[spec.name + ".A"] if spec.alpha_src == "A" else w
    if spec.n_alphas == 1:
        alphas = jnp.mean(jnp.abs(a_src)).reshape(1)  # Eq. 7
        scale = jnp.broadcast_to(alphas, (spec.size,))
    else:
        alphas = jnp.mean(jnp.abs(a_src.reshape(p, q)), axis=1)  # Eq. 9
        scale = jnp.repeat(alphas, q)
    b = jnp.tile(t, p) * scale  # Eqs. 4-5 + scaling
    return b.reshape(spec.shape)


def inference_weight_arrays(w: jnp.ndarray, a: Optional[jnp.ndarray],
                            spec: ParamSpec) -> Dict[str, jnp.ndarray]:
    """What gets *stored* for inference (mirrors the Rust-side exporter).

    tiled -> {tile (q,), alphas (n_alphas,)}; bwnn -> {bin (shape), alpha (1,)};
    fp -> {w}.  Used by tests and by aot.py to build the forward graph's
    example inputs.
    """
    if spec.quant == "tiled":
        t = ref.tile_from_weights(w, spec.p)
        src = a if (spec.alpha_src == "A" and a is not None) else w
        alphas = ref.alphas_from(src, spec.p, per_tile=spec.n_alphas > 1)
        return {"tile": t, "alphas": alphas}
    if spec.quant == "bwnn":
        b, alpha = ref.binarize_bwnn(w)
        return {"bin": b, "alpha": alpha}
    return {"w": w}


# ---------------------------------------------------------------------------
# NN primitives (training path; no biases on quantized layers, per the paper)
# ---------------------------------------------------------------------------


def _inference_weight(params: Params, spec: ParamSpec) -> Optional[jnp.ndarray]:
    """Reconstruct a weight from *inference* parameters if present.

    The forward (serving) graph is traced over a params dict keyed by the
    exported artifact names: ``<name>.tile``/``<name>.alphas`` for tiled
    layers, ``<name>.bin``/``<name>.alpha`` for BWNN layers, plain ``<name>``
    for full-precision.  Returns None when ``params`` holds training params.
    """
    if spec.name + ".tile" in params:
        t = params[spec.name + ".tile"]
        alphas = params[spec.name + ".alphas"]
        return ref.expand_tile(t, alphas, spec.shape)
    if spec.name + ".bin" in params:
        return params[spec.name + ".bin"] * params[spec.name + ".alpha"]
    return None


def dense(params: Params, spec: ParamSpec, x: jnp.ndarray) -> jnp.ndarray:
    """y = x @ W^T with W of shape (out, in); x (..., in).

    On the inference path a *tiled* dense layer routes through the Pallas
    tile-reusing kernel (paper §5.2): only the q-length tile and the alpha
    vector are weight-side operands — the full matrix is never materialized.
    """
    if spec.name + ".tile" in params:
        from .kernels.tiled_matmul import tiled_matmul

        out_f, in_f = spec.shape
        xb = x.reshape(-1, in_f)
        y = tiled_matmul(xb, params[spec.name + ".tile"],
                         params[spec.name + ".alphas"], out_f, in_f,
                         interpret=True)
        return y.reshape(*x.shape[:-1], out_f).astype(x.dtype)
    if spec.name + ".bin" in params:
        w = params[spec.name + ".bin"] * params[spec.name + ".alpha"]
        return x @ w.T
    w = effective_weight(params, spec)
    return x @ w.T


def conv2d(params: Params, spec: ParamSpec, x: jnp.ndarray, stride: int = 1,
           padding: str = "SAME", groups: int = 1) -> jnp.ndarray:
    """NCHW conv with OIHW weights (tiled convs expand the tile in-graph)."""
    w = _inference_weight(params, spec)
    if w is None:
        w = effective_weight(params, spec)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def groupnorm(params: Params, prefix: str, x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """GroupNorm over NCHW (batch-independent; BN substitute, see DESIGN §7)."""
    n, c, h, w = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(n, g, c // g, h, w)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    xn = xg.reshape(n, c, h, w)
    scale = params[prefix + ".gn_scale"].reshape(1, c, 1, 1)
    bias = params[prefix + ".gn_bias"].reshape(1, c, 1, 1)
    return xn * scale + bias


def layernorm(params: Params, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * params[prefix + ".ln_scale"] + params[prefix + ".ln_bias"]


def declare_groupnorm(b: SpecBuilder, prefix: str, channels: int) -> None:
    b.other(prefix + ".gn_scale", (channels,), "ones")
    b.other(prefix + ".gn_bias", (channels,), "zeros")


def declare_layernorm(b: SpecBuilder, prefix: str, dim: int) -> None:
    b.other(prefix + ".ln_scale", (dim,), "ones")
    b.other(prefix + ".ln_bias", (dim,), "zeros")


def attention(params: Params, model: "ModelBind", prefix: str, x: jnp.ndarray,
              heads: int) -> jnp.ndarray:
    """Multi-head self-attention; q/k/v/proj are tileable dense layers.

    x: (batch, tokens, dim).
    """
    bsz, tok, dim = x.shape
    hd = dim // heads
    q = model.dense(prefix + ".wq", x)
    k = model.dense(prefix + ".wk", x)
    v = model.dense(prefix + ".wv", x)

    def split(z):
        return z.reshape(bsz, tok, heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    att = (qh @ kh.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ vh).transpose(0, 2, 1, 3).reshape(bsz, tok, dim)
    return model.dense(prefix + ".wo", out)


class ModelBind:
    """Convenience wrapper binding a spec list to a params dict at apply time."""

    def __init__(self, specs: List[ParamSpec], params: Params):
        self._by_name = {s.name: s for s in specs}
        self.params = params

    def dense(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        return dense(self.params, self._by_name[name], x)

    def conv(self, name: str, x: jnp.ndarray, stride: int = 1,
             padding: str = "SAME", groups: int = 1) -> jnp.ndarray:
        return conv2d(self.params, self._by_name[name], x, stride, padding, groups)

    def gn(self, prefix: str, x: jnp.ndarray, groups: int = 8) -> jnp.ndarray:
        return groupnorm(self.params, prefix, x, groups)

    def ln(self, prefix: str, x: jnp.ndarray) -> jnp.ndarray:
        return layernorm(self.params, prefix, x)

    def p(self, name: str) -> jnp.ndarray:
        return self.params[name]


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 smoothing: float = 0.0) -> jnp.ndarray:
    """Mean cross-entropy; labels int32 of shape logits.shape[:-1]."""
    nclass = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, nclass, dtype=logits.dtype)
    if smoothing > 0.0:
        onehot = onehot * (1.0 - smoothing) + smoothing / nclass
    return -(onehot * logp).sum(axis=-1).mean()


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32).mean()


def mse(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((pred - target) ** 2)
