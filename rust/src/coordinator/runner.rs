//! The per-experiment pipeline: train → export → verify → record.

use anyhow::{Context, Result};

use crate::config::Experiment;
use crate::info;
use crate::runtime::{self, Runtime};
use crate::tensor::Tensor;
use crate::train::{export, metrics, TrainOptions, Trainer};
use crate::util::Json;

/// Outcome of the forward-graph verification step: the AOT `forward` graph
/// (Pallas tile-reuse kernel inside) is fed the *Rust-exported* tiles and
/// compared against the eval graph's predictions on the same samples.
#[derive(Debug, Clone, Default)]
pub struct VerifyOutcome {
    pub checked: usize,
    pub agreed: usize,
    /// Max |logit| produced (finite-ness witness).
    pub max_abs_logit: f64,
}

impl VerifyOutcome {
    pub fn agreement(&self) -> f64 {
        self.agreed as f64 / self.checked.max(1) as f64
    }
}

/// Persisted record of one completed run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub id: String,
    pub steps: usize,
    pub loss: f64,
    /// Accuracy (cls/seg) or MSE (forecast).
    pub metric: f64,
    pub class_iou: Option<f64>,
    pub instance_iou: Option<f64>,
    pub bit_width: f64,
    pub storage_bits: usize,
    pub total_params: usize,
    pub duration_s: f64,
    pub forward_agreement: f64,
    /// (step, loss, metric) eval curve for the figure benches.
    pub eval_curve: Vec<(usize, f64, f64)>,
    /// (step, loss) train curve (subsampled).
    pub train_curve: Vec<(usize, f64)>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("loss", Json::Num(self.loss)),
            ("metric", Json::Num(self.metric)),
            ("class_iou", self.class_iou.map(Json::Num).unwrap_or(Json::Null)),
            ("instance_iou", self.instance_iou.map(Json::Num).unwrap_or(Json::Null)),
            ("bit_width", Json::Num(self.bit_width)),
            ("storage_bits", Json::Num(self.storage_bits as f64)),
            ("total_params", Json::Num(self.total_params as f64)),
            ("duration_s", Json::Num(self.duration_s)),
            ("forward_agreement", Json::Num(self.forward_agreement)),
            ("eval_curve", Json::Arr(self.eval_curve.iter().map(|(s, l, m)| {
                Json::from_f64s(&[*s as f64, *l, *m])
            }).collect())),
            ("train_curve", Json::Arr(self.train_curve.iter().map(|(s, l)| {
                Json::from_f64s(&[*s as f64, *l])
            }).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunRecord, String> {
        let curve3 = |key: &str| -> Vec<(usize, f64, f64)> {
            j.get(key).and_then(Json::as_arr).map(|a| {
                a.iter()
                    .filter_map(|row| {
                        let v = row.as_arr()?;
                        Some((v[0].as_usize()?, v[1].as_f64()?, v[2].as_f64()?))
                    })
                    .collect()
            }).unwrap_or_default()
        };
        let curve2 = |key: &str| -> Vec<(usize, f64)> {
            j.get(key).and_then(Json::as_arr).map(|a| {
                a.iter()
                    .filter_map(|row| {
                        let v = row.as_arr()?;
                        Some((v[0].as_usize()?, v[1].as_f64()?))
                    })
                    .collect()
            }).unwrap_or_default()
        };
        Ok(RunRecord {
            id: j.str_or("id", "").to_string(),
            steps: j.usize_or("steps", 0),
            loss: j.f64_or("loss", 0.0),
            metric: j.f64_or("metric", 0.0),
            class_iou: j.get("class_iou").and_then(Json::as_f64),
            instance_iou: j.get("instance_iou").and_then(Json::as_f64),
            bit_width: j.f64_or("bit_width", 32.0),
            storage_bits: j.usize_or("storage_bits", 0),
            total_params: j.usize_or("total_params", 0),
            duration_s: j.f64_or("duration_s", 0.0),
            forward_agreement: j.f64_or("forward_agreement", 0.0),
            eval_curve: curve3("eval_curve"),
            train_curve: curve2("train_curve"),
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("write {path}"))
    }

    pub fn load(path: &str) -> Result<RunRecord, String> {
        RunRecord::from_json(&Json::parse_file(path)?)
    }
}

/// Verify the exported model through the AOT forward graph.
fn verify_forward(rt: &Runtime, exp: &Experiment, trainer: &Trainer,
                  model: &crate::train::TrainedModel,
                  eval_preds: &[i32]) -> Result<VerifyOutcome> {
    let Some(file) = exp.graph_file("forward") else {
        return Ok(VerifyOutcome::default());
    };
    let exe = rt.load(file)?;
    let batch = exp.io.serve_batch;
    let idxs: Vec<usize> = (0..batch).collect();
    let (x, _, _) = trainer.test_ds.gather(&idxs);
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(&exp.io.x);
    let mut inputs = vec![runtime::literal_f32(&Tensor::new(x_shape, x))?];
    inputs.extend(export::forward_inputs(exp, model)?);
    let out = exe.run(&inputs)?;
    let logits = runtime::tensor_from_literal(&out[0])?;
    let max_abs = logits.data.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64));
    if !max_abs.is_finite() {
        anyhow::bail!("{}: forward produced non-finite logits", exp.id);
    }
    let mut outcome = VerifyOutcome { checked: 0, agreed: 0, max_abs_logit: max_abs };
    if exp.io.task != "forecast" && !eval_preds.is_empty() {
        let fwd_preds: Vec<i32> = logits.argmax_last().iter().map(|&i| i as i32).collect();
        let per_sample = if exp.io.task == "seg" { trainer.test_ds.y_int_elems } else { 1 };
        let n = (batch * per_sample).min(eval_preds.len()).min(fwd_preds.len());
        outcome.checked = n;
        outcome.agreed = (0..n).filter(|&i| fwd_preds[i] == eval_preds[i]).count();
    }
    Ok(outcome)
}

/// Run one experiment end to end and build its record.
pub fn run_experiment(rt: &Runtime, exp: &Experiment, opts: &TrainOptions)
                      -> Result<RunRecord> {
    info!("coord", "running {} ({} steps{})", exp.id,
          opts.steps.unwrap_or(exp.train_steps),
          if opts.steps.is_some() { ", override" } else { "" });
    let trainer = Trainer::new(rt, exp)?;
    let (result, model) = trainer.run(opts)?;

    // export; the bit-width column counts conv/FC *weight* layers only
    // (paper convention — norm scales / embeddings are excluded), while
    // storage_bits is the whole TBNZ file.
    let tbnz = export::to_tbnz(exp, &model)?;
    let (total_params, storage_bits, _) = export::export_summary(&tbnz);
    let weight_names: std::collections::HashSet<&str> = exp
        .params
        .iter()
        .filter(|p| p.role == "weight")
        .map(|p| p.name.as_str())
        .collect();
    let (mut w_bits, mut w_params) = (0usize, 0usize);
    for l in tbnz.layers.iter().filter(|l| weight_names.contains(l.name.as_str())) {
        w_bits += l.storage_bits();
        w_params += l.n();
    }
    let bit_width = w_bits as f64 / w_params.max(1) as f64;

    // eval predictions on the verification slice (re-run eval graph once)
    let eval_preds = eval_predictions(rt, exp, &trainer, &model)?;
    let verify = verify_forward(rt, exp, &trainer, &model, &eval_preds)?;
    if verify.checked > 0 {
        info!("coord", "{} forward-graph agreement {:.1}% over {} preds",
              exp.id, 100.0 * verify.agreement(), verify.checked);
    }

    let train_curve: Vec<(usize, f64)> = result
        .train_history
        .iter()
        .filter(|h| h.step % 10 == 0 || h.step + 1 == result.steps)
        .map(|h| (h.step, h.loss))
        .collect();

    Ok(RunRecord {
        id: exp.id.clone(),
        steps: result.steps,
        loss: result.final_eval.loss,
        metric: result.final_eval.metric,
        class_iou: result.final_eval.class_iou,
        instance_iou: result.final_eval.instance_iou,
        bit_width,
        storage_bits,
        total_params,
        duration_s: result.duration_s,
        forward_agreement: verify.agreement(),
        eval_curve: result
            .eval_history
            .iter()
            .map(|e| (e.step, e.loss, e.metric))
            .collect(),
        train_curve,
    })
}

/// Predictions of the eval graph on the first serve_batch samples (the same
/// slice `verify_forward` uses), via the full eval batch.
fn eval_predictions(rt: &Runtime, exp: &Experiment, trainer: &Trainer,
                    model: &crate::train::TrainedModel) -> Result<Vec<i32>> {
    if exp.io.task == "forecast" {
        return Ok(vec![]);
    }
    let Some(file) = exp.graph_file("eval_step") else { return Ok(vec![]) };
    let exe = rt.load(file)?;
    let batch = exp.io.eval_batch;
    let idxs: Vec<usize> = (0..batch).collect();
    let (x, yi, _) = trainer.test_ds.gather(&idxs);
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(&exp.io.x);
    let mut inputs: Vec<xla::Literal> = model
        .params
        .iter()
        .map(|t| runtime::literal_f32(t))
        .collect::<Result<Vec<_>>>()?;
    inputs.push(runtime::literal_f32(&Tensor::new(x_shape, x))?);
    let y_shape = if exp.io.task == "seg" {
        vec![batch, trainer.test_ds.y_int_elems]
    } else {
        vec![batch]
    };
    inputs.push(runtime::literal_i32(&y_shape, &yi)?);
    let out = exe.run(&inputs)?;
    let preds = runtime::i32_from_literal(&out[2])?;
    // sanity: accuracy from preds ~= metric reported by the graph
    let acc = metrics::accuracy(&preds, &yi);
    let graph_acc = runtime::f32_scalar_from_literal(&out[1])? as f64;
    if (acc - graph_acc).abs() > 1e-3 {
        anyhow::bail!("{}: pred/metric mismatch {acc} vs {graph_acc}", exp.id);
    }
    // truncate to the serve slice (+ per-point for seg)
    let per_sample = if exp.io.task == "seg" { trainer.test_ds.y_int_elems } else { 1 };
    Ok(preds[..exp.io.serve_batch * per_sample].to_vec())
}
