//! `MlpEngine` — the deployable model runner of §5.1 (Table 6).
//!
//! Wraps a `TbnzModel` whose layers are FC weights applied in order, with a
//! fused nonlinearity between layers (ReLU in the paper's deployment).  The
//! engine also carries the byte-exact memory/storage accounting used for the
//! Table 6 comparison against the BWNN baseline.
//!
//! Two implementations sit behind the [`EnginePath`] selector:
//!
//! * `Reference` — the f32 Algorithm 1 path (tile reuse, expand-free), the
//!   crate's oracle.  `forward` runs the exact paper math on f32
//!   activations; `forward_quantized` runs the f32 oracle of the deployment
//!   forward with sign-binarized hidden activations.
//! * `Packed` — the XNOR-popcount fast path (`nn::packed`): expanded sign
//!   rows packed to `u64` words at load time, hidden activations
//!   sign-binarized with an XNOR-Net scale.  `forward` and
//!   `forward_quantized` coincide on this path.

use crate::tbn::TbnzModel;
use super::packed::{forward_quantized_reference, EnginePath, PackedModel};
use super::{fc_layer_forward, layer_resident_bytes};

/// Hidden-layer nonlinearity (fused into the FC kernel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Nonlin {
    Relu,
    None,
}

/// Feed-forward inference engine over a TBNZ model.
pub struct MlpEngine {
    pub model: TbnzModel,
    pub nonlin: Nonlin,
    path: EnginePath,
    /// Built eagerly at construction when `path == Packed`.
    packed: Option<PackedModel>,
}

impl MlpEngine {
    /// Reference-path engine (the original constructor).
    pub fn new(model: TbnzModel, nonlin: Nonlin) -> Result<MlpEngine, String> {
        MlpEngine::with_path(model, nonlin, EnginePath::Reference)
    }

    /// Engine with an explicit implementation path. `Packed` pays the
    /// row-packing cost here, once, so the serve path never packs weights.
    pub fn with_path(model: TbnzModel, nonlin: Nonlin, path: EnginePath)
                     -> Result<MlpEngine, String> {
        for l in &model.layers {
            if l.shape.len() != 2 {
                return Err(format!("{}: MlpEngine requires 2-D FC layers", l.name));
            }
        }
        // check chain: layer i input = layer i-1 output
        for w in model.layers.windows(2) {
            if w[1].shape[1] != w[0].shape[0] {
                return Err(format!("{} -> {}: shape chain broken ({} != {})",
                                   w[0].name, w[1].name, w[0].shape[0], w[1].shape[1]));
            }
        }
        let packed = match path {
            EnginePath::Packed => Some(PackedModel::from_tbnz(&model)?),
            EnginePath::Reference => None,
        };
        Ok(MlpEngine { model, nonlin, path, packed })
    }

    pub fn path(&self) -> EnginePath {
        self.path
    }

    pub fn in_dim(&self) -> usize {
        self.model.layers.first().map(|l| l.shape[1]).unwrap_or(0)
    }

    pub fn out_dim(&self) -> usize {
        self.model.layers.last().map(|l| l.shape[0]).unwrap_or(0)
    }

    /// Forward one sample through the active path. The final layer is always
    /// linear (logits). On `Packed` this is the XNOR fast path (hidden
    /// activations sign-binarized); on `Reference` it is the exact f32
    /// Algorithm 1 math.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim());
        match &self.packed {
            Some(p) => p.forward(x, self.nonlin == Nonlin::Relu),
            None => self.forward_reference(x),
        }
    }

    fn forward_reference(&self, x: &[f32]) -> Vec<f32> {
        let last = self.model.layers.len() - 1;
        let mut h = x.to_vec();
        for (i, layer) in self.model.layers.iter().enumerate() {
            let relu = i < last && self.nonlin == Nonlin::Relu;
            h = fc_layer_forward(layer, &h, relu);
        }
        h
    }

    /// The quantized deployment forward regardless of path: on a `Packed`
    /// engine this is the XNOR fast path itself; on a `Reference` engine it
    /// is the f32 oracle of the identical math (`nn::packed` module docs).
    /// `rust/tests/packed_parity.rs` pins the two against each other.
    pub fn forward_quantized(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim());
        match &self.packed {
            Some(p) => p.forward(x, self.nonlin == Nonlin::Relu),
            None => forward_quantized_reference(&self.model, x, self.nonlin == Nonlin::Relu),
        }
    }

    /// Forward a whole batch. On the `Packed` path the batch runs
    /// layer-major (each layer's packed rows stay cache-warm across the
    /// batch) and the bit-packing scratch buffer is reused across samples.
    pub fn forward_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match &self.packed {
            Some(p) => p.forward_batch(xs, self.nonlin == Nonlin::Relu),
            None => xs.iter().map(|x| self.forward_reference(x)).collect(),
        }
    }

    /// Forward a batch (rows of `xs`), returning argmax labels.
    pub fn classify_batch(&self, xs: &[Vec<f32>]) -> Vec<usize> {
        self.forward_batch(xs)
            .iter()
            .map(|y| {
                y.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Max memory at any layer: weights resident for that layer *on the
    /// active path* + input and output activation buffers (f32) — the
    /// Table 6 "Max Memory Usage" model (the paper's peak lands on the
    /// first FC layer).  On the packed path the per-layer weight term is
    /// the expanded packed rows, not the sub-bit tile.
    pub fn peak_memory_bytes(&self) -> usize {
        match &self.packed {
            Some(p) => p.peak_memory_bytes(),
            None => self
                .model
                .layers
                .iter()
                .map(|l| layer_resident_bytes(l) + 4 * (l.shape[0] + l.shape[1]))
                .max()
                .unwrap_or(0),
        }
    }

    /// Total storage for the serialized model (Table 6 "Storage").
    pub fn storage_bytes(&self) -> usize {
        self.model.storage_bytes()
    }

    /// Weight bytes resident for the *active* path: sub-bit tiles on the
    /// reference path, expanded packed rows (1 bit per weight plus alpha-run
    /// metadata) on the packed path — the storage/speed trade the fast path
    /// makes explicit.
    pub fn resident_weight_bytes(&self) -> usize {
        match &self.packed {
            Some(p) => p.resident_bytes(),
            None => self.model.layers.iter().map(layer_resident_bytes).sum(),
        }
    }

    /// Measure frames/second over `iters` runs of one sample (Table 6 FPS).
    pub fn measure_fps(&self, x: &[f32], iters: usize) -> f64 {
        let start = std::time::Instant::now();
        let mut sink = 0.0f32;
        for _ in 0..iters {
            let y = self.forward(x);
            sink += y[0];
        }
        let dt = start.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        iters as f64 / dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
    use crate::tensor::BitVec;
    use crate::util::Rng;

    /// Build the paper's deployment model: in 256 -> hidden 128 -> 10.
    fn tbn_mlp(p: usize) -> MlpEngine {
        let mut r = Rng::new(42);
        let w1: Vec<f32> = (0..128 * 256).map(|_| r.gauss_f32()).collect();
        let tile = tile_from_weights(&w1, p);
        let alphas = alphas_from(&w1, p, AlphaMode::PerTile);
        let w2: Vec<f32> = (0..10 * 128).map(|_| r.gauss_f32()).collect();
        // untiled layers ship 1-bit (the exporter's binarize fallback)
        let model = TbnzModel {
            layers: vec![
                LayerRecord { name: "fc0".into(), shape: vec![128, 256],
                              payload: WeightPayload::Tiled { p, tile, alphas } },
                LayerRecord { name: "head".into(), shape: vec![10, 128],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w2),
                                  alpha: w2.iter().map(|x| x.abs()).sum::<f32>()
                                      / w2.len() as f32 } },
            ],
        };
        MlpEngine::new(model, Nonlin::Relu).unwrap()
    }

    fn bwnn_mlp() -> MlpEngine {
        let mut r = Rng::new(42);
        let w1: Vec<f32> = (0..128 * 256).map(|_| r.gauss_f32()).collect();
        let w2: Vec<f32> = (0..10 * 128).map(|_| r.gauss_f32()).collect();
        let model = TbnzModel {
            layers: vec![
                LayerRecord { name: "fc0".into(), shape: vec![128, 256],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w1),
                                  alpha: w1.iter().map(|x| x.abs()).sum::<f32>()
                                      / w1.len() as f32 } },
                LayerRecord { name: "head".into(), shape: vec![10, 128],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w2),
                                  alpha: w2.iter().map(|x| x.abs()).sum::<f32>()
                                      / w2.len() as f32 } },
            ],
        };
        MlpEngine::new(model, Nonlin::Relu).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let e = tbn_mlp(4);
        let x = vec![0.1f32; 256];
        assert_eq!(e.forward(&x).len(), 10);
        assert_eq!(e.in_dim(), 256);
        assert_eq!(e.out_dim(), 10);
    }

    #[test]
    fn chain_validation() {
        let e = tbn_mlp(4);
        let mut broken = e.model.clone();
        broken.layers[1].shape = vec![10, 64];
        assert!(MlpEngine::new(broken, Nonlin::Relu).is_err());
    }

    /// Table 6's claim: TBN_4 memory and storage are ~4x below BWNN, speed
    /// is in the same ballpark.
    #[test]
    fn table6_memory_and_storage_ordering() {
        let tbn = tbn_mlp(4);
        let bwnn = bwnn_mlp();
        let mem_ratio = bwnn.peak_memory_bytes() as f64 / tbn.peak_memory_bytes() as f64;
        let sto_ratio = bwnn.storage_bytes() as f64 / tbn.storage_bytes() as f64;
        // memory includes fixed activation buffers, so ratio < 4 (paper: 2.4x)
        assert!(mem_ratio > 1.5 && mem_ratio < 4.0, "mem ratio {mem_ratio}");
        // storage dominated by the tiled layer: close to 4x (paper: 3.8x)
        assert!(sto_ratio > 2.5 && sto_ratio < 4.3, "storage ratio {sto_ratio}");
    }

    #[test]
    fn classify_batch_is_deterministic() {
        let e = tbn_mlp(8);
        let mut r = Rng::new(1);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| r.normal_vec(256, 1.0)).collect();
        assert_eq!(e.classify_batch(&xs), e.classify_batch(&xs));
    }

    #[test]
    fn fps_positive() {
        let e = tbn_mlp(4);
        let x = vec![0.5f32; 256];
        assert!(e.measure_fps(&x, 20) > 0.0);
    }

    #[test]
    fn forward_batch_matches_forward_on_reference_path() {
        let e = tbn_mlp(4);
        let mut r = Rng::new(5);
        let xs: Vec<Vec<f32>> = (0..4).map(|_| r.normal_vec(256, 1.0)).collect();
        let batch = e.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&e.forward(x), y);
        }
    }

    #[test]
    fn packed_path_builds_and_matches_quantized_oracle() {
        let model = tbn_mlp(4).model;
        let reference = MlpEngine::new(model.clone(), Nonlin::Relu).unwrap();
        let packed = MlpEngine::with_path(model, Nonlin::Relu, EnginePath::Packed).unwrap();
        assert_eq!(packed.path(), EnginePath::Packed);
        assert_eq!(reference.path(), EnginePath::Reference);

        let mut r = Rng::new(77);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| r.normal_vec(256, 1.0)).collect();
        assert_eq!(packed.forward(&xs[0]).len(), 10);
        // classify_batch must be the argmax of the per-sample packed forward
        let argmax: Vec<usize> = xs
            .iter()
            .map(|x| {
                let y = packed.forward(x);
                y.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect();
        assert_eq!(packed.classify_batch(&xs), argmax);
        for (k, x) in xs.iter().enumerate() {
            let a = packed.forward(x);
            let b = reference.forward_quantized(x);
            for (i, (u, v)) in a.iter().zip(&b).enumerate() {
                assert!((u - v).abs() < 1e-3 * v.abs().max(1.0),
                        "sample {k} logit {i}: {u} vs {v}");
            }
            // on the packed path, forward and forward_quantized coincide
            assert_eq!(a, packed.forward_quantized(x));
        }
    }

    #[test]
    fn packed_residency_stays_sub_fp() {
        let tbn = tbn_mlp(4);
        let packed =
            MlpEngine::with_path(tbn.model.clone(), Nonlin::Relu, EnginePath::Packed).unwrap();
        let fp_bytes = 4 * tbn.model.total_params();
        // packed rows cost ~1 bit/weight (plus run metadata): far below f32
        assert!(packed.resident_weight_bytes() < fp_bytes / 8,
                "packed {} vs fp {}", packed.resident_weight_bytes(), fp_bytes);
        // reference residency reports the sub-bit tiles
        assert!(tbn.resident_weight_bytes() < packed.resident_weight_bytes() * 8);
    }
}
