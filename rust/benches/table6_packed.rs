//! Table 6 companion: the bit-packed XNOR-popcount fast path vs the f32
//! reference engine on the deployment micro MLP (256 -> 128 -> 10, the
//! Table 6 model shape), plus the Table 7-style weight-residency numbers for
//! both paths.
//!
//! Artifact-free: models are built from a seeded RNG exactly like the engine
//! unit tests, so this bench runs on a bare checkout
//! (`cargo bench --bench table6_packed`).

use tiledbits::bench_util::{bench, header};
use tiledbits::nn::{EnginePath, MlpEngine, Nonlin};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::tensor::BitVec;
use tiledbits::util::Rng;

/// The paper's deployment MLP: 256 -> 128 tiled at p, 128 -> 10 stored 1-bit.
fn micro_model(p: usize) -> TbnzModel {
    let mut r = Rng::new(42);
    let w1: Vec<f32> = r.normal_vec(128 * 256, 1.0);
    let w2: Vec<f32> = r.normal_vec(10 * 128, 1.0);
    TbnzModel {
        layers: vec![
            LayerRecord {
                name: "fc0".into(),
                shape: vec![128, 256],
                payload: WeightPayload::Tiled {
                    p,
                    tile: tile_from_weights(&w1, p),
                    alphas: alphas_from(&w1, p, AlphaMode::PerTile),
                },
            },
            LayerRecord {
                name: "head".into(),
                shape: vec![10, 128],
                payload: WeightPayload::Bwnn {
                    bits: BitVec::from_signs(&w2),
                    alpha: w2.iter().map(|x| x.abs()).sum::<f32>() / w2.len() as f32,
                },
            },
        ],
    }
}

fn main() {
    header("Table 6 companion: packed XNOR path vs f32 reference (micro MLP)");

    let p = 4usize;
    let model = micro_model(p);
    let reference =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let packed = MlpEngine::with_path(model, Nonlin::Relu, EnginePath::Packed).unwrap();

    let mut r = Rng::new(7);
    let x = r.normal_vec(256, 1.0);
    let batch: Vec<Vec<f32>> = (0..32).map(|_| r.normal_vec(256, 1.0)).collect();

    // single-sample latency
    let r_ref = bench("reference forward (1 sample)", 20, 200, || {
        std::hint::black_box(reference.forward(&x));
    });
    let r_refq = bench("reference quantized oracle (1 sample)", 20, 200, || {
        std::hint::black_box(reference.forward_quantized(&x));
    });
    let r_pkd = bench("packed xnor forward (1 sample)", 20, 200, || {
        std::hint::black_box(packed.forward(&x));
    });

    // batched throughput (the serving path)
    let b_ref = bench("reference forward_batch (32)", 5, 60, || {
        std::hint::black_box(reference.forward_batch(&batch));
    });
    let b_pkd = bench("packed forward_batch (32)", 5, 60, || {
        std::hint::black_box(packed.forward_batch(&batch));
    });

    for r in [&r_ref, &r_refq, &r_pkd, &b_ref, &b_pkd] {
        println!("{}", r.report());
    }

    println!("\n-- throughput (samples/s) --");
    println!("reference single: {:>12.0}", r_ref.per_sec());
    println!("packed single:    {:>12.0}  ({:.2}x vs reference quantized oracle)",
             r_pkd.per_sec(), r_pkd.per_sec() / r_refq.per_sec());
    println!("reference batch:  {:>12.0}", b_ref.throughput(batch.len()));
    println!("packed batch:     {:>12.0}", b_pkd.throughput(batch.len()));

    println!("\n-- Table 6/7-style memory (bytes) --");
    println!("{:28} {:>12} {:>12} {:>12}", "engine", "resident W", "peak mem",
             "storage");
    for (name, e) in [("reference (sub-bit tiles)", &reference),
                      ("packed (tile-resident)", &packed)] {
        println!("{:28} {:>12} {:>12} {:>12}", name, e.resident_weight_bytes(),
                 e.peak_memory_bytes(), e.storage_bytes());
    }
    println!("\nnote: the packed path keeps one q-bit tile (plus alphas) resident per");
    println!("binarized tiled layer (PackedLayout::TileResident; this model's only");
    println!("tiled layer is the f32 entry layer, which stays a reference tile).");
    println!("benches/table7_memory.rs carries the expanded-vs-tile-resident A/B;");
    println!("storage on disk (TBNZ) is unchanged.");
}
