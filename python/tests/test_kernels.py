"""Hypothesis sweeps: Pallas kernels vs the pure-jnp oracle (ref.py).

These pin the semantics of the paper's Eqs. 1-5 & 7/9 and the §5.2 tile-reuse
kernel across shapes, compression factors and value distributions.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.tile_construct import tile_alphas, tile_construct
from compile.kernels.tiled_matmul import tiled_matmul, vmem_bytes_tiled

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def rng_array(seed, shape, scale=1.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(scale * r.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# ref.py self-consistency (closed-form cases)
# ---------------------------------------------------------------------------


class TestRefClosedForm:
    def test_tile_sign_convention_zero_is_minus_one(self):
        w = jnp.zeros((2, 4), jnp.float32)
        t = ref.tile_from_weights(w, 2)
        assert t.shape == (4,)
        np.testing.assert_array_equal(np.asarray(t), -np.ones(4))

    def test_tile_simple_sum(self):
        # p=2, q=2: rows [1,-3],[2,1] -> s=[3,-2] -> t=[1,-1]
        w = jnp.asarray([[1.0, -3.0], [2.0, 1.0]])
        t = ref.tile_from_weights(w, 2)
        np.testing.assert_array_equal(np.asarray(t), [1.0, -1.0])

    def test_alpha_single_is_mean_abs(self):
        a = jnp.asarray([[1.0, -2.0], [3.0, -4.0]])
        al = ref.alphas_from(a, 2, per_tile=False)
        assert al.shape == (1,)
        assert float(al[0]) == pytest.approx(2.5)

    def test_alpha_per_tile(self):
        a = jnp.asarray([1.0, -2.0, 3.0, -5.0])
        al = ref.alphas_from(a, 2, per_tile=True)
        np.testing.assert_allclose(np.asarray(al), [1.5, 4.0])

    def test_expand_roundtrip_values(self):
        t = jnp.asarray([1.0, -1.0, 1.0])
        al = jnp.asarray([2.0, 0.5])
        b = ref.expand_tile(t, al, (2, 3))
        np.testing.assert_allclose(
            np.asarray(b), [[2.0, -2.0, 2.0], [0.5, -0.5, 0.5]])

    def test_expand_single_alpha_broadcasts(self):
        t = jnp.asarray([1.0, -1.0])
        b = ref.expand_tile(t, jnp.asarray([3.0]), (2, 2))
        np.testing.assert_allclose(np.asarray(b), [[3.0, -3.0], [3.0, -3.0]])

    def test_bwnn_binarize(self):
        w = jnp.asarray([0.5, -1.5, 2.0, -4.0])
        b, alpha = ref.binarize_bwnn(w)
        np.testing.assert_array_equal(np.asarray(b), [1, -1, 1, -1])
        assert float(alpha[0]) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Pallas tile_construct / tile_alphas vs ref
# ---------------------------------------------------------------------------


@st.composite
def layer_and_p(draw):
    p = draw(st.sampled_from([1, 2, 4, 8]))
    q = draw(st.integers(min_value=2, max_value=96))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return p, q, seed


class TestTileConstructKernel:
    @given(layer_and_p())
    def test_matches_ref(self, pq):
        p, q, seed = pq
        w = rng_array(seed, (p * q,))
        got = tile_construct(w, p)
        want = ref.tile_from_weights(w, p)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(layer_and_p())
    def test_alphas_match_ref(self, pq):
        p, q, seed = pq
        a = rng_array(seed, (p * q,), scale=3.0)
        got = tile_alphas(a, p)
        want = ref.alphas_from(a, p, per_tile=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_output_is_binary(self):
        w = rng_array(7, (8 * 33,))
        t = np.asarray(tile_construct(w, 8))
        assert set(np.unique(t)).issubset({-1.0, 1.0})


# ---------------------------------------------------------------------------
# Pallas tiled_matmul vs ref (the §5.2 kernel)
# ---------------------------------------------------------------------------


@st.composite
def matmul_case(draw):
    m = draw(st.sampled_from([4, 8, 16, 32]))
    n = draw(st.sampled_from([8, 16, 24, 64]))
    # q must divide m*n; pick p from divisors of m*n
    total = m * n
    p = draw(st.sampled_from([d for d in (1, 2, 4, 8, 16) if total % d == 0]))
    batch = draw(st.sampled_from([1, 3, 8]))
    per_tile = draw(st.booleans())
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return m, n, p, batch, per_tile, seed


class TestTiledMatmulKernel:
    @given(matmul_case())
    def test_matches_ref(self, case):
        m, n, p, batch, per_tile, seed = case
        q = (m * n) // p
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.standard_normal((batch, n)), jnp.float32)
        t = jnp.asarray(r.choice([-1.0, 1.0], size=q), jnp.float32)
        alphas = jnp.asarray(np.abs(r.standard_normal(p if per_tile else 1)) + 0.1,
                             jnp.float32)
        got = tiled_matmul(x, t, alphas, m, n)
        want = ref.tiled_dense_ref(x, t, alphas, m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_block_rows_override(self):
        m, n, p, batch = 16, 8, 4, 2
        q = (m * n) // p
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((batch, n)), jnp.float32)
        t = jnp.asarray(r.choice([-1.0, 1.0], size=q), jnp.float32)
        al = jnp.asarray([1.0], jnp.float32)
        full = tiled_matmul(x, t, al, m, n)
        blocked = tiled_matmul(x, t, al, m, n, block_rows=4)
        np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                                   rtol=1e-5, atol=1e-5)

    def test_vmem_model_tile_vs_dense(self):
        stats = vmem_bytes_tiled(batch=8, m=512, n=512, q=512 * 512 // 8, p=8)
        # the whole point: weight-side stream is q, not m*n
        assert stats["weight_stream_total"] * 8 == stats["dense_weight_stream_total"]


# ---------------------------------------------------------------------------
# gradient flow through the STE construction
# ---------------------------------------------------------------------------


class TestSTEGradients:
    def test_grad_reaches_every_weight(self):
        from compile.layers import ParamSpec, effective_weight

        spec = ParamSpec("w", (4, 8), "kaiming", "weight", "tiled",
                         p=4, n_alphas=4, alpha_src="W")
        w = rng_array(3, (4, 8))

        def f(w):
            return jnp.sum(effective_weight({"w": w}, spec) ** 2)

        g = jax.grad(f)(w)
        assert g.shape == w.shape
        assert float(jnp.sum(jnp.abs(g))) > 0.0

    def test_ste_sign_backward_is_identity(self):
        from compile.layers import ste_sign

        g = jax.grad(lambda s: jnp.sum(ste_sign(s) * jnp.arange(1.0, 5.0)))(
            jnp.asarray([0.3, -0.2, 0.9, -0.7]))
        np.testing.assert_allclose(np.asarray(g), [1.0, 2.0, 3.0, 4.0])
