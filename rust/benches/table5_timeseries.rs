//! Table 5: multivariate time-series forecasting MSE (electricity + weather
//! stand-ins), averaged over seeds with std, exactly the paper's protocol;
//! plus the native TST encoder lowering/forward stats and the
//! expanded-vs-tile packed residency delta.

use tiledbits::arch;
use tiledbits::bench_util::{bench_dirs, bench_steps, header,
                            print_native_lowering_stats};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_experiment;
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;
use tiledbits::util::mean_std;

fn main() {
    header("Table 5: time-series forecasting (MSE over seeds)");

    // native TST execution (the tentpole): both Table 5 encoders lower to
    // pre-LN attention graphs and run on the tile-resident packed engine
    println!("\n-- native layer-graph lowering (attention joins, packed residency) --");
    print_native_lowering_stats(&arch::tst_micro());
    print_native_lowering_stats(&arch::tst_weather());
    print_native_lowering_stats(&arch::tst_electricity());

    let (artifacts, _) = bench_dirs();
    let steps = bench_steps(60);
    let seeds: usize = std::env::var("TBN_SEEDS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(3);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("(artifacts not built; skipping)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");

    println!("{steps} steps x {seeds} seeds per row\n");
    for ds in ["elec", "weather"] {
        println!("-- synthetic {ds} --");
        for method in ["fp", "bwnn", "tbn4"] {
            let id = format!("tst_{ds}_{method}");
            let Some(exp) = manifest.by_id(&id) else { continue };
            let mut mses = Vec::new();
            let mut bw = 32.0;
            for s in 0..seeds {
                match run_experiment(&rt, exp, &TrainOptions {
                    steps: Some(steps), eval_every: 0, log_every: 10_000,
                    seed: Some(1000 + s as u64) }) {
                    Ok(rec) => {
                        mses.push(rec.metric);
                        bw = rec.bit_width;
                    }
                    Err(e) => println!("  seed {s} FAILED: {e:#}"),
                }
            }
            if !mses.is_empty() {
                let (m, sd) = mean_std(&mses);
                println!("{id:20} MSE {m:.4} +- {sd:.4}  bit-width {bw:.3}");
            }
        }
    }
    println!("\npaper: Electricity 0.212/0.210/0.209, Weather 0.165/0.165/0.168 —");
    println!("TBN_4 statistically indistinguishable from FP/BWNN. Check the same here.");
}
