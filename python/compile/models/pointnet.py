"""PointNet (classification + segmentation) for Table 3.

Faithful to Qi et al.'s vanilla PointNet minus the input/feature T-Nets
(documented substitution; the T-Nets are small and below lambda in the paper
anyway).  Shared per-point MLPs are dense layers applied to (batch, points,
features) — exactly the 1x1-conv-as-FC structure that makes PointNet a
fully-connected model in the paper's Fig. 2 accounting.

Classification: shared MLP [64,128,256] -> max-pool -> FC [128] -> classes.
Segmentation:   per-point features concat global feature -> per-point head.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (ModelBind, ModelDef, SpecBuilder, TilingConfig,
                      declare_layernorm)


def _shared_mlp_declare(b: SpecBuilder, dims, pre: str) -> None:
    for i in range(len(dims) - 1):
        b.weight(f"{pre}{i}", (dims[i + 1], dims[i]))
        declare_layernorm(b, f"{pre}{i}", dims[i + 1])


def _shared_mlp(m: ModelBind, dims, pre: str, h: jnp.ndarray) -> jnp.ndarray:
    for i in range(len(dims) - 1):
        h = jax.nn.relu(m.ln(f"{pre}{i}", m.dense(f"{pre}{i}", h)))
    return h


def build_cls(cfg: dict, tiling: TilingConfig) -> ModelDef:
    classes = int(cfg["classes"])
    feat = [3, 64, 128, 256]

    b = SpecBuilder(tiling)
    _shared_mlp_declare(b, feat, "sa")
    b.weight("fc1", (128, feat[-1]))
    declare_layernorm(b, "fc1", 128)
    b.weight("head", (classes, 128))
    specs = b.specs

    def apply(params, x):
        # x: (batch, points, 3)
        m = ModelBind(specs, params)
        h = _shared_mlp(m, feat, "sa", x)
        g = h.max(axis=1)  # global max pool over points
        g = jax.nn.relu(m.ln("fc1", m.dense("fc1", g)))
        return m.dense("head", g)

    return ModelDef(specs, apply)


def build_seg(cfg: dict, tiling: TilingConfig) -> ModelDef:
    classes = int(cfg["classes"])
    feat = [3, 64, 128, 256]
    seg = [feat[-1] + feat[-1], 128, 64]

    b = SpecBuilder(tiling)
    _shared_mlp_declare(b, feat, "sa")
    _shared_mlp_declare(b, seg, "seg")
    b.weight("head", (classes, seg[-1]))
    specs = b.specs

    def apply(params, x):
        # x: (batch, points, 3) -> per-point logits (batch, points, classes)
        m = ModelBind(specs, params)
        h = _shared_mlp(m, feat, "sa", x)  # (b, n, 256)
        g = h.max(axis=1, keepdims=True)  # (b, 1, 256) global feature
        hg = jnp.concatenate([h, jnp.broadcast_to(g, h.shape)], axis=-1)
        hs = _shared_mlp(m, seg, "seg", hg)
        return m.dense("head", hs)

    return ModelDef(specs, apply)
