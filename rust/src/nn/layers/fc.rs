//! Fully-connected graph node: the Algorithm 1 FC kernels behind the
//! [`super::Node`] abstraction, with Reference, Packed (single and batched)
//! and layer-0 int8 entry points.  In a branching [`super::Graph`] an FC
//! node is an ordinary unary node — T-Net transform heads are plain `Fc`
//! nodes whose output feeds the `MatMulFeature` join's second slot.

use std::sync::Arc;

use super::Scratch;
use crate::nn::packed::{
    activation_gamma, binarize_activations, binarize_activations_into,
    payload_row_dot_i8, quantize_input_i8, split_ranges, IntRowRule, IntThresholds,
    PackedLayer, PackedLayout, PackedPayload,
};
use crate::nn::{fc_fp_forward, fc_layer_forward};
use crate::tbn::bitops::SimdBackend;
use crate::tbn::LayerRecord;

/// A `[m, n]` weight layer: `y = W x` with an optional fused ReLU.
///
/// The record is held behind an `Arc` so a node and any model-level owner
/// (e.g. the engine builders consuming a `TbnzModel`) share one payload
/// copy instead of duplicating it.
#[derive(Debug, Clone)]
pub struct FcLayer {
    pub record: Arc<LayerRecord>,
    /// Output features.
    pub m: usize,
    /// Input features.
    pub n: usize,
}

impl FcLayer {
    pub fn from_record(record: LayerRecord) -> Result<FcLayer, String> {
        FcLayer::from_record_shared(Arc::new(record))
    }

    /// Build from an already-shared record without copying the payload.
    pub fn from_record_shared(record: Arc<LayerRecord>) -> Result<FcLayer, String> {
        if record.shape.len() != 2 {
            return Err(format!("{}: Fc node requires a 2-D shape", record.name));
        }
        let (m, n) = (record.shape[0], record.shape[1]);
        Ok(FcLayer { record, m, n })
    }

    pub(crate) fn build_packed(&self, layout: PackedLayout) -> Result<PackedLayer, String> {
        PackedLayer::from_record_mn_layout(&self.record, self.m, self.n, layout)
    }

    /// f32 Algorithm 1 forward (tile reuse, expand-free — the oracle).
    pub fn forward_reference(&self, x: &[f32], relu: bool) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.n);
        fc_layer_forward(&self.record, x, relu)
    }

    /// Packed forward: sign-binarize the input with an XNOR-Net scale, then
    /// XNOR-popcount every row on the `simd` backend.  With `threads > 1`
    /// the row loop splits across scoped std threads (`PackedLayer::
    /// forward_batch_binarized_rows_mt_simd` with a batch of one) —
    /// bit-exact against the serial path at any thread count and on any
    /// backend.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_packed(&self, packed: &PackedLayer, x: &[f32], relu: bool,
                          scratch: &mut Scratch, threads: usize,
                          simd: SimdBackend) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.n);
        let gamma = binarize_activations(x, &mut scratch.words);
        if threads <= 1 {
            return packed.forward_binarized_simd(&scratch.words, gamma, relu, simd);
        }
        let mut out = vec![0.0f32; self.m];
        packed.forward_batch_binarized_rows_mt_simd(0, self.m, &scratch.words,
                                                    scratch.words.len(), &[gamma], relu,
                                                    &mut out, threads, simd);
        out
    }

    /// Batched packed forward: binarize all `B` inputs side by side into
    /// one scratch buffer, then run every row over the whole batch in one
    /// pass (`PackedLayer::forward_batch_binarized_rows`), so per-row
    /// weight state — and on the tile-resident layout the one shared tile —
    /// stays hot across the batch.  Outputs are bit-identical to per-sample
    /// [`FcLayer::forward_packed`].  `threads > 1` row-splits the batched
    /// kernel (`PackedLayer::forward_batch_binarized_rows_mt_simd`),
    /// preserving that bit-identity at any thread count; `simd` selects the
    /// XNOR-popcount backend every worker runs.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_packed_batch(&self, packed: &PackedLayer, xs: &[Vec<f32>],
                                relu: bool, scratch: &mut Scratch, threads: usize,
                                simd: SimdBackend)
                                -> Vec<Vec<f32>> {
        let stride = self.n.div_ceil(64).max(1);
        let bsz = xs.len();
        scratch.batch_words.clear();
        scratch.batch_words.resize(bsz * stride, 0);
        scratch.gammas.clear();
        for (b, x) in xs.iter().enumerate() {
            debug_assert_eq!(x.len(), self.n);
            let g = binarize_activations_into(
                x, &mut scratch.batch_words[b * stride..(b + 1) * stride]);
            scratch.gammas.push(g);
        }
        let mut out = vec![0.0f32; bsz * self.m];
        packed.forward_batch_binarized_rows_mt_simd(0, self.m, &scratch.batch_words,
                                                    stride, &scratch.gammas, relu,
                                                    &mut out, threads, simd);
        out.chunks(self.m).map(|row| row.to_vec()).collect()
    }

    /// Layer-0 forward on the `PackedInt8` path: quantize the input to i8
    /// once, run integer MACs per row, rescale.  With `threads > 1` the row
    /// loop splits across scoped std threads, each writing a contiguous
    /// disjoint chunk of the output — bit-exact against the serial loop.
    pub fn forward_int8(&self, x: &[f32], relu: bool, scratch: &mut Scratch,
                        threads: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.n);
        let scale = quantize_input_i8(x, &mut scratch.qi8);
        let qi8: &[i8] = &scratch.qi8;
        let row = |i: usize| {
            let v = payload_row_dot_i8(&self.record.payload, i * self.n, qi8, scale);
            if relu { v.max(0.0) } else { v }
        };
        let t = threads.min(self.m).max(1);
        if t <= 1 {
            return (0..self.m).map(row).collect();
        }
        let mut y = vec![0.0f32; self.m];
        let ranges = split_ranges(self.m, t);
        std::thread::scope(|scope| {
            let mut rest = y.as_mut_slice();
            for &(lo, hi) in &ranges {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
                rest = tail;
                let row = &row;
                scope.spawn(move || {
                    for (k, v) in chunk.iter_mut().enumerate() {
                        *v = row(lo + k);
                    }
                });
            }
        });
        y
    }

    /// Integer-pipeline forward, bit output: the input is already packed
    /// sign bits (`xw`, bits `>= n` zero) and the output is the next
    /// layer's packed sign bits — one `u64` word buffer, no f32 anywhere.
    /// ReLU needs no parameter: `relu(v) > 0 ⇔ v > 0`, so the emitted bit
    /// is the same either way.  Threads split output *words*; any thread
    /// count and backend is bit-exact (see
    /// `PackedLayer::forward_batch_bits_mt_simd`).
    pub fn forward_int_bits(&self, packed: &PackedLayer, thr: &IntThresholds,
                            xw: &[u64], threads: usize, simd: SimdBackend)
                            -> Vec<u64> {
        let stride_out = self.m.div_ceil(64).max(1);
        let mut out = vec![0u64; stride_out];
        packed.forward_batch_bits_mt_simd(thr, xw, xw.len(), 1, &mut out, stride_out,
                                          threads, simd);
        out
    }

    /// Integer-pipeline forward, f32 output — the boundary form for the
    /// output layer (or a non-FC consumer): the same bit input, but values
    /// are emitted as `thr.gamma * row_dot` with the per-layer *calibrated
    /// constant* in place of the data-dependent XNOR-Net scale.  Reuses the
    /// exact f32 batch kernel, so the accumulation order matches the
    /// Packed path run for run.
    pub fn forward_int_f32(&self, packed: &PackedLayer, thr: &IntThresholds,
                           xw: &[u64], relu: bool, threads: usize,
                           simd: SimdBackend) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m];
        packed.forward_batch_binarized_rows_mt_simd(0, self.m, xw, xw.len(),
                                                    &[thr.gamma], relu, &mut out,
                                                    threads, simd);
        out
    }

    /// Batched [`FcLayer::forward_int_bits`]: `bsz` bit inputs of `stride`
    /// words each, producing `bsz` bit outputs of `ceil(m/64)` words each
    /// in one buffer (returned with that output stride implied).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_int_bits_batch(&self, packed: &PackedLayer, thr: &IntThresholds,
                                  xws: &[u64], stride: usize, bsz: usize,
                                  threads: usize, simd: SimdBackend) -> Vec<u64> {
        let stride_out = self.m.div_ceil(64).max(1);
        let mut out = vec![0u64; bsz * stride_out];
        packed.forward_batch_bits_mt_simd(thr, xws, stride, bsz, &mut out, stride_out,
                                          threads, simd);
        out
    }

    /// Batched [`FcLayer::forward_int_f32`] (boundary layers inside a
    /// batched forward): the constant gamma is broadcast across the batch
    /// through the shared `scratch.gammas` staging buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_int_f32_batch(&self, packed: &PackedLayer, thr: &IntThresholds,
                                 xws: &[u64], stride: usize, bsz: usize, relu: bool,
                                 scratch: &mut Scratch, threads: usize,
                                 simd: SimdBackend) -> Vec<Vec<f32>> {
        scratch.gammas.clear();
        scratch.gammas.resize(bsz, thr.gamma);
        let mut out = vec![0.0f32; bsz * self.m];
        packed.forward_batch_binarized_rows_mt_simd(0, self.m, xws, stride,
                                                    &scratch.gammas, relu, &mut out,
                                                    threads, simd);
        out.chunks(self.m).map(|row| row.to_vec()).collect()
    }

    /// Exact per-run accumulation of row `i` over a ±1 input given as sign
    /// bools — the plain-Rust (scalar bit reads, no popcount words) half of
    /// the integer oracle, f32-bit-exact against the kernels' `Mixed` path.
    fn oracle_acc(&self, packed: &PackedLayer, i: usize, x_pos: &[bool]) -> f32 {
        if let PackedPayload::Dense(w) = &packed.payload {
            let row = &w[i * self.n..(i + 1) * self.n];
            let mut acc = 0.0f32;
            for (j, &wj) in row.iter().enumerate() {
                if x_pos[j] { acc += wj } else { acc -= wj }
            }
            return acc;
        }
        let mut acc = 0.0f32;
        packed.for_each_run(i, |start, len, alpha| {
            let same = (start..start + len)
                .filter(|&j| packed.weight_bit(i, j) == x_pos[j])
                .count() as i64;
            acc += alpha * (2 * same - len as i64) as f32;
        });
        acc
    }

    /// Plain-Rust integer oracle of [`FcLayer::forward_int_bits`]: per row,
    /// count matching sign bits with scalar loops and compare against the
    /// folded threshold in the same-count domain (`Pos`: `same ≥ t`,
    /// `Neg`: `same ≤ t`), falling back to the exact per-run f32 sum for
    /// `Mixed` rows.  No packed words, no SIMD — the independent
    /// formulation `tests/int_pipeline_parity.rs` pins the kernels against.
    pub fn forward_int_oracle(&self, packed: &PackedLayer, thr: &IntThresholds,
                              x_pos: &[bool]) -> Vec<bool> {
        debug_assert_eq!(x_pos.len(), self.n);
        let same = |i: usize| {
            (0..self.n).filter(|&j| packed.weight_bit(i, j) == x_pos[j]).count() as i64
        };
        (0..self.m)
            .map(|i| match thr.rules[i] {
                IntRowRule::Zero => false,
                IntRowRule::Pos { t } => same(i) >= t as i64,
                IntRowRule::Neg { t } => same(i) <= t as i64,
                IntRowRule::Mixed => self.oracle_acc(packed, i, x_pos) > 0.0,
            })
            .collect()
    }

    /// Plain-Rust oracle of [`FcLayer::forward_int_f32`]: the boundary f32
    /// emission `thr.gamma * acc` with the same per-run accumulation
    /// order — bit-exact against the kernel.
    pub fn forward_int_oracle_f32(&self, packed: &PackedLayer, thr: &IntThresholds,
                                  x_pos: &[bool], relu: bool) -> Vec<f32> {
        debug_assert_eq!(x_pos.len(), self.n);
        (0..self.m)
            .map(|i| {
                let v = thr.gamma * self.oracle_acc(packed, i, x_pos);
                if relu { v.max(0.0) } else { v }
            })
            .collect()
    }

    /// f32 oracle of [`FcLayer::forward_packed`] — the same sign/gamma math
    /// over the expanded weights, no bit tricks.  `Engine::forward_quantized`
    /// runs this on the Reference path.  Gamma carries the packed path's
    /// non-finite guard ([`activation_gamma`]) so parity holds on poisoned
    /// inputs too.
    pub fn forward_quantized_oracle(&self, x: &[f32], relu: bool) -> Vec<f32> {
        let gamma = activation_gamma(x);
        let signs: Vec<f32> = x.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let w = self.record.expand();
        let mut y = fc_fp_forward(&w, &signs, self.m, false);
        for v in y.iter_mut() {
            let s = gamma * *v;
            *v = if relu { s.max(0.0) } else { s };
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, WeightPayload};
    use crate::util::Rng;

    fn tiled_fc(m: usize, n: usize, p: usize, seed: u64) -> FcLayer {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(m * n, 1.0);
        FcLayer::from_record(LayerRecord {
            name: "fc".into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        })
        .unwrap()
    }

    #[test]
    fn rejects_non_2d() {
        let rec = LayerRecord {
            name: "x".into(),
            shape: vec![2, 2, 2, 2],
            payload: WeightPayload::Fp(vec![0.0; 16]),
        };
        assert!(FcLayer::from_record(rec).is_err());
    }

    #[test]
    fn packed_matches_oracle() {
        let fc = tiled_fc(12, 40, 4, 9);
        let mut rng = Rng::new(10);
        let x = rng.normal_vec(40, 1.0);
        let want = fc.forward_quantized_oracle(&x, false);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let packed = fc.build_packed(layout).unwrap();
            let mut scratch = Scratch::default();
            let got = fc.forward_packed(&packed, &x, false, &mut scratch, 1,
                                        SimdBackend::default());
            for i in 0..12 {
                assert!((got[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                        "{layout:?} row {i}");
            }
        }
    }

    /// Batched and per-sample packed forwards must be bit-identical, on
    /// both weight layouts — and threaded variants of both must match the
    /// single-threaded results exactly (rows=9 < 64 threads covers the
    /// rows-fewer-than-threads edge).
    #[test]
    fn packed_batch_is_bit_identical_to_single() {
        let fc = tiled_fc(9, 70, 7, 15); // ragged width, mid-row alpha splits
        let mut rng = Rng::new(16);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(70, 1.0)).collect();
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let packed = fc.build_packed(layout).unwrap();
            let mut scratch = Scratch::default();
            let batch = fc.forward_packed_batch(&packed, &xs, true, &mut scratch, 1,
                                                SimdBackend::default());
            assert_eq!(batch.len(), xs.len());
            for (b, x) in xs.iter().enumerate() {
                let single = fc.forward_packed(&packed, x, true, &mut scratch, 1,
                                               SimdBackend::default());
                assert_eq!(batch[b], single, "{layout:?} sample {b}");
                for threads in [2usize, 4, 64] {
                    assert_eq!(
                        fc.forward_packed(&packed, x, true, &mut scratch, threads,
                                          SimdBackend::default()),
                        single, "{layout:?} sample {b} threads={threads}");
                }
            }
            for threads in [2usize, 4, 64] {
                assert_eq!(
                    fc.forward_packed_batch(&packed, &xs, true, &mut scratch,
                                            threads, SimdBackend::default()),
                    batch, "{layout:?} threads={threads}");
            }
        }
    }

    #[test]
    fn int8_close_to_reference_on_layer0() {
        let fc = tiled_fc(16, 60, 4, 11);
        let mut rng = Rng::new(12);
        let x = rng.normal_vec(60, 1.0);
        let mut scratch = Scratch::default();
        let got = fc.forward_int8(&x, false, &mut scratch, 1);
        for threads in [2usize, 4, 64] {
            assert_eq!(fc.forward_int8(&x, false, &mut scratch, threads), got,
                       "threads={threads}");
        }
        let want = fc.forward_reference(&x, false);
        // documented bound: scale/2 * sum|w_row| per output
        let scale = x.iter().fold(0.0f32, |m, v| m.max(v.abs())) / 127.0;
        let dense = fc.record.expand();
        for i in 0..16 {
            let bound = 0.5 * scale
                * dense[i * 60..(i + 1) * 60].iter().map(|w| w.abs()).sum::<f32>()
                * 1.05
                + 1e-4;
            assert!((got[i] - want[i]).abs() <= bound,
                    "row {i}: {} vs {} (bound {bound})", got[i], want[i]);
        }
    }

    /// Bit and f32 integer forwards are bit-exact against their plain-Rust
    /// oracles on both layouts, at several thread counts, with m > 64 so
    /// the bit output spans words (and word-split threading engages).
    #[test]
    fn int_forwards_match_oracles() {
        let fc = tiled_fc(70, 70, 7, 21); // PerTile alphas: Mixed rows included
        let mut rng = Rng::new(22);
        let x = rng.normal_vec(70, 1.0);
        let x_pos: Vec<bool> = x.iter().map(|&v| v > 0.0).collect();
        let mut xw = Vec::new();
        crate::nn::packed::binarize_signs(&x, &mut xw);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let packed = fc.build_packed(layout).unwrap();
            let thr = IntThresholds::from_layer(&packed);
            let want_bits = fc.forward_int_oracle(&packed, &thr, &x_pos);
            let want_f32 = fc.forward_int_oracle_f32(&packed, &thr, &x_pos, true);
            for threads in [1usize, 2, 4, 64] {
                let bits = fc.forward_int_bits(&packed, &thr, &xw, threads,
                                               SimdBackend::default());
                for (i, &want) in want_bits.iter().enumerate() {
                    assert_eq!((bits[i / 64] >> (i % 64)) & 1 == 1, want,
                               "{layout:?} threads={threads} row {i}");
                }
                assert_eq!(fc.forward_int_f32(&packed, &thr, &xw, true, threads,
                                              SimdBackend::default()),
                           want_f32, "{layout:?} threads={threads}");
            }
            // the batched bit kernel agrees with the single-sample one
            let stride = 70usize.div_ceil(64);
            let mut xws = vec![0u64; 3 * stride];
            for b in 0..3 {
                xws[b * stride..(b + 1) * stride].copy_from_slice(&xw);
            }
            let batch = fc.forward_int_bits_batch(&packed, &thr, &xws, stride, 3, 2,
                                                  SimdBackend::default());
            let single = fc.forward_int_bits(&packed, &thr, &xw, 1,
                                             SimdBackend::default());
            let so = 70usize.div_ceil(64);
            for b in 0..3 {
                assert_eq!(&batch[b * so..(b + 1) * so], &single[..], "sample {b}");
            }
            let mut scratch = Scratch::default();
            let fbatch = fc.forward_int_f32_batch(&packed, &thr, &xws, stride, 3,
                                                  true, &mut scratch, 2,
                                                  SimdBackend::default());
            for (b, row) in fbatch.iter().enumerate() {
                assert_eq!(row, &want_f32, "f32 batch sample {b}");
            }
        }
    }

    #[test]
    fn relu_applies_on_all_paths() {
        let fc = tiled_fc(8, 24, 4, 13);
        let packed = fc.build_packed(PackedLayout::default()).unwrap();
        let mut rng = Rng::new(14);
        let x = rng.normal_vec(24, 1.0);
        let mut s = Scratch::default();
        assert!(fc.forward_reference(&x, true).iter().all(|&v| v >= 0.0));
        assert!(fc.forward_packed(&packed, &x, true, &mut s, 1, SimdBackend::default())
            .iter()
            .all(|&v| v >= 0.0));
        assert!(fc.forward_int8(&x, true, &mut s, 1).iter().all(|&v| v >= 0.0));
        assert!(fc.forward_quantized_oracle(&x, true).iter().all(|&v| v >= 0.0));
    }
}
