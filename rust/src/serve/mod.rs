//! Serving stack: bounded request queue + dynamic batcher + worker pool.
//!
//! TBN is a compression paper, so the serving layer is deliberately thin
//! (DESIGN.md §1): a threaded inference server that batches concurrent
//! requests up to `max_batch` within a `window`, runs them through a
//! `BatchModel`, and reports latency/throughput stats.  It serves the
//! *native* sub-bit engine (`nn::MlpEngine`) — including the bit-packed
//! XNOR fast path — and is exercised end-to-end by `tbn serve` and
//! `rust/tests/serving.rs`.
//!
//! Concurrency model: one shared `Mutex`+`Condvar` request queue feeds N
//! worker threads (`Server::start_pool`), each of which independently forms
//! dynamic batches.  The model is shared through an `Arc`, so a packed
//! `MlpEngine` is packed once and served by every worker.
//!
//! Backpressure: the queue is bounded by [`ServePolicy::queue_cap`]; when
//! full, [`OverflowPolicy`] selects between shedding the request
//! (`Reject` — `submit` returns an error and `ServerStats::rejected`
//! counts it) and blocking the submitter until a worker drains space
//! (`Block`).  Per-worker request/batch counters live in
//! [`ServerStats::per_worker`], and a ring buffer of recent request
//! durations feeds the tail-latency report
//! ([`ServerStats::latency_percentiles`]: p50/p95/p99, printed by
//! `tbn serve`).
//!
//! Network layer: [`registry::ModelRegistry`] holds many named pools in
//! one process with `Arc`-swap hot model replacement, and
//! [`net::NetServer`] fronts the registry with a `std::net` TCP listener
//! speaking minimal HTTP/1.1 (load shedding as `503`, graceful drain on
//! shutdown/SIGTERM).  Connections are handled by one of two
//! [`net::NetModel`]s: the default readiness-driven `mux` event loop
//! (epoll/poll FFI + nonblocking sockets; bounded threads at any
//! connection count, blocking inference dispatched off-loop to keep the
//! pool semantics above intact) or the thread-per-connection `threads`
//! baseline kept for A/B comparison.  [`loadgen`] is the open-loop
//! Poisson load generator that turns "heavy traffic" into measured
//! p50/p95/p99/p99.9 and saturation-throughput numbers across connection
//! counts (`tbn loadgen`, `benches/table_serve.rs`, `BENCH_serve.json`).

pub mod loadgen;
#[cfg(unix)]
mod mux;
pub mod net;
pub mod registry;

pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use net::{
    install_shutdown_flag, ModelBuilder, NetConfig, NetModel, NetServer, NetStatsSnapshot,
};
pub use registry::{ModelInfo, ModelRegistry};

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::nn::{EnginePath, SimdBackend};

/// Anything that can run a batch of flat f32 samples to output vectors.
pub trait BatchModel: Send + 'static {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>>;
    fn in_dim(&self) -> usize;
}

impl BatchModel for crate::nn::MlpEngine {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        // batched entry point: amortizes bit-packing on the packed path
        self.forward_batch(xs)
    }

    fn in_dim(&self) -> usize {
        crate::nn::MlpEngine::in_dim(self)
    }
}

/// A raw layer-graph engine serves directly, so lowered branching
/// architectures (ResNet residual graphs, T-Net PointNets) run behind the
/// same batching pool as the FC-chain wrapper.
impl BatchModel for crate::nn::Engine {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.forward_batch(xs)
    }

    fn in_dim(&self) -> usize {
        self.in_len()
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// A completed inference with its timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub y: Vec<f32>,
    pub queue_us: u64,
    pub total_us: u64,
    pub batch_size: usize,
}

/// Per-worker serving counters.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub served: usize,
    pub batches: usize,
}

/// Capacity of the recent-latency ring buffer behind
/// [`ServerStats::latency_percentiles`].
pub const LATENCY_RING_CAP: usize = 4096;

/// Latency percentiles over the most recent [`LATENCY_RING_CAP`] requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyPercentiles {
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Requests the report was computed over (`<= LATENCY_RING_CAP`).
    pub samples: usize,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: usize,
    pub batches: usize,
    /// Requests shed by the `Reject` overflow policy (never enqueued).
    pub rejected: usize,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
    pub batch_size_sum: usize,
    /// Worker threads serving the queue.
    pub workers: usize,
    /// One entry per worker thread; sums match `served` / `batches`.
    pub per_worker: Vec<WorkerStats>,
    /// End-to-end latencies (us) of the most recent requests, oldest
    /// first, capacity [`LATENCY_RING_CAP`] — the window behind the
    /// percentile report.
    pub latency_ring: VecDeque<u64>,
    /// Intra-op kernel threads each worker's engine runs per request
    /// ([`ServePolicy::kernel_threads`]): kernel-level parallelism that
    /// composes with the worker pool, so peak busy cores ≈
    /// `workers * kernel_threads`.
    pub kernel_threads: usize,
    /// XNOR-popcount backend the served engine's packed kernels dispatch to
    /// ([`ServePolicy::simd`]) — printed in the serve stats line so a
    /// perf report always names the kernel generation it measured.
    pub simd: SimdBackend,
    /// Execution path the served engine runs ([`ServePolicy::engine`]) —
    /// printed in the serve stats line so a perf report always names the
    /// path (packed vs the threshold-folded integer pipeline vs reference)
    /// it measured.
    pub engine: EnginePath,
}

impl ServerStats {
    pub fn mean_latency_us(&self) -> f64 {
        self.total_latency_us as f64 / self.served.max(1) as f64
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_size_sum as f64 / self.batches.max(1) as f64
    }

    /// Record one completed request's end-to-end latency: aggregate
    /// counters plus the bounded percentile ring (oldest entry evicted at
    /// capacity).  The single write path the workers and the ring-bound
    /// test share.
    pub fn record_latency(&mut self, total_us: u64) {
        self.served += 1;
        self.total_latency_us += total_us;
        self.max_latency_us = self.max_latency_us.max(total_us);
        if self.latency_ring.len() == LATENCY_RING_CAP {
            self.latency_ring.pop_front();
        }
        self.latency_ring.push_back(total_us);
    }

    /// p50/p95/p99 over the latency ring (nearest-rank on the sorted
    /// window); `None` before the first completed request.
    ///
    /// True nearest-rank: the p-th percentile of `N` sorted samples is the
    /// value at 1-based rank `ceil(p * N)` (clamped to `[1, N]`) — e.g.
    /// p50 over 4 samples is the 2nd smallest, not the 3rd as the previous
    /// `round(p * (N - 1))` interpolation index picked.
    pub fn latency_percentiles(&self) -> Option<LatencyPercentiles> {
        if self.latency_ring.is_empty() {
            return None;
        }
        let mut v: Vec<u64> = self.latency_ring.iter().copied().collect();
        v.sort_unstable();
        let pick = |p: f64| {
            let rank = (p * v.len() as f64).ceil() as usize;
            v[rank.clamp(1, v.len()) - 1]
        };
        Some(LatencyPercentiles {
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            samples: v.len(),
        })
    }
}

/// Dynamic batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// How long the batcher waits for more requests after the first arrives.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, window: Duration::from_micros(200) }
    }
}

/// What `submit` does when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Shed the request: `submit` returns an error, `stats.rejected` counts it.
    Reject,
    /// Block the submitter until a worker drains space (or the server closes).
    Block,
}

/// Full serving policy: batching + queue bound + overflow behavior.
#[derive(Debug, Clone)]
pub struct ServePolicy {
    pub batch: BatchPolicy,
    /// Max requests waiting in the queue (in-flight batches not counted);
    /// clamped to at least 1.
    pub queue_cap: usize,
    pub on_full: OverflowPolicy,
    /// Intra-op kernel threads the served engine runs with (informational
    /// for the stats report — the engine itself is configured via
    /// `Engine::with_threads`; keep the two in sync).  Composes with the
    /// worker pool: each in-flight batch occupies up to this many cores.
    pub kernel_threads: usize,
    /// XNOR-popcount backend the served engine runs (informational for the
    /// stats report, like `kernel_threads` — the engine itself is
    /// configured via `Engine::with_simd`; keep the two in sync).
    /// Defaults to the process-wide [`SimdBackend::default`] resolution.
    pub simd: SimdBackend,
    /// Execution path of the served engine (informational for the stats
    /// report, like `simd` — the engine itself is built with
    /// `Engine::with_layout_graph`/`MlpEngine::with_path`; keep the two in
    /// sync).
    pub engine: EnginePath,
}

impl Default for ServePolicy {
    fn default() -> Self {
        ServePolicy {
            batch: BatchPolicy::default(),
            queue_cap: 1024,
            on_full: OverflowPolicy::Block,
            kernel_threads: 1,
            simd: SimdBackend::default(),
            engine: EnginePath::default(),
        }
    }
}

impl ServePolicy {
    /// The pre-backpressure behavior: an effectively unbounded queue.
    pub fn unbounded(batch: BatchPolicy) -> ServePolicy {
        ServePolicy { batch, queue_cap: usize::MAX, ..ServePolicy::default() }
    }
}

// ---------------------------------------------------------------------------
// Shared request queue
// ---------------------------------------------------------------------------

enum Pop {
    Got(Request),
    TimedOut,
    Closed,
}

/// Why a push was refused (the request is dropped either way).
enum PushRefusal {
    Full,
    Closed,
}

/// Bounded MPMC request queue: any number of submitters, N batching workers.
/// Closing lets workers drain what is already queued, then exit — no request
/// that was accepted is ever dropped.  Submitters blocked on a full queue
/// are woken by pops (space) and by close (shutdown error).
struct Queue {
    state: Mutex<(VecDeque<Request>, bool)>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl Queue {
    fn new(cap: usize) -> Queue {
        Queue {
            state: Mutex::new((VecDeque::new(), false)),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue; refuses after `close`, and on a full queue either refuses
    /// (`block_on_full = false`) or waits for space.
    fn push(&self, r: Request, block_on_full: bool) -> Result<(), PushRefusal> {
        let mut s = self.state.lock().unwrap();
        while !s.1 && s.0.len() >= self.cap {
            if !block_on_full {
                return Err(PushRefusal::Full);
            }
            s = self.not_full.wait(s).unwrap();
        }
        if s.1 {
            return Err(PushRefusal::Closed);
        }
        s.0.push_back(r);
        self.not_empty.notify_one();
        Ok(())
    }

    fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.1 = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Block until a request is available or the queue is closed and empty.
    fn pop_blocking(&self) -> Option<Request> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(r) = s.0.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if s.1 {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Wait until `deadline` for one more request (used to fill a batch).
    fn pop_until(&self, deadline: Instant) -> Pop {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(r) = s.0.pop_front() {
                self.not_full.notify_one();
                return Pop::Got(r);
            }
            if s.1 {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(s, deadline - now).unwrap();
            s = guard;
            if timeout.timed_out() {
                // a request may have raced in right at the deadline
                if let Some(r) = s.0.pop_front() {
                    self.not_full.notify_one();
                    return Pop::Got(r);
                }
                return Pop::TimedOut;
            }
        }
    }
}

fn worker_loop<M: BatchModel>(worker: usize, queue: &Queue, model: &M,
                              stats: &Mutex<ServerStats>, policy: &BatchPolicy) {
    loop {
        let Some(first) = queue.pop_blocking() else { return };
        let mut batch = vec![first];
        let deadline = Instant::now() + policy.window;
        while batch.len() < policy.max_batch {
            match queue.pop_until(deadline) {
                Pop::Got(r) => batch.push(r),
                Pop::TimedOut | Pop::Closed => break,
            }
        }
        let run_start = Instant::now();
        let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
        let ys = model.infer_batch(&xs);
        let bsz = batch.len();
        let mut s = stats.lock().unwrap();
        s.batches += 1;
        s.batch_size_sum += bsz;
        s.per_worker[worker].batches += 1;
        s.per_worker[worker].served += bsz;
        for (req, y) in batch.into_iter().zip(ys) {
            let queue_us = run_start.saturating_duration_since(req.enqueued).as_micros() as u64;
            let total_us = req.enqueued.elapsed().as_micros() as u64;
            s.record_latency(total_us);
            let _ = req.resp.send(Response { y, queue_us, total_us, batch_size: bsz });
        }
    }
}

/// Handle to a running server. Dropping it shuts the workers down after they
/// drain the queue.
pub struct Server {
    queue: Arc<Queue>,
    workers: Vec<thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    on_full: OverflowPolicy,
    in_dim: usize,
}

impl Server {
    /// Single-worker server owning its model (the original API; unbounded
    /// queue).
    pub fn start<M: BatchModel + Sync>(model: M, policy: BatchPolicy) -> Server {
        Server::start_pool(Arc::new(model), policy, 1)
    }

    /// `workers` batching threads sharing one `Arc`'d model over a single
    /// request queue (unbounded, the pre-backpressure behavior). With a
    /// packed `MlpEngine` the rows are packed once and every worker serves
    /// from the same packed weights.
    pub fn start_pool<M: BatchModel + Sync>(model: Arc<M>, policy: BatchPolicy,
                                            workers: usize) -> Server {
        Server::start_pool_with(model, ServePolicy::unbounded(policy), workers)
    }

    /// Worker pool with the full serving policy: bounded queue +
    /// backpressure behavior.
    pub fn start_pool_with<M: BatchModel + Sync>(model: Arc<M>, policy: ServePolicy,
                                                 workers: usize) -> Server {
        let n_workers = workers.max(1);
        let queue = Arc::new(Queue::new(policy.queue_cap));
        let stats = Arc::new(Mutex::new(ServerStats {
            workers: n_workers,
            per_worker: vec![WorkerStats::default(); n_workers],
            kernel_threads: policy.kernel_threads.max(1),
            simd: policy.simd,
            engine: policy.engine,
            ..ServerStats::default()
        }));
        let in_dim = model.in_dim();
        let handles = (0..n_workers)
            .map(|w| {
                let q = queue.clone();
                let m = model.clone();
                let st = stats.clone();
                let pol = policy.batch.clone();
                thread::spawn(move || worker_loop(w, &q, &*m, &st, &pol))
            })
            .collect();
        Server { queue, workers: handles, stats, on_full: policy.on_full, in_dim }
    }

    /// Submit a request; returns a receiver for the response.  On a full
    /// queue this sheds (`Reject`) or blocks (`Block`) per the policy.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>, String> {
        if x.len() != self.in_dim {
            return Err(format!("input dim {} != model dim {}", x.len(), self.in_dim));
        }
        let (rtx, rrx) = mpsc::channel();
        let block = self.on_full == OverflowPolicy::Block;
        match self.queue.push(Request { x, enqueued: Instant::now(), resp: rtx }, block) {
            Ok(()) => Ok(rrx),
            Err(PushRefusal::Full) => {
                self.stats.lock().unwrap().rejected += 1;
                Err("server queue full (backpressure: rejected)".to_string())
            }
            Err(PushRefusal::Closed) => Err("server shut down".to_string()),
        }
    }

    /// Blocking single-request convenience.
    pub fn infer(&self, x: Vec<f32>) -> Result<Response, String> {
        self.submit(x)?
            .recv()
            .map_err(|_| "server dropped response".to_string())
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Input width the served model expects (what `submit` validates
    /// against; served by `GET /models` so load generators can synthesize
    /// well-formed requests).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close(); // workers drain the queue, then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: y = [sum(x)], records batch sizes implicitly via stats.
    struct SumModel {
        dim: usize,
        delay: Duration,
    }

    impl BatchModel for SumModel {
        fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
            if !self.delay.is_zero() {
                thread::sleep(self.delay);
            }
            xs.iter().map(|x| vec![x.iter().sum()]).collect()
        }

        fn in_dim(&self) -> usize {
            self.dim
        }
    }

    #[test]
    fn serves_correct_results() {
        let server = Server::start(SumModel { dim: 4, delay: Duration::ZERO },
                                   BatchPolicy::default());
        let r = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.y, vec![10.0]);
    }

    #[test]
    fn rejects_wrong_dim() {
        let server = Server::start(SumModel { dim: 4, delay: Duration::ZERO },
                                   BatchPolicy::default());
        assert!(server.submit(vec![1.0]).is_err());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let server = Arc::new(Server::start(
            SumModel { dim: 2, delay: Duration::from_micros(100) },
            BatchPolicy { max_batch: 8, window: Duration::from_micros(500) },
        ));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = server.clone();
            handles.push(thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = s.infer(vec![v, 1.0]).unwrap();
                    got.push((v, r.y[0]));
                }
                got
            }));
        }
        let mut total = 0;
        for h in handles {
            for (v, y) in h.join().unwrap() {
                assert_eq!(y, v + 1.0);
                total += 1;
            }
        }
        assert_eq!(total, 100);
        let stats = server.stats();
        assert_eq!(stats.served, 100);
        assert!(stats.batches <= 100);
        assert_eq!(stats.workers, 1);
    }

    #[test]
    fn batching_actually_batches() {
        let server = Arc::new(Server::start(
            SumModel { dim: 1, delay: Duration::from_millis(2) },
            BatchPolicy { max_batch: 16, window: Duration::from_millis(4) },
        ));
        // submit 16 requests as fast as possible, then await all
        let rxs: Vec<_> = (0..16).map(|i| server.submit(vec![i as f32]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.stats();
        assert!(stats.mean_batch() > 1.5, "mean batch {}", stats.mean_batch());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let server = Server::start(SumModel { dim: 1, delay: Duration::ZERO },
                                   BatchPolicy::default());
        let _ = server.infer(vec![1.0]).unwrap();
        drop(server); // must not hang
    }

    #[test]
    fn pool_shares_one_model_across_workers() {
        let model = Arc::new(SumModel { dim: 2, delay: Duration::from_micros(200) });
        let server = Arc::new(Server::start_pool(
            model,
            BatchPolicy { max_batch: 4, window: Duration::from_micros(300) },
            3,
        ));
        assert_eq!(server.stats().workers, 3);
        let mut handles = Vec::new();
        for t in 0..6 {
            let s = server.clone();
            handles.push(thread::spawn(move || {
                for i in 0..20 {
                    let v = (t * 1000 + i) as f32;
                    let r = s.infer(vec![v, 2.0]).unwrap();
                    assert_eq!(r.y[0], v + 2.0);
                    assert!(r.batch_size >= 1 && r.batch_size <= 4);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.served, 120);
        assert_eq!(stats.batch_size_sum, 120);
        assert!(stats.batches >= 120 / 4);
        drop(server); // pool must join cleanly
    }

    #[test]
    fn pool_of_zero_workers_clamps_to_one() {
        let server = Server::start_pool(
            Arc::new(SumModel { dim: 1, delay: Duration::ZERO }),
            BatchPolicy::default(),
            0,
        );
        assert_eq!(server.stats().workers, 1);
        assert_eq!(server.infer(vec![5.0]).unwrap().y, vec![5.0]);
    }

    #[test]
    fn reject_policy_sheds_load_and_counts_it() {
        // one slow worker, queue of 1, no batching: a fast burst must shed
        let server = Server::start_pool_with(
            Arc::new(SumModel { dim: 1, delay: Duration::from_millis(30) }),
            ServePolicy {
                batch: BatchPolicy { max_batch: 1, window: Duration::ZERO },
                queue_cap: 1,
                on_full: OverflowPolicy::Reject,
                kernel_threads: 1,
                simd: SimdBackend::default(),
                engine: EnginePath::default(),
            },
            1,
        );
        let total = 12usize;
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..total {
            match server.submit(vec![i as f32]) {
                Ok(rx) => accepted.push(rx),
                Err(e) => {
                    assert!(e.contains("queue full"), "unexpected error: {e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected >= 1, "a 12-deep instant burst must overflow cap 1");
        // every accepted request is still answered
        for rx in accepted {
            rx.recv().expect("accepted request dropped");
        }
        let stats = server.stats();
        assert_eq!(stats.rejected, rejected);
        assert_eq!(stats.served + stats.rejected, total);
    }

    #[test]
    fn block_policy_never_drops() {
        let server = Arc::new(Server::start_pool_with(
            Arc::new(SumModel { dim: 1, delay: Duration::from_micros(300) }),
            ServePolicy {
                batch: BatchPolicy { max_batch: 4, window: Duration::from_micros(100) },
                queue_cap: 2,
                on_full: OverflowPolicy::Block,
                kernel_threads: 1,
                simd: SimdBackend::default(),
                engine: EnginePath::default(),
            },
            2,
        ));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let s = server.clone();
            handles.push(thread::spawn(move || {
                for i in 0..15 {
                    let v = (t * 100 + i) as f32;
                    let r = s.infer(vec![v]).unwrap(); // blocks, never rejects
                    assert_eq!(r.y[0], v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.served, 60);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn latency_percentiles_report_tail_order() {
        // empty stats: no report
        assert!(ServerStats::default().latency_percentiles().is_none());

        let server = Server::start(SumModel { dim: 1, delay: Duration::from_micros(50) },
                                   BatchPolicy { max_batch: 4, window: Duration::ZERO });
        let rxs: Vec<_> = (0..40).map(|i| server.submit(vec![i as f32]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.latency_ring.len(), 40);
        let p = stats.latency_percentiles().expect("served requests -> report");
        assert_eq!(p.samples, 40);
        assert!(p.p50_us <= p.p95_us && p.p95_us <= p.p99_us,
                "percentiles must be ordered: {p:?}");
        assert!(p.p99_us <= stats.max_latency_us);
        assert!(p.p50_us > 0, "a 50us model cannot have zero p50");
    }

    /// True nearest-rank (1-based rank `ceil(p * N)`) pinned at every
    /// window size 1–5.  The regression case is N=4: p50 must be the 2nd
    /// smallest sample (rank `ceil(0.5 * 4) = 2`), where the old
    /// `round(p * (N - 1))` index picked the 3rd.
    #[test]
    fn latency_percentiles_are_nearest_rank_on_small_windows() {
        let window = |vals: &[u64]| {
            let mut stats = ServerStats::default();
            for &v in vals {
                stats.record_latency(v);
            }
            stats.latency_percentiles().unwrap()
        };
        // N=1: every percentile is the only sample
        let p = window(&[7]);
        assert_eq!((p.p50_us, p.p95_us, p.p99_us, p.samples), (7, 7, 7, 1));
        // N=2: p50 -> rank 1, p95/p99 -> rank 2
        let p = window(&[10, 20]);
        assert_eq!((p.p50_us, p.p95_us, p.p99_us), (10, 20, 20));
        // N=3: p50 -> rank 2, p95/p99 -> rank 3
        let p = window(&[10, 20, 30]);
        assert_eq!((p.p50_us, p.p95_us, p.p99_us), (20, 30, 30));
        // N=4: p50 -> rank 2 (the bugfix case), p95/p99 -> rank 4
        let p = window(&[10, 20, 30, 40]);
        assert_eq!((p.p50_us, p.p95_us, p.p99_us), (20, 40, 40));
        // N=5: p50 -> rank 3, p95/p99 -> rank 5; order of arrival irrelevant
        let p = window(&[50, 10, 40, 20, 30]);
        assert_eq!((p.p50_us, p.p95_us, p.p99_us), (30, 50, 50));
    }

    #[test]
    fn kernel_threads_flow_into_stats() {
        let server = Server::start_pool_with(
            Arc::new(SumModel { dim: 1, delay: Duration::ZERO }),
            ServePolicy { kernel_threads: 4, engine: EnginePath::PackedInt,
                          ..ServePolicy::default() },
            2,
        );
        assert_eq!(server.stats().kernel_threads, 4);
        assert_eq!(server.stats().simd, SimdBackend::default());
        assert_eq!(server.stats().engine, EnginePath::PackedInt);
        // the unbounded/legacy constructors report the serial default
        let legacy = Server::start(SumModel { dim: 1, delay: Duration::ZERO },
                                   BatchPolicy::default());
        assert_eq!(legacy.stats().kernel_threads, 1);
        assert_eq!(legacy.stats().engine, EnginePath::Reference);
    }

    #[test]
    fn latency_ring_is_bounded() {
        let mut stats = ServerStats::default();
        for i in 0..(LATENCY_RING_CAP as u64 + 100) {
            stats.record_latency(i); // the same path worker_loop uses
        }
        assert_eq!(stats.latency_ring.len(), LATENCY_RING_CAP);
        // oldest entries evicted first
        assert_eq!(*stats.latency_ring.front().unwrap(), 100);
        assert_eq!(stats.served, LATENCY_RING_CAP + 100);
        assert_eq!(stats.max_latency_us, LATENCY_RING_CAP as u64 + 99);
        let p = stats.latency_percentiles().unwrap();
        assert_eq!(p.samples, LATENCY_RING_CAP);
    }

    #[test]
    fn per_worker_counters_sum_to_totals() {
        let server = Arc::new(Server::start_pool_with(
            Arc::new(SumModel { dim: 1, delay: Duration::from_micros(200) }),
            ServePolicy {
                batch: BatchPolicy { max_batch: 4, window: Duration::from_micros(200) },
                queue_cap: 64,
                on_full: OverflowPolicy::Block,
                kernel_threads: 1,
                simd: SimdBackend::default(),
                engine: EnginePath::default(),
            },
            3,
        ));
        let rxs: Vec<_> = (0..48).map(|i| server.submit(vec![i as f32]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.per_worker.len(), 3);
        assert_eq!(stats.per_worker.iter().map(|w| w.served).sum::<usize>(), stats.served);
        assert_eq!(stats.per_worker.iter().map(|w| w.batches).sum::<usize>(),
                   stats.batches);
        assert_eq!(stats.served, 48);
    }
}
