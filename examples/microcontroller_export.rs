//! Microcontroller deployment (paper §5.1 / Table 6): train the deployment
//! MLP, export both a BWNN and a TBN_4 model to TBNZ, and compare speed
//! (FPS), max memory and storage exactly as the paper's Table 6 does —
//! against the Arduino budget (1MB flash, 250KB RAM).

use anyhow::{anyhow, Result};
use tiledbits::config::Manifest;
use tiledbits::nn::{MlpEngine, Nonlin};
use tiledbits::runtime::Runtime;
use tiledbits::train::{export, Trainer, TrainOptions};
use tiledbits::util::human_bytes;

const FLASH_BUDGET: usize = 1_000_000; // 1MB storage
const RAM_BUDGET: usize = 250_000; // 250KB memory

fn build(rt: &Runtime, manifest: &Manifest, id: &str, steps: usize)
         -> Result<(MlpEngine, f64)> {
    let exp = manifest.by_id(id).ok_or_else(|| anyhow!("missing {id}"))?;
    let trainer = Trainer::new(rt, exp)?;
    let (result, model) = trainer.run(&TrainOptions {
        steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None })?;
    let tbnz = export::to_tbnz(exp, &model)?;
    Ok((MlpEngine::new(tbnz, Nonlin::Relu).map_err(|e| anyhow!(e))?,
        result.final_eval.metric))
}

fn main() -> Result<()> {
    let artifacts = std::env::var("TBN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("TBN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(300);
    let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
    let rt = Runtime::new(&artifacts)?;

    println!("== microcontroller deployment (Table 6) ==");
    println!("model: MLP 256 -> 128 -> 10, fused ReLU; budget: 1MB flash / 250KB RAM\n");

    let (bwnn, bwnn_acc) = build(&rt, &manifest, "mlp_micro_bwnn", steps)?;
    let (tbn, tbn_acc) = build(&rt, &manifest, "mlp_micro_tbn4", steps)?;

    let x = vec![0.25f32; bwnn.in_dim()];
    let iters = 2000;
    let rows = [
        ("BWNN", &bwnn, bwnn_acc),
        ("TBN_4", &tbn, tbn_acc),
    ];
    println!("{:8} {:>12} {:>14} {:>12} {:>10}", "Model", "Speed (FPS)",
             "Max Mem (KB)", "Storage (KB)", "Test Acc");
    for (name, engine, acc) in rows {
        let fps = engine.measure_fps(&x, iters);
        let mem = engine.peak_memory_bytes();
        let sto = engine.storage_bytes();
        println!("{:8} {:>12.1} {:>14.2} {:>12.2} {:>9.1}%",
                 name, fps, mem as f64 / 1e3, sto as f64 / 1e3, 100.0 * acc);
        assert!(sto < FLASH_BUDGET, "{name} exceeds the flash budget");
        assert!(mem < RAM_BUDGET, "{name} exceeds the RAM budget");
    }

    let mem_saving = bwnn.peak_memory_bytes() as f64 / tbn.peak_memory_bytes() as f64;
    let sto_saving = bwnn.storage_bytes() as f64 / tbn.storage_bytes() as f64;
    println!("\nTBN_4 vs BWNN: {mem_saving:.2}x less memory, {sto_saving:.2}x less storage");
    println!("(paper: 2.4x memory, 3.8x storage on the 784-input MNIST variant)");
    println!("headroom: storage {} of {}, memory {} of {}",
             human_bytes(tbn.storage_bytes() as f64), human_bytes(FLASH_BUDGET as f64),
             human_bytes(tbn.peak_memory_bytes() as f64), human_bytes(RAM_BUDGET as f64));
    Ok(())
}
