//! Figure 5: GPU memory allocated during model inference, layer by layer —
//! the allocator-model trace for the ImageNet ViT and PointNet, standard vs
//! tiled kernels, rendered as an ASCII profile; plus a measured per-layer
//! trace of the weight words the packed engine touches per forward,
//! expanded rows vs the tile-resident layout.

use tiledbits::arch;
use tiledbits::bench_util::header;
use tiledbits::nn::{lower_arch_spec, Engine, EnginePath, LowerOptions, Node, Nonlin,
                    PackedLayout};
use tiledbits::tbn::memory::{simulate, KernelKind, MemoryReport};
use tiledbits::tbn::{AlphaMode, TilingPolicy};

fn sparkline(r: &MemoryReport, width: usize) -> String {
    let max = r.trace.iter().map(|(_, b)| *b).fold(0.0, f64::max).max(1.0);
    let step = (r.trace.len().max(1) as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
    let mut i = 0.0;
    while (i as usize) < r.trace.len() && out.len() < width {
        let v = r.trace[i as usize].1 / max;
        out.push(glyphs[((v * (glyphs.len() - 1) as f64).round() as usize)
                            .min(glyphs.len() - 1)]);
        i += step;
    }
    out
}

fn show(title: &str, std_r: &MemoryReport, tiled_r: &MemoryReport) {
    println!("\n-- {title} --");
    println!("standard kernel: peak {:7.2} MB  |{}|",
             std_r.peak_bytes / 1e6, sparkline(std_r, 60));
    println!("tiled kernel:    peak {:7.2} MB  |{}|",
             tiled_r.peak_bytes / 1e6, sparkline(tiled_r, 60));
    println!("reduction: {:.1}x", std_r.peak_bytes / tiled_r.peak_bytes);
}

fn main() {
    header("Figure 5: per-layer memory trace during inference");

    // ViT: full-precision weights, standard vs tiled (paper left panel, 2.8x)
    let vit = arch::vit_small_imagenet();
    let tbn4 = TilingPolicy::tbn(4, 150_000);
    let fp = TilingPolicy::fp();
    let vit_std = simulate(&vit, &fp, KernelKind::FpStandard);
    let vit_tiled = simulate(&vit, &tbn4, KernelKind::FpTiled);
    show("ImageNet ViT (fp32 weights)", &vit_std, &vit_tiled);
    println!("paper: 2.8x peak reduction (222.5 -> 78.5 MB)");

    // PointNet: the paper's right panel (1.2x — activations dominate)
    let pn = arch::pointnet_cls();
    let pn_pol = TilingPolicy::tbn(4, 64_000);
    let pn_std = simulate(&pn, &fp, KernelKind::FpStandard);
    let pn_tiled = simulate(&pn, &pn_pol, KernelKind::FpTiled);
    show("PointNet (fp32 weights)", &pn_std, &pn_tiled);
    println!("paper: 1.2x peak reduction (activations dominate PointNet)");

    // packed variants for completeness
    let vit_tbn = simulate(&vit, &tbn4, KernelKind::TbnPacked);
    let vit_bw = simulate(&vit, &TilingPolicy::bwnn(0), KernelKind::BwnnPacked);
    println!("\npacked: BWNN peak {:.2} MB, TBN_4 peak {:.2} MB ({:.1}x)",
             vit_bw.peak_bytes / 1e6, vit_tbn.peak_bytes / 1e6,
             vit_bw.peak_bytes / vit_tbn.peak_bytes);
    println!("\nshape check: ViT reduction >> PointNet reduction, as in the paper.");

    // measured per-layer weight-word trace on the native packed engine:
    // how many distinct u64 weight words each binarized layer touches per
    // forward under the expanded rows vs the tile-resident layout (the
    // total word *reads* are identical; residency is the delta).  The list
    // includes a branching graph (resnet_micro) and two transformer
    // encoders (vit_micro, tst_weather) — joins, layer norms and attention
    // are weightless, so the trace covers exactly the weight nodes.
    for (name, spec) in [
        ("cnn_micro", arch::cnn_micro()),
        ("resnet_micro", arch::resnet_micro()),
        ("vgg_small_cifar", arch::vgg_small_cifar()),
        ("vit_micro", arch::vit_micro()),
        ("tst_weather", arch::tst_weather()),
    ] {
        let input = spec.native_input().expect("first-layer input shape");
        let opts = LowerOptions { input, p: 4, alpha_mode: AlphaMode::PerTile, seed: 5 };
        let graph = lower_arch_spec(&spec, &opts).expect("lowerable paper spec");
        let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::Expanded)
            .unwrap();
        let tile = Engine::with_layout_graph(graph, Nonlin::Relu, EnginePath::Packed,
                                             PackedLayout::TileResident)
            .unwrap();
        println!("\n-- {name}: weight words touched per forward (binarized layers) --");
        println!("{:14} {:>10} {:>12} {:>14} {:>8}", "layer", "row passes",
                 "expanded w", "tile-resident", "ratio");
        for idx in 0..expanded.graph().len() {
            let Some(pe) = expanded.packed_layer(idx) else { continue };
            let pt = tile.packed_layer(idx).expect("same packed node set");
            let passes = match expanded.node(idx) {
                Node::Conv2d(c) => c.h_out * c.w_out,
                _ => 1,
            };
            let (we, wt) = (pe.weight_words(), pt.weight_words());
            println!("{:14} {passes:>10} {we:>12} {wt:>14} {:>7.1}x",
                     expanded.node(idx).name(),
                     we as f64 / wt.max(1) as f64);
        }
    }
}
