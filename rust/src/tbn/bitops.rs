//! Bit operations: the Table 2 accounting model *and* the measured kernels
//! it models — word-level XNOR + popcount dot products over `u64`-packed
//! sign vectors, the arithmetic the `nn::packed` fast path runs on.
//!
//! Unit convention (standard in the BNN literature and consistent with the
//! paper's numbers — FP/IR-Net = 64x exactly): one full-precision MAC costs
//! 64 bit-ops; one binary (XNOR+popcount) MAC costs 1 bit-op.
//!
//! TBN reduction model (paper §4.1): with default training (single tile per
//! layer) a tiled conv layer's output channels replicate in groups of p, so
//! only one channel per group is computed — a p-fold reduction.  In addition,
//! when the *previous* layer was tiled, this layer's input channels arrive in
//! p identical groups, so the inner reduction folds weight sums per group —
//! a further p-fold reduction where applicable.  This yields the >p overall
//! savings the paper reports (6.7x at p=4 on ResNet18).

use crate::arch::{ArchSpec, Kind};
use super::policy::{decide, Quant, TilingPolicy};

// ---------------------------------------------------------------------------
// Word-level XNOR-popcount kernels
// ---------------------------------------------------------------------------
//
// Layout convention is `tensor::BitVec`'s: bit k of a packed slice lives in
// word k / 64 at position k % 64 (LSB-first); bit = 1 encodes +1.

/// XNOR-popcount dot product over the bit range `[start, start + len)` of
/// two packed sign slices: returns `sum_i a_i * b_i` over that range, i.e.
/// `2 * agreements - len`.
///
/// This is the one bit-op the whole packed inference path reduces to; the
/// per-layer alpha scaling happens outside, once per constant-alpha run.
///
/// The interior full words run through a 4-wide unrolled `count_ones`
/// accumulation (four independent chains the CPU can retire in parallel);
/// only the boundary words pay the masking.
/// `benches/table2_bitops.rs` reports the words-per-second delta against
/// [`xnor_dot_words_range_scalar`].
#[inline]
pub fn xnor_dot_words_range(a: &[u64], b: &[u64], start: usize, len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    let end = start + len;
    debug_assert!(end <= a.len() * 64 && end <= b.len() * 64);
    let first_w = start / 64;
    let last_w = (end - 1) / 64;
    // whole range inside one word: mask both ends at once
    if first_w == last_w {
        let mut mask = u64::MAX << (start % 64);
        let valid = end - last_w * 64; // 1..=64 bits of this word are in range
        if valid < 64 {
            mask &= (1u64 << valid) - 1;
        }
        let same = ((!(a[first_w] ^ b[first_w])) & mask).count_ones() as i64;
        return 2 * same - len as i64;
    }
    let mut same: u64 = 0;
    let mut w = first_w;
    if start % 64 != 0 {
        // leading partial word
        let mask = u64::MAX << (start % 64);
        same += ((!(a[w] ^ b[w])) & mask).count_ones() as u64;
        w += 1;
    }
    // full words: [w, full_end)
    let full_end = if end % 64 == 0 { last_w + 1 } else { last_w };
    let (mut s0, mut s1, mut s2, mut s3) = (0u64, 0u64, 0u64, 0u64);
    while w + 4 <= full_end {
        s0 += (!(a[w] ^ b[w])).count_ones() as u64;
        s1 += (!(a[w + 1] ^ b[w + 1])).count_ones() as u64;
        s2 += (!(a[w + 2] ^ b[w + 2])).count_ones() as u64;
        s3 += (!(a[w + 3] ^ b[w + 3])).count_ones() as u64;
        w += 4;
    }
    same += s0 + s1 + s2 + s3;
    while w < full_end {
        same += (!(a[w] ^ b[w])).count_ones() as u64;
        w += 1;
    }
    if end % 64 != 0 {
        // trailing partial word
        let valid = end - last_w * 64;
        let mask = (1u64 << valid) - 1;
        same += ((!(a[last_w] ^ b[last_w])) & mask).count_ones() as u64;
    }
    2 * same as i64 - len as i64
}

/// Scalar (one-word-at-a-time) form of [`xnor_dot_words_range`] — the
/// pre-unroll baseline, kept for the before/after words-per-second
/// comparison in `benches/table2_bitops.rs` and as a second oracle for the
/// property tests.
#[inline]
pub fn xnor_dot_words_range_scalar(a: &[u64], b: &[u64], start: usize, len: usize) -> i64 {
    if len == 0 {
        return 0;
    }
    let end = start + len;
    debug_assert!(end <= a.len() * 64 && end <= b.len() * 64);
    let first_w = start / 64;
    let last_w = (end - 1) / 64;
    let mut same: i64 = 0;
    for w in first_w..=last_w {
        let mut mask = u64::MAX;
        if w == first_w {
            mask &= u64::MAX << (start % 64);
        }
        if w == last_w {
            let valid = end - w * 64; // 1..=64 bits of this word are in range
            if valid < 64 {
                mask &= (1u64 << valid) - 1;
            }
        }
        same += ((!(a[w] ^ b[w])) & mask).count_ones() as i64;
    }
    2 * same - len as i64
}

/// XNOR-popcount dot over the first `bits` bits of two packed sign slices.
#[inline]
pub fn xnor_dot_words(a: &[u64], b: &[u64], bits: usize) -> i64 {
    xnor_dot_words_range(a, b, 0, bits)
}

/// Bit-ops per fp MAC.
pub const FP_MAC_BITOPS: f64 = 64.0;
/// Bit-ops per binary MAC (XNOR + popcount, amortized per the BNN convention).
pub const BIN_MAC_BITOPS: f64 = 1.0;

/// Total bit-ops for a full-precision model.
pub fn fp_bitops(arch: &ArchSpec) -> f64 {
    arch.total_macs() as f64 * FP_MAC_BITOPS
}

/// Binary-weight model (IR-Net-style): every conv/FC MAC becomes binary.
pub fn bwnn_bitops(arch: &ArchSpec, policy: &TilingPolicy) -> f64 {
    arch.layers
        .iter()
        .map(|l| {
            let quantized = matches!(l.kind, Kind::Conv { .. } | Kind::Fc { .. })
                && decide(policy, l.params) != Quant::Fp;
            l.macs as f64 * if quantized { BIN_MAC_BITOPS } else { FP_MAC_BITOPS }
        })
        .sum()
}

/// TBN model: binary MACs with the replication reductions described above.
///
/// A tiled layer gets the output-replication p-fold reduction only when its
/// tile length is a multiple of the per-output-channel weight count (so whole
/// channels replicate — true for the paper's default configs); the input-fold
/// reduction applies when the producing layer was tiled.
pub fn tbn_bitops(arch: &ArchSpec, policy: &TilingPolicy) -> f64 {
    let mut total = 0.0;
    let mut prev_tiled_p: usize = 1;
    for l in &arch.layers {
        if !matches!(l.kind, Kind::Conv { .. } | Kind::Fc { .. }) {
            continue;
        }
        let quant = decide(policy, l.params);
        // input folding: if the producing layer's output channels replicate
        // in groups of p, any consumer can pre-sum weights per group
        let in_red = prev_tiled_p as f64;
        let cost = match quant {
            Quant::Fp => l.macs as f64 * FP_MAC_BITOPS,
            Quant::Bwnn => l.macs as f64 * BIN_MAC_BITOPS / in_red,
            Quant::Tiled { p } => {
                let q = l.params / p;
                // output replication: whole channels replicate iff q is a
                // multiple of the per-channel weight count
                let out_red = if q % l.per_channel() == 0 { p as f64 } else { 1.0 };
                l.macs as f64 * BIN_MAC_BITOPS / (out_red * in_red)
            }
        };
        total += cost;
        prev_tiled_p = match quant {
            Quant::Tiled { p } => {
                let q = l.params / p;
                if q % l.per_channel() == 0 { p } else { 1 }
            }
            _ => 1,
        };
    }
    total
}

/// One Table 2 row: (fp, bwnn, tbn) in G bit-ops plus the savings factor.
pub fn table2_row(arch: &ArchSpec, p: usize, lambda: usize) -> (f64, f64, f64, f64) {
    let tbn_pol = TilingPolicy::tbn(p, lambda);
    let bw_pol = TilingPolicy::bwnn(lambda);
    let fp = fp_bitops(arch) / 1e9;
    let bw = bwnn_bitops(arch, &bw_pol) / 1e9;
    let tb = tbn_bitops(arch, &tbn_pol) / 1e9;
    (fp, bw, tb, bw / tb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::tensor::BitVec;
    use crate::util::Rng;

    fn naive_sign_dot(a: &BitVec, b: &BitVec, start: usize, len: usize) -> i64 {
        (start..start + len)
            .map(|i| if a.get_bit(i) == b.get_bit(i) { 1i64 } else { -1i64 })
            .sum()
    }

    #[test]
    fn xnor_words_matches_naive_full_width() {
        let mut r = Rng::new(21);
        for len in [1usize, 5, 63, 64, 65, 128, 130, 200] {
            let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
            let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
            assert_eq!(
                xnor_dot_words(a.words(), b.words(), len),
                naive_sign_dot(&a, &b, 0, len),
                "len={len}"
            );
            assert_eq!(xnor_dot_words(a.words(), b.words(), len), a.xnor_dot(&b));
        }
    }

    #[test]
    fn xnor_words_range_matches_naive_subranges() {
        let mut r = Rng::new(22);
        let len = 300;
        let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
        let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
        for _ in 0..200 {
            let start = r.below(len);
            let l = 1 + r.below(len - start);
            assert_eq!(
                xnor_dot_words_range(a.words(), b.words(), start, l),
                naive_sign_dot(&a, &b, start, l),
                "start={start} len={l}"
            );
        }
        assert_eq!(xnor_dot_words_range(a.words(), b.words(), 17, 0), 0);
    }

    /// The 4-wide unrolled kernel and the scalar baseline are the same
    /// function — over long word runs (where the unroll engages), ragged
    /// boundaries and sub-word ranges.
    #[test]
    fn unrolled_matches_scalar_baseline() {
        let mut r = Rng::new(23);
        let len = 64 * 40 + 17; // > 4-word unroll body plus ragged tail
        let a = BitVec::from_signs(&r.normal_vec(len, 1.0));
        let b = BitVec::from_signs(&r.normal_vec(len, 1.0));
        for _ in 0..300 {
            let start = r.below(len);
            let l = 1 + r.below(len - start);
            assert_eq!(
                xnor_dot_words_range(a.words(), b.words(), start, l),
                xnor_dot_words_range_scalar(a.words(), b.words(), start, l),
                "start={start} len={l}"
            );
        }
        // word-aligned full-width run (pure unroll body)
        assert_eq!(
            xnor_dot_words_range(a.words(), b.words(), 0, 64 * 40),
            xnor_dot_words_range_scalar(a.words(), b.words(), 0, 64 * 40),
        );
    }

    #[test]
    fn xnor_words_single_word_masks() {
        // start and end inside the same word
        let a = BitVec::from_signs(&[1.0; 10]);
        let b = BitVec::from_signs(&[-1.0; 10]);
        assert_eq!(xnor_dot_words_range(a.words(), b.words(), 3, 5), -5);
        let b2 = BitVec::from_signs(&[1.0; 10]);
        assert_eq!(xnor_dot_words_range(a.words(), b2.words(), 3, 5), 5);
    }

    #[test]
    fn fp_to_bwnn_is_64x() {
        // the paper's FP/IR-Net ratio is exactly 64 (35.03 / 0.547)
        let a = arch::resnet18_cifar();
        let fp = fp_bitops(&a);
        let bw = bwnn_bitops(&a, &TilingPolicy::bwnn(0));
        assert!((fp / bw - 64.0).abs() < 1e-9);
    }

    #[test]
    fn tbn_beats_bwnn_substantially_on_resnet18() {
        // Table 2: IR-Net 0.547 -> TBN 0.082 is 6.7x at p=4.  Our accounting
        // model (output replication x input folding, residual/downsample
        // layers unfolded) lands in the same regime; the exact factor depends
        // on how aggressively the folded small-int MACs are costed.
        let (fp, bw, tb, factor) = table2_row(&arch::resnet18_cifar(), 4, 64_000);
        assert!(fp > bw && bw > tb);
        assert!((fp / bw - 64.0).abs() < 1e-9, "fp/bwnn must be 64x");
        assert!(factor > 2.0, "expected substantial reduction, got {factor:.2}");
        assert!(factor < 16.0, "reduction cannot exceed p^2, got {factor:.2}");
    }

    #[test]
    fn resnet50_reduction_larger_than_resnet18() {
        // Paper: 6.7x (ResNet18) vs 7.9x (ResNet50)
        let (_, _, _, f18) = table2_row(&arch::resnet18_cifar(), 4, 64_000);
        let (_, _, _, f50) = table2_row(&arch::resnet50_cifar(), 4, 64_000);
        assert!(f50 > f18 * 0.7, "f18={f18:.2} f50={f50:.2}");
    }

    #[test]
    fn imagenet_tbn2_reduction_reasonable() {
        // Paper: FP 225.66 / IR-Net 3.526 / TBN 0.58 (6.1x) at p=2
        let (fp, bw, tb, factor) = table2_row(&arch::resnet34_imagenet(), 2, 150_000);
        assert!(fp > 200.0 && fp < 260.0, "fp G bitops = {fp}"); // paper: 225.66
        assert!(bw > 3.0 && bw < 4.1, "bw = {bw}"); // paper: 3.526
        assert!(tb < bw / 1.5, "tb = {tb}");
        assert!(factor >= 1.5 && factor <= 4.0, "factor = {factor}");
    }

    #[test]
    fn nothing_tiled_degenerates_to_bwnn() {
        let a = arch::resnet18_cifar();
        // lambda so high nothing tiles: every layer falls back to 1-bit,
        // so tbn cost == bwnn cost
        let pol = TilingPolicy::tbn(4, usize::MAX);
        let bw_pol = TilingPolicy::bwnn(0);
        assert!((tbn_bitops(&a, &pol) - bwnn_bitops(&a, &bw_pol)).abs() < 1e-6);
    }
}
