//! Serving stack: request queue + dynamic batcher + worker thread.
//!
//! TBN is a compression paper, so the serving layer is deliberately thin
//! (DESIGN.md §1): a threaded inference server that batches concurrent
//! requests up to `max_batch` within a `window`, runs them through a
//! `BatchModel`, and reports latency/throughput stats.  It serves the
//! *native* sub-bit engine (`nn::MlpEngine`) — the memory-saving deployment
//! path of §5.1 — and is exercised end-to-end by `examples/serving_demo.rs`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Anything that can run a batch of flat f32 samples to output vectors.
pub trait BatchModel: Send + 'static {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>>;
    fn in_dim(&self) -> usize;
}

impl BatchModel for crate::nn::MlpEngine {
    fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.forward(x)).collect()
    }

    fn in_dim(&self) -> usize {
        crate::nn::MlpEngine::in_dim(self)
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Response>,
}

/// A completed inference with its timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub y: Vec<f32>,
    pub queue_us: u64,
    pub total_us: u64,
    pub batch_size: usize,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: usize,
    pub batches: usize,
    pub total_latency_us: u64,
    pub max_latency_us: u64,
    pub batch_size_sum: usize,
}

impl ServerStats {
    pub fn mean_latency_us(&self) -> f64 {
        self.total_latency_us as f64 / self.served.max(1) as f64
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_size_sum as f64 / self.batches.max(1) as f64
    }
}

/// Dynamic batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// How long the batcher waits for more requests after the first arrives.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, window: Duration::from_micros(200) }
    }
}

/// Handle to a running server. Dropping it shuts the worker down.
pub struct Server {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    in_dim: usize,
}

impl Server {
    /// Spawn the worker thread around a model.
    pub fn start<M: BatchModel>(model: M, policy: BatchPolicy) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let in_dim = model.in_dim();
        let worker = thread::spawn(move || {
            loop {
                // block for the first request of a batch
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all senders dropped: shutdown
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + policy.window;
                while batch.len() < policy.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                let run_start = Instant::now();
                let xs: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
                let ys = model.infer_batch(&xs);
                let bsz = batch.len();
                let mut s = stats_w.lock().unwrap();
                s.batches += 1;
                s.batch_size_sum += bsz;
                for (req, y) in batch.into_iter().zip(ys) {
                    let queue_us = (run_start - req.enqueued).as_micros() as u64;
                    let total_us = req.enqueued.elapsed().as_micros() as u64;
                    s.served += 1;
                    s.total_latency_us += total_us;
                    s.max_latency_us = s.max_latency_us.max(total_us);
                    let _ = req.resp.send(Response { y, queue_us, total_us, batch_size: bsz });
                }
            }
        });
        Server { tx: Some(tx), worker: Some(worker), stats, in_dim }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Response>, String> {
        if x.len() != self.in_dim {
            return Err(format!("input dim {} != model dim {}", x.len(), self.in_dim));
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send(Request { x, enqueued: Instant::now(), resp: rtx })
            .map_err(|_| "server shut down".to_string())?;
        Ok(rrx)
    }

    /// Blocking single-request convenience.
    pub fn infer(&self, x: Vec<f32>) -> Result<Response, String> {
        self.submit(x)?
            .recv()
            .map_err(|_| "server dropped response".to_string())
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel -> worker exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: y = [sum(x)], records batch sizes implicitly via stats.
    struct SumModel {
        dim: usize,
        delay: Duration,
    }

    impl BatchModel for SumModel {
        fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
            if !self.delay.is_zero() {
                thread::sleep(self.delay);
            }
            xs.iter().map(|x| vec![x.iter().sum()]).collect()
        }

        fn in_dim(&self) -> usize {
            self.dim
        }
    }

    #[test]
    fn serves_correct_results() {
        let server = Server::start(SumModel { dim: 4, delay: Duration::ZERO },
                                   BatchPolicy::default());
        let r = server.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.y, vec![10.0]);
    }

    #[test]
    fn rejects_wrong_dim() {
        let server = Server::start(SumModel { dim: 4, delay: Duration::ZERO },
                                   BatchPolicy::default());
        assert!(server.submit(vec![1.0]).is_err());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let server = Arc::new(Server::start(
            SumModel { dim: 2, delay: Duration::from_micros(100) },
            BatchPolicy { max_batch: 8, window: Duration::from_micros(500) },
        ));
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = server.clone();
            handles.push(thread::spawn(move || {
                let mut got = Vec::new();
                for i in 0..25 {
                    let v = (t * 100 + i) as f32;
                    let r = s.infer(vec![v, 1.0]).unwrap();
                    got.push((v, r.y[0]));
                }
                got
            }));
        }
        let mut total = 0;
        for h in handles {
            for (v, y) in h.join().unwrap() {
                assert_eq!(y, v + 1.0);
                total += 1;
            }
        }
        assert_eq!(total, 100);
        let stats = server.stats();
        assert_eq!(stats.served, 100);
        assert!(stats.batches <= 100);
    }

    #[test]
    fn batching_actually_batches() {
        let server = Arc::new(Server::start(
            SumModel { dim: 1, delay: Duration::from_millis(2) },
            BatchPolicy { max_batch: 16, window: Duration::from_millis(4) },
        ));
        // submit 16 requests as fast as possible, then await all
        let rxs: Vec<_> = (0..16).map(|i| server.submit(vec![i as f32]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.stats();
        assert!(stats.mean_batch() > 1.5, "mean batch {}", stats.mean_batch());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let server = Server::start(SumModel { dim: 1, delay: Duration::ZERO },
                                   BatchPolicy::default());
        let _ = server.infer(vec![1.0]).unwrap();
        drop(server); // must not hang
    }
}
