//! Hand-rolled CLI: flag parsing + subcommand registry (no clap offline).

use std::collections::HashMap;

/// Parsed command line: subcommand, positional args, `--key value` /
/// `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Cli {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                cli.command = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --key=value, --key value, or bare flag
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    cli.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    cli.flags.push(name.to_string());
                }
            } else {
                cli.positional.push(arg);
            }
        }
        cli
    }

    pub fn from_env() -> Cli {
        Cli::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.opt(key).and_then(|v| v.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Usage text for the `tbn` binary.
pub const USAGE: &str = "\
tbn — Tiled Bit Networks coordinator (CIKM 2024 reproduction)

USAGE:
  tbn <COMMAND> [OPTIONS]

COMMANDS:
  list                      list experiments (and their tables) from the manifest
  info                      platform + manifest + architecture summary
  train <exp_id>            train one experiment end-to-end and record runs/<id>.json
  run-table <T1|...|F8>     run every experiment behind a paper table/figure
  run-all                   run every experiment in the manifest
  report                    render all analytic tables (T2, T7, F2) + cached runs
  export <exp_id>           train (or reuse) and write the TBNZ model file
  serve <exp_id>            start the native serving demo on a trained model
  serve --arch <name>       serve a natively-lowered architecture instead
                            (synthesized weights, no artifacts needed): any
                            spec the graph lowering accepts — CNNs, ResNets,
                            PointNets, and the transformers vit_cifar /
                            tst_electricity / tst_weather / mlpmixer_cifar
                            plus the vit_micro / tst_micro / mixer_micro minis
  serve --listen <h:p>      network front end: HTTP/1.1 over TCP serving every
                            --arch name (comma-separated) from one process.
                            POST /infer {\"model\",\"x\"}; POST /reload hot-swaps
                            a model in place; GET /models | /stats | /healthz.
                            Full queues shed load as 503 (--overflow reject);
                            SIGTERM (or --duration-s) drains gracefully and
                            prints final per-model stats + `drain: complete`
  loadgen --addr <h:p>      open-loop Poisson load generator against a running
                            serve --listen: measures p50/p95/p99/p99.9 latency
                            from the scheduled arrival time (coordinated-
                            omission free) and saturation throughput over
                            --rates, crossed with a --conns connection ladder

OPTIONS:
  --artifacts <dir>         artifact directory            [default: artifacts]
  --runs <dir>              run-record directory          [default: runs]
  --steps <n>               override training step count
  --eval-every <n>          evaluation period             [default: 100]
  --seed <n>                override the experiment seed (or lowering seed)
  --out <path>              output path (export)
  --engine <path>           serve engine:
                            packed|packed-int|packed-int8|reference
                            (packed-int: threshold-folded integer pipeline)
                                                          [default: packed]
  --p <n>                   tiles per layer for serve --arch [default: 4]
  --requests <n>            demo request count for serve --arch [default: 64]
  --layout <layout>         packed weight layout: tile|expanded (A/B)
                                        [default: tile, or $TBN_LAYOUT if set]
  --threads <n>             intra-op kernel threads per forward (bit-exact
                            at any count) [default: 1, or $TBN_THREADS if set]
  --simd <backend>          XNOR-popcount kernel backend:
                            scalar|u64x4|u128|avx2|auto (bit-exact at any
                            choice; avx2 needs CPU support)
                                        [default: auto, or $TBN_SIMD if set]
  --workers <n>             serve worker threads          [default: 2]
  --queue-cap <n>           serve queue bound             [default: 1024]
  --overflow <policy>       full-queue behavior: block|reject [default: block]
  --max-batch <n>           dynamic batching cap          [default: 32]
  --window-us <n>           batching window in us         [default: 200]
  --net-model <model>       serve --listen connection handling: mux (one
                            readiness-driven event loop, bounded threads at
                            any connection count) | threads (one handler
                            thread per connection, the A/B baseline)
                                                 [default: mux on unix]
  --max-conns <n>           serve --listen open-connection limit; accepts
                            beyond it are shed with 503 [default: 4096]
  --addr-file <path>        serve --listen: write the bound host:port (the
                            resolved ephemeral port with --listen host:0)
  --duration-s <secs>       serve --listen: exit after this long (otherwise
                            runs until SIGTERM/SIGINT); loadgen: seconds of
                            offered load per rate        [default: 2]
  --addr <host:port>        loadgen: target server        (required)
  --model <name>            loadgen: target model   [default: the sole model]
  --rate <rps>              loadgen: offered arrival rate [default: 200]
  --rates <r1,r2,...>       loadgen: sweep these rates and report the
                            saturation throughput across them
  --conns <n1,n2,...>       loadgen: client connections; a comma list sweeps
                            every rate at each count      [default: 4]
  --json <path>             loadgen: write BENCH_serve.json-style report
  --quiet                   errors only
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_positional() {
        let c = parse("train mlp_micro_tbn4");
        assert_eq!(c.command, "train");
        assert_eq!(c.positional, vec!["mlp_micro_tbn4"]);
    }

    #[test]
    fn parses_options_and_flags() {
        let c = parse("train x --steps 50 --runs=/tmp/r --quiet");
        assert_eq!(c.opt_usize("steps"), Some(50));
        assert_eq!(c.opt("runs"), Some("/tmp/r"));
        assert!(c.has_flag("quiet"));
        assert!(!c.has_flag("verbose"));
    }

    #[test]
    fn empty_args() {
        let c = parse("");
        assert_eq!(c.command, "");
    }

    #[test]
    fn flag_before_value_option() {
        let c = parse("report --quiet --steps 10");
        assert!(c.has_flag("quiet"));
        assert_eq!(c.opt_usize("steps"), Some(10));
    }

    #[test]
    fn defaults() {
        let c = parse("info");
        assert_eq!(c.opt_or("artifacts", "artifacts"), "artifacts");
    }
}
