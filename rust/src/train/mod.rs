//! The training coordinator: drives the AOT train/eval graphs from Rust.
//!
//! The loop is entirely Rust-owned: Rust holds every parameter and optimizer
//! tensor as a PJRT literal, computes the LR schedule, synthesizes batches
//! from the dataset substrates, feeds the `train_step` graph positionally and
//! swaps the returned tensors in place.  Python is never invoked.

pub mod export;
pub mod metrics;
pub mod schedule;

use anyhow::{anyhow, Context, Result};

use crate::config::Experiment;
use crate::data::{self, BatchIter, Dataset};
use crate::info;
use crate::runtime::{self, Runtime};
use crate::tensor::Tensor;
use crate::util::Rng;
use schedule::Schedule;

/// One point of the training history.
#[derive(Debug, Clone)]
pub struct HistPoint {
    pub step: usize,
    pub loss: f64,
    pub metric: f64,
    pub lr: f64,
}

/// One evaluation snapshot.
#[derive(Debug, Clone, Default)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    /// Accuracy (cls/seg) or MSE (forecast).
    pub metric: f64,
    /// Class-average IoU (seg tasks only).
    pub class_iou: Option<f64>,
    /// Instance-average IoU (seg tasks only).
    pub instance_iou: Option<f64>,
}

/// Everything a finished run produces.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub id: String,
    pub steps: usize,
    pub train_history: Vec<HistPoint>,
    pub eval_history: Vec<EvalPoint>,
    pub final_eval: EvalPoint,
    pub duration_s: f64,
}

/// Trained parameters, positionally aligned with `exp.params`.
pub struct TrainedModel {
    pub id: String,
    pub params: Vec<Tensor>,
}

impl TrainedModel {
    pub fn param(&self, exp: &Experiment, name: &str) -> Option<&Tensor> {
        exp.params.iter().position(|p| p.name == name).map(|i| &self.params[i])
    }
}

/// Runtime knobs (the config holds the science; these hold the mechanics).
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Override the configured step count (benches use short runs).
    pub steps: Option<usize>,
    pub eval_every: usize,
    pub log_every: usize,
    pub seed: Option<u64>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { steps: None, eval_every: 100, log_every: 50, seed: None }
    }
}

pub struct Trainer<'a> {
    rt: &'a Runtime,
    exp: &'a Experiment,
    pub train_ds: Dataset,
    pub test_ds: Dataset,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, exp: &'a Experiment) -> Result<Trainer<'a>> {
        let (train_ds, test_ds) = data::generate_split(
            &exp.dataset_kind, &exp.io.x, exp.dataset_classes,
            exp.dataset_n_train.max(exp.io.train_batch),
            exp.dataset_n_test.max(exp.io.eval_batch),
            exp.seed,
        )
        .map_err(|e| anyhow!("{}: {e}", exp.id))?;
        Ok(Trainer { rt, exp, train_ds, test_ds })
    }

    /// Run `init` to get deterministic initial parameters.
    pub fn init_params(&self, seed: i32) -> Result<Vec<xla::Literal>> {
        let init = self.rt.load(self.exp.graph_file("init").context("no init graph")?)?;
        let out = init.run(&[runtime::scalar_i32(seed)])?;
        if out.len() != self.exp.n_params() {
            return Err(anyhow!("init returned {} tensors, manifest says {}",
                               out.len(), self.exp.n_params()));
        }
        Ok(out)
    }

    fn zeros_like_params(&self) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(self.exp.n_opt());
        for p in &self.exp.params {
            for _ in 0..self.exp.opt_slots {
                out.push(runtime::literal_f32(&Tensor::zeros(p.shape.clone()))?);
            }
        }
        Ok(out)
    }

    fn batch_literals(&self, ds: &Dataset, idxs: &[usize], batch: usize)
                      -> Result<(xla::Literal, xla::Literal)> {
        let (x, yi, yf) = ds.gather(idxs);
        let mut x_shape = vec![batch];
        x_shape.extend_from_slice(&self.exp.io.x);
        let xl = runtime::literal_f32(&Tensor::new(x_shape, x))?;
        let yl = if self.exp.io.y_is_int {
            let shape = if self.exp.io.task == "seg" {
                vec![batch, ds.y_int_elems]
            } else {
                vec![batch]
            };
            runtime::literal_i32(&shape, &yi)?
        } else {
            runtime::literal_f32(&Tensor::new(vec![batch, ds.y_elems], yf))?
        };
        Ok((xl, yl))
    }

    /// Evaluate current training params on the held-out set.
    pub fn evaluate(&self, params: &[xla::Literal], step: usize) -> Result<EvalPoint> {
        let exe = self.rt.load(self.exp.graph_file("eval_step").context("no eval graph")?)?;
        let batch = self.exp.io.eval_batch;
        let idxs: Vec<usize> = (0..batch).collect();
        let (xl, yl) = self.batch_literals(&self.test_ds, &idxs, batch)?;
        // pass by reference: Literal::clone deep-copies device buffers
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&xl);
        inputs.push(&yl);
        let out = exe.run(&inputs)?;
        let loss = runtime::f32_scalar_from_literal(&out[0])? as f64;
        let metric = runtime::f32_scalar_from_literal(&out[1])? as f64;
        let mut point = EvalPoint { step, loss, metric, ..Default::default() };
        if self.exp.io.task == "seg" {
            let preds = runtime::i32_from_literal(&out[2])?;
            let (_, labels, _) = self.test_ds.gather(&idxs);
            let classes = self.exp.dataset_classes;
            let points = self.test_ds.y_int_elems;
            point.class_iou = Some(metrics::class_avg_iou(&preds, &labels, classes));
            point.instance_iou =
                Some(metrics::instance_avg_iou(&preds, &labels, classes, points));
        }
        Ok(point)
    }

    /// Full training run: init → step loop → periodic eval → final eval.
    pub fn run(&self, opts: &TrainOptions) -> Result<(TrainResult, TrainedModel)> {
        let t0 = std::time::Instant::now();
        let exp = self.exp;
        let steps = opts.steps.unwrap_or(exp.train_steps);
        let seed = opts.seed.unwrap_or(exp.seed);
        let sched = Schedule::from_config(&exp.schedule, exp.lr, exp.warmup, steps);
        let train_exe = self.rt.load(exp.graph_file("train_step").context("no train graph")?)?;

        let mut params = self.init_params(seed as i32)?;
        let mut opt = self.zeros_like_params()?;
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B9).wrapping_add(7));

        let mut train_history = Vec::new();
        let mut eval_history = Vec::new();
        let mut batches = BatchIter::new(self.train_ds.n, exp.io.train_batch, &mut rng);
        for step in 0..steps {
            let idxs = match batches.next() {
                Some(b) => b,
                None => {
                    batches = BatchIter::new(self.train_ds.n, exp.io.train_batch, &mut rng);
                    batches.next().context("dataset smaller than one batch")?
                }
            };
            let (xl, yl) = self.batch_literals(&self.train_ds, &idxs, exp.io.train_batch)?;
            let lr = sched.at(step);

            // hot loop: everything is passed by reference — Literal::clone
            // deep-copies the underlying buffer (124 -> 116 ms/step on
            // ResNet-mini; EXPERIMENTS.md §Perf).
            let step_lit = runtime::scalar_f32((step + 1) as f32);
            let lr_lit = runtime::scalar_f32(lr as f32);
            let mut inputs: Vec<&xla::Literal> =
                Vec::with_capacity(2 + params.len() + opt.len() + 2);
            inputs.push(&step_lit);
            inputs.push(&lr_lit);
            inputs.extend(params.iter());
            inputs.extend(opt.iter());
            inputs.push(&xl);
            inputs.push(&yl);

            let mut out = train_exe.run(&inputs)?;
            let metric = runtime::f32_scalar_from_literal(&out.pop().unwrap())? as f64;
            let loss = runtime::f32_scalar_from_literal(&out.pop().unwrap())? as f64;
            opt = out.split_off(exp.n_params());
            params = out;

            if step % opts.log_every == 0 || step + 1 == steps {
                info!("train", "{} step {step}/{steps} loss {loss:.4} metric {metric:.4} lr {lr:.5}",
                      exp.id);
            }
            train_history.push(HistPoint { step, loss, metric, lr });

            if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 && step + 1 != steps {
                eval_history.push(self.evaluate(&params, step + 1)?);
            }
        }

        let final_eval = self.evaluate(&params, steps)?;
        info!("train", "{} final: loss {:.4} metric {:.4}{}",
              exp.id, final_eval.loss, final_eval.metric,
              final_eval.class_iou.map(|i| format!(" mIoU {i:.3}")).unwrap_or_default());
        eval_history.push(final_eval.clone());

        let tensors = params
            .iter()
            .map(runtime::tensor_from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok((
            TrainResult {
                id: exp.id.clone(),
                steps,
                train_history,
                eval_history,
                final_eval,
                duration_s: t0.elapsed().as_secs_f64(),
            },
            TrainedModel { id: exp.id.clone(), params: tensors },
        ))
    }
}
