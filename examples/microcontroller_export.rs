//! Microcontroller deployment (paper §5.1 / Table 6): train the deployment
//! MLP, export both a BWNN and a TBN_4 model to TBNZ, and compare speed
//! (FPS), max memory and storage exactly as the paper's Table 6 does —
//! against the Arduino budget (1MB flash, 250KB RAM).  The TBN model also
//! runs the threshold-folded integer pipeline (`EnginePath::PackedInt`) and
//! its folded per-row `i32` popcount thresholds are written out as a C
//! header — the table a microcontroller needs next to the packed tile to
//! run hidden layers with no f32 at all.

use anyhow::{anyhow, Result};
use tiledbits::config::Manifest;
use tiledbits::nn::{EnginePath, MlpEngine, Nonlin};
use tiledbits::runtime::Runtime;
use tiledbits::tbn::TbnzModel;
use tiledbits::train::{export, Trainer, TrainOptions};
use tiledbits::util::{human_bytes, Rng};

const FLASH_BUDGET: usize = 1_000_000; // 1MB storage
const RAM_BUDGET: usize = 250_000; // 250KB memory

fn build(rt: &Runtime, manifest: &Manifest, id: &str, steps: usize)
         -> Result<(MlpEngine, TbnzModel, f64)> {
    let exp = manifest.by_id(id).ok_or_else(|| anyhow!("missing {id}"))?;
    let trainer = Trainer::new(rt, exp)?;
    let (result, model) = trainer.run(&TrainOptions {
        steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None })?;
    let tbnz = export::to_tbnz(exp, &model)?;
    Ok((MlpEngine::new(tbnz.clone(), Nonlin::Relu).map_err(|e| anyhow!(e))?,
        tbnz, result.final_eval.metric))
}

/// Render every packed layer's folded thresholds
/// ([`tiledbits::nn::IntThresholds::export_i32`]) as a C header: one
/// `int32_t` per output row.  Encoding (see the `nn::packed` docs):
/// `v >= 1` fires at `same >= v`, `v <= -1` fires at `same <= -v - 1`
/// (negative scale), `INT32_MAX` never fires (zero scale), `INT32_MIN`
/// marks a mixed-alpha row that needs the weighted-run fallback.
fn threshold_header(int: &MlpEngine) -> (String, usize) {
    let e = int.engine();
    let mut h = String::from(
        "/* Folded popcount thresholds (EnginePath::PackedInt).\n\
         \x20* Per row (same = popcount(xnor(row_bits, x_bits))):\n\
         \x20*   v >= 1     -> bit fires at same >= v       (positive scale)\n\
         \x20*   v <= -1    -> bit fires at same <= -v - 1  (negative scale)\n\
         \x20*   INT32_MAX  -> never fires                  (zero scale)\n\
         \x20*   INT32_MIN  -> mixed alphas: weighted-run fallback needed */\n\
         #include <stdint.h>\n");
    let mut tables = 0usize;
    for idx in 0..e.graph().len() {
        let Some(thr) = e.int_thresholds(idx) else { continue };
        let node = e.node(idx);
        let cname: String = node
            .name()
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        let table = thr.export_i32();
        h.push_str(&format!(
            "\n/* {}: {} rows, calibrated gamma {:e} (f32 boundaries only) */\n",
            node.name(), table.len(), thr.gamma));
        h.push_str(&format!("static const int32_t {cname}_thr[{}] = {{",
                            table.len()));
        for (i, v) in table.iter().enumerate() {
            h.push_str(if i % 8 == 0 { "\n    " } else { " " });
            h.push_str(&format!("{v},"));
        }
        h.push_str("\n};\n");
        tables += 1;
    }
    (h, tables)
}

fn main() -> Result<()> {
    let artifacts = std::env::var("TBN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("TBN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(300);
    let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
    let rt = Runtime::new(&artifacts)?;

    println!("== microcontroller deployment (Table 6) ==");
    println!("model: MLP 256 -> 128 -> 10, fused ReLU; budget: 1MB flash / 250KB RAM\n");

    let (bwnn, _, bwnn_acc) = build(&rt, &manifest, "mlp_micro_bwnn", steps)?;
    let (tbn, tbn_model, tbn_acc) = build(&rt, &manifest, "mlp_micro_tbn4", steps)?;

    // the integer pipeline on the same trained TBN model, gammas calibrated
    // on a synthetic batch (calibration only moves f32 boundaries)
    let mut rng = Rng::new(6);
    let calib: Vec<Vec<f32>> =
        (0..16).map(|_| rng.normal_vec(tbn.in_dim(), 1.0)).collect();
    let int = MlpEngine::with_path(tbn_model, Nonlin::Relu, EnginePath::PackedInt)
        .map_err(|e| anyhow!(e))?
        .calibrate_int_gammas(&calib);

    let x = vec![0.25f32; bwnn.in_dim()];
    let iters = 2000;
    let rows = [
        ("BWNN", &bwnn, bwnn_acc),
        ("TBN_4", &tbn, tbn_acc),
        ("TBN_4/int", &int, tbn_acc),
    ];
    println!("{:10} {:>12} {:>14} {:>12} {:>10}", "Model", "Speed (FPS)",
             "Max Mem (KB)", "Storage (KB)", "Test Acc");
    for (name, engine, acc) in rows {
        let fps = engine.measure_fps(&x, iters);
        let mem = engine.peak_memory_bytes();
        let sto = engine.storage_bytes();
        println!("{:10} {:>12.1} {:>14.2} {:>12.2} {:>9.1}%",
                 name, fps, mem as f64 / 1e3, sto as f64 / 1e3, 100.0 * acc);
        assert!(sto < FLASH_BUDGET, "{name} exceeds the flash budget");
        assert!(mem < RAM_BUDGET, "{name} exceeds the RAM budget");
    }

    // -- integer-pipeline export: folded per-row popcount thresholds --
    let (header, tables) = threshold_header(&int);
    let out = std::env::var("TBN_THRESHOLDS_OUT")
        .unwrap_or_else(|_| "tbn_thresholds.h".into());
    std::fs::write(&out, &header)?;
    println!("\nwrote {tables} folded i32 threshold table(s) to {out} \
              ({} bytes)", header.len());

    let mem_saving = bwnn.peak_memory_bytes() as f64 / tbn.peak_memory_bytes() as f64;
    let sto_saving = bwnn.storage_bytes() as f64 / tbn.storage_bytes() as f64;
    println!("\nTBN_4 vs BWNN: {mem_saving:.2}x less memory, {sto_saving:.2}x less storage");
    println!("(paper: 2.4x memory, 3.8x storage on the 784-input MNIST variant)");
    println!("headroom: storage {} of {}, memory {} of {}",
             human_bytes(tbn.storage_bytes() as f64), human_bytes(FLASH_BUDGET as f64),
             human_bytes(tbn.peak_memory_bytes() as f64), human_bytes(RAM_BUDGET as f64));
    Ok(())
}
