//! `tbn` — the leader binary: CLI entry for training, reporting, exporting
//! and serving Tiled Bit Networks.

use anyhow::{anyhow, Result};

use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tiledbits::arch;
use tiledbits::cli::{Cli, USAGE};
use tiledbits::config::Manifest;
use tiledbits::coordinator::{self, report, TABLES};
use tiledbits::nn::{init_backend, lower_arch_spec, threads_from_env, Engine,
                    EnginePath, LowerOptions, MlpEngine, Nonlin, PackedLayout,
                    SimdBackend};
use tiledbits::runtime::Runtime;
use tiledbits::serve::{install_shutdown_flag, loadgen, BatchPolicy, LoadgenConfig,
                       ModelBuilder, ModelRegistry, NetConfig, NetModel, NetServer,
                       OverflowPolicy, ServePolicy, Server, ServerStats};
use tiledbits::tbn::AlphaMode;
use tiledbits::train::{export, TrainOptions};
use tiledbits::util::{log, Rng};
use tiledbits::{data, info};

fn main() {
    let cli = Cli::from_env();
    if cli.has_flag("quiet") {
        log::set_level(log::ERROR);
    }
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn train_opts(cli: &Cli) -> TrainOptions {
    TrainOptions {
        steps: cli.opt_usize("steps"),
        eval_every: cli.opt_usize("eval-every").unwrap_or(100),
        log_every: 50,
        seed: cli.opt_usize("seed").map(|s| s as u64),
    }
}

fn engine_path_opt(cli: &Cli) -> EnginePath {
    match cli.opt_or("engine", "packed") {
        "reference" => EnginePath::Reference,
        "packed-int8" | "int8" => EnginePath::PackedInt8,
        "packed-int" | "int" => EnginePath::PackedInt,
        _ => EnginePath::Packed,
    }
}

/// `--layout` wins; without it the `TBN_LAYOUT` env override (the CI A/B
/// hook) picks the default.  Unknown values fail loudly: this flag exists
/// for A/B measurement, so a typo must not silently benchmark the wrong
/// layout.
fn packed_layout_opt(cli: &Cli) -> Result<PackedLayout> {
    match cli.opt("layout") {
        Some("expanded") => Ok(PackedLayout::Expanded),
        Some("tile") | Some("tile-resident") => Ok(PackedLayout::TileResident),
        Some(other) => Err(anyhow!("unknown --layout {other:?} (tile|expanded)")),
        None => Ok(PackedLayout::from_env()),
    }
}

/// `--threads` wins; without it the `TBN_THREADS` env override (the CI A/B
/// hook) picks the default.  Like `--layout`, a typo must not silently
/// benchmark the wrong kernel configuration, so parse errors fail loudly.
fn threads_opt(cli: &Cli) -> Result<usize> {
    match cli.opt("threads") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(anyhow!("invalid --threads {v:?} (want an integer >= 1)")),
        },
        None => Ok(threads_from_env()),
    }
}

/// `--simd` wins; without it the `TBN_SIMD` env override (the CI A/B hook)
/// picks the default.  Unlike the env var (which clamps quietly so one
/// matrix config runs everywhere), an explicit flag fails loudly both on a
/// typo and on a backend this CPU cannot run — `--simd avx2` on a machine
/// without AVX2 must not silently benchmark the u128 kernels.
fn simd_opt(cli: &Cli) -> Result<SimdBackend> {
    match cli.opt("simd") {
        Some(v) => match SimdBackend::parse(v) {
            Some(b) if b.supported() => Ok(b),
            Some(b) => Err(anyhow!("--simd {v:?}: {b} is not supported on this CPU")),
            None => Err(anyhow!("unknown --simd {v:?} (scalar|u64x4|u128|avx2|auto)")),
        },
        None => Ok(SimdBackend::from_env()),
    }
}

/// Loud integer flag (mirrors `--layout`/`--simd`): a typo must not
/// silently fall back to the default.
fn usize_flag(cli: &Cli, key: &str, default: usize, min: usize) -> Result<usize> {
    match cli.opt(key) {
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= min => Ok(n),
            _ => Err(anyhow!("invalid --{key} {v:?} (want an integer >= {min})")),
        },
        None => Ok(default),
    }
}

/// Loud positive-float flag (loadgen rates and durations).
fn f64_flag(cli: &Cli, key: &str, default: f64) -> Result<f64> {
    match cli.opt(key) {
        Some(v) => match v.parse::<f64>() {
            Ok(x) if x > 0.0 && x.is_finite() => Ok(x),
            _ => Err(anyhow!("invalid --{key} {v:?} (want a positive number)")),
        },
        None => Ok(default),
    }
}

/// `--net-model mux|threads` (the serving front end's connection model,
/// default mux on unix), parsed loudly like the other A/B switches.
fn net_model_opt(cli: &Cli) -> Result<NetModel> {
    match cli.opt("net-model") {
        Some(v) => NetModel::parse(v).map_err(|e| anyhow!(e)),
        None => Ok(NetModel::default()),
    }
}

/// Loud comma-separated positive-integer list (`--conns 1,64,512`); a
/// bare integer is a 1-point list.
fn usize_list_flag(cli: &Cli, key: &str, default: usize) -> Result<Vec<usize>> {
    match cli.opt(key) {
        Some(list) => {
            let mut v = Vec::new();
            for part in list.split(',') {
                let n = part
                    .trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|n| *n >= 1)
                    .ok_or_else(|| {
                        anyhow!("invalid --{key} entry {:?} \
                                 (want integers >= 1, comma-separated)",
                                part.trim())
                    })?;
                v.push(n);
            }
            Ok(v)
        }
        None => Ok(vec![default]),
    }
}

/// `--listen <host:port>`, parsed loudly (`127.0.0.1:0` asks the kernel
/// for an ephemeral port; the bound address is printed and optionally
/// written to `--addr-file`).
fn listen_addr_opt(cli: &Cli) -> Result<Option<SocketAddr>> {
    match cli.opt("listen") {
        Some(v) => v.parse::<SocketAddr>().map(Some).map_err(|_| {
            anyhow!("invalid --listen {v:?} (want host:port, e.g. 127.0.0.1:8080)")
        }),
        None => Ok(None),
    }
}

fn serve_policy_opt(cli: &Cli, kernel_threads: usize, simd: SimdBackend,
                    engine: EnginePath) -> Result<ServePolicy> {
    Ok(ServePolicy {
        batch: BatchPolicy {
            max_batch: usize_flag(cli, "max-batch", 32, 1)?,
            window: Duration::from_micros(usize_flag(cli, "window-us", 200, 0)? as u64),
        },
        queue_cap: usize_flag(cli, "queue-cap", 1024, 1)?,
        on_full: match cli.opt_or("overflow", "block") {
            "reject" => OverflowPolicy::Reject,
            "block" => OverflowPolicy::Block,
            other => return Err(anyhow!("unknown --overflow {other:?} (block|reject)")),
        },
        kernel_threads,
        simd,
        engine,
    })
}

fn print_serve_stats(stats: &ServerStats, elapsed_s: f64) {
    info!("serve", "{} requests in {elapsed_s:.3}s ({} rejected), mean latency \
           {:.0}us, mean batch {:.1}, {} kernel thread(s)/request, {} kernels, \
           {:?} engine",
          stats.served, stats.rejected, stats.mean_latency_us(), stats.mean_batch(),
          stats.kernel_threads, stats.simd, stats.engine);
    if let Some(p) = stats.latency_percentiles() {
        info!("serve", "latency percentiles over last {} requests: \
               p50 {}us  p95 {}us  p99 {}us  (lifetime max {}us)",
              p.samples, p.p50_us, p.p95_us, p.p99_us, stats.max_latency_us);
    }
    if !stats.per_worker.is_empty() {
        info!("serve", "peak kernel occupancy ~{} cores ({} workers x {} \
               kernel threads)",
              stats.per_worker.len() * stats.kernel_threads,
              stats.per_worker.len(), stats.kernel_threads);
    }
    for (w, ws) in stats.per_worker.iter().enumerate() {
        info!("serve", "  worker {w}: {} requests in {} batches", ws.served, ws.batches);
    }
}

/// `tbn serve --arch <name>`: lower a paper architecture or demo mini
/// natively (synthesized weights — no artifacts or PJRT runtime needed)
/// and serve the layer-graph engine behind the batching pool under a
/// synthetic concurrent load.  Covers everything `nn::lower_arch_spec`
/// accepts, including the transformer specs (`vit_cifar`, `tst_*`,
/// `mlpmixer_cifar`, `vit_micro`, `tst_micro`, `mixer_micro`).
fn serve_arch(cli: &Cli, name: &str) -> Result<()> {
    let spec = arch::any_arch_by_name(name)
        .ok_or_else(|| anyhow!("unknown architecture {name:?}"))?;
    let input = spec
        .native_input()
        .ok_or_else(|| anyhow!("{name}: cannot infer the native input shape"))?;
    let lopts = LowerOptions {
        input,
        p: cli.opt_usize("p").unwrap_or(4),
        alpha_mode: AlphaMode::PerTile,
        seed: cli.opt_usize("seed").map(|s| s as u64).unwrap_or(0),
    };
    let graph = lower_arch_spec(&spec, &lopts).map_err(|e| anyhow!(e))?;
    let path = engine_path_opt(cli);
    let layout = packed_layout_opt(cli)?;
    let threads = threads_opt(cli)?;
    // resolve the process-wide dispatch once at startup (OnceLock): the
    // engine carries the same choice explicitly
    let simd = init_backend(simd_opt(cli)?);
    let engine = Engine::with_layout_graph(graph, Nonlin::Relu, path, layout)
        .map_err(|e| anyhow!(e))?
        .with_threads(threads)
        .with_simd(simd);
    let (in_dim, out_dim) = (engine.in_len(), engine.out_len());
    let workers = cli.opt_usize("workers").unwrap_or(2);
    let policy = serve_policy_opt(cli, threads, simd, path)?;
    info!("serve", "{name}: natively lowered graph ({} nodes), {path:?} engine \
           ({layout:?} weights, {threads} kernel thread(s), {simd} kernels), \
           {workers} workers, queue cap {} ({:?}), {} resident weight bytes",
          engine.graph().len(), policy.queue_cap, policy.on_full,
          engine.resident_weight_bytes());
    let server = Arc::new(Server::start_pool_with(Arc::new(engine), policy, workers));
    let n_requests = cli.opt_usize("requests").unwrap_or(64);
    let t0 = std::time::Instant::now();
    let clients = 4usize;
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let mut rng = Rng::new(1000 + c as u64);
        let xs: Vec<Vec<f32>> = (c..n_requests)
            .step_by(clients)
            .map(|_| rng.normal_vec(in_dim, 1.0))
            .collect();
        handles.push(std::thread::spawn(move || -> Result<(), String> {
            for x in xs {
                match s.infer(x) {
                    Ok(r) if r.y.len() != out_dim => {
                        return Err(format!("bad output width {}", r.y.len()));
                    }
                    Ok(_) => {}
                    // shed requests are the Reject policy working as
                    // intended: counted in the server stats
                    Err(e) if e.contains("queue full") => {}
                    Err(e) => return Err(e),
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("client thread panicked"))?
            .map_err(|e| anyhow!(e))?;
    }
    print_serve_stats(&server.stats(), t0.elapsed().as_secs_f64());
    Ok(())
}

/// Lower `name` natively and wrap it in a worker pool — the unit the
/// model registry holds and `POST /reload` rebuilds (with a fresh seed)
/// for hot swaps.
#[allow(clippy::too_many_arguments)]
fn build_arch_server(name: &str, seed: u64, p: usize, path: EnginePath,
                     layout: PackedLayout, threads: usize, simd: SimdBackend,
                     policy: &ServePolicy, workers: usize) -> Result<Server, String> {
    let spec = arch::any_arch_by_name(name)
        .ok_or_else(|| format!("unknown architecture {name:?}"))?;
    let input = spec
        .native_input()
        .ok_or_else(|| format!("{name}: cannot infer the native input shape"))?;
    let lopts = LowerOptions { input, p, alpha_mode: AlphaMode::PerTile, seed };
    let graph = lower_arch_spec(&spec, &lopts)?;
    let engine = Engine::with_layout_graph(graph, Nonlin::Relu, path, layout)?
        .with_threads(threads)
        .with_simd(simd);
    Ok(Server::start_pool_with(Arc::new(engine), policy.clone(), workers))
}

/// `tbn serve --listen <host:port>`: the production front end.  Registers
/// every `--arch` name (comma-separated) as a served model, accepts HTTP
/// traffic until SIGTERM/SIGINT (or `--duration-s`), then drains
/// gracefully and prints final per-model stats plus `drain: complete` —
/// the lines the serve-e2e CI job greps.
fn serve_listen(cli: &Cli, addr: SocketAddr) -> Result<()> {
    let archs: Vec<String> = cli
        .opt_or("arch", "cnn_micro")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if archs.is_empty() {
        return Err(anyhow!("--arch gave no model names"));
    }
    let p = usize_flag(cli, "p", 4, 1)?;
    let path = engine_path_opt(cli);
    let layout = packed_layout_opt(cli)?;
    let threads = threads_opt(cli)?;
    let simd = init_backend(simd_opt(cli)?);
    let workers = usize_flag(cli, "workers", 2, 1)?;
    let policy = serve_policy_opt(cli, threads, simd, path)?;
    let seed = cli.opt_usize("seed").map(|s| s as u64).unwrap_or(0);
    let duration_s = match cli.opt("duration-s") {
        Some(_) => Some(f64_flag(cli, "duration-s", 0.0)?),
        None => None,
    };
    let registry = Arc::new(ModelRegistry::new());
    for name in &archs {
        let server =
            build_arch_server(name, seed, p, path, layout, threads, simd, &policy, workers)
                .map_err(|e| anyhow!(e))?;
        info!("serve", "{name}: registered (in_dim {}, {path:?} engine, {layout:?} \
               weights, {workers} workers, queue cap {} ({:?}))",
              server.in_dim(), policy.queue_cap, policy.on_full);
        registry.register(name, server);
    }
    let builder_policy = policy.clone();
    let builder: ModelBuilder = Arc::new(move |name: &str, seed: u64| {
        build_arch_server(name, seed, p, path, layout, threads, simd, &builder_policy,
                          workers)
    });
    // enough dispatchers to keep every worker's batches formed, bounded so
    // the mux model's thread count stays independent of connection count
    let net_config = NetConfig {
        model: net_model_opt(cli)?,
        max_conns: usize_flag(cli, "max-conns", 4096, 1)?,
        dispatch_threads: (workers * policy.batch.max_batch).clamp(8, 64),
    };
    let net = NetServer::start_with(registry, &addr.to_string(), Some(builder),
                                    net_config.clone())
        .map_err(|e| anyhow!(e))?;
    let bound = net.addr();
    // machine-readable: resolves `:0` to the real port for scripts/CI
    println!("listening on {bound}");
    info!("serve", "net model {} (max {} conns, {} dispatchers)",
          net.net_stats().model, net_config.max_conns, net_config.dispatch_threads);
    if let Some(file) = cli.opt("addr-file") {
        std::fs::write(file, format!("{bound}\n"))
            .map_err(|e| anyhow!("write {file}: {e}"))?;
    }
    let stop = install_shutdown_flag();
    let deadline = duration_s.map(|s| Instant::now() + Duration::from_secs_f64(s));
    let mut ticks = 0u64;
    while !stop.load(Ordering::SeqCst)
        && !deadline.is_some_and(|d| Instant::now() >= d)
    {
        std::thread::sleep(Duration::from_millis(100));
        ticks += 1;
        // periodic stats line (~5s): connection counters + request totals
        if ticks % 50 == 0 {
            let ns = net.net_stats();
            let (served, rejected) = net.registry().totals();
            info!("serve", "net={} open={} accepted={} closed={} read_stalls={} \
                   write_stalls={} shed_at_accept={} served={served} \
                   rejected={rejected}",
                  ns.model, ns.open, ns.accepted, ns.closed, ns.read_stalls,
                  ns.write_stalls, ns.shed_at_accept);
        }
    }
    info!("serve", "shutdown requested: draining");
    let ns = net.net_stats();
    println!("final net model={} accepted={} read_stalls={} write_stalls={} \
              shed_at_accept={}",
             ns.model, ns.accepted, ns.read_stalls, ns.write_stalls, ns.shed_at_accept);
    for (name, generation, s) in net.shutdown() {
        let tail = s
            .latency_percentiles()
            .map(|lp| format!(" p50_us={} p95_us={} p99_us={}", lp.p50_us, lp.p95_us,
                              lp.p99_us))
            .unwrap_or_default();
        println!("final model={name} generation={generation} served={} rejected={} \
                  mean_latency_us={:.0}{tail}",
                 s.served, s.rejected, s.mean_latency_us());
    }
    println!("drain: complete");
    Ok(())
}

fn dispatch(cli: &Cli) -> Result<()> {
    let artifacts = cli.opt_or("artifacts", "artifacts").to_string();
    let runs_dir = cli.opt_or("runs", "runs").to_string();
    match cli.command.as_str() {
        "list" => {
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            for e in &manifest.experiments {
                println!("{:32} {:14} [{}]", e.id, e.model_family, e.tables.join(","));
            }
            Ok(())
        }
        "info" => {
            let rt = Runtime::new(&artifacts)?;
            println!("platform: {}", rt.platform());
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            println!("experiments: {}", manifest.experiments.len());
            print!("{}", report::composition_table().render());
            Ok(())
        }
        "train" => {
            let id = cli.positional.first().ok_or_else(|| anyhow!("train needs <exp_id>"))?;
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let rec = coordinator::run_or_load(&rt, &manifest, id, &train_opts(cli), &runs_dir)?;
            println!("{}", rec.to_json().to_string_pretty());
            Ok(())
        }
        "run-table" => {
            let table = cli.positional.first().ok_or_else(|| anyhow!("run-table needs an id"))?;
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            let ids: Vec<String> = coordinator::experiments_for(&manifest, table)
                .into_iter().map(String::from).collect();
            if ids.is_empty() {
                return Err(anyhow!("no experiments map to {table}"));
            }
            for id in &ids {
                let rec = coordinator::run_or_load(&rt, &manifest, id, &train_opts(cli), &runs_dir)?;
                println!("{:32} metric {:.4}  bit-width {:.3}", id, rec.metric, rec.bit_width);
            }
            Ok(())
        }
        "run-all" => {
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            let rt = Runtime::new(&artifacts)?;
            for e in &manifest.experiments {
                let rec = coordinator::run_or_load(&rt, &manifest, &e.id, &train_opts(cli), &runs_dir)?;
                println!("{:32} metric {:.4}  bit-width {:.3}", e.id, rec.metric, rec.bit_width);
            }
            Ok(())
        }
        "report" => {
            print!("{}", report::bitops_table().render());
            print!("{}", report::memory_table(4).render());
            print!("{}", report::composition_table().render());
            // cached accuracy runs, grouped by table
            if let Ok(manifest) = Manifest::load(&artifacts).map_err(|e| anyhow!(e)) {
                for (table, title) in TABLES {
                    let mut cached = Vec::new();
                    for e in manifest.for_table(table) {
                        if let Some(rec) = coordinator::load_run(&runs_dir, &e.id) {
                            cached.push((e.id.clone(), rec));
                        }
                    }
                    if !cached.is_empty() {
                        println!("-- {table}: {title} (cached runs) --");
                        for (id, rec) in cached {
                            println!("  {:32} metric {:.4}  bit-width {:.3}  ({} steps)",
                                     id, rec.metric, rec.bit_width, rec.steps);
                        }
                    }
                }
            }
            Ok(())
        }
        "export" => {
            let id = cli.positional.first().ok_or_else(|| anyhow!("export needs <exp_id>"))?;
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            let exp = manifest.by_id(id).ok_or_else(|| anyhow!("unknown experiment {id}"))?;
            let rt = Runtime::new(&artifacts)?;
            let trainer = tiledbits::train::Trainer::new(&rt, exp)?;
            let (_, model) = trainer.run(&train_opts(cli))?;
            let tbnz = export::to_tbnz(exp, &model)?;
            let out = cli.opt_or("out", &format!("{id}.tbnz")).to_string();
            tbnz.save(&out)?;
            let (params, bits, bw) = export::export_summary(&tbnz);
            println!("wrote {out}: {params} params, {} bytes, bit-width {bw:.3}",
                     bits / 8);
            Ok(())
        }
        "serve" => {
            // --listen <host:port>: the production network front end
            // (model registry, load shedding, graceful drain)
            if let Some(addr) = listen_addr_opt(cli)? {
                return serve_listen(cli, addr);
            }
            // --arch <name>: the artifact-free native-lowering path (any
            // spec `nn::lower_arch_spec` accepts, incl. the transformers)
            if let Some(name) = cli.opt("arch") {
                return serve_arch(cli, name);
            }
            let id = cli.positional.first().ok_or_else(|| anyhow!("serve needs <exp_id>"))?;
            let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
            let exp = manifest.by_id(id).ok_or_else(|| anyhow!("unknown experiment {id}"))?;
            if exp.model_family != "mlp" {
                return Err(anyhow!("the native serving demo requires an mlp experiment"));
            }
            let rt = Runtime::new(&artifacts)?;
            let trainer = tiledbits::train::Trainer::new(&rt, exp)?;
            let (_, model) = trainer.run(&train_opts(cli))?;
            let tbnz = export::to_tbnz(exp, &model)?;
            let path = engine_path_opt(cli);
            let layout = packed_layout_opt(cli)?;
            let threads = threads_opt(cli)?;
            let simd = init_backend(simd_opt(cli)?);
            let workers = cli.opt_usize("workers").unwrap_or(2);
            let policy = serve_policy_opt(cli, threads, simd, path)?;
            let engine = MlpEngine::with_path_layout(tbnz, Nonlin::Relu, path, layout)
                .map_err(|e| anyhow!(e))?
                .with_threads(threads)
                .with_simd(simd);
            info!("serve", "{path:?} engine ({layout:?} weights, {threads} kernel \
                   thread(s), {simd} kernels), {workers} workers, queue cap {} \
                   ({:?}), {} resident weight bytes",
                  policy.queue_cap, policy.on_full, engine.resident_weight_bytes());
            let server = Arc::new(Server::start_pool_with(Arc::new(engine),
                                                          policy, workers));
            // demo load: classify a synthetic batch from concurrent clients
            let ds = data::generate(&exp.dataset_kind, &exp.io.x, exp.dataset_classes,
                                    256, 99).map_err(|e| anyhow!(e))?;
            let t0 = std::time::Instant::now();
            let clients = 4usize;
            let mut handles = Vec::new();
            for c in 0..clients {
                let s = server.clone();
                let xs: Vec<Vec<f32>> = (c..ds.n)
                    .step_by(clients)
                    .map(|i| ds.x[i * ds.x_elems..(i + 1) * ds.x_elems].to_vec())
                    .collect();
                handles.push(std::thread::spawn(move || -> Result<(), String> {
                    for x in xs {
                        match s.infer(x) {
                            Ok(_) => {}
                            // shed requests are the Reject policy working as
                            // intended: count them (server stats) and go on
                            Err(e) if e.contains("queue full") => {}
                            Err(e) => return Err(e),
                        }
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow!("client thread panicked"))?
                    .map_err(|e| anyhow!(e))?;
            }
            print_serve_stats(&server.stats(), t0.elapsed().as_secs_f64());
            Ok(())
        }
        "loadgen" => {
            let addr = cli
                .opt("addr")
                .ok_or_else(|| anyhow!("loadgen needs --addr <host:port>"))?;
            // --conns 1,64,512 crosses every rate with a connection ladder
            let conns_list = usize_list_flag(cli, "conns", 4)?;
            let base = LoadgenConfig {
                addr: addr.to_string(),
                model: cli.opt_or("model", "").to_string(),
                rate_rps: f64_flag(cli, "rate", 200.0)?,
                duration: Duration::from_secs_f64(f64_flag(cli, "duration-s", 2.0)?),
                conns: conns_list[0],
                seed: cli.opt_usize("seed").unwrap_or(1) as u64,
            };
            // --rates 100,400,1600 sweeps; --rate alone is a 1-point sweep
            let rates: Vec<f64> = match cli.opt("rates") {
                Some(list) => {
                    let mut v = Vec::new();
                    for part in list.split(',') {
                        let part = part.trim();
                        let r = part
                            .parse::<f64>()
                            .ok()
                            .filter(|x| *x > 0.0 && x.is_finite())
                            .ok_or_else(|| {
                                anyhow!("invalid --rates entry {part:?} \
                                         (want positive numbers, comma-separated)")
                            })?;
                        v.push(r);
                    }
                    v
                }
                None => vec![base.rate_rps],
            };
            let reports =
                loadgen::sweep_grid(&base, &rates, &conns_list).map_err(|e| anyhow!(e))?;
            for r in &reports {
                println!("{}", r.summary());
            }
            println!("loadgen saturation_rps={:.1}", loadgen::saturation_rps(&reports));
            if let Some(out) = cli.opt("json") {
                std::fs::write(out, loadgen::sweep_to_json(&reports).to_string_pretty())
                    .map_err(|e| anyhow!("write {out}: {e}"))?;
                info!("loadgen", "wrote {out}");
            }
            Ok(())
        }
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}\n\n{USAGE}")),
    }
}
