//! Serving bench: open-loop load against the network front end.
//!
//! Boots the full production serving path in-process — `ModelRegistry` +
//! `NetServer` on `127.0.0.1:0` over a packed micro-MLP worker pool — and
//! drives it with the in-crate Poisson load generator at a ladder of
//! offered rates.  Reports per-rate completed/rejected counts, p50/p95/p99
//! latency (measured from the scheduled arrival, so client-side queueing
//! under overload is charged to the server), and the saturation throughput
//! across the sweep.  `--json` writes the machine-readable
//! `BENCH_serve.json` (grep-gated in CI next to `BENCH_table2/table6`).
//!
//! Artifact-free and short: the model is seeded like the engine unit
//! tests, rates/durations are sized for a CI smoke run
//! (`cargo bench --bench table_serve`), not a steady-state soak.

use std::sync::Arc;
use std::time::Duration;

use tiledbits::bench_util::header;
use tiledbits::nn::{EnginePath, MlpEngine, Nonlin, SimdBackend};
use tiledbits::serve::{loadgen, BatchPolicy, LoadgenConfig, ModelRegistry, NetServer,
                       OverflowPolicy, ServePolicy, Server};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::util::Rng;

/// The deployment micro MLP (256 -> 128 -> 10), fully tiled at p=4.
fn micro_model() -> TbnzModel {
    let p = 4usize;
    let mut r = Rng::new(42);
    let mk = |name: &str, m: usize, n: usize, r: &mut Rng| {
        let w: Vec<f32> = r.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        }
    };
    TbnzModel { layers: vec![mk("fc0", 128, 256, &mut r), mk("head", 10, 128, &mut r)] }
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let simd = SimdBackend::default();
    header("Serving: open-loop load vs the network front end (micro MLP)");
    println!("packed kernels run the {simd} xnor-popcount backend");

    let engine =
        MlpEngine::with_path(micro_model(), Nonlin::Relu, EnginePath::Packed).unwrap();
    let policy = ServePolicy {
        batch: BatchPolicy { max_batch: 32, window: Duration::from_micros(200) },
        queue_cap: 256,
        // shed under overload so the saturation sweep measures the server,
        // not a convoy of blocked submitters
        on_full: OverflowPolicy::Reject,
        kernel_threads: 1,
        simd,
        engine: EnginePath::Packed,
    };
    let workers = 2usize;
    let registry = Arc::new(ModelRegistry::new());
    registry.register("micro", Server::start_pool_with(Arc::new(engine), policy, workers));
    let net = NetServer::start(registry, "127.0.0.1:0", None).expect("bind loopback");
    let addr = net.addr().to_string();
    println!("serving micro on {addr} ({workers} workers, queue cap 256, reject)");

    let base = LoadgenConfig {
        addr,
        model: "micro".into(),
        duration: Duration::from_millis(600),
        conns: 4,
        seed: 9,
        ..LoadgenConfig::default()
    };
    let rates = [500.0, 2000.0, 8000.0];
    let reports = loadgen::sweep(&base, &rates).expect("loadgen sweep");

    println!("\n{:>12} {:>8} {:>10} {:>10} {:>12} {:>9} {:>9} {:>9}", "offered_rps",
             "sent", "completed", "rejected", "achieved_rps", "p50_us", "p95_us",
             "p99_us");
    for r in &reports {
        println!("{:>12.0} {:>8} {:>10} {:>10} {:>12.1} {:>9} {:>9} {:>9}",
                 r.offered_rps, r.sent, r.completed, r.rejected, r.achieved_rps,
                 r.p50_us, r.p95_us, r.p99_us);
    }
    let saturation = loadgen::saturation_rps(&reports);
    println!("\nsaturation throughput: {saturation:.1} req/s (max achieved across the \
              sweep)");

    if json_mode {
        let doc = loadgen::sweep_to_json(&reports);
        let path = "BENCH_serve.json";
        std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_serve.json");
        println!("wrote {path}");
    }

    // graceful drain: every accepted request completed before this returns
    let final_stats = net.shutdown();
    for (name, generation, s) in final_stats {
        println!("final model={name} generation={generation} served={} rejected={}",
                 s.served, s.rejected);
    }
    println!("drain: complete");
}
