//! Table 6: microcontroller deployment — FPS, max memory, storage of the
//! BWNN vs TBN_4 deployment MLP on the native Algorithm 1 engine.

use tiledbits::bench_util::{bench_dirs, bench_steps, header};
use tiledbits::config::Manifest;
use tiledbits::nn::{MlpEngine, Nonlin};
use tiledbits::runtime::Runtime;
use tiledbits::train::{export, Trainer, TrainOptions};
use tiledbits::util::mean_std;

fn engine_for(rt: &Runtime, manifest: &Manifest, id: &str, steps: usize) -> MlpEngine {
    let exp = manifest.by_id(id).expect(id);
    let trainer = Trainer::new(rt, exp).unwrap();
    let (_, model) = trainer.run(&TrainOptions {
        steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None }).unwrap();
    MlpEngine::new(export::to_tbnz(exp, &model).unwrap(), Nonlin::Relu).unwrap()
}

fn main() {
    header("Table 6: microcontroller deployment (native Algorithm 1 engine)");
    let (artifacts, _) = bench_dirs();
    let steps = bench_steps(120);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("(artifacts not built; skipping)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");

    let bwnn = engine_for(&rt, &manifest, "mlp_micro_bwnn", steps);
    let tbn = engine_for(&rt, &manifest, "mlp_micro_tbn4", steps);
    let x = vec![0.25f32; bwnn.in_dim()];

    println!("\n{:8} {:>16} {:>14} {:>12}", "Model", "Speed (FPS)", "Max Mem (KB)",
             "Storage(KB)");
    for (name, engine) in [("BWNN", &bwnn), ("TBN_4", &tbn)] {
        // five runs of 1000 executions, mean +- std (the paper's protocol)
        let fps: Vec<f64> = (0..5).map(|_| engine.measure_fps(&x, 1000)).collect();
        let (m, s) = mean_std(&fps);
        println!("{:8} {:>9.1}+-{:<5.1} {:>14.2} {:>12.2}",
                 name, m, s,
                 engine.peak_memory_bytes() as f64 / 1e3,
                 engine.storage_bytes() as f64 / 1e3);
    }
    println!("\npaper (784-input MNIST variant): BWNN 704.5 FPS / 16.20KB / 12.70KB;");
    println!("TBN_4 705.1 FPS / 6.80KB / 3.32KB — same speed, ~2.4x memory, ~3.8x storage.");
    println!("shape check: FPS within noise; memory and storage ratios ~2-4x here.");
}
