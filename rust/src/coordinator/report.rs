//! Report rendering: the paper's table layouts over measured + analytic rows.

use crate::arch;
use crate::baselines;
use super::runner::RunRecord;

/// A rendered table: title + header + rows of strings.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

fn fmt_opt(v: Option<f64>, mul: f64) -> String {
    v.map(|x| format!("{:.2}", x * mul)).unwrap_or_else(|| "-".into())
}

/// Accuracy-style tables (T1/T3/T4/T5): published analytic columns on the
/// full-size arch + measured metric from the scaled-down run.
pub fn accuracy_table(title: &str, arch_name: &str, table_id: &str,
                      runs: &[(&str, &RunRecord)]) -> Table {
    let mut rows = Vec::new();
    if let Some(a) = arch::arch_by_name(arch_name) {
        for r in baselines::rows_for(table_id, arch_name) {
            let _ = &a;
            rows.push(vec![
                format!("{}{}", r.method, if r.binary_act { "*" } else { "" }),
                format!("{:.3}", r.bit_width),
                format!("{:.2}", r.mbit),
                format!("{:.2}", r.metric),
                "paper".into(),
            ]);
        }
    }
    for (label, rec) in runs {
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", rec.bit_width),
            format!("{:.3}", rec.storage_bits as f64 / 1e6),
            format!("{:.2}", rec.metric * 100.0),
            "measured (mini)".into(),
        ]);
    }
    Table {
        title: title.to_string(),
        header: vec!["Method".into(), "Bit-Width".into(), "#Params (M-bit)".into(),
                     "Metric".into(), "Source".into()],
        rows,
    }
}

/// Table 2: bit-ops accounting over the paper's CNNs.
pub fn bitops_table() -> Table {
    let cases = [
        ("CIFAR-10", "resnet18_cifar", 4usize, 64_000usize),
        ("CIFAR-10", "resnet50_cifar", 4, 64_000),
        ("ImageNet", "resnet34_imagenet", 2, 150_000),
    ];
    let mut rows = Vec::new();
    for (ds, name, p, lam) in cases {
        let a = arch::arch_by_name(name).unwrap();
        let (fp, bw, tb, factor) = crate::tbn::bitops::table2_row(&a, p, lam);
        rows.push(vec![
            ds.into(), name.into(),
            format!("{fp:.2}"), format!("{bw:.3}"), format!("{tb:.3}"),
            format!("({factor:.1}x)"),
        ]);
    }
    Table {
        title: "Table 2: Bit-Ops (G) — Full Precision / IR-Net(BWNN) / TBN".into(),
        header: vec!["Dataset".into(), "Model".into(), "Full Prec".into(),
                     "Binary".into(), "TBN".into(), "Savings".into()],
        rows,
    }
}

/// Figure 2: conv/FC composition of popular DNNs.
pub fn composition_table() -> Table {
    let mut rows = Vec::new();
    for a in arch::all_archs() {
        rows.push(vec![
            a.name.clone(),
            format!("{:.1}", a.total_params() as f64 / 1e6),
            format!("{:.1}%", 100.0 * (1.0 - a.fc_fraction())),
            format!("{:.1}%", 100.0 * a.fc_fraction()),
        ]);
    }
    Table {
        title: "Figure 2: composition of popular DNNs".into(),
        header: vec!["Architecture".into(), "Params (M)".into(),
                     "Conv %".into(), "FC %".into()],
        rows,
    }
}

/// Table 7: memory rows for the ImageNet ViT.
pub fn memory_table(p: usize) -> Table {
    let a = arch::vit_small_imagenet();
    let rows_data = crate::tbn::memory::table7_rows(&a, p, 150_000);
    let fp_peak = rows_data[0].1.peak_bytes;
    let fp_param = rows_data[0].1.param_bytes;
    let mut rows = Vec::new();
    for (name, r) in &rows_data {
        rows.push(vec![
            name.to_string(),
            format!("{:.1} ({:.1}x)", r.peak_bytes / 1e6, fp_peak / r.peak_bytes),
            format!("{:.1} ({:.1}x)", r.param_bytes / 1e6, fp_param / r.param_bytes),
            format!("{:.1}%", 100.0 * r.param_fraction()),
        ]);
    }
    Table {
        title: format!("Table 7: inference memory, ImageNet ViT (p={p})"),
        header: vec!["Model".into(), "Peak Mem (MB)".into(),
                     "Param Mem (MB)".into(), "% Param Mem".into()],
        rows,
    }
}

/// Compression sweep rows (Figure 6): accuracy vs p from cached runs.
pub fn sweep_table(title: &str, runs: &[(usize, &RunRecord)]) -> Table {
    let rows = runs
        .iter()
        .map(|(p, r)| {
            vec![format!("p={p}"), format!("{:.3}", r.bit_width),
                 format!("{:.2}", r.metric * 100.0),
                 fmt_opt(Some(r.loss), 1.0)]
        })
        .collect();
    Table {
        title: title.to_string(),
        header: vec!["Compression".into(), "Bit-Width".into(),
                     "Test Acc %".into(), "Loss".into()],
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitops_table_renders() {
        let t = bitops_table();
        assert_eq!(t.rows.len(), 3);
        let s = t.render();
        assert!(s.contains("resnet18_cifar"));
        assert!(s.contains("ImageNet"));
    }

    #[test]
    fn composition_covers_all_archs() {
        let t = composition_table();
        assert_eq!(t.rows.len(), crate::arch::all_archs().len());
    }

    #[test]
    fn memory_table_four_rows() {
        let t = memory_table(4);
        assert_eq!(t.rows.len(), 4);
        assert!(t.render().contains("Full Precision"));
    }

    #[test]
    fn accuracy_table_includes_published() {
        let t = accuracy_table("Table 1: ResNet18", "resnet18_cifar", "T1", &[]);
        assert!(t.rows.len() >= 8);
        assert!(t.render().contains("IR-Net"));
    }

    #[test]
    fn render_alignment_stable() {
        let t = Table {
            title: "x".into(),
            header: vec!["a".into(), "bb".into()],
            rows: vec![vec!["lllllong".into(), "1".into()]],
        };
        let s = t.render();
        assert!(s.lines().count() >= 4);
    }
}
