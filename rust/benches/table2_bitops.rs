//! Table 2: bit-operations of ResNet architectures (FP / IR-Net / TBN).
//!
//! Analytic accounting on the exact architecture specs plus a measured
//! micro-benchmark of the three kernel classes (fp MAC, XNOR-popcount,
//! tile-reuse) to show the per-op cost ordering really holds on hardware.
//!
//! The XNOR word loop is measured once per SIMD backend generation
//! (scalar / u64x4 / u128 / avx2 where the CPU has it), on both the aligned
//! range kernel and the misaligned shift-stitched kernel the tile-resident
//! layout runs, so the AVX2-vs-u128 win is a number.  `--json` additionally
//! writes the machine-readable `BENCH_table2.json` next to the cwd so the
//! perf trajectory is tracked in-repo instead of only in scrollback.

use tiledbits::arch;
use tiledbits::bench_util::{bench, header};
use tiledbits::coordinator::report;
use tiledbits::nn;
use tiledbits::nn::{binarize_activations_into, PackedLayer, PackedLayout};
use tiledbits::tbn::bitops::{active_backend, xnor_dot_words_offset_with,
                             xnor_dot_words_range_with, SimdBackend};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     WeightPayload};
use tiledbits::tensor::BitVec;
use tiledbits::util::{Json, Rng};

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    header("Table 2: Bit-Ops accounting + kernel-class micro-bench");
    print!("{}", report::bitops_table().render());
    println!("paper reference: 35.03 / 0.547 / 0.082 (6.7x), 78.12 / 1.22 / 0.155 (7.9x),");
    println!("                 225.66 / 3.526 / 0.58 (6.1x)\n");

    // measured per-op cost ordering on a 512x512 FC layer
    let (m, n, p) = (512usize, 512usize, 4usize);
    let mut rng = Rng::new(42);
    let w = rng.normal_vec(m * n, 1.0);
    let x = rng.normal_vec(n, 1.0);
    let bits = BitVec::from_signs(&w);
    let tile = tile_from_weights(&w, p);
    let alphas = alphas_from(&w, p, AlphaMode::PerTile);

    let r_fp = bench("fp dense 512x512", 3, 30, || {
        std::hint::black_box(nn::fc_fp_forward(&w, &x, m, false));
    });
    let r_bw = bench("bwnn packed 512x512", 3, 30, || {
        std::hint::black_box(nn::fc_bwnn_forward(&bits, 0.5, &x, m, false));
    });
    let r_tb = bench("tbn tile-reuse 512x512 (p=4)", 3, 30, || {
        std::hint::black_box(nn::fc_tiled_forward_fast(&tile, &alphas, &x, m, false));
    });
    let r_tr = bench("tbn replicated-rows 512x512 (p=4)", 3, 30, || {
        std::hint::black_box(nn::fc_tiled_forward_replicated(&tile, &alphas, &x, m, false));
    });
    for r in [&r_fp, &r_bw, &r_tb, &r_tr] {
        println!("{}", r.report());
    }
    println!("\nweight bytes touched: fp {}  bwnn {}  tbn {}",
             4 * m * n, bits.storage_bytes(), tile.storage_bytes());

    // the packed path's one inner loop, once per backend generation, on
    // both phases the engine runs it: aligned (`xnor_dot_words_range`, the
    // expanded layout) and misaligned shift-stitched
    // (`xnor_dot_words_offset` at tile phase 1, the tile-resident default)
    let words = 1usize << 15; // 32k words = 2M bits per call
    let nbits = words * 64;
    let wa: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let wb: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let detected = SimdBackend::detect();
    let active = active_backend();
    println!("\n-- xnor-popcount word loop by SIMD backend (32k words) --");
    println!("simd backend: detected {detected}, active {active}{}",
             if active == detected { " (auto)" } else { " (forced via TBN_SIMD)" });
    let backends: Vec<SimdBackend> = [SimdBackend::Scalar, SimdBackend::U64x4,
                                      SimdBackend::U128, SimdBackend::Avx2]
        .into_iter()
        .filter(|b| b.supported())
        .collect();
    let mut kernel_rows: Vec<(SimdBackend, f64, f64)> = Vec::new();
    for &b in &backends {
        let r_al = bench(&format!("xnor popcount {b} aligned"), 5, 200, || {
            std::hint::black_box(xnor_dot_words_range_with(b, &wa, &wb, 0, nbits));
        });
        let r_off = bench(&format!("xnor popcount {b} misaligned"), 5, 200, || {
            std::hint::black_box(
                xnor_dot_words_offset_with(b, &wa, 1, &wb, 0, nbits - 64));
        });
        kernel_rows.push((b,
                          words as f64 * r_al.per_sec(),
                          (words - 1) as f64 * r_off.per_sec()));
    }
    println!("{:>8} {:>16} {:>18} {:>10}", "backend", "aligned words/s",
             "misaligned words/s", "vs u128");
    let u128_aligned = kernel_rows
        .iter()
        .find(|(b, _, _)| *b == SimdBackend::U128)
        .map(|&(_, al, _)| al)
        .unwrap_or(1.0);
    for &(b, al, off) in &kernel_rows {
        println!("{:>8} {al:>16.3e} {off:>18.3e} {:>9.2}x",
                 b.as_str(), al / u128_aligned);
    }

    // intra-op thread scaling of the batched row kernel itself (the loop the
    // packed engine runs per weight layer): 512x512 tiled layer, batch of
    // 32 pre-binarized inputs, output rows split across 1/2/4/8 threads.
    let rec = LayerRecord {
        name: "mt".into(),
        shape: vec![m, n],
        payload: WeightPayload::Tiled { p, tile, alphas },
    };
    let packed = PackedLayer::from_record_mn_layout(&rec, m, n,
                                                    PackedLayout::TileResident)
        .unwrap();
    let bsz = 32usize;
    let stride = n.div_ceil(64);
    let mut bwords = vec![0u64; bsz * stride];
    let mut gammas = vec![0.0f32; bsz];
    for b in 0..bsz {
        let xb = rng.normal_vec(n, 1.0);
        gammas[b] = binarize_activations_into(
            &xb, &mut bwords[b * stride..(b + 1) * stride]);
    }
    let kernel_words = m * bsz * stride; // row-dot words touched per call
    println!("\n-- batched row-kernel thread scaling (512x512, batch 32, {active} \
              kernels) --");
    println!("{:>8} {:>14} {:>8}", "threads", "words/s", "speedup");
    let mut out = vec![0.0f32; bsz * m];
    let mut base = 0.0f64;
    let mut thread_rows: Vec<(usize, f64)> = Vec::new();
    for t in [1usize, 2, 4, 8] {
        let res = bench(&format!("batched rows threads={t}"), 3, 60, || {
            packed.forward_batch_binarized_rows_mt(0, m, &bwords, stride, &gammas,
                                                   false, &mut out, t);
            std::hint::black_box(&out);
        });
        let wps = res.throughput(kernel_words);
        if t == 1 {
            base = wps;
        }
        thread_rows.push((t, wps));
        println!("{t:>8} {:>14.3e} {:>7.2}x", wps, wps / base);
    }

    if json_mode {
        let kernels = Json::Arr(
            kernel_rows
                .iter()
                .map(|&(b, al, off)| Json::obj(vec![
                    ("backend", Json::Str(b.as_str().to_string())),
                    ("aligned_words_per_s", Json::Num(al)),
                    ("misaligned_words_per_s", Json::Num(off)),
                ]))
                .collect(),
        );
        let batched = Json::Arr(
            thread_rows
                .iter()
                .map(|&(t, wps)| Json::obj(vec![
                    ("backend", Json::Str(active.as_str().to_string())),
                    ("threads", Json::Num(t as f64)),
                    ("words_per_s", Json::Num(wps)),
                ]))
                .collect(),
        );
        let doc = Json::obj(vec![
            ("bench", Json::Str("table2_bitops".to_string())),
            ("detected_backend", Json::Str(detected.as_str().to_string())),
            ("active_backend", Json::Str(active.as_str().to_string())),
            ("words_per_call", Json::Num(words as f64)),
            ("kernels", kernels),
            ("batched_rows", batched),
        ]);
        let path = "BENCH_table2.json";
        std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_table2.json");
        println!("\nwrote {path}");
    }
}
