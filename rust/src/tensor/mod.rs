//! Host tensor type and bit-packed binary storage.
//!
//! `Tensor` is a shape + contiguous f32 buffer (row-major) — the host-side
//! mirror of a PJRT literal. `BitVec` stores {-1,+1} sequences at one bit per
//! element with sign-dot kernels; it is the storage substrate of the TBNZ
//! format and the native inference engine.

mod bitvec;

pub use bitvec::BitVec;

/// Row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshaped(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Mean absolute value (the XNOR-Net alpha, Eq. 7).
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// argmax over the last axis; returns indices of shape[..rank-1].
    pub fn argmax_last(&self) -> Vec<usize> {
        let k = *self.shape.last().expect("argmax over scalar");
        self.data
            .chunks_exact(k)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rank(), 2);
    }

    #[test]
    #[should_panic]
    fn new_rejects_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn mean_abs() {
        let t = Tensor::new(vec![4], vec![1.0, -2.0, 3.0, -4.0]);
        assert!((t.mean_abs() - 2.5).abs() < 1e-6);
    }

    #[test]
    fn argmax_last_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.rank(), 0);
        assert_eq!(t.data, vec![3.5]);
    }

    #[test]
    fn reshape_keeps_data() {
        let t = Tensor::new(vec![6], (0..6).map(|i| i as f32).collect()).reshaped(vec![2, 3]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.data[5], 5.0);
    }
}
