//! Experiment coordinator: registry, runner and report rendering.
//!
//! The runner takes one manifest experiment through the full pipeline —
//! train → eval → export (TBNZ + forward literals) → forward-graph
//! verification → record — and persists a `runs/<id>.json` record so
//! benches and reports can reuse completed runs instead of retraining.

pub mod report;
mod runner;

pub use runner::{run_experiment, RunRecord, VerifyOutcome};

use crate::config::Manifest;
use crate::train::TrainOptions;
use crate::runtime::Runtime;

/// Paper table/figure ids in presentation order.
pub const TABLES: &[(&str, &str)] = &[
    ("T1", "CNN results on CIFAR-10 and ImageNet"),
    ("T2", "Bit-Ops of ResNet architectures"),
    ("T3", "PointNet classification / part seg / semantic seg"),
    ("T4", "Vision Transformers on CIFAR-10 and ImageNet"),
    ("T5", "Multivariate time series forecasting"),
    ("T6", "Microcontroller deployment"),
    ("T7", "GPU inference memory (ImageNet ViT)"),
    ("F2", "Conv vs FC composition of popular DNNs"),
    ("F5", "Per-layer memory trace during inference"),
    ("F6", "Accuracy vs compression (ConvMixer / MLPMixer)"),
    ("F7", "Hyperparameter configurations across training"),
    ("F8", "ResNet tiling-configuration test loss"),
];

/// Load a cached run record if present.
pub fn load_run(runs_dir: &str, id: &str) -> Option<RunRecord> {
    RunRecord::load(&format!("{runs_dir}/{id}.json")).ok()
}

/// Train (or reuse a cached record for) one experiment.
pub fn run_or_load(rt: &Runtime, manifest: &Manifest, id: &str,
                   opts: &TrainOptions, runs_dir: &str)
                   -> anyhow::Result<RunRecord> {
    if let Some(rec) = load_run(runs_dir, id) {
        // only reuse records trained for at least as many steps
        if opts.steps.is_none_or(|s| rec.steps >= s) {
            return Ok(rec);
        }
    }
    let exp = manifest
        .by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment {id}"))?;
    let rec = run_experiment(rt, exp, opts)?;
    std::fs::create_dir_all(runs_dir).ok();
    rec.save(&format!("{runs_dir}/{id}.json"))?;
    Ok(rec)
}

/// Resolve the experiments behind one table/figure id.
pub fn experiments_for<'m>(manifest: &'m Manifest, table: &str) -> Vec<&'m str> {
    manifest.for_table(table).iter().map(|e| e.id.as_str()).collect()
}
