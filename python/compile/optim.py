"""Hand-rolled optimizers as pure functions (no optax in the vendor set).

State layout is deliberately flat and positional so the Rust trainer can hold
opt-state tensors as opaque PJRT literals next to the parameters:

* SGD+momentum: one slot per parameter (the velocity buffer).
* Adam/AdamW:   two slots per parameter (m then v, interleaved per param).

The learning rate (and, for Adam, the step counter for bias correction) are
*inputs* to the train-step graph — schedules are computed by the Rust
coordinator (L3 owns scheduling), never baked into the HLO.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp

from .layers import ParamSpec, Params


def opt_slot_count(kind: str) -> int:
    return {"sgd": 1, "adam": 2, "adamw": 2}[kind]


def init_opt_state(kind: str, params: Params, specs: List[ParamSpec]) -> List[jnp.ndarray]:
    slots = opt_slot_count(kind)
    out: List[jnp.ndarray] = []
    for spec in specs:
        for _ in range(slots):
            out.append(jnp.zeros(spec.shape, jnp.float32))
    return out


def _decay_mask(spec: ParamSpec) -> bool:
    """Weight decay applies to weights (and A), not to norm scales/embeddings."""
    return spec.role in ("weight", "alpha_src")


def sgd_update(
    specs: List[ParamSpec],
    params: Params,
    grads: Params,
    state: List[jnp.ndarray],
    lr: jnp.ndarray,
    momentum: float,
    weight_decay: float,
) -> Tuple[Params, List[jnp.ndarray]]:
    """Classic SGD with momentum and (coupled) weight decay."""
    new_params: Params = {}
    new_state: List[jnp.ndarray] = []
    for i, spec in enumerate(specs):
        w = params[spec.name]
        g = grads[spec.name]
        if weight_decay > 0.0 and _decay_mask(spec):
            g = g + weight_decay * w
        v = momentum * state[i] + g
        new_state.append(v)
        new_params[spec.name] = w - lr * v
    return new_params, new_state


def adam_update(
    specs: List[ParamSpec],
    params: Params,
    grads: Params,
    state: List[jnp.ndarray],
    lr: jnp.ndarray,
    step: jnp.ndarray,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    decoupled: bool = False,
) -> Tuple[Params, List[jnp.ndarray]]:
    """Adam (coupled wd) or AdamW (decoupled); ``step`` is 1-based, f32."""
    new_params: Params = {}
    new_state: List[jnp.ndarray] = []
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    for i, spec in enumerate(specs):
        w = params[spec.name]
        g = grads[spec.name]
        if weight_decay > 0.0 and not decoupled and _decay_mask(spec):
            g = g + weight_decay * w
        m = beta1 * state[2 * i] + (1.0 - beta1) * g
        v = beta2 * state[2 * i + 1] + (1.0 - beta2) * g * g
        new_state.append(m)
        new_state.append(v)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay > 0.0 and decoupled and _decay_mask(spec):
            update = update + weight_decay * w
        new_params[spec.name] = w - lr * update
    return new_params, new_state


def apply_update(
    kind: str,
    specs: List[ParamSpec],
    params: Params,
    grads: Params,
    state: List[jnp.ndarray],
    lr: jnp.ndarray,
    step: jnp.ndarray,
    hp: Dict,
) -> Tuple[Params, List[jnp.ndarray]]:
    """Dispatch on optimizer kind with hyperparameters from the config."""
    wd = float(hp.get("weight_decay", 0.0))
    if kind == "sgd":
        return sgd_update(specs, params, grads, state, lr,
                          momentum=float(hp.get("momentum", 0.9)), weight_decay=wd)
    if kind == "adam":
        return adam_update(specs, params, grads, state, lr, step, weight_decay=wd)
    if kind == "adamw":
        return adam_update(specs, params, grads, state, lr, step,
                           weight_decay=wd, decoupled=True)
    raise ValueError(f"unknown optimizer {kind!r}")
