//! Figure 5: GPU memory allocated during model inference, layer by layer —
//! the allocator-model trace for the ImageNet ViT and PointNet, standard vs
//! tiled kernels, rendered as an ASCII profile.

use tiledbits::arch;
use tiledbits::bench_util::header;
use tiledbits::tbn::memory::{simulate, KernelKind, MemoryReport};
use tiledbits::tbn::TilingPolicy;

fn sparkline(r: &MemoryReport, width: usize) -> String {
    let max = r.trace.iter().map(|(_, b)| *b).fold(0.0, f64::max).max(1.0);
    let step = (r.trace.len().max(1) as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#'];
    let mut i = 0.0;
    while (i as usize) < r.trace.len() && out.len() < width {
        let v = r.trace[i as usize].1 / max;
        out.push(glyphs[((v * (glyphs.len() - 1) as f64).round() as usize)
                            .min(glyphs.len() - 1)]);
        i += step;
    }
    out
}

fn show(title: &str, std_r: &MemoryReport, tiled_r: &MemoryReport) {
    println!("\n-- {title} --");
    println!("standard kernel: peak {:7.2} MB  |{}|",
             std_r.peak_bytes / 1e6, sparkline(std_r, 60));
    println!("tiled kernel:    peak {:7.2} MB  |{}|",
             tiled_r.peak_bytes / 1e6, sparkline(tiled_r, 60));
    println!("reduction: {:.1}x", std_r.peak_bytes / tiled_r.peak_bytes);
}

fn main() {
    header("Figure 5: per-layer memory trace during inference");

    // ViT: full-precision weights, standard vs tiled (paper left panel, 2.8x)
    let vit = arch::vit_small_imagenet();
    let tbn4 = TilingPolicy::tbn(4, 150_000);
    let fp = TilingPolicy::fp();
    let vit_std = simulate(&vit, &fp, KernelKind::FpStandard);
    let vit_tiled = simulate(&vit, &tbn4, KernelKind::FpTiled);
    show("ImageNet ViT (fp32 weights)", &vit_std, &vit_tiled);
    println!("paper: 2.8x peak reduction (222.5 -> 78.5 MB)");

    // PointNet: the paper's right panel (1.2x — activations dominate)
    let pn = arch::pointnet_cls();
    let pn_pol = TilingPolicy::tbn(4, 64_000);
    let pn_std = simulate(&pn, &fp, KernelKind::FpStandard);
    let pn_tiled = simulate(&pn, &pn_pol, KernelKind::FpTiled);
    show("PointNet (fp32 weights)", &pn_std, &pn_tiled);
    println!("paper: 1.2x peak reduction (activations dominate PointNet)");

    // packed variants for completeness
    let vit_tbn = simulate(&vit, &tbn4, KernelKind::TbnPacked);
    let vit_bw = simulate(&vit, &TilingPolicy::bwnn(0), KernelKind::BwnnPacked);
    println!("\npacked: BWNN peak {:.2} MB, TBN_4 peak {:.2} MB ({:.1}x)",
             vit_bw.peak_bytes / 1e6, vit_tbn.peak_bytes / 1e6,
             vit_bw.peak_bytes / vit_tbn.peak_bytes);
    println!("\nshape check: ViT reduction >> PointNet reduction, as in the paper.");
}
