//! Compression accounting: the Bit-Width / #Params (M-bit) / savings columns
//! of Tables 1, 3, 4, 5 — computed over the full-size architecture specs.

use crate::arch::{ArchSpec, Kind};
use super::policy::{decide, Quant, TilingPolicy};

/// Accounting result for one (architecture, policy) pair.
#[derive(Debug, Clone)]
pub struct Accounting {
    pub arch: String,
    pub mode: String,
    pub total_params: usize,
    pub total_bits: f64,
    /// Per-layer decisions: (layer, quant, bits, params).
    pub layers: Vec<(String, Quant, f64, usize)>,
}

impl Accounting {
    /// Bits stored per model parameter (the paper's Bit-Width column).
    pub fn bit_width(&self) -> f64 {
        self.total_bits / self.total_params.max(1) as f64
    }

    /// #Params column in M-bit.
    pub fn mbit(&self) -> f64 {
        self.total_bits / 1e6
    }

    /// Savings factor vs a 1-bit binary-weight model (blue column).
    pub fn savings_vs_binary(&self) -> f64 {
        1.0 / self.bit_width()
    }

    /// Fraction of parameters living in tiled layers.
    pub fn tiled_fraction(&self) -> f64 {
        let tiled: usize = self
            .layers
            .iter()
            .filter(|(_, q, _, _)| matches!(q, Quant::Tiled { .. }))
            .map(|(_, _, _, n)| *n)
            .sum();
        tiled as f64 / self.total_params.max(1) as f64
    }
}

/// Bits to store one layer of `n` params under `quant` (storage model used
/// consistently across the paper's tables: tiles are 1-bit packed, alphas
/// and fp weights are 32-bit).
pub fn layer_bits(n: usize, quant: Quant, policy: &TilingPolicy) -> f64 {
    match quant {
        Quant::Tiled { p } => {
            let q = n / p;
            q as f64 + 32.0 * policy.alpha.count(p) as f64
        }
        Quant::Bwnn => n as f64 + 32.0,
        Quant::Fp => 32.0 * n as f64,
    }
}

/// Apply a tiling policy to a full-size architecture.
///
/// Per the paper's accounting, only conv/FC *weight* parameters enter the
/// bit-width and #Params columns (norm scales / position embeddings are
/// excluded — e.g. ResNet18-CIFAR is 10.99M weight params, ViT-CIFAR 9.49M).
pub fn accounting(arch: &ArchSpec, policy: &TilingPolicy) -> Accounting {
    let mut total_bits = 0.0;
    let mut total_params = 0usize;
    let mut layers = Vec::with_capacity(arch.layers.len());
    for l in &arch.layers {
        let quant = match l.kind {
            Kind::Conv { .. } | Kind::Fc { .. } => decide(policy, l.params),
            Kind::Other => continue,
        };
        let bits = layer_bits(l.params, quant, policy);
        total_bits += bits;
        total_params += l.params;
        layers.push((l.name.clone(), quant, bits, l.params));
    }
    Accounting {
        arch: arch.name.clone(),
        mode: policy.mode.clone(),
        total_params,
        total_bits,
        layers,
    }
}

/// Convenience: the (bit_width, mbit, savings) triple for a table row.
pub fn table_row(arch: &ArchSpec, policy: &TilingPolicy) -> (f64, f64, f64) {
    let acc = accounting(arch, policy);
    (acc.bit_width(), acc.mbit(), acc.savings_vs_binary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;

    #[test]
    fn fp_is_exactly_32_bits() {
        let a = accounting(&arch::resnet18_cifar(), &TilingPolicy::fp());
        assert!((a.bit_width() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn bwnn_close_to_one_bit() {
        let a = accounting(&arch::resnet18_cifar(), &TilingPolicy::bwnn(0));
        assert!(a.bit_width() > 1.0 && a.bit_width() < 1.01);
    }

    /// Table 1 sanity: TBN_p bit-widths on ResNet18-CIFAR near the paper's
    /// column (0.256 / 0.131 / 0.069 at p = 4 / 8 / 16 with lambda = 64k).
    #[test]
    fn resnet18_cifar_bitwidths_match_table1() {
        let arch = arch::resnet18_cifar();
        for (p, want, tol) in [(4usize, 0.256, 0.02), (8, 0.131, 0.012), (16, 0.069, 0.015)] {
            let pol = TilingPolicy::tbn(p, 64_000);
            let a = accounting(&arch, &pol);
            let got = a.bit_width();
            assert!((got - want).abs() < tol,
                    "p={p}: got {got:.3}, paper {want} (lambda 64k)");
        }
    }

    #[test]
    fn resnet50_cifar_bitwidths_match_table1() {
        let arch = arch::resnet50_cifar();
        for (p, want, tol) in [(4usize, 0.259, 0.03), (8, 0.136, 0.02), (16, 0.075, 0.015)] {
            let a = accounting(&arch, &TilingPolicy::tbn(p, 64_000));
            assert!((a.bit_width() - want).abs() < tol,
                    "p={p}: got {:.3}, paper {want}", a.bit_width());
        }
    }

    #[test]
    fn imagenet_resnet34_tbn2_matches() {
        // Table 1: TBN_2 bit-width 0.53 with lambda = 150k
        let a = accounting(&arch::resnet34_imagenet(), &TilingPolicy::tbn(2, 150_000));
        assert!((a.bit_width() - 0.53).abs() < 0.05, "got {}", a.bit_width());
    }

    #[test]
    fn vit_cifar_tbn_matches_table4() {
        let arch = arch::vit_cifar();
        for (p, want, tol) in [(4usize, 0.253, 0.02), (8, 0.129, 0.012)] {
            let a = accounting(&arch, &TilingPolicy::tbn(p, 64_000));
            assert!((a.bit_width() - want).abs() < tol,
                    "p={p}: got {:.3}, paper {want}", a.bit_width());
        }
    }

    #[test]
    fn savings_monotone_in_p() {
        let arch = arch::vit_cifar();
        let mut prev = 0.0;
        for p in [2usize, 4, 8, 16] {
            let s = accounting(&arch, &TilingPolicy::tbn(p, 64_000)).savings_vs_binary();
            assert!(s > prev, "p={p}");
            prev = s;
        }
    }

    #[test]
    fn lambda_global_tiles_more_than_default() {
        let arch = arch::resnet18_cifar();
        let global = accounting(&arch, &TilingPolicy::tbn(4, 0));
        let lam = accounting(&arch, &TilingPolicy::tbn(4, 64_000));
        assert!(global.total_bits < lam.total_bits);
    }

    #[test]
    fn single_alpha_costs_less_than_per_tile() {
        let arch = arch::vit_cifar();
        let mut per_tile = TilingPolicy::tbn(16, 64_000);
        let mut single = TilingPolicy::tbn(16, 64_000);
        single.alpha = crate::tbn::AlphaMode::Single;
        per_tile.alpha = crate::tbn::AlphaMode::PerTile;
        assert!(accounting(&arch, &single).total_bits
                < accounting(&arch, &per_tile).total_bits);
    }
}
