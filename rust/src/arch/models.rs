//! Builders for every paper architecture (see mod.rs for calibration notes).
//!
//! Branching topologies (ResNet skips, PointNet T-Nets) carry [`BlockRole`]
//! annotations so `nn::lower_arch_spec` can rebuild the graph edges from
//! the flat layer list; the analytic accounting ignores them.

use super::{ArchSpec, AttnPart, BlockRole, LayerSpec};

// ---------------------------------------------------------------------------
// ResNets
// ---------------------------------------------------------------------------

fn body(l: LayerSpec, id: &str) -> LayerSpec {
    l.in_block(BlockRole::ResidualBody { id: id.into() })
}

fn down(l: LayerSpec, id: &str) -> LayerSpec {
    l.in_block(BlockRole::ResidualDown { id: id.into() })
}

/// Basic-block ResNet (18/34-style). `stage_blocks` per stage, widths
/// doubling from `width0`; `img` is the input spatial size after the stem.
fn basic_resnet(name: &str, stage_blocks: [usize; 4], width0: usize, img: usize,
                stem: LayerSpec, classes: usize) -> ArchSpec {
    let mut layers = vec![stem];
    let mut cin = width0;
    let mut sp = img;
    for (si, &nblocks) in stage_blocks.iter().enumerate() {
        let ch = width0 << si;
        for bi in 0..nblocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            if stride == 2 {
                sp /= 2;
            }
            let pre = format!("s{si}b{bi}");
            layers.push(body(LayerSpec::conv(&format!("{pre}.conv1"), cin, ch, 3, sp, sp,
                                             sp * stride, sp * stride), &pre));
            layers.push(body(LayerSpec::conv(&format!("{pre}.conv2"), ch, ch, 3, sp, sp,
                                             sp, sp), &pre));
            if stride != 1 || cin != ch {
                layers.push(down(LayerSpec::conv(&format!("{pre}.down"), cin, ch, 1, sp, sp,
                                                 sp * stride, sp * stride), &pre));
            }
            cin = ch;
        }
    }
    layers.push(LayerSpec::fc("fc", cin, classes));
    ArchSpec { name: name.into(), layers }
}

/// Bottleneck ResNet (50-style), expansion 4.
fn bottleneck_resnet(name: &str, stage_blocks: [usize; 4], width0: usize, img: usize,
                     stem: LayerSpec, classes: usize) -> ArchSpec {
    let mut layers = vec![stem];
    let mut cin = width0;
    let mut sp = img;
    for (si, &nblocks) in stage_blocks.iter().enumerate() {
        let mid = width0 << si;
        let out = mid * 4;
        for bi in 0..nblocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            if stride == 2 {
                sp /= 2;
            }
            let pre = format!("s{si}b{bi}");
            layers.push(body(LayerSpec::conv(&format!("{pre}.conv1"), cin, mid, 1, sp, sp,
                                             sp * stride, sp * stride), &pre));
            layers.push(body(LayerSpec::conv(&format!("{pre}.conv2"), mid, mid, 3, sp, sp,
                                             sp, sp), &pre));
            layers.push(body(LayerSpec::conv(&format!("{pre}.conv3"), mid, out, 1, sp, sp,
                                             sp, sp), &pre));
            if stride != 1 || cin != out {
                layers.push(down(LayerSpec::conv(&format!("{pre}.down"), cin, out, 1, sp, sp,
                                                 sp * stride, sp * stride), &pre));
            }
            cin = out;
        }
    }
    layers.push(LayerSpec::fc("fc", cin, classes));
    ArchSpec { name: name.into(), layers }
}

pub fn resnet18_cifar() -> ArchSpec {
    basic_resnet("resnet18_cifar", [2, 2, 2, 2], 64, 32,
                 LayerSpec::conv("stem", 3, 64, 3, 32, 32, 32, 32), 10)
}

pub fn resnet50_cifar() -> ArchSpec {
    bottleneck_resnet("resnet50_cifar", [3, 4, 6, 3], 64, 32,
                      LayerSpec::conv("stem", 3, 64, 3, 32, 32, 32, 32), 10)
}

pub fn resnet34_imagenet() -> ArchSpec {
    basic_resnet("resnet34_imagenet", [3, 4, 6, 3], 64, 56,
                 LayerSpec::conv("stem", 3, 64, 7, 112, 112, 224, 224), 1000)
}

// ---------------------------------------------------------------------------
// VGG-Small (the BNN literature's CIFAR VGG)
// ---------------------------------------------------------------------------

pub fn vgg_small_cifar() -> ArchSpec {
    let plan: [(usize, usize); 6] =
        [(128, 32), (128, 32), (256, 16), (256, 16), (512, 8), (512, 8)];
    let mut layers = Vec::new();
    let mut cin = 3;
    let mut sp_in = 32;
    for (i, &(ch, sp)) in plan.iter().enumerate() {
        layers.push(LayerSpec::conv(&format!("conv{i}"), cin, ch, 3, sp, sp, sp_in, sp_in));
        cin = ch;
        sp_in = sp;
    }
    layers.push(LayerSpec::fc("fc", 512 * 4 * 4, 10));
    ArchSpec { name: "vgg_small_cifar".into(), layers }
}

// ---------------------------------------------------------------------------
// Transformers
// ---------------------------------------------------------------------------

/// How [`encoder_blocks`] tags its layers for the native graph lowering.
enum EncoderTag<'a> {
    /// Standard multi-head self-attention with this many heads: the blocks
    /// lower natively (pre-LN `LayerNorm`/`Attention` nodes, linear
    /// residual joins).
    Native { heads: usize },
    /// An attention variant the engine has no node for (Swin shifted
    /// windows, MobileViT unfold/fold): lowering fails naming it.
    Unsupported(&'a str),
}

/// Standard encoder stack: qkv + proj + 2-layer MLP per block, FC applied
/// across `tokens` positions, annotated for `nn::lower_arch_spec` per
/// `tag` (the analytic accounting ignores the tags).
fn encoder_blocks(layers: &mut Vec<LayerSpec>, depth: usize, dim: usize,
                  mlp: usize, tokens: usize, tag: &EncoderTag) {
    for d in 0..depth {
        let pre = format!("blk{d}");
        let attn = |part: AttnPart| match tag {
            EncoderTag::Native { heads } => BlockRole::AttnProj {
                id: format!("{pre}.attn"), heads: *heads, part },
            EncoderTag::Unsupported(c) => BlockRole::Unsupported {
                id: format!("{pre}.attn"), construct: (*c).into() },
        };
        let mlp_role = || BlockRole::MlpBody { id: format!("{pre}.mlp") };
        layers.push(LayerSpec::fc_tok(&format!("{pre}.wq"), dim, dim, tokens)
            .in_block(attn(AttnPart::Q)));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.wk"), dim, dim, tokens)
            .in_block(attn(AttnPart::K)));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.wv"), dim, dim, tokens)
            .in_block(attn(AttnPart::V)));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.wo"), dim, dim, tokens)
            .in_block(attn(AttnPart::O)));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.mlp.fc1"), dim, mlp, tokens)
            .in_block(mlp_role()));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.mlp.fc2"), mlp, dim, tokens)
            .in_block(mlp_role()));
    }
}

/// ViT trained on CIFAR-10 (Table 4): patch 4, dim 512, depth 6, mlp 512.
/// The pos-embed record sits right after the patch embedding (where the
/// lowering turns it into a `PosEmbedAdd` node); 8 heads (head dim 64).
pub fn vit_cifar() -> ArchSpec {
    let (dim, depth, mlp, tokens, heads) = (512, 6, 512, 64, 8);
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 3 * 4 * 4, dim, tokens)];
    layers.push(LayerSpec::other("pos_embed", tokens * dim));
    encoder_blocks(&mut layers, depth, dim, mlp, tokens,
                   &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, 10));
    ArchSpec { name: "vit_cifar".into(), layers }
}

/// ImageNet ViT (Small) used in Table 7 / Fig 5: ~52M params, six ~8.4M
/// attention blocks (dim 832, mlp ratio 4, patch 16 on 224), 8 heads.
pub fn vit_small_imagenet() -> ArchSpec {
    let (dim, depth, tokens, heads) = (832, 6, 196, 8);
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 3 * 16 * 16, dim, tokens)];
    layers.push(LayerSpec::other("pos_embed", tokens * dim));
    encoder_blocks(&mut layers, depth, dim, 4 * dim, tokens,
                   &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, 1000));
    ArchSpec { name: "vit_small_imagenet".into(), layers }
}

/// Swin-t: stages [2,2,6,2] at dims [96,192,384,768], patch-merging FCs.
pub fn swin_t() -> ArchSpec {
    let dims = [96usize, 192, 384, 768];
    let depths = [2usize, 2, 6, 2];
    let tokens = [3136usize, 784, 196, 49]; // 224/4 = 56 -> 56^2 ...
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 3 * 4 * 4, dims[0], tokens[0])];
    for s in 0..4 {
        let mut stage = Vec::new();
        encoder_blocks(&mut stage, depths[s], dims[s], 4 * dims[s], tokens[s],
                       &EncoderTag::Unsupported("Swin shifted-window attention"));
        for mut l in stage {
            l.name = format!("st{s}.{}", l.name);
            layers.push(l);
        }
        if s < 3 {
            layers.push(LayerSpec::fc_tok(&format!("st{s}.merge"), 4 * dims[s],
                                          dims[s + 1], tokens[s + 1]));
        }
    }
    layers.push(LayerSpec::fc("head", dims[3], 1000));
    ArchSpec { name: "swin_t".into(), layers }
}

/// MobileViT-S-like hybrid (Figure 2 only): conv stem/stages + transformer
/// blocks, roughly balanced conv/FC split at ~5.6M params.
pub fn mobilevit() -> ArchSpec {
    let mut layers = vec![
        LayerSpec::conv("stem", 3, 16, 3, 128, 128, 256, 256),
        LayerSpec::conv("mv2_0", 16, 32, 3, 128, 128, 128, 128),
        LayerSpec::conv("mv2_1", 32, 64, 3, 64, 64, 128, 128),
        LayerSpec::conv("mv2_2", 64, 96, 3, 32, 32, 64, 64),
        LayerSpec::conv("mv2_3", 96, 128, 3, 16, 16, 32, 32),
        LayerSpec::conv("mv2_4", 128, 160, 3, 8, 8, 16, 16),
    ];
    let fold = EncoderTag::Unsupported("MobileViT unfold/fold attention");
    encoder_blocks(&mut layers, 2, 144, 288, 256, &fold);
    encoder_blocks(&mut layers, 4, 192, 384, 64, &fold);
    encoder_blocks(&mut layers, 3, 240, 480, 16, &fold);
    layers.push(LayerSpec::conv("proj", 160, 640, 1, 8, 8, 8, 8));
    layers.push(LayerSpec::fc("head", 640, 1000));
    ArchSpec { name: "mobilevit".into(), layers }
}

// ---------------------------------------------------------------------------
// PointNets (Qi et al., incl. T-Nets — FC-dominated per Figure 2)
// ---------------------------------------------------------------------------

fn tnet(layers: &mut Vec<LayerSpec>, pre: &str, k: usize, points: usize) {
    let t = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: pre.into(), k });
    layers.push(t(LayerSpec::fc_tok(&format!("{pre}.conv1"), k, 64, points)));
    layers.push(t(LayerSpec::fc_tok(&format!("{pre}.conv2"), 64, 128, points)));
    layers.push(t(LayerSpec::fc_tok(&format!("{pre}.conv3"), 128, 1024, points)));
    layers.push(t(LayerSpec::fc(&format!("{pre}.fc1"), 1024, 512)));
    layers.push(t(LayerSpec::fc(&format!("{pre}.fc2"), 512, 256)));
    layers.push(t(LayerSpec::fc(&format!("{pre}.fc3"), 256, k * k)));
}

pub fn pointnet_cls() -> ArchSpec {
    let n = 1024; // points
    let mut layers = Vec::new();
    tnet(&mut layers, "tnet3", 3, n);
    layers.push(LayerSpec::fc_tok("conv1", 3, 64, n));
    layers.push(LayerSpec::fc_tok("conv2", 64, 64, n));
    tnet(&mut layers, "tnet64", 64, n);
    layers.push(LayerSpec::fc_tok("conv3", 64, 64, n));
    layers.push(LayerSpec::fc_tok("conv4", 64, 128, n));
    layers.push(LayerSpec::fc_tok("conv5", 128, 1024, n));
    layers.push(LayerSpec::fc("fc1", 1024, 512));
    layers.push(LayerSpec::fc("fc2", 512, 256));
    layers.push(LayerSpec::fc("head", 256, 40));
    ArchSpec { name: "pointnet_cls".into(), layers }
}

pub fn pointnet_part_seg() -> ArchSpec {
    let n = 2048;
    let mut layers = Vec::new();
    tnet(&mut layers, "tnet3", 3, n);
    layers.push(LayerSpec::fc_tok("conv1", 3, 64, n));
    layers.push(LayerSpec::fc_tok("conv2", 64, 128, n));
    layers.push(LayerSpec::fc_tok("conv3", 128, 128, n));
    tnet(&mut layers, "tnet128", 128, n);
    layers.push(LayerSpec::fc_tok("conv4", 128, 512, n));
    layers.push(LayerSpec::fc_tok("conv5", 512, 2048, n));
    // per-point concat of skip features + global feature + class one-hot
    // concat: skip features (64+128+128+512) + global (2048) + one-hot (16)
    layers.push(LayerSpec::fc_tok("seg1", 2048 + 512 + 128 + 128 + 64 + 16, 256, n));
    layers.push(LayerSpec::fc_tok("seg2", 256, 256, n));
    layers.push(LayerSpec::fc_tok("seg3", 256, 128, n));
    layers.push(LayerSpec::fc_tok("head", 128, 50, n));
    ArchSpec { name: "pointnet_part_seg".into(), layers }
}

pub fn pointnet_sem_seg() -> ArchSpec {
    let n = 4096;
    let mut layers = Vec::new();
    tnet(&mut layers, "tnet3", 3, n);
    layers.push(LayerSpec::fc_tok("conv1", 3, 64, n));
    layers.push(LayerSpec::fc_tok("conv2", 64, 64, n));
    tnet(&mut layers, "tnet64", 64, n);
    layers.push(LayerSpec::fc_tok("conv3", 64, 64, n));
    layers.push(LayerSpec::fc_tok("conv4", 64, 128, n));
    layers.push(LayerSpec::fc_tok("conv5", 128, 1024, n));
    layers.push(LayerSpec::fc_tok("seg1", 1024 + 64, 512, n));
    layers.push(LayerSpec::fc_tok("seg2", 512, 256, n));
    layers.push(LayerSpec::fc_tok("head", 256, 13, n));
    ArchSpec { name: "pointnet_sem_seg".into(), layers }
}

// ---------------------------------------------------------------------------
// Mixers (Figure 6 ablation architectures)
// ---------------------------------------------------------------------------

/// Mixer block pair-annotations: token-mixing MLPs lower transposed
/// (`BlockRole::TokenMix`), channel MLPs as plain pre-LN MLP sub-blocks.
fn mixer_blocks(layers: &mut Vec<LayerSpec>, depth: usize, dim: usize,
                tokens: usize, tok_h: usize, ch_h: usize) {
    for d in 0..depth {
        let pre = format!("blk{d}");
        let tok = || BlockRole::TokenMix { id: format!("{pre}.tok") };
        let ch = || BlockRole::MlpBody { id: format!("{pre}.ch") };
        layers.push(LayerSpec::fc_tok(&format!("{pre}.tok.fc1"), tokens, tok_h, dim)
            .in_block(tok()));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.tok.fc2"), tok_h, tokens, dim)
            .in_block(tok()));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.ch.fc1"), dim, ch_h, tokens)
            .in_block(ch()));
        layers.push(LayerSpec::fc_tok(&format!("{pre}.ch.fc2"), ch_h, dim, tokens)
            .in_block(ch()));
    }
}

/// MLPMixer whose largest layers are 131k elements (512x256), per Fig 6.
pub fn mlpmixer_cifar() -> ArchSpec {
    let (dim, depth, tokens, tok_h, ch_h) = (512, 6, 64, 64, 256);
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 3 * 4 * 4, dim, tokens)];
    mixer_blocks(&mut layers, depth, dim, tokens, tok_h, ch_h);
    layers.push(LayerSpec::fc("head", dim, 10));
    ArchSpec { name: "mlpmixer_cifar".into(), layers }
}

/// ConvMixer-256/16 kernel 8 patch 1: largest layer 65,536 (256x256), Fig 6.
pub fn convmixer_cifar() -> ArchSpec {
    let (dim, depth, k, sp) = (256, 16, 8, 32);
    let mut layers = vec![LayerSpec::conv("patch_embed", 3, dim, 1, sp, sp, sp, sp)];
    for d in 0..depth {
        let pre = format!("blk{d}");
        // depthwise: ci = 1 per group; params dim*k*k
        layers.push(LayerSpec {
            name: format!("{pre}.dw"),
            kind: super::Kind::Conv { co: dim, ci: 1, kh: k, kw: k },
            params: dim * k * k,
            macs: (dim * k * k * sp * sp) as u64,
            in_act: dim * sp * sp,
            out_act: dim * sp * sp,
            block: None,
        });
        layers.push(LayerSpec::conv(&format!("{pre}.pw"), dim, dim, 1, sp, sp, sp, sp));
    }
    layers.push(LayerSpec::fc("head", dim, 10));
    ArchSpec { name: "convmixer_cifar".into(), layers }
}

// ---------------------------------------------------------------------------
// Native-engine demo minis (not paper architectures; excluded from
// `all_archs` so the analytic tables stay paper-only)
// ---------------------------------------------------------------------------

/// Tiny CNN sized so the full forward runs in the artifact-free test tier on
/// both engine paths: two convs (the second stride-2), an implied global
/// pool, and an FC head.  `nn::lower_arch_spec` turns this into a native
/// layer graph; `tests/conv_parity.rs` runs it end-to-end.
pub fn cnn_micro() -> ArchSpec {
    ArchSpec {
        name: "cnn_micro".into(),
        layers: vec![
            LayerSpec::conv("conv0", 3, 8, 3, 16, 16, 16, 16),
            LayerSpec::conv("conv1", 8, 16, 3, 8, 8, 16, 16),
            LayerSpec::fc("head", 16, 10),
        ],
    }
}

/// PointNet-style shared-MLP backbone mini: token-wise 1x1 convs
/// (`fc_tok`) over 64 points, a global pool, and FC layers — exercises the
/// native lowering of the paper's point-cloud shared MLPs.
pub fn pointnet_micro() -> ArchSpec {
    let n = 64;
    ArchSpec {
        name: "pointnet_micro".into(),
        layers: vec![
            LayerSpec::fc_tok("conv1", 3, 16, n),
            LayerSpec::fc_tok("conv2", 16, 32, n),
            LayerSpec::fc("fc1", 32, 16),
            LayerSpec::fc("head", 16, 10),
        ],
    }
}

/// Two-block residual mini on a 7x7 input: one identity-skip block and one
/// stride-2 block with a 1x1 projection shortcut.  The 7x7 map makes the
/// first residual join `8 * 7 * 7 = 392` elements wide (`392 % 64 != 0`),
/// so the packed join path exercises ragged activation widths end-to-end.
pub fn resnet_micro() -> ArchSpec {
    let mut layers = vec![LayerSpec::conv("stem", 3, 8, 3, 7, 7, 7, 7)];
    layers.push(body(LayerSpec::conv("b0.conv1", 8, 8, 3, 7, 7, 7, 7), "b0"));
    layers.push(body(LayerSpec::conv("b0.conv2", 8, 8, 3, 7, 7, 7, 7), "b0"));
    layers.push(body(LayerSpec::conv("b1.conv1", 8, 12, 3, 4, 4, 7, 7), "b1"));
    layers.push(body(LayerSpec::conv("b1.conv2", 12, 12, 3, 4, 4, 4, 4), "b1"));
    layers.push(down(LayerSpec::conv("b1.down", 8, 12, 1, 4, 4, 7, 7), "b1"));
    layers.push(LayerSpec::fc("head", 12, 10));
    ArchSpec { name: "resnet_micro".into(), layers }
}

/// PointNet mini **with T-Nets**: a 3x3 input transform and an 8x8 feature
/// transform, each a shared-MLP subgraph ending in a `k*k` matrix that
/// multiplies the features it branched from (`MatMulFeature` joins).
pub fn pointnet_tnet_micro() -> ArchSpec {
    let n = 16; // points
    let t3 = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: "tnet3".into(), k: 3 });
    let t8 = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: "tnet8".into(), k: 8 });
    ArchSpec {
        name: "pointnet_tnet_micro".into(),
        layers: vec![
            t3(LayerSpec::fc_tok("tnet3.conv1", 3, 8, n)),
            t3(LayerSpec::fc_tok("tnet3.conv2", 8, 16, n)),
            t3(LayerSpec::fc("tnet3.fc1", 16, 8)),
            t3(LayerSpec::fc("tnet3.fc2", 8, 9)),
            LayerSpec::fc_tok("conv1", 3, 8, n),
            t8(LayerSpec::fc_tok("tnet8.conv1", 8, 16, n)),
            t8(LayerSpec::fc("tnet8.fc1", 16, 64)),
            LayerSpec::fc_tok("conv2", 8, 16, n),
            LayerSpec::fc("head", 16, 10),
        ],
    }
}

/// ViT mini for the native transformer engine: ragged dims everywhere
/// (dim 20 with 4 heads -> head dim 5; 10 tokens; neither a multiple of
/// 64), a learned pos-embed after the patch embedding, and two pre-LN
/// encoder blocks.  `tests/transformer_parity.rs` runs it end-to-end on
/// every path, and CI's `TBN_LAYOUT` matrix covers both packed layouts.
pub fn vit_micro() -> ArchSpec {
    let (dim, depth, mlp, tokens, heads) = (20, 2, 28, 10, 4);
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 12, dim, tokens)];
    layers.push(LayerSpec::other("pos_embed", tokens * dim));
    encoder_blocks(&mut layers, depth, dim, mlp, tokens,
                   &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, 6));
    ArchSpec { name: "vit_micro".into(), layers }
}

/// Time-series Transformer mini: 5 input channels projected to dim 12 over
/// a 9-step window, two encoder blocks with 3 heads (head dim 4), per-step
/// forecast head after the token mean pool.
pub fn tst_micro() -> ArchSpec {
    let (dim, depth, mlp, seq, ch, heads) = (12, 2, 20, 9, 5, 3);
    let mut layers = vec![LayerSpec::fc_tok("in_proj", ch, dim, seq)];
    encoder_blocks(&mut layers, depth, dim, mlp, seq, &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, ch));
    ArchSpec { name: "tst_micro".into(), layers }
}

/// MLP-Mixer mini: token-mixing MLPs run transposed through the native
/// `Transpose` node; the token-MLP hidden width (12) differs from the
/// token count (9) so the transposed shapes are actually exercised.
pub fn mixer_micro() -> ArchSpec {
    let (dim, depth, tokens, tok_h, ch_h) = (16, 2, 9, 12, 24);
    let mut layers = vec![LayerSpec::fc_tok("patch_embed", 6, dim, tokens)];
    mixer_blocks(&mut layers, depth, dim, tokens, tok_h, ch_h);
    layers.push(LayerSpec::fc("head", dim, 4));
    ArchSpec { name: "mixer_micro".into(), layers }
}

// ---------------------------------------------------------------------------
// Time-series Transformers (Table 5)
// ---------------------------------------------------------------------------

pub fn tst_electricity() -> ArchSpec {
    let (dim, depth, mlp, seq, ch, heads) = (512, 2, 1024, 96, 321, 8);
    let mut layers = vec![LayerSpec::fc_tok("in_proj", ch, dim, seq)];
    encoder_blocks(&mut layers, depth, dim, mlp, seq, &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, ch));
    ArchSpec { name: "tst_electricity".into(), layers }
}

pub fn tst_weather() -> ArchSpec {
    let (dim, depth, mlp, seq, ch, heads) = (128, 2, 448, 96, 7, 8);
    let mut layers = vec![LayerSpec::fc_tok("in_proj", ch, dim, seq)];
    encoder_blocks(&mut layers, depth, dim, mlp, seq, &EncoderTag::Native { heads });
    layers.push(LayerSpec::fc("head", dim, ch));
    ArchSpec { name: "tst_weather".into(), layers }
}
