//! Full-pipeline integration: coordinator runs (train → eval → export →
//! verify → record) on micro experiments, run-record caching, and the
//! TBN-vs-BWNN-vs-FP ordering the paper's tables rest on.

use tiledbits::config::Manifest;
use tiledbits::coordinator::{self, run_experiment};
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;

fn setup() -> Option<(Runtime, Manifest)> {
    let Some(artifacts) = tiledbits::util::locate_upwards("artifacts") else {
        eprintln!("skipping pipeline tests: artifacts/ not built");
        return None;
    };
    let manifest = match Manifest::load(&artifacts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping pipeline tests: {e}");
            return None;
        }
    };
    let rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping pipeline tests: {e:#}");
            return None;
        }
    };
    Some((rt, manifest))
}

fn opts(steps: usize) -> TrainOptions {
    TrainOptions { steps: Some(steps), eval_every: 0, log_every: 10_000, seed: Some(11) }
}

#[test]
fn micro_pipeline_produces_complete_record() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("mlp_micro_tbn4").unwrap();
    let rec = run_experiment(&rt, exp, &opts(60)).unwrap();
    assert_eq!(rec.id, "mlp_micro_tbn4");
    assert_eq!(rec.steps, 60);
    assert!(rec.metric > 0.2, "60 steps should beat chance, got {}", rec.metric);
    assert!(rec.bit_width < 1.0, "TBN must be sub-bit, got {}", rec.bit_width);
    assert!(rec.forward_agreement >= 0.95,
            "forward-graph verification failed: {}", rec.forward_agreement);
    assert!(!rec.train_curve.is_empty());
    assert!(!rec.eval_curve.is_empty());
    assert!(rec.duration_s > 0.0);
}

#[test]
fn run_or_load_caches() {
    let Some((rt, manifest)) = setup() else { return };
    let dir = std::env::temp_dir().join("tbn_runs_cache_test");
    let dir = dir.to_str().unwrap().to_string();
    let _ = std::fs::remove_dir_all(&dir);
    let r1 = coordinator::run_or_load(&rt, &manifest, "mlp_micro_fp", &opts(20), &dir).unwrap();
    let t0 = std::time::Instant::now();
    let r2 = coordinator::run_or_load(&rt, &manifest, "mlp_micro_fp", &opts(20), &dir).unwrap();
    assert!(t0.elapsed().as_millis() < 500, "second call must be a cache hit");
    assert_eq!(r1.steps, r2.steps);
    assert!((r1.metric - r2.metric).abs() < 1e-9);
    // asking for more steps must retrain
    let r3 = coordinator::run_or_load(&rt, &manifest, "mlp_micro_fp", &opts(25), &dir).unwrap();
    assert_eq!(r3.steps, 25);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fp_bwnn_tbn_ordering_on_micro_mlp() {
    // Table 6 / Table 1 structure at micro scale: FP >= BWNN ~ TBN in
    // accuracy; TBN < BWNN < FP in storage.
    let Some((rt, manifest)) = setup() else { return };
    let mut recs = Vec::new();
    for id in ["mlp_micro_fp", "mlp_micro_bwnn", "mlp_micro_tbn4"] {
        let exp = manifest.by_id(id).unwrap();
        recs.push(run_experiment(&rt, exp, &opts(120)).unwrap());
    }
    let (fp, bwnn, tbn) = (&recs[0], &recs[1], &recs[2]);
    // storage ordering is exact
    assert!(fp.storage_bits > bwnn.storage_bits, "{} vs {}", fp.storage_bits, bwnn.storage_bits);
    assert!(bwnn.storage_bits > tbn.storage_bits, "{} vs {}", bwnn.storage_bits, tbn.storage_bits);
    assert!((fp.bit_width - 32.0).abs() < 0.5);
    assert!(tbn.bit_width < 0.6, "tbn bit width {}", tbn.bit_width);
    // accuracy: all should be well above chance; FP at least as good as TBN
    for r in &recs {
        assert!(r.metric > 0.4, "{}: {}", r.id, r.metric);
    }
    assert!(fp.metric + 0.05 >= tbn.metric, "FP {} vs TBN {}", fp.metric, tbn.metric);
}

#[test]
fn experiments_for_tables_resolve() {
    let Some((_, manifest)) = setup() else { return };
    for (table, _) in coordinator::TABLES {
        let ids = coordinator::experiments_for(&manifest, table);
        // analytic tables (T2, T7, F2, F5) may have no training runs; all
        // others must
        if ["T1", "T3", "T4", "T5", "T6", "F6", "F7", "F8"].contains(table) {
            assert!(!ids.is_empty(), "no experiments for {table}");
        }
    }
}

#[test]
fn seg_pipeline_reports_iou() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("pointnet_seg_tbn4").unwrap();
    let rec = run_experiment(&rt, exp, &opts(25)).unwrap();
    assert!(rec.class_iou.is_some(), "seg run must report class IoU");
    assert!(rec.instance_iou.is_some());
    let iou = rec.class_iou.unwrap();
    assert!((0.0..=1.0).contains(&iou), "IoU {iou}");
}

#[test]
fn forecast_pipeline_reports_mse() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("tst_weather_tbn4").unwrap();
    let rec = run_experiment(&rt, exp, &opts(25)).unwrap();
    // metric is MSE for forecasting: positive, and training should have
    // brought it below the raw series variance (~1.0-2.5)
    assert!(rec.metric > 0.0);
    assert!(rec.metric < 5.0, "MSE {} looks untrained", rec.metric);
    assert!(rec.class_iou.is_none());
}
