//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1 / PJRT C API). Graphs arrive
//! as HLO *text* — the text parser reassigns instruction ids, which is what
//! makes jax >= 0.5 output loadable on this XLA (see aot.py).
//!
//! All lowered graphs return a tuple (aot.py lowers with return_tuple=True);
//! `Executable::run` decomposes it into one `Literal` per logical output.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

/// A compiled graph ready to execute on the CPU PJRT client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs (owned or borrowed — pass `&Literal`s in
    /// hot loops; cloning a literal deep-copies its buffer); returns the
    /// decomposed output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let root = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        Ok(root.to_tuple()?)
    }

    /// Execute with device-resident buffers (used by the trainer hot loop to
    /// avoid host round-trips on inputs that don't change).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("execute_b {}", self.name))?;
        let root = bufs[0][0].to_literal_sync()?;
        Ok(root.to_tuple()?)
    }
}

/// The PJRT client + compile cache over an artifacts directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: String,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_string(),
            cache: std::cell::RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = format!("{}/{}", self.artifacts_dir, file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {file}"))?;
        let e = std::rc::Rc::new(Executable { exe, name: file.to_string() });
        self.cache.borrow_mut().insert(file.to_string(), e.clone());
        Ok(e)
    }

    /// Upload a literal to the device (for `run_b` steady-state inputs).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("to_device: {e}"))
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------------

/// f32 literal from a host tensor.
pub fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// i32 literal from indices.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Host tensor from an f32 literal.
pub fn tensor_from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

/// i32 host vector from a literal.
pub fn i32_from_literal(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// f32 scalar from a 0-d literal.
pub fn f32_scalar_from_literal(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
