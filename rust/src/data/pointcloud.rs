//! Parametric point-cloud generators (ModelNet40 / ShapeNet stand-ins).

use crate::util::Rng;
use super::{Dataset, Task};

/// Sample one point on shape `class` (unit scale, canonical pose).
fn sample_point(class: usize, rng: &mut Rng) -> [f32; 3] {
    match class % 8 {
        0 => {
            // sphere surface
            let v = [rng.gauss_f32(), rng.gauss_f32(), rng.gauss_f32()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt().max(1e-6);
            [v[0] / n, v[1] / n, v[2] / n]
        }
        1 => {
            // cube surface: pick a face, uniform on it
            let face = rng.below(6);
            let (u, v) = (rng.next_f32() * 2.0 - 1.0, rng.next_f32() * 2.0 - 1.0);
            match face {
                0 => [1.0, u, v],
                1 => [-1.0, u, v],
                2 => [u, 1.0, v],
                3 => [u, -1.0, v],
                4 => [u, v, 1.0],
                _ => [u, v, -1.0],
            }
        }
        2 => {
            // cylinder (side + caps)
            let th = std::f32::consts::TAU * rng.next_f32();
            let z = rng.next_f32() * 2.0 - 1.0;
            [th.cos(), th.sin(), z]
        }
        3 => {
            // cone
            let th = std::f32::consts::TAU * rng.next_f32();
            let h = rng.next_f32();
            let r = 1.0 - h;
            [r * th.cos(), r * th.sin(), 2.0 * h - 1.0]
        }
        4 => {
            // torus, R=1, r=0.35
            let (a, b) = (std::f32::consts::TAU * rng.next_f32(),
                          std::f32::consts::TAU * rng.next_f32());
            let r = 0.35;
            [(1.0 + r * b.cos()) * a.cos(), (1.0 + r * b.cos()) * a.sin(), r * b.sin()]
        }
        5 => {
            // thin plane with ripples
            let (u, v) = (rng.next_f32() * 2.0 - 1.0, rng.next_f32() * 2.0 - 1.0);
            [u, v, 0.15 * (3.0 * u).sin() * (3.0 * v).cos()]
        }
        6 => {
            // pyramid (4 triangular faces over a square base)
            let (u, v) = (rng.next_f32() * 2.0 - 1.0, rng.next_f32() * 2.0 - 1.0);
            let h = 1.0 - u.abs().max(v.abs());
            [u, v, h * 2.0 - 1.0]
        }
        _ => {
            // helix
            let t = 2.0 * std::f32::consts::TAU * rng.next_f32();
            [0.8 * t.cos(), 0.8 * t.sin(), t / (2.0 * std::f32::consts::TAU) * 2.0 - 1.0]
        }
    }
}

fn rotate_z(p: [f32; 3], th: f32) -> [f32; 3] {
    let (s, c) = th.sin_cos();
    [c * p[0] - s * p[1], s * p[0] + c * p[1], p[2]]
}

/// SynthModelNet: one of `classes` parametric shapes per sample, random
/// z-rotation + scale + jitter — the PointNet classification stand-in.
pub fn synth_modelnet(input: &[usize], classes: usize, n: usize, rng: &mut Rng) -> Dataset {
    assert_eq!(input.len(), 2, "pointcloud wants [points, 3]");
    let points = input[0];
    let mut x = Vec::with_capacity(n * points * 3);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        y.push(c as i32);
        let th = std::f32::consts::TAU * rng.next_f32();
        let scale = 0.8 + 0.4 * rng.next_f32();
        for _ in 0..points {
            let p = rotate_z(sample_point(c, rng), th);
            for k in 0..3 {
                x.push(scale * p[k] + 0.02 * rng.gauss_f32());
            }
        }
    }
    Dataset { n, x_elems: points * 3, x, y_int: y, y_float: vec![], y_elems: 0,
              y_int_elems: 1, task: Task::Cls }
}

/// SynthShapeNet (part segmentation): composite objects whose per-point part
/// label follows geometry — a "lamp"-like object with `classes` parts
/// stacked along z with distinct radial profiles.  Labels are recoverable
/// from local + global geometry, as in real part segmentation.
pub fn synth_shapenet(input: &[usize], classes: usize, n: usize, rng: &mut Rng) -> Dataset {
    assert_eq!(input.len(), 2);
    let points = input[0];
    let mut x = Vec::with_capacity(n * points * 3);
    let mut y = Vec::with_capacity(n * points);
    for _ in 0..n {
        let th = std::f32::consts::TAU * rng.next_f32();
        let scale = 0.85 + 0.3 * rng.next_f32();
        // object-level shape variation: per-part radius multipliers
        let radii: Vec<f32> = (0..classes).map(|_| 0.3 + 0.7 * rng.next_f32()).collect();
        for _ in 0..points {
            let part = rng.below(classes);
            // part occupies a z-band; radial profile distinguishes parts
            let z0 = -1.0 + 2.0 * (part as f32 + rng.next_f32()) / classes as f32;
            let r = radii[part] * (0.8 + 0.2 * rng.next_f32());
            let a = std::f32::consts::TAU * rng.next_f32();
            let p = rotate_z([r * a.cos(), r * a.sin(), z0], th);
            for k in 0..3 {
                x.push(scale * p[k] + 0.01 * rng.gauss_f32());
            }
            y.push(part as i32);
        }
    }
    Dataset { n, x_elems: points * 3, x, y_int: y, y_float: vec![], y_elems: 0,
              y_int_elems: points, task: Task::Seg }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modelnet_points_bounded() {
        let mut rng = Rng::new(3);
        let d = synth_modelnet(&[128, 3], 8, 16, &mut rng);
        assert_eq!(d.x.len(), 16 * 128 * 3);
        assert!(d.x.iter().all(|v| v.abs() < 4.0));
    }

    #[test]
    fn shapenet_labels_follow_height() {
        // part index should correlate with (un-rotated) z: check rank corr > 0
        let mut rng = Rng::new(4);
        let d = synth_shapenet(&[128, 3], 4, 8, &mut rng);
        let mut agree = 0usize;
        let mut total = 0usize;
        for s in 0..8 {
            for i in 0..128 {
                for j in 0..128 {
                    let zi = d.x[(s * 128 + i) * 3 + 2];
                    let zj = d.x[(s * 128 + j) * 3 + 2];
                    let yi = d.y_int[s * 128 + i];
                    let yj = d.y_int[s * 128 + j];
                    if yi != yj {
                        total += 1;
                        if (zi < zj) == (yi < yj) {
                            agree += 1;
                        }
                    }
                }
            }
        }
        let frac = agree as f64 / total.max(1) as f64;
        assert!(frac > 0.8, "z-order agreement {frac}");
    }

    #[test]
    fn all_shape_classes_sample() {
        let mut rng = Rng::new(5);
        for c in 0..8 {
            for _ in 0..50 {
                let p = sample_point(c, &mut rng);
                assert!(p.iter().all(|v| v.is_finite() && v.abs() <= 2.01), "class {c}");
            }
        }
    }
}
