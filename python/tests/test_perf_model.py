"""Performance-model tests for the L1 Pallas kernel (structure, not wallclock).

interpret=True timings are CPU-numpy and meaningless as a TPU proxy, so the
perf contract is structural: VMEM footprint per grid step, weight-stream
reduction, and MXU-friendly block shapes — checked over every tiled FC layer
that actually ships in the manifest.
"""

import json
import os

import pytest

from compile.kernels.tiled_matmul import _block_rows, vmem_bytes_tiled

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TPU core


def manifest():
    path = os.path.join(REPO, "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        return json.load(f)


def tiled_fc_layers(man):
    """(exp_id, m, n, p, q) for every tiled 2-D weight in the manifest."""
    out = []
    for e in man["experiments"]:
        for p in e["params"]:
            if p["quant"] == "tiled" and len(p["shape"]) == 2:
                m, n = p["shape"]
                out.append((e["id"], m, n, p["p"], p["q"]))
    return out


class TestVmemBudget:
    def test_every_tiled_fc_fits_vmem(self):
        man = manifest()
        layers = tiled_fc_layers(man)
        assert layers, "no tiled FC layers in the manifest?"
        for (eid, m, n, p, q) in layers:
            batch = next(e for e in man["experiments"] if e["id"] == eid)
            batch = batch["io"]["serve_batch"]
            stats = vmem_bytes_tiled(batch, m, n, q, p)
            step_bytes = (stats["x"] + stats["tile"] + stats["alphas"]
                          + stats["w_block_scratch"] + stats["out"])
            assert step_bytes < VMEM_BUDGET, (
                f"{eid} {m}x{n} p={p}: {step_bytes} bytes/step")

    def test_weight_stream_reduction_is_exactly_p(self):
        for (eid, m, n, p, q) in tiled_fc_layers(manifest()):
            stats = vmem_bytes_tiled(8, m, n, q, p)
            ratio = stats["dense_weight_stream_total"] / stats["weight_stream_total"]
            assert ratio == pytest.approx(p), f"{eid}: {ratio} != {p}"

    def test_block_rows_divides_and_bounded(self):
        for (eid, m, n, p, q) in tiled_fc_layers(manifest()):
            bm = _block_rows(m)
            assert m % bm == 0
            assert bm <= 128, f"{eid}: bm={bm} exceeds the MXU-aligned cap"


class TestBlockShapeChoice:
    """The bm sweep recorded in EXPERIMENTS.md §Perf: larger bm amortizes
    grid overhead but grows the in-register expansion scratch linearly;
    bm=128 is the largest MXU-aligned block that keeps every manifest layer
    under budget."""

    def test_bm_sweep_scratch_growth_linear(self):
        m, n, p = 512, 512, 4
        q = m * n // p
        prev = 0
        for bm in [32, 64, 128]:
            s = vmem_bytes_tiled(8, m, n, q, p, bm=bm)["w_block_scratch"]
            assert s == bm * n * 4
            assert s > prev
            prev = s

    def test_tile_resident_cost_independent_of_bm(self):
        m, n, p = 512, 512, 4
        q = m * n // p
        tiles = {vmem_bytes_tiled(8, m, n, q, p, bm=bm)["tile"] for bm in [32, 64, 128]}
        assert len(tiles) == 1
