//! Evaluation metrics: accuracy, MSE, and the IoU family used by the
//! PointNet segmentation benchmarks (Table 3).

/// Classification accuracy from predictions and labels.
pub fn accuracy(preds: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / preds.len() as f64
}

/// Mean squared error.
pub fn mse(pred: &[f32], target: &[f32]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(target)
        .map(|(p, t)| ((p - t) as f64).powi(2))
        .sum::<f64>()
        / pred.len() as f64
}

/// Per-class IoU over a flat prediction/label pair.
///
/// Classes absent from both prediction and ground truth are skipped in the
/// averages (the PointNet convention).
pub fn per_class_iou(preds: &[i32], labels: &[i32], classes: usize) -> Vec<Option<f64>> {
    assert_eq!(preds.len(), labels.len());
    let mut inter = vec![0usize; classes];
    let mut union = vec![0usize; classes];
    for (&p, &l) in preds.iter().zip(labels) {
        let (p, l) = (p as usize, l as usize);
        if p < classes {
            union[p] += 1;
        }
        if l < classes {
            union[l] += 1;
        }
        if p == l && p < classes {
            inter[p] += 1;
            union[p] -= 1; // counted twice above
        }
    }
    (0..classes)
        .map(|c| {
            if union[c] == 0 {
                None
            } else {
                Some(inter[c] as f64 / union[c] as f64)
            }
        })
        .collect()
}

/// Class-average IoU (mIoU): mean over classes present anywhere.
pub fn class_avg_iou(preds: &[i32], labels: &[i32], classes: usize) -> f64 {
    let per = per_class_iou(preds, labels, classes);
    let present: Vec<f64> = per.into_iter().flatten().collect();
    if present.is_empty() {
        0.0
    } else {
        present.iter().sum::<f64>() / present.len() as f64
    }
}

/// Instance-average IoU: per-sample mIoU averaged over samples (ShapeNet's
/// "Instance Avg" column). `points` is the per-sample point count.
pub fn instance_avg_iou(preds: &[i32], labels: &[i32], classes: usize,
                        points: usize) -> f64 {
    assert_eq!(preds.len(), labels.len());
    assert!(points > 0 && preds.len() % points == 0);
    let n = preds.len() / points;
    let mut total = 0.0;
    for s in 0..n {
        total += class_avg_iou(&preds[s * points..(s + 1) * points],
                               &labels[s * points..(s + 1) * points], classes);
    }
    total / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 3.0], &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn iou_perfect_is_one() {
        let p = [0, 1, 2, 0, 1, 2];
        assert_eq!(class_avg_iou(&p, &p, 3), 1.0);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        let p = [0, 0, 0];
        let l = [1, 1, 1];
        assert_eq!(class_avg_iou(&p, &l, 2), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // class 0: pred {0,1}, label {0,2} -> inter 1, union 3
        let p = [0, 0, 1, 1];
        let l = [0, 1, 0, 1];
        let per = per_class_iou(&p, &l, 2);
        assert_eq!(per[0], Some(1.0 / 3.0));
        assert_eq!(per[1], Some(1.0 / 3.0));
    }

    #[test]
    fn absent_class_skipped() {
        let p = [0, 0];
        let l = [0, 0];
        let per = per_class_iou(&p, &l, 3);
        assert_eq!(per[0], Some(1.0));
        assert_eq!(per[1], None);
        assert_eq!(per[2], None);
        assert_eq!(class_avg_iou(&p, &l, 3), 1.0);
    }

    #[test]
    fn instance_avg_differs_from_global() {
        // sample 1 perfect, sample 2 all-wrong: instance avg = 0.5
        let p = [0, 1, 0, 1];
        let l = [0, 1, 1, 0];
        let inst = instance_avg_iou(&p, &l, 2, 2);
        assert!((inst - 0.5).abs() < 1e-9);
    }
}
