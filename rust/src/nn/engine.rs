//! The layer-graph inference engine and its FC-chain wrapper.
//!
//! [`Engine`] executes a layer [`Graph`] — a DAG of [`Node`]s (FC, Conv2d,
//! pooling, flatten, the transformer plumbing `LayerNorm` /
//! `TokenMeanPool` / `Transpose` / `PosEmbedAdd`, plus the
//! `Add`/`MatMulFeature`/`Attention` join nodes of `nn::layers`) in
//! topological order — behind the [`EnginePath`] selector:
//!
//! * `Reference` — the f32 Algorithm 1 path (tile reuse, expand-free), the
//!   crate's oracle.  `forward` runs the exact paper math on f32
//!   activations; `forward_quantized` runs the f32 oracle of the deployment
//!   forward with sign-binarized hidden activations.
//! * `Packed` — the XNOR-popcount fast path: every weight layer after the
//!   first builds packed state at construction (`PackedLayer`), hidden
//!   activations (FC vectors and conv im2col patches alike) are
//!   sign-binarized with an XNOR-Net scale.  Tiled layers default to the
//!   **tile-resident** weight layout ([`PackedLayout::TileResident`]:
//!   `O(q)` bits resident per layer); [`Engine::with_layout`] selects
//!   [`PackedLayout::Expanded`] for A/B measurement.  `forward` and
//!   `forward_quantized` coincide on this path, and `forward_batch` runs
//!   packed FC layers batched (all samples per row pass) with bit-identical
//!   results.
//! * `PackedInt8` — `Packed` with the *first* weight layer's input
//!   quantized to 8-bit integers (the paper's microcontroller input
//!   packing) instead of running layer 0 in f32.
//! * `PackedInt` — the threshold-folded integer pipeline: a hidden FC
//!   whose consumers are all packed FCs never materializes f32
//!   activations — each row's sign test collapses into an integer
//!   popcount threshold precomputed at build time, and the row kernel
//!   writes the next layer's bit-words directly (`nn::packed` module
//!   docs derive the fold).  f32 boundaries (the entry layer, convs,
//!   joins, the output layer) emit with a per-layer *constant* gamma
//!   ([`Engine::calibrate_int_gammas`]) instead of the data-dependent
//!   XNOR-Net scale, so `Packed` remains the exact baseline.
//!
//! **Execution model.**  The engine walks the graph with a per-node value
//! table: every node's output is addressable by node id while any later
//! node still reads it, and is freed as soon as its last consumer has run
//! (consumer counts are precomputed at construction).  Join nodes fetch
//! all their input slots from the table (two for `Add`/`MatMulFeature`,
//! three — Q, K, V — for `Attention`); a residual skip simply keeps its
//! producer's activation alive across the block body.  Joins are weightless
//! and run in f32 on every path, so the branching executor changes nothing
//! about packed-vs-reference parity of the weight layers.  `forward_batch`
//! runs the same walk over per-node activation *batches* (packed FC nodes
//! keep the batched row kernel, packed convs batch positions internally).
//!
//! [`MlpEngine`] wraps an `Engine` built from a `TbnzModel`'s FC chain and
//! preserves the original deployable-runner API of §5.1 (Table 6),
//! including the byte-exact memory/storage accounting used for the Table 6
//! comparison against the BWNN baseline.  The wrapper consumes the model:
//! its layer records live once, behind `Arc`s inside the engine's nodes
//! (no duplicate payload copy — the ROADMAP's `Arc`-sharing item).

use std::sync::Arc;

use super::layers::{FcLayer, Graph, GraphNode, Node, Scratch, Slot};
use super::packed::{activation_gamma, binarize_signs, binarize_signs_into,
                    threads_from_env, EnginePath, IntThresholds, PackedLayer,
                    PackedLayout};
use crate::tbn::bitops::{active_backend, SimdBackend};
use crate::tbn::{LayerRecord, TbnzModel};

/// Hidden-layer nonlinearity (fused into the weight-layer kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Nonlin {
    Relu,
    None,
}

/// Per-node value of the `PackedInt` walk: f32 activations at the
/// boundaries (entry layer, convs, joins, weightless plumbing, the output
/// node), packed sign bits on hidden FC -> FC edges.
enum IntVal {
    F32(Vec<f32>),
    Bits(Vec<u64>),
}

/// Batched twin of [`IntVal`]: `Bits` holds one packed bit-vector per
/// sample side by side, `stride` words apart.
enum IntBatch {
    F32(Vec<Vec<f32>>),
    Bits { words: Vec<u64>, stride: usize },
}

fn int_f32(v: &IntVal) -> &Vec<f32> {
    match v {
        IntVal::F32(h) => h,
        IntVal::Bits(_) => unreachable!("bits flow only into packed FC nodes"),
    }
}

fn int_f32_batch(v: &IntBatch) -> &Vec<Vec<f32>> {
    match v {
        IntBatch::F32(hs) => hs,
        IntBatch::Bits { .. } => unreachable!("bits flow only into packed FC nodes"),
    }
}

/// Layer-graph engine over typed nodes wired into a DAG (see the module
/// docs for the execution model).
#[derive(Debug, Clone)]
pub struct Engine {
    graph: Vec<GraphNode>,
    nonlin: Nonlin,
    path: EnginePath,
    layout: PackedLayout,
    /// Parallel to the graph: packed state for every weight node that runs
    /// binarized (all weight nodes after the first) when `path.is_packed()`.
    packed: Vec<Option<PackedLayer>>,
    /// `PackedInt` only: folded per-row integer threshold rules (plus the
    /// calibrated constant gamma) for every packed node; `None` everywhere
    /// else.
    int_state: Vec<Option<IntThresholds>>,
    /// `PackedInt` only: true for nodes whose output stays packed sign
    /// bits (a hidden FC feeding only packed FCs).  All-false on every
    /// other path, so activation accounting is unchanged there.
    emit_bits: Vec<bool>,
    first_weight: Option<usize>,
    /// Precomputed per-node ReLU decision (overrides + default policy,
    /// gated on `nonlin`).
    relu_after: Vec<bool>,
    /// Consumer count per node (the graph output counts as one consumer):
    /// the executor frees a node's activation when this many readers ran.
    uses: Vec<usize>,
    in_len: usize,
    /// Intra-op kernel threads for the packed/int8 weight kernels (1 =
    /// serial; the Reference path never threads).  Defaults to
    /// `threads_from_env()` (`TBN_THREADS`); [`Engine::with_threads`]
    /// overrides.  Threading is bit-exact at any count — each thread owns
    /// disjoint output slices and runs the unchanged serial per-element
    /// math.
    threads: usize,
    /// XNOR-popcount backend the packed row kernels dispatch to.  Defaults
    /// to [`active_backend`] (the process-wide `TBN_SIMD` / `--simd`
    /// resolution); [`Engine::with_simd`] overrides per engine.  Every
    /// backend is bit-exact against scalar, so this only moves throughput.
    simd: SimdBackend,
}

impl Engine {
    /// [`Engine::with_layout`] under the default (tile-resident) weight
    /// layout.
    pub fn new(nodes: Vec<Node>, nonlin: Nonlin, path: EnginePath)
               -> Result<Engine, String> {
        Engine::with_layout(nodes, nonlin, path, PackedLayout::default())
    }

    /// Sequential-chain engine (node `i` reads node `i - 1`) with an
    /// explicit tiled-weight layout.
    pub fn with_layout(nodes: Vec<Node>, nonlin: Nonlin, path: EnginePath,
                       layout: PackedLayout) -> Result<Engine, String> {
        Engine::with_layout_graph(Graph::sequential(nodes), nonlin, path, layout)
    }

    /// [`Engine::with_layout_graph`] under the default (tile-resident)
    /// weight layout.
    pub fn from_graph(graph: Graph, nonlin: Nonlin, path: EnginePath)
                      -> Result<Engine, String> {
        Engine::with_layout_graph(graph, nonlin, path, PackedLayout::default())
    }

    /// Validate the graph wiring (arity, topological order, per-slot shape
    /// agreement, a consistent source width) and (on the packed paths)
    /// build per-layer packed state — paid once here so the serve path
    /// never packs weights.  `layout` selects how tiled layers keep their
    /// packed weights: tile-resident (`O(q)` bits per layer, the default)
    /// or fully expanded rows (the A/B baseline).
    pub fn with_layout_graph(graph: Graph, nonlin: Nonlin, path: EnginePath,
                             layout: PackedLayout) -> Result<Engine, String> {
        let graph = graph.nodes;
        if graph.is_empty() {
            return Err("engine requires at least one node".to_string());
        }
        let mut in_len: Option<usize> = None;
        for (i, gn) in graph.iter().enumerate() {
            if gn.inputs.len() != gn.node.arity() {
                return Err(format!("{}: {} input slots, expected {}",
                                   gn.node.name(), gn.inputs.len(), gn.node.arity()));
            }
            if let Node::Attention { heads, dim, tokens } = gn.node {
                if heads == 0 || dim == 0 || tokens == 0 || dim % heads != 0 {
                    return Err(format!(
                        "attention: {heads} heads do not divide dim {dim} \
                         ({tokens} tokens)"
                    ));
                }
            }
            for (s, slot) in gn.inputs.iter().enumerate() {
                let want = gn.node.slot_in_len(s);
                match *slot {
                    Slot::Source => match in_len {
                        None => in_len = Some(want),
                        Some(l) if l == want => {}
                        Some(l) => {
                            return Err(format!(
                                "{}: reads the source as {want} elements but the \
                                 graph input is {l}",
                                gn.node.name()
                            ));
                        }
                    },
                    Slot::Node(j) => {
                        if j >= i {
                            return Err(format!(
                                "{}: input slot {s} reads node {j}, which does not \
                                 precede node {i} (graphs must be topologically \
                                 ordered)",
                                gn.node.name()
                            ));
                        }
                        if graph[j].node.out_len() != want {
                            return Err(format!(
                                "{} -> {}: shape chain broken ({} != {})",
                                graph[j].node.name(), gn.node.name(),
                                graph[j].node.out_len(), want
                            ));
                        }
                    }
                }
            }
        }
        let in_len =
            in_len.ok_or_else(|| "graph never reads the engine input".to_string())?;
        let weight_idx: Vec<usize> = graph
            .iter()
            .enumerate()
            .filter(|(_, gn)| gn.node.is_weight())
            .map(|(i, _)| i)
            .collect();
        if weight_idx.is_empty() {
            return Err("engine requires at least one weight layer".to_string());
        }
        let first_weight = weight_idx.first().copied();
        let last_weight = weight_idx.last().copied();
        // ReLU applies after every weight node except the last (logits stay
        // linear); overrides move the activation (residual joins activate,
        // the body conv and T-Net head in front of a join stay linear).
        let relu_after: Vec<bool> = graph
            .iter()
            .enumerate()
            .map(|(i, gn)| {
                let default = gn.node.is_weight() && Some(i) != last_weight;
                gn.relu.unwrap_or(default) && nonlin == Nonlin::Relu
            })
            .collect();
        let mut uses = vec![0usize; graph.len()];
        for gn in &graph {
            for slot in &gn.inputs {
                if let Slot::Node(j) = slot {
                    uses[*j] += 1;
                }
            }
        }
        *uses.last_mut().expect("non-empty graph") += 1; // the caller reads the output
        let mut packed: Vec<Option<PackedLayer>> = vec![None; graph.len()];
        if path.is_packed() {
            // the first weight layer stays f32 (or int8-input); later weight
            // layers run binarized from packed state
            for &i in weight_idx.iter().skip(1) {
                packed[i] = graph[i].node.build_packed(layout)?;
            }
        }
        let mut int_state: Vec<Option<IntThresholds>> = vec![None; graph.len()];
        let mut emit_bits = vec![false; graph.len()];
        if path == EnginePath::PackedInt {
            for (i, p) in packed.iter().enumerate() {
                if let Some(p) = p {
                    int_state[i] = Some(IntThresholds::from_layer(p));
                }
            }
            // a node's output stays packed bits iff it is a binarized FC
            // whose every consumer is a binarized FC (the last node always
            // reports f32 — the caller reads logits)
            let last = graph.len() - 1;
            for i in 0..last {
                emit_bits[i] = int_state[i].is_some()
                    && matches!(graph[i].node, Node::Fc(_))
                    && graph.iter().enumerate().all(|(k, gn)| {
                        !gn.inputs.contains(&Slot::Node(i))
                            || (int_state[k].is_some()
                                && matches!(gn.node, Node::Fc(_)))
                    });
            }
        }
        Ok(Engine {
            graph, nonlin, path, layout, packed, int_state, emit_bits,
            first_weight, relu_after, uses, in_len,
            threads: threads_from_env(),
            simd: active_backend(),
        })
    }

    /// Set the intra-op kernel thread count (clamped to at least 1).
    /// Composes with any outer pool: a serve worker running a 4-thread
    /// engine occupies up to 4 cores per request.  Results are unchanged at
    /// any setting (see the field docs / module determinism contract).
    pub fn with_threads(mut self, threads: usize) -> Engine {
        self.threads = threads.max(1);
        self
    }

    /// Intra-op kernel threads the packed/int8 weight kernels run with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Force the XNOR-popcount backend for this engine's packed kernels.
    /// Backends that need CPU features the host lacks (e.g. `Avx2` off
    /// x86-64) clamp to the detected best, mirroring `TBN_SIMD=auto`.
    /// Bit-exact at any setting — selection only moves throughput.
    pub fn with_simd(mut self, simd: SimdBackend) -> Engine {
        self.simd = if simd.supported() { simd } else { SimdBackend::detect() };
        self
    }

    /// The XNOR-popcount backend the packed row kernels dispatch to.
    pub fn simd(&self) -> SimdBackend {
        self.simd
    }

    /// Build an FC-chain engine from a borrowed TBNZ model (one `Fc` node
    /// per layer; records are copied once into the nodes' `Arc`s).
    pub fn from_tbnz(model: &TbnzModel, nonlin: Nonlin, path: EnginePath)
                     -> Result<Engine, String> {
        Engine::from_records(model.layers.iter().cloned().map(Arc::new).collect(),
                             nonlin, path, PackedLayout::default())
    }

    /// Build an FC-chain engine from shared layer records without copying
    /// any payload — the single-copy entry point `MlpEngine` uses.
    pub fn from_records(layers: Vec<Arc<LayerRecord>>, nonlin: Nonlin,
                        path: EnginePath, layout: PackedLayout)
                        -> Result<Engine, String> {
        if layers.is_empty() {
            return Err("engine requires at least one layer".to_string());
        }
        let nodes = layers
            .into_iter()
            .map(|l| FcLayer::from_record_shared(l).map(Node::Fc))
            .collect::<Result<Vec<_>, String>>()?;
        Engine::with_layout(nodes, nonlin, path, layout)
    }

    pub fn path(&self) -> EnginePath {
        self.path
    }

    /// The weight layout tiled layers were packed with.
    pub fn layout(&self) -> PackedLayout {
        self.layout
    }

    /// Packed per-layer state of node `idx` (`None` on the reference path,
    /// for weightless nodes and for the entry weight layer).
    pub fn packed_layer(&self, idx: usize) -> Option<&PackedLayer> {
        self.packed.get(idx).and_then(Option::as_ref)
    }

    /// Folded integer threshold rules of node `idx` (`PackedInt` path
    /// only; `None` elsewhere and for non-packed nodes).  The exporter
    /// reads these through [`IntThresholds::export_i32`].
    pub fn int_thresholds(&self, idx: usize) -> Option<&IntThresholds> {
        self.int_state.get(idx).and_then(Option::as_ref)
    }

    /// True when node `idx`'s output stays packed sign bits on the active
    /// path (a hidden FC feeding only packed FCs, `PackedInt` only).
    pub fn emits_bits(&self, idx: usize) -> bool {
        self.emit_bits.get(idx).copied().unwrap_or(false)
    }

    /// Calibrate the `PackedInt` path's per-layer constant gammas from
    /// sample inputs: each packed node's gamma becomes the mean XNOR-Net
    /// scale ([`activation_gamma`]) its input activation shows under the
    /// exact `Packed` semantics (the packed state is identical, so the
    /// calibration walk reuses the packed kernels directly).  Gamma only
    /// scales f32 emission — hidden bit decisions are invariant under any
    /// positive constant — so calibration moves boundary layers (convs,
    /// the output layer) closer to `Packed` without touching the folded
    /// thresholds.  No-op on every other path, for empty `xs`, and for
    /// layers whose observed mean is non-finite or not positive.
    pub fn calibrate_int_gammas(mut self, xs: &[Vec<f32>]) -> Engine {
        if self.path != EnginePath::PackedInt || xs.is_empty() {
            return self;
        }
        let n = self.graph.len();
        let mut sums = vec![0.0f64; n];
        let mut counts = vec![0usize; n];
        let mut scratch = Scratch::default();
        for x in xs {
            assert_eq!(x.len(), self.in_len);
            let source = x.clone();
            self.walk(&source, |idx, ins: &[&Vec<f32>]| {
                let gn = &self.graph[idx];
                if gn.node.is_join() {
                    let a = ins[0].as_slice();
                    let slices: [&[f32]; 3] = [
                        a,
                        ins.get(1).map_or(a, |v| v.as_slice()),
                        ins.get(2).map_or(a, |v| v.as_slice()),
                    ];
                    return gn.node.forward_join(&slices[..ins.len()],
                                                self.relu_after[idx], &mut scratch);
                }
                let a = ins[0];
                if self.int_state[idx].is_some() {
                    let g = activation_gamma(a);
                    if g.is_finite() && g > 0.0 {
                        sums[idx] += g as f64;
                        counts[idx] += 1;
                    }
                }
                self.node_forward(idx, a, &mut scratch)
            });
        }
        for i in 0..n {
            if counts[i] == 0 {
                continue;
            }
            if let Some(thr) = self.int_state[i].as_mut() {
                let mean = (sums[i] / counts[i] as f64) as f32;
                if mean.is_finite() && mean > 0.0 {
                    thr.gamma = mean;
                }
            }
        }
        self
    }

    pub fn nonlin(&self) -> Nonlin {
        self.nonlin
    }

    /// The wired graph (topological order; the last node is the output).
    pub fn graph(&self) -> &[GraphNode] {
        &self.graph
    }

    /// The compute node behind graph id `idx`.
    pub fn node(&self, idx: usize) -> &Node {
        &self.graph[idx].node
    }

    /// Input width: the element count every `Slot::Source` reader expects.
    pub fn in_len(&self) -> usize {
        self.in_len
    }

    pub fn out_len(&self) -> usize {
        self.graph.last().map(|gn| gn.node.out_len()).unwrap_or(0)
    }

    /// Run one unary node on the active path.
    fn node_forward(&self, idx: usize, h: &[f32], scratch: &mut Scratch) -> Vec<f32> {
        let relu = self.relu_after[idx];
        let node = &self.graph[idx].node;
        if let Some(p) = &self.packed[idx] {
            return match node {
                Node::Fc(fc) => {
                    fc.forward_packed(p, h, relu, scratch, self.threads, self.simd)
                }
                Node::Conv2d(c) => {
                    c.forward_packed(p, h, relu, scratch, self.threads, self.simd)
                }
                _ => unreachable!("packed state only exists for weight nodes"),
            };
        }
        if self.path == EnginePath::PackedInt8 && Some(idx) == self.first_weight {
            return match node {
                Node::Fc(fc) => fc.forward_int8(h, relu, scratch, self.threads),
                Node::Conv2d(c) => c.forward_int8(h, relu, scratch, self.threads),
                _ => unreachable!("first weight index always names a weight node"),
            };
        }
        node.forward_reference(h, relu, scratch)
    }

    /// Walk the graph with a value table: every node's activation is
    /// addressable by node id while a later node still reads it, and is
    /// freed after its last consumer ran (`uses` counts).  `apply` computes
    /// one node from its fetched input slots (one entry per slot, in slot
    /// order — 1 for chain nodes, 2 for `Add`/`MatMulFeature`, 3 for
    /// `Attention`).  The single walker behind both the per-sample and the
    /// batched forwards, so the liveness/ordering logic exists once.
    fn walk<V, F>(&self, source: &V, mut apply: F) -> V
    where
        F: FnMut(usize, &[&V]) -> V,
    {
        fn get<'a, V>(slot: Slot, source: &'a V, values: &'a [Option<V>]) -> &'a V {
            match slot {
                Slot::Source => source,
                Slot::Node(j) => {
                    values[j].as_ref().expect("freed before last consumer")
                }
            }
        }
        let n = self.graph.len();
        let mut values: Vec<Option<V>> = (0..n).map(|_| None).collect();
        let mut remaining = self.uses.clone();
        for idx in 0..n {
            let gn = &self.graph[idx];
            let out = {
                // node arity is bounded at 3 (Attention), so the fetched
                // slots fit a stack buffer — no per-node heap allocation on
                // the inference hot path (unused tail entries alias slot 0)
                let n_in = gn.inputs.len();
                debug_assert!((1..=3).contains(&n_in));
                let a = get(gn.inputs[0], source, &values);
                let ins: [&V; 3] = [
                    a,
                    gn.inputs.get(1).map_or(a, |&s| get(s, source, &values)),
                    gn.inputs.get(2).map_or(a, |&s| get(s, source, &values)),
                ];
                apply(idx, &ins[..n_in])
            };
            for slot in &gn.inputs {
                if let Slot::Node(j) = slot {
                    remaining[*j] -= 1;
                    if remaining[*j] == 0 {
                        values[*j] = None;
                    }
                }
            }
            values[idx] = Some(out);
        }
        values[n - 1].take().expect("the output node is never freed early")
    }

    /// Per-sample walk.  With `quantized` set (Reference path only), weight
    /// nodes after the entry layer run the f32 sign/gamma oracle of the
    /// packed math.
    fn exec(&self, x: &[f32], scratch: &mut Scratch, quantized: bool) -> Vec<f32> {
        let source = x.to_vec();
        self.walk(&source, |idx, ins: &[&Vec<f32>]| {
            let gn = &self.graph[idx];
            if gn.node.is_join() {
                let a = ins[0].as_slice();
                let slices: [&[f32]; 3] = [
                    a,
                    ins.get(1).map_or(a, |v| v.as_slice()),
                    ins.get(2).map_or(a, |v| v.as_slice()),
                ];
                return gn.node.forward_join(&slices[..ins.len()],
                                            self.relu_after[idx], scratch);
            }
            let a = ins[0];
            if quantized && gn.node.is_weight() && Some(idx) != self.first_weight {
                return match &gn.node {
                    Node::Fc(fc) => fc.forward_quantized_oracle(a, self.relu_after[idx]),
                    Node::Conv2d(c) => {
                        c.forward_quantized_oracle(a, self.relu_after[idx], scratch)
                    }
                    _ => unreachable!("weight nodes are Fc or Conv2d"),
                };
            }
            self.node_forward(idx, a, scratch)
        })
    }

    /// Per-sample walk of the `PackedInt` path.  Hidden FC -> FC edges
    /// carry packed sign bits ([`IntVal::Bits`]); every other edge carries
    /// f32.  A packed FC consumes bits directly (or sign-binarizes an f32
    /// input into `scratch.words`) and either emits the next layer's
    /// bit-words straight from the threshold rules (`emit_bits`) or, at an
    /// f32 boundary, the constant-gamma f32 activation.
    fn exec_int(&self, x: &[f32], scratch: &mut Scratch) -> Vec<f32> {
        let source = IntVal::F32(x.to_vec());
        let out = self.walk(&source, |idx, ins: &[&IntVal]| {
            let gn = &self.graph[idx];
            let relu = self.relu_after[idx];
            if gn.node.is_join() {
                let a = int_f32(ins[0]).as_slice();
                let slices: [&[f32]; 3] = [
                    a,
                    ins.get(1).map_or(a, |v| int_f32(v).as_slice()),
                    ins.get(2).map_or(a, |v| int_f32(v).as_slice()),
                ];
                return IntVal::F32(gn.node.forward_join(&slices[..ins.len()], relu,
                                                        scratch));
            }
            if let (Some(p), Some(thr)) = (&self.packed[idx], &self.int_state[idx]) {
                match &gn.node {
                    Node::Fc(fc) => {
                        let xw: &[u64] = match ins[0] {
                            IntVal::Bits(w) => w.as_slice(),
                            IntVal::F32(h) => {
                                binarize_signs(h, &mut scratch.words);
                                scratch.words.as_slice()
                            }
                        };
                        return if self.emit_bits[idx] {
                            IntVal::Bits(fc.forward_int_bits(p, thr, xw, self.threads,
                                                             self.simd))
                        } else {
                            IntVal::F32(fc.forward_int_f32(p, thr, xw, relu,
                                                           self.threads, self.simd))
                        };
                    }
                    Node::Conv2d(c) => {
                        return IntVal::F32(c.forward_int(p, thr, int_f32(ins[0]),
                                                         relu, scratch, self.threads,
                                                         self.simd));
                    }
                    _ => unreachable!("packed state only exists for weight nodes"),
                }
            }
            IntVal::F32(self.node_forward(idx, int_f32(ins[0]), scratch))
        });
        match out {
            IntVal::F32(y) => y,
            IntVal::Bits(_) => unreachable!("the output node never emits bits"),
        }
    }

    /// Batched twin of [`Engine::exec_int`] (node-major, like
    /// [`Engine::forward_batch`]): hidden FC -> FC edges carry the batch's
    /// packed bit-vectors side by side and the batched bit kernel walks
    /// every row once over all samples.
    fn exec_int_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut scratch = Scratch::default();
        let bsz = xs.len();
        let source = IntBatch::F32(xs.to_vec());
        let out = self.walk(&source, |idx, ins: &[&IntBatch]| {
            let gn = &self.graph[idx];
            let relu = self.relu_after[idx];
            if gn.node.is_join() {
                let hs: Vec<Vec<f32>> = (0..bsz)
                    .map(|b| {
                        let a = int_f32_batch(ins[0])[b].as_slice();
                        let slices: [&[f32]; 3] = [
                            a,
                            ins.get(1).map_or(a, |v| int_f32_batch(v)[b].as_slice()),
                            ins.get(2).map_or(a, |v| int_f32_batch(v)[b].as_slice()),
                        ];
                        gn.node.forward_join(&slices[..ins.len()], relu, &mut scratch)
                    })
                    .collect();
                return IntBatch::F32(hs);
            }
            if let (Some(p), Some(thr)) = (&self.packed[idx], &self.int_state[idx]) {
                match &gn.node {
                    Node::Fc(fc) => {
                        let staged: Vec<u64>;
                        let (xw, stride): (&[u64], usize) = match ins[0] {
                            IntBatch::Bits { words, stride } => {
                                (words.as_slice(), *stride)
                            }
                            IntBatch::F32(hs) => {
                                let s = fc.n.div_ceil(64).max(1);
                                let mut w = vec![0u64; bsz * s];
                                for (b, h) in hs.iter().enumerate() {
                                    binarize_signs_into(
                                        h, &mut w[b * s..(b + 1) * s]);
                                }
                                staged = w;
                                (staged.as_slice(), s)
                            }
                        };
                        return if self.emit_bits[idx] {
                            IntBatch::Bits {
                                words: fc.forward_int_bits_batch(
                                    p, thr, xw, stride, bsz, self.threads,
                                    self.simd),
                                stride: fc.m.div_ceil(64).max(1),
                            }
                        } else {
                            IntBatch::F32(fc.forward_int_f32_batch(
                                p, thr, xw, stride, bsz, relu, &mut scratch,
                                self.threads, self.simd))
                        };
                    }
                    Node::Conv2d(c) => {
                        return IntBatch::F32(
                            int_f32_batch(ins[0])
                                .iter()
                                .map(|h| c.forward_int(p, thr, h, relu, &mut scratch,
                                                       self.threads, self.simd))
                                .collect());
                    }
                    _ => unreachable!("packed state only exists for weight nodes"),
                }
            }
            IntBatch::F32(
                int_f32_batch(ins[0])
                    .iter()
                    .map(|h| self.node_forward(idx, h, &mut scratch))
                    .collect())
        });
        match out {
            IntBatch::F32(ys) => ys,
            IntBatch::Bits { .. } => unreachable!("the output node never emits bits"),
        }
    }

    /// Forward one sample through the active path.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::default();
        self.forward_with_scratch(x, &mut scratch)
    }

    /// Forward with caller-owned scratch buffers (serve workers and batch
    /// loops reuse one allocation across samples).
    pub fn forward_with_scratch(&self, x: &[f32], scratch: &mut Scratch) -> Vec<f32> {
        assert_eq!(x.len(), self.in_len);
        if self.path == EnginePath::PackedInt {
            return self.exec_int(x, scratch);
        }
        self.exec(x, scratch, false)
    }

    /// Forward a whole batch, node-major: all samples pass through a node
    /// before the next node starts, so one layer's packed weight state
    /// stays cache-warm across the batch and the scratch buffers are
    /// allocated once.  The value table holds per-node activation batches;
    /// packed FC nodes take the batched row kernel
    /// (`FcLayer::forward_packed_batch`: every row walked once over all
    /// samples, amortizing the per-run alpha/popcount bookkeeping), packed
    /// conv nodes batch their output positions internally, and join nodes
    /// join per sample.  Results are bit-identical to per-sample
    /// [`Engine::forward`].
    pub fn forward_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        if self.path == EnginePath::PackedInt {
            return self.exec_int_batch(xs);
        }
        let mut scratch = Scratch::default();
        let source = xs.to_vec();
        self.walk(&source, |idx, ins: &[&Vec<Vec<f32>>]| {
            let gn = &self.graph[idx];
            if gn.node.is_join() {
                let bsz = ins[0].len();
                return (0..bsz)
                    .map(|b| {
                        let a = ins[0][b].as_slice();
                        let slices: [&[f32]; 3] = [
                            a,
                            ins.get(1).map_or(a, |v| v[b].as_slice()),
                            ins.get(2).map_or(a, |v| v[b].as_slice()),
                        ];
                        gn.node.forward_join(&slices[..ins.len()],
                                             self.relu_after[idx], &mut scratch)
                    })
                    .collect();
            }
            let a = ins[0];
            if let (Some(p), Node::Fc(fc)) = (&self.packed[idx], &gn.node) {
                return fc.forward_packed_batch(p, a, self.relu_after[idx], &mut scratch,
                                               self.threads, self.simd);
            }
            a.iter().map(|h| self.node_forward(idx, h, &mut scratch)).collect()
        })
    }

    /// The quantized deployment forward regardless of path: on the packed
    /// paths this is the fast path itself; on a `Reference` engine it is
    /// the f32 oracle of the identical math — per-node sign/gamma
    /// binarization over expanded weights, no bit tricks.
    pub fn forward_quantized(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_len);
        if self.path.is_packed() {
            return self.forward(x);
        }
        let mut scratch = Scratch::default();
        self.exec(x, &mut scratch, true)
    }

    fn node_resident_bytes(&self, idx: usize) -> usize {
        match &self.packed[idx] {
            Some(p) => p.resident_bytes(),
            None => self.graph[idx].node.resident_bytes_reference(),
        }
    }

    /// Bytes of node `idx`'s output activation on the active path: packed
    /// bit-words (8 bytes per 64 elements) when the node emits bits on the
    /// `PackedInt` path, f32 otherwise.
    fn out_bytes(&self, idx: usize) -> usize {
        let len = self.graph[idx].node.out_len();
        if self.emit_bits[idx] {
            8 * len.div_ceil(64).max(1)
        } else {
            4 * len
        }
    }

    /// Total bytes of per-node output activations one forward moves on the
    /// active path (the bench's activation-traffic column): on `PackedInt`,
    /// hidden FC -> FC edges count their packed bit-words — 32x below the
    /// f32 buffers every other path materializes for the same edges.
    pub fn activation_bytes(&self) -> usize {
        (0..self.graph.len()).map(|i| self.out_bytes(i)).sum()
    }

    /// Weight bytes resident for the *active* path: sub-bit tiles on the
    /// reference path (and for the f32/int8 entry layer); on the packed
    /// paths, the true per-layout number — `O(q)` tile words + alphas on
    /// the tile-resident layout, expanded packed rows (1 bit per weight
    /// plus alpha-run metadata) on the expanded layout.
    pub fn resident_weight_bytes(&self) -> usize {
        (0..self.graph.len()).map(|i| self.node_resident_bytes(i)).sum()
    }

    /// Serialized-model bits across all weight nodes (the TBNZ storage
    /// accounting, summed from the shared records), plus any f32 parameter
    /// tables carried outside a record (the learned pos-embedding).
    pub fn storage_bits(&self) -> usize {
        self.graph
            .iter()
            .map(|gn| {
                gn.node.record().map(LayerRecord::storage_bits).unwrap_or(0)
                    + gn.node.extra_param_bits()
            })
            .sum()
    }

    /// Max memory at any node, following the executor's own liveness model:
    /// weights resident for that node *on the active path* + all input-slot
    /// and output activation buffers (f32) — the Table 6 "Max Memory Usage"
    /// model — plus, for nodes that run packed, the scratch the batched
    /// packed forward stages (a conv's binarized im2col map and
    /// position-major output copy; `Node::packed_scratch_bytes`), plus the
    /// path-independent f32 staging of an attention node (the
    /// `tokens x tokens` score matrix, `Node::f32_scratch_bytes`; its
    /// context accumulator is the output buffer already counted), plus any
    /// earlier activation the value table still holds for a *later*
    /// consumer (a residual skip stays live across the whole block body and
    /// is charged to every node it spans).  On a linear chain the held term
    /// is always zero, so the original Table 6 numbers are unchanged.
    ///
    /// On the `PackedInt` path, a hidden FC -> FC edge never materializes
    /// f32: the producer's activation is charged at its packed bit-word
    /// size (`out_bytes`), wherever it appears — as an input slot, as the
    /// produced output, or held live for a later consumer.
    pub fn peak_memory_bytes(&self) -> usize {
        let n = self.graph.len();
        // last consumer of each node's activation (the executor frees after
        // this index; an unconsumed/output activation never spans past
        // itself for the purposes of the per-node max below), and of the
        // engine input (live until its last reader — e.g. across a T-Net
        // subgraph whose MatMulFeature reads the source features)
        let mut last_use: Vec<usize> = (0..n).collect();
        let mut src_last_use = 0usize;
        for (i, gn) in self.graph.iter().enumerate() {
            for slot in &gn.inputs {
                match slot {
                    Slot::Node(j) => last_use[*j] = i,
                    Slot::Source => src_last_use = i,
                }
            }
        }
        (0..n)
            .map(|i| {
                let gn = &self.graph[i];
                // packed staging when the node runs packed, plus any
                // path-independent f32 staging (the attention score matrix)
                let scratch = if self.packed[i].is_some() {
                    gn.node.packed_scratch_bytes()
                } else {
                    0
                } + gn.node.f32_scratch_bytes();
                let in_bytes: usize = gn
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(s, slot)| match slot {
                        Slot::Source => 4 * gn.node.slot_in_len(s),
                        Slot::Node(j) => self.out_bytes(*j),
                    })
                    .sum();
                // activations produced earlier, not read here, but still
                // held for a later consumer (e.g. the skip during the body,
                // or the source across a subgraph branching off it)
                let mut held_bytes: usize = (0..i)
                    .filter(|&j| last_use[j] > i && !gn.inputs.contains(&Slot::Node(j)))
                    .map(|j| self.out_bytes(j))
                    .sum();
                if src_last_use > i && !gn.inputs.contains(&Slot::Source) {
                    held_bytes += 4 * self.in_len;
                }
                self.node_resident_bytes(i)
                    + in_bytes + self.out_bytes(i) + held_bytes
                    + scratch
            })
            .max()
            .unwrap_or(0)
    }
}

/// Feed-forward FC-chain engine over a TBNZ model — a thin wrapper around
/// [`Engine`] preserving the original deployable-runner API.
///
/// The constructor consumes the `TbnzModel`: each layer record is moved
/// into an `Arc` shared with the engine's nodes, so the wrapper holds
/// exactly **one** copy of every payload (the ROADMAP's `Arc`-sharing
/// item; the PR 2 wrapper kept two).  Model-level accounting
/// (`storage_bytes`) is served from the shared records.
pub struct MlpEngine {
    engine: Engine,
}

impl MlpEngine {
    /// Reference-path engine (the original constructor).
    pub fn new(model: TbnzModel, nonlin: Nonlin) -> Result<MlpEngine, String> {
        MlpEngine::with_path(model, nonlin, EnginePath::Reference)
    }

    /// Engine with an explicit implementation path and the default
    /// (tile-resident) weight layout. The packed paths pay the packing cost
    /// here, once, so the serve path never packs weights.
    /// 2-D/shape-chain validation happens inside `Engine::from_records`
    /// (`FcLayer::from_record_shared` + the node-chain check).
    pub fn with_path(model: TbnzModel, nonlin: Nonlin, path: EnginePath)
                     -> Result<MlpEngine, String> {
        MlpEngine::with_path_layout(model, nonlin, path, PackedLayout::default())
    }

    /// [`MlpEngine::with_path`] with an explicit tiled-weight layout
    /// (tile-resident vs expanded — the A/B toggle the benches measure).
    pub fn with_path_layout(model: TbnzModel, nonlin: Nonlin, path: EnginePath,
                            layout: PackedLayout) -> Result<MlpEngine, String> {
        let records = model.layers.into_iter().map(Arc::new).collect();
        let engine = Engine::from_records(records, nonlin, path, layout)?;
        Ok(MlpEngine { engine })
    }

    /// Set the intra-op kernel thread count ([`Engine::with_threads`]).
    pub fn with_threads(mut self, threads: usize) -> MlpEngine {
        self.engine = self.engine.with_threads(threads);
        self
    }

    /// Force the XNOR-popcount backend ([`Engine::with_simd`]).
    pub fn with_simd(mut self, simd: SimdBackend) -> MlpEngine {
        self.engine = self.engine.with_simd(simd);
        self
    }

    /// Calibrate the `PackedInt` path's constant gammas from sample inputs
    /// ([`Engine::calibrate_int_gammas`]; no-op on every other path).
    pub fn calibrate_int_gammas(mut self, xs: &[Vec<f32>]) -> MlpEngine {
        self.engine = self.engine.calibrate_int_gammas(xs);
        self
    }

    /// The underlying layer-graph engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn path(&self) -> EnginePath {
        self.engine.path()
    }

    pub fn nonlin(&self) -> Nonlin {
        self.engine.nonlin()
    }

    pub fn in_dim(&self) -> usize {
        self.engine.in_len()
    }

    pub fn out_dim(&self) -> usize {
        self.engine.out_len()
    }

    /// Forward one sample through the active path. The final layer is always
    /// linear (logits). On the packed paths this is the XNOR fast path
    /// (hidden activations sign-binarized); on `Reference` it is the exact
    /// f32 Algorithm 1 math.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim());
        self.engine.forward(x)
    }

    /// The quantized deployment forward regardless of path: on a packed
    /// engine this is the fast path itself; on a `Reference` engine it is
    /// the f32 oracle of the identical math (`nn::packed` module docs).
    /// `rust/tests/packed_parity.rs` pins the two against each other.
    pub fn forward_quantized(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim());
        self.engine.forward_quantized(x)
    }

    /// Forward a whole batch, layer-major (each layer's packed rows stay
    /// cache-warm across the batch; scratch buffers are reused).
    pub fn forward_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.engine.forward_batch(xs)
    }

    /// Forward a batch (rows of `xs`), returning argmax labels.
    pub fn classify_batch(&self, xs: &[Vec<f32>]) -> Vec<usize> {
        self.forward_batch(xs)
            .iter()
            .map(|y| {
                y.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Max memory at any layer: weights resident for that layer *on the
    /// active path* + input and output activation buffers (f32) — the
    /// Table 6 "Max Memory Usage" model (the paper's peak lands on the
    /// first FC layer).
    pub fn peak_memory_bytes(&self) -> usize {
        self.engine.peak_memory_bytes()
    }

    /// Total activation bytes one forward moves on the active path
    /// ([`Engine::activation_bytes`]; `PackedInt` counts hidden FC -> FC
    /// edges at their packed bit-word size).
    pub fn activation_bytes(&self) -> usize {
        self.engine.activation_bytes()
    }

    /// Total storage for the serialized model (Table 6 "Storage"), summed
    /// from the shared layer records.
    pub fn storage_bytes(&self) -> usize {
        self.engine.storage_bits().div_ceil(8)
    }

    /// Weight bytes resident for the *active* path: sub-bit tiles on the
    /// reference path; on the packed paths the per-layout number —
    /// `O(q)` tile words on the tile-resident layout, expanded packed rows
    /// on the expanded layout.
    pub fn resident_weight_bytes(&self) -> usize {
        self.engine.resident_weight_bytes()
    }

    /// Measure frames/second over `iters` runs of one sample (Table 6 FPS).
    pub fn measure_fps(&self, x: &[f32], iters: usize) -> f64 {
        let start = std::time::Instant::now();
        let mut sink = 0.0f32;
        for _ in 0..iters {
            let y = self.forward(x);
            sink += y[0];
        }
        let dt = start.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        iters as f64 / dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layers::PoolKind;
    use crate::nn::packed::forward_quantized_reference;
    use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
    use crate::tensor::BitVec;
    use crate::util::Rng;

    /// The paper's deployment model: in 256 -> hidden 128 -> 10.
    fn tbn_mlp_model(p: usize) -> TbnzModel {
        let mut r = Rng::new(42);
        let w1: Vec<f32> = (0..128 * 256).map(|_| r.gauss_f32()).collect();
        let tile = tile_from_weights(&w1, p);
        let alphas = alphas_from(&w1, p, AlphaMode::PerTile);
        let w2: Vec<f32> = (0..10 * 128).map(|_| r.gauss_f32()).collect();
        // untiled layers ship 1-bit (the exporter's binarize fallback)
        TbnzModel {
            layers: vec![
                LayerRecord { name: "fc0".into(), shape: vec![128, 256],
                              payload: WeightPayload::Tiled { p, tile, alphas } },
                LayerRecord { name: "head".into(), shape: vec![10, 128],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w2),
                                  alpha: w2.iter().map(|x| x.abs()).sum::<f32>()
                                      / w2.len() as f32 } },
            ],
        }
    }

    fn tbn_mlp(p: usize) -> MlpEngine {
        MlpEngine::new(tbn_mlp_model(p), Nonlin::Relu).unwrap()
    }

    fn bwnn_mlp() -> MlpEngine {
        let mut r = Rng::new(42);
        let w1: Vec<f32> = (0..128 * 256).map(|_| r.gauss_f32()).collect();
        let w2: Vec<f32> = (0..10 * 128).map(|_| r.gauss_f32()).collect();
        let model = TbnzModel {
            layers: vec![
                LayerRecord { name: "fc0".into(), shape: vec![128, 256],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w1),
                                  alpha: w1.iter().map(|x| x.abs()).sum::<f32>()
                                      / w1.len() as f32 } },
                LayerRecord { name: "head".into(), shape: vec![10, 128],
                              payload: WeightPayload::Bwnn {
                                  bits: BitVec::from_signs(&w2),
                                  alpha: w2.iter().map(|x| x.abs()).sum::<f32>()
                                      / w2.len() as f32 } },
            ],
        };
        MlpEngine::new(model, Nonlin::Relu).unwrap()
    }

    fn tiled_record(name: &str, m: usize, n: usize, p: usize, mode: AlphaMode,
                    rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, mode),
            },
        }
    }

    fn bwnn_record(name: &str, m: usize, n: usize, rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Bwnn { bits: BitVec::from_signs(&w), alpha: 0.4 },
        }
    }

    #[test]
    fn forward_shapes() {
        let e = tbn_mlp(4);
        let x = vec![0.1f32; 256];
        assert_eq!(e.forward(&x).len(), 10);
        assert_eq!(e.in_dim(), 256);
        assert_eq!(e.out_dim(), 10);
        assert_eq!(e.engine().in_len(), 256);
        assert_eq!(e.engine().out_len(), 10);
    }

    #[test]
    fn chain_validation() {
        let mut broken = tbn_mlp_model(4);
        broken.layers[1].shape = vec![10, 64];
        assert!(MlpEngine::new(broken, Nonlin::Relu).is_err());
    }

    /// Table 6's claim: TBN_4 memory and storage are ~4x below BWNN, speed
    /// is in the same ballpark.
    #[test]
    fn table6_memory_and_storage_ordering() {
        let tbn = tbn_mlp(4);
        let bwnn = bwnn_mlp();
        let mem_ratio = bwnn.peak_memory_bytes() as f64 / tbn.peak_memory_bytes() as f64;
        let sto_ratio = bwnn.storage_bytes() as f64 / tbn.storage_bytes() as f64;
        // memory includes fixed activation buffers, so ratio < 4 (paper: 2.4x)
        assert!(mem_ratio > 1.5 && mem_ratio < 4.0, "mem ratio {mem_ratio}");
        // storage dominated by the tiled layer: close to 4x (paper: 3.8x)
        assert!(sto_ratio > 2.5 && sto_ratio < 4.3, "storage ratio {sto_ratio}");
    }

    #[test]
    fn classify_batch_is_deterministic() {
        let e = tbn_mlp(8);
        let mut r = Rng::new(1);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| r.normal_vec(256, 1.0)).collect();
        assert_eq!(e.classify_batch(&xs), e.classify_batch(&xs));
    }

    #[test]
    fn fps_positive() {
        let e = tbn_mlp(4);
        let x = vec![0.5f32; 256];
        assert!(e.measure_fps(&x, 20) > 0.0);
    }

    #[test]
    fn forward_batch_matches_forward_on_reference_path() {
        let e = tbn_mlp(4);
        let mut r = Rng::new(5);
        let xs: Vec<Vec<f32>> = (0..4).map(|_| r.normal_vec(256, 1.0)).collect();
        let batch = e.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&e.forward(x), y);
        }
    }

    #[test]
    fn packed_path_builds_and_matches_quantized_oracle() {
        let model = tbn_mlp_model(4);
        let reference = MlpEngine::new(model.clone(), Nonlin::Relu).unwrap();
        let packed = MlpEngine::with_path(model, Nonlin::Relu, EnginePath::Packed).unwrap();
        assert_eq!(packed.path(), EnginePath::Packed);
        assert_eq!(reference.path(), EnginePath::Reference);

        let mut r = Rng::new(77);
        let xs: Vec<Vec<f32>> = (0..6).map(|_| r.normal_vec(256, 1.0)).collect();
        assert_eq!(packed.forward(&xs[0]).len(), 10);
        // classify_batch must be the argmax of the per-sample packed forward
        let argmax: Vec<usize> = xs
            .iter()
            .map(|x| {
                let y = packed.forward(x);
                y.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect();
        assert_eq!(packed.classify_batch(&xs), argmax);
        for (k, x) in xs.iter().enumerate() {
            let a = packed.forward(x);
            let b = reference.forward_quantized(x);
            for (i, (u, v)) in a.iter().zip(&b).enumerate() {
                assert!((u - v).abs() < 1e-3 * v.abs().max(1.0),
                        "sample {k} logit {i}: {u} vs {v}");
            }
            // on the packed path, forward and forward_quantized coincide
            assert_eq!(a, packed.forward_quantized(x));
        }
    }

    #[test]
    fn packed_residency_stays_sub_fp() {
        let model = tbn_mlp_model(4);
        let fp_bytes = 4 * model.total_params();
        let tbn = MlpEngine::new(model.clone(), Nonlin::Relu).unwrap();
        let packed =
            MlpEngine::with_path(model, Nonlin::Relu, EnginePath::Packed).unwrap();
        // packed state costs at most ~1 bit/weight (plus metadata): far
        // below f32
        assert!(packed.resident_weight_bytes() < fp_bytes / 8,
                "packed {} vs fp {}", packed.resident_weight_bytes(), fp_bytes);
        // reference residency reports the sub-bit tiles
        assert!(tbn.resident_weight_bytes() < packed.resident_weight_bytes() * 8);
    }

    /// The tile-resident and expanded layouts are bit-exact against each
    /// other, and the tile-resident engine keeps `O(q)` weight bytes for
    /// its tiled layers.
    #[test]
    fn layouts_agree_and_tile_residency_is_o_q() {
        let mut rng = Rng::new(40);
        // fc0 runs f32 (entry layer); fc1/head run packed — fc1 is tiled,
        // so the layouts actually differ in state
        let model = TbnzModel {
            layers: vec![
                bwnn_record("fc0", 48, 70, &mut rng),
                tiled_record("fc1", 40, 48, 4, AlphaMode::PerTile, &mut rng),
                tiled_record("head", 10, 40, 2, AlphaMode::Single, &mut rng),
            ],
        };
        let tile = MlpEngine::with_path_layout(
            model.clone(), Nonlin::Relu, EnginePath::Packed,
            PackedLayout::TileResident).unwrap();
        let expanded = MlpEngine::with_path_layout(
            model.clone(), Nonlin::Relu, EnginePath::Packed,
            PackedLayout::Expanded).unwrap();
        assert_eq!(tile.engine().layout(), PackedLayout::TileResident);
        assert_eq!(expanded.engine().layout(), PackedLayout::Expanded);
        for s in 0..4 {
            let mut r = Rng::new(700 + s);
            let x = r.normal_vec(70, 1.0);
            assert_eq!(tile.forward(&x), expanded.forward(&x), "sample {s}");
        }
        // residency: fc1 keeps q = 40*48/4 = 480 bits + 4 alphas; the
        // expanded layout keeps 40 x 48 bits + run metadata
        assert!(tile.resident_weight_bytes() < expanded.resident_weight_bytes(),
                "tile {} vs expanded {}", tile.resident_weight_bytes(),
                expanded.resident_weight_bytes());
        let fc1_tile = tile.engine().packed_layer(1).unwrap();
        let q = 480usize;
        assert_eq!(fc1_tile.resident_bytes(), 8 * q.div_ceil(64) + 4 * 4);
        // storage accounting is unchanged by layout and matches the model's
        assert_eq!(tile.storage_bytes(), model.storage_bytes());
        assert_eq!(expanded.storage_bytes(), model.storage_bytes());
    }

    // -- ported from the old `PackedModel` suite: the same guarantees now
    //    hold at the Engine level ------------------------------------------

    #[test]
    fn engine_packed_matches_reference_oracle() {
        let mut rng = Rng::new(33);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 48, 70, 4, AlphaMode::PerTile, &mut rng),
                bwnn_record("fc1", 33, 48, &mut rng),
                tiled_record("head", 10, 33, 2, AlphaMode::Single, &mut rng),
            ],
        };
        let packed = Engine::from_tbnz(&model, Nonlin::Relu, EnginePath::Packed).unwrap();
        for s in 0..4 {
            let mut r = Rng::new(100 + s);
            let x = r.normal_vec(70, 1.0);
            let a = packed.forward(&x);
            let b = forward_quantized_reference(&model, &x, true);
            assert_eq!(a.len(), b.len());
            for i in 0..a.len() {
                assert!((a[i] - b[i]).abs() < 1e-3 * b[i].abs().max(1.0),
                        "sample {s} out {i}: {} vs {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn engine_forward_batch_equals_per_sample() {
        let mut rng = Rng::new(34);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 32, 65, 4, AlphaMode::PerTile, &mut rng),
                bwnn_record("head", 6, 32, &mut rng),
            ],
        };
        let packed = Engine::from_tbnz(&model, Nonlin::Relu, EnginePath::Packed).unwrap();
        let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(65, 1.0)).collect();
        let batch = packed.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&packed.forward(x), y);
        }
    }

    #[test]
    fn single_layer_model_is_exactly_reference() {
        let mut rng = Rng::new(35);
        let model = TbnzModel {
            layers: vec![tiled_record("only", 9, 20, 4, AlphaMode::PerTile, &mut rng)],
        };
        let packed = Engine::from_tbnz(&model, Nonlin::Relu, EnginePath::Packed).unwrap();
        let x = rng.normal_vec(20, 1.0);
        // one layer: no binarization anywhere, bit-exact against the oracle
        assert_eq!(packed.forward(&x), forward_quantized_reference(&model, &x, true));
    }

    #[test]
    fn engine_resident_bytes_scale_with_rows() {
        let mut rng = Rng::new(36);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 16, 64, 4, AlphaMode::Single, &mut rng),
                bwnn_record("fc1", 64, 16, &mut rng),
            ],
        };
        let packed = Engine::from_tbnz(&model, Nonlin::Relu, EnginePath::Packed).unwrap();
        // fc1 packed rows: 64 rows x 1 word = 512 bytes of words at least
        assert!(packed.resident_weight_bytes() >= 512);
    }

    #[test]
    fn rejects_empty_models() {
        let empty = TbnzModel { layers: vec![] };
        for path in [EnginePath::Reference, EnginePath::Packed,
                     EnginePath::PackedInt8, EnginePath::PackedInt] {
            assert!(Engine::from_tbnz(&empty, Nonlin::Relu, path).is_err());
        }
        assert!(Engine::new(vec![], Nonlin::Relu, EnginePath::Reference).is_err());
        // a weightless chain is not an engine either
        let pool = Node::Flatten { len: 8 };
        assert!(Engine::new(vec![pool], Nonlin::Relu, EnginePath::Reference).is_err());
    }

    // -- DAG executor ------------------------------------------------------

    /// Residual FC graph over shared helpers:
    /// `x -> fc0 -> fc1 -(Add with fc0's output)-> head`, ReLU moved after
    /// the join (fc1 forced linear), the standard residual placement.
    fn residual_fc_graph(m: usize, n: usize, classes: usize, seed: u64)
                         -> (Graph, FcLayer, FcLayer, FcLayer) {
        let mut rng = Rng::new(seed);
        let fc0 = FcLayer::from_record(tiled_record("fc0", m, n, 4, AlphaMode::PerTile,
                                                    &mut rng))
            .unwrap();
        let fc1 = FcLayer::from_record(bwnn_record("fc1", m, m, &mut rng)).unwrap();
        let head = FcLayer::from_record(tiled_record("head", classes, m, 2,
                                                     AlphaMode::Single, &mut rng))
            .unwrap();
        let mut g = Graph::new();
        let trunk = g.push(Node::Fc(fc0.clone()), vec![Slot::Source]);
        let body = g.push_with_relu(Node::Fc(fc1.clone()), vec![trunk], Some(false));
        let join = g.push_with_relu(Node::Add { len: m }, vec![body, trunk], Some(true));
        g.push(Node::Fc(head.clone()), vec![join]);
        (g, fc0, fc1, head)
    }

    #[test]
    fn dag_executor_matches_handrolled_residual_math() {
        let (m, n, classes) = (24usize, 40usize, 10usize);
        let (g, fc0, fc1, head) = residual_fc_graph(m, n, classes, 50);
        let engine = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        assert_eq!(engine.in_len(), n);
        assert_eq!(engine.out_len(), classes);
        let mut rng = Rng::new(51);
        for _ in 0..4 {
            let x = rng.normal_vec(n, 1.0);
            // hand-rolled: fc0 (ReLU) -> fc1 (linear) -> add + ReLU -> head
            let t = fc0.forward_reference(&x, true);
            let b = fc1.forward_reference(&t, false);
            let joined: Vec<f32> =
                b.iter().zip(&t).map(|(u, v)| (u + v).max(0.0)).collect();
            let want = head.forward_reference(&joined, false);
            assert_eq!(engine.forward(&x), want, "DAG walk must be bit-exact");
        }
    }

    #[test]
    fn dag_relu_overrides_gate_on_engine_nonlin() {
        let (g, fc0, fc1, head) = residual_fc_graph(16, 30, 6, 52);
        // Nonlin::None: every override is gated off — all nodes linear
        let engine = Engine::from_graph(g, Nonlin::None, EnginePath::Reference).unwrap();
        let mut rng = Rng::new(53);
        let x = rng.normal_vec(30, 1.0);
        let t = fc0.forward_reference(&x, false);
        let b = fc1.forward_reference(&t, false);
        let joined: Vec<f32> = b.iter().zip(&t).map(|(u, v)| u + v).collect();
        assert_eq!(engine.forward(&x), head.forward_reference(&joined, false));
    }

    #[test]
    fn dag_batch_equals_per_sample_on_packed_paths() {
        let (g, ..) = residual_fc_graph(24, 40, 10, 54);
        for path in [EnginePath::Reference, EnginePath::Packed,
                     EnginePath::PackedInt8, EnginePath::PackedInt] {
            let engine = Engine::from_graph(g.clone(), Nonlin::Relu, path).unwrap();
            let mut rng = Rng::new(55);
            let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(40, 1.0)).collect();
            let batch = engine.forward_batch(&xs);
            for (x, y) in xs.iter().zip(&batch) {
                assert_eq!(&engine.forward(x), y, "{path:?}");
            }
        }
    }

    #[test]
    fn dag_peak_memory_charges_both_join_operands() {
        let (g, ..) = residual_fc_graph(64, 16, 4, 56);
        let engine = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        // the Add node holds two 64-wide operands + a 64-wide output
        assert!(engine.peak_memory_bytes() >= 4 * (64 + 64 + 64));
    }

    /// The liveness model charges a residual skip to the body nodes it
    /// spans: a two-FC body peaks exactly `4 * m` bytes above the identical
    /// chain without the skip (the held trunk activation).
    #[test]
    fn dag_peak_memory_charges_held_skip_across_body() {
        let mut rng = Rng::new(59);
        let (m, n) = (256usize, 8usize);
        let fc0 = FcLayer::from_record(bwnn_record("fc0", m, n, &mut rng)).unwrap();
        let b1 = FcLayer::from_record(bwnn_record("b1", m, m, &mut rng)).unwrap();
        let b2 = FcLayer::from_record(bwnn_record("b2", m, m, &mut rng)).unwrap();
        let head = FcLayer::from_record(bwnn_record("head", 4, m, &mut rng)).unwrap();
        let mut g = Graph::new();
        let trunk = g.push(Node::Fc(fc0.clone()), vec![Slot::Source]);
        let x1 = g.push(Node::Fc(b1.clone()), vec![trunk]);
        let x2 = g.push_with_relu(Node::Fc(b2.clone()), vec![x1], Some(false));
        let j = g.push_with_relu(Node::Add { len: m }, vec![x2, trunk], Some(true));
        g.push(Node::Fc(head.clone()), vec![j]);
        let residual = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        let chain = Engine::new(
            vec![Node::Fc(fc0), Node::Fc(b1), Node::Fc(b2), Node::Fc(head)],
            Nonlin::Relu, EnginePath::Reference)
            .unwrap();
        // both peak on the m x m body FCs; the residual version additionally
        // holds the m-wide trunk there (b2 does not read it, the join does)
        assert_eq!(residual.peak_memory_bytes(),
                   chain.peak_memory_bytes() + 4 * m);
    }

    #[test]
    fn dag_rejects_malformed_wiring() {
        let mut rng = Rng::new(57);
        let fc = FcLayer::from_record(bwnn_record("fc", 8, 8, &mut rng)).unwrap();
        // wrong arity: a join with one input
        let mut g = Graph::new();
        let a = g.push(Node::Fc(fc.clone()), vec![Slot::Source]);
        g.push(Node::Add { len: 8 }, vec![a]);
        assert!(Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference)
            .unwrap_err()
            .contains("input slots"));
        // forward reference: topological order violated
        let mut g = Graph::new();
        g.push(Node::Add { len: 8 }, vec![Slot::Node(1), Slot::Source]);
        g.push(Node::Fc(fc.clone()), vec![Slot::Source]);
        assert!(Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference)
            .unwrap_err()
            .contains("topologically"));
        // join shape mismatch: Add reads an 8-wide and a 6-wide producer
        // (both branches read the source consistently at 8)
        let fc6 = FcLayer::from_record(bwnn_record("fc6", 6, 8, &mut rng)).unwrap();
        let mut g = Graph::new();
        let a = g.push(Node::Fc(fc.clone()), vec![Slot::Source]);
        let b = g.push(Node::Fc(fc6), vec![Slot::Source]);
        g.push(Node::Add { len: 8 }, vec![a, b]);
        let err = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap_err();
        assert!(err.contains("shape chain broken"), "{err}");
        // inconsistent source width: 8-wide fc and a 6-wide flatten both
        // read the engine input
        let mut g = Graph::new();
        let a = g.push(Node::Fc(fc), vec![Slot::Source]);
        let b = g.push(Node::Flatten { len: 6 }, vec![Slot::Source]);
        let _ = (a, b);
        let err = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap_err();
        assert!(err.contains("graph input"), "{err}");
    }

    /// A transform branch (MatMulFeature) through the DAG equals the
    /// hand-rolled math: per-position matmul of the branch's k*k output.
    #[test]
    fn dag_matmul_feature_matches_handrolled_math() {
        let (k, positions) = (4usize, 10usize);
        let mut rng = Rng::new(58);
        // branch: pool the (k, positions) features then predict k*k
        let tfc = FcLayer::from_record(bwnn_record("tnet.fc", k * k, k, &mut rng))
            .unwrap();
        let head = FcLayer::from_record(
            tiled_record("head", 5, k * positions, 4, AlphaMode::PerTile, &mut rng))
            .unwrap();
        let mut g = Graph::new();
        let pooled = g.push(Node::GlobalPool { kind: PoolKind::Avg, c: k, positions },
                            vec![Slot::Source]);
        let transform = g.push_with_relu(Node::Fc(tfc.clone()), vec![pooled], Some(false));
        let applied = g.push_with_relu(Node::MatMulFeature { k, positions },
                                       vec![Slot::Source, transform], Some(false));
        g.push(Node::Fc(head.clone()), vec![applied]);
        let engine = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        assert_eq!(engine.in_len(), k * positions);
        let x = rng.normal_vec(k * positions, 1.0);
        let pooled_v: Vec<f32> = (0..k)
            .map(|c| x[c * positions..(c + 1) * positions].iter().sum::<f32>()
                / positions as f32)
            .collect();
        let t = tfc.forward_reference(&pooled_v, false);
        let mut applied_v = vec![0.0f32; k * positions];
        for co in 0..k {
            for ci in 0..k {
                for p in 0..positions {
                    applied_v[co * positions + p] +=
                        t[co * k + ci] * x[ci * positions + p];
                }
            }
        }
        let want = head.forward_reference(&applied_v, false);
        assert_eq!(engine.forward(&x), want);
    }

    /// A hand-built attention graph (Q/K/V FCs off one trunk, Attention
    /// join, head) through the DAG executor equals the hand-rolled
    /// per-node math, on the engine's own kernels.
    #[test]
    fn dag_attention_graph_matches_handrolled_walk() {
        let (dim, tokens, heads) = (8usize, 5usize, 2usize);
        let n = dim * tokens;
        let mut rng = Rng::new(60);
        let wq = FcLayer::from_record(bwnn_record("wq", n, n, &mut rng)).unwrap();
        let wk = FcLayer::from_record(bwnn_record("wk", n, n, &mut rng)).unwrap();
        let wv = FcLayer::from_record(bwnn_record("wv", n, n, &mut rng)).unwrap();
        let head = FcLayer::from_record(bwnn_record("head", 4, n, &mut rng)).unwrap();
        let g = engine_graph(&wq, &wk, &wv, &head, heads, dim, tokens);
        let engine = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        assert_eq!(engine.in_len(), n);
        assert_eq!(engine.out_len(), 4);
        let x = rng.normal_vec(n, 1.0);
        let qv = wq.forward_reference(&x, false);
        let kv = wk.forward_reference(&x, false);
        let vv = wv.forward_reference(&x, false);
        let node = Node::Attention { heads, dim, tokens };
        let mut s = Scratch::default();
        let ctx = node.forward_join(&[&qv, &kv, &vv], false, &mut s);
        let want = head.forward_reference(&ctx, false);
        assert_eq!(engine.forward(&x), want, "attention DAG walk must be bit-exact");
        // batch == single on every path
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n, 1.0)).collect();
        for path in [EnginePath::Reference, EnginePath::Packed] {
            let e = Engine::from_graph(engine_graph(&wq, &wk, &wv, &head, heads, dim,
                                                    tokens),
                                       Nonlin::Relu, path)
                .unwrap();
            let batch = e.forward_batch(&xs);
            for (x, y) in xs.iter().zip(&batch) {
                assert_eq!(&e.forward(x), y, "{path:?}");
            }
        }
    }

    fn engine_graph(wq: &FcLayer, wk: &FcLayer, wv: &FcLayer, head: &FcLayer,
                    heads: usize, dim: usize, tokens: usize) -> Graph {
        let mut g = Graph::new();
        let q = g.push_with_relu(Node::Fc(wq.clone()), vec![Slot::Source], Some(false));
        let k = g.push_with_relu(Node::Fc(wk.clone()), vec![Slot::Source], Some(false));
        let v = g.push_with_relu(Node::Fc(wv.clone()), vec![Slot::Source], Some(false));
        let attn = g.push_with_relu(Node::Attention { heads, dim, tokens },
                                    vec![q, k, v], Some(false));
        g.push(Node::Fc(head.clone()), vec![attn]);
        g
    }

    #[test]
    fn dag_rejects_bad_attention_configs() {
        let mut rng = Rng::new(61);
        let n = 12usize; // dim 4 x tokens 3
        let fc = FcLayer::from_record(bwnn_record("p", n, n, &mut rng)).unwrap();
        // heads not dividing dim
        let mut g = Graph::new();
        let q = g.push(Node::Fc(fc.clone()), vec![Slot::Source]);
        g.push(Node::Attention { heads: 3, dim: 4, tokens: 3 }, vec![q, q, q]);
        let err = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap_err();
        assert!(err.contains("heads do not divide"), "{err}");
        // wrong arity: attention with two inputs
        let mut g = Graph::new();
        let q = g.push(Node::Fc(fc), vec![Slot::Source]);
        g.push(Node::Attention { heads: 2, dim: 4, tokens: 3 }, vec![q, q]);
        let err = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap_err();
        assert!(err.contains("input slots"), "{err}");
    }

    /// The attention score matrix is charged to the peak: the same graph
    /// with more tokens peaks higher by exactly the scratch delta when the
    /// attention node is the peak.
    #[test]
    fn dag_peak_memory_charges_attention_scratch() {
        let mut rng = Rng::new(62);
        let (dim, tokens, heads) = (4usize, 32usize, 2usize);
        let n = dim * tokens;
        let wq = FcLayer::from_record(bwnn_record("wq", n, n, &mut rng)).unwrap();
        let wk = FcLayer::from_record(bwnn_record("wk", n, n, &mut rng)).unwrap();
        let wv = FcLayer::from_record(bwnn_record("wv", n, n, &mut rng)).unwrap();
        let head = FcLayer::from_record(bwnn_record("head", 4, n, &mut rng)).unwrap();
        let g = engine_graph(&wq, &wk, &wv, &head, heads, dim, tokens);
        let engine = Engine::from_graph(g, Nonlin::Relu, EnginePath::Reference).unwrap();
        // at the attention node: 3 inputs + output (4 * n each) + scores
        let attn_bytes = 4 * (3 * n + n) + 4 * tokens * tokens;
        assert!(engine.peak_memory_bytes() >= attn_bytes,
                "peak {} must cover the attention term {attn_bytes}",
                engine.peak_memory_bytes());
    }

    #[test]
    fn int8_path_close_to_packed_on_mlp() {
        let model = tbn_mlp_model(4);
        let packed =
            MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Packed).unwrap();
        let int8 =
            MlpEngine::with_path(model, Nonlin::Relu, EnginePath::PackedInt8).unwrap();
        assert_eq!(int8.path(), EnginePath::PackedInt8);
        // residency matches the packed path (same rows; layer 0 stays a tile)
        assert_eq!(int8.resident_weight_bytes(), packed.resident_weight_bytes());
        let mut r = Rng::new(88);
        let mut agree = 0usize;
        let n = 32;
        for _ in 0..n {
            let x = r.normal_vec(256, 1.0);
            let a = packed.classify_batch(&[x.clone()])[0];
            let b = int8.classify_batch(&[x])[0];
            if a == b {
                agree += 1;
            }
        }
        // int8 input quantization perturbs layer 0 by <1% — argmax stays
        // stable for the large majority of samples
        assert!(agree * 10 >= n * 7, "argmax agreement {agree}/{n}");
    }

    /// On a pure FC chain the `PackedInt` path classifies *identically* to
    /// `Packed`: hidden bit decisions are invariant under the (positive)
    /// data-dependent gamma, and the output layer's constant gamma scales
    /// all logits together, so the argmax is unchanged.
    #[test]
    fn int_path_argmax_matches_packed_on_fc_chain() {
        // three layers so the hidden fc1 -> head edge actually carries bits
        let mut rng = Rng::new(90);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 96, 256, 4, AlphaMode::PerTile, &mut rng),
                tiled_record("fc1", 64, 96, 4, AlphaMode::PerTile, &mut rng),
                bwnn_record("head", 10, 64, &mut rng),
            ],
        };
        let packed =
            MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Packed)
                .unwrap();
        let int =
            MlpEngine::with_path(model, Nonlin::Relu, EnginePath::PackedInt).unwrap();
        assert_eq!(int.path(), EnginePath::PackedInt);
        // same packed rows resident on both paths
        assert_eq!(int.resident_weight_bytes(), packed.resident_weight_bytes());
        // fc1 feeds only the packed head, so its output stays bit-words;
        // the head (output node) and the f32 entry layer do not
        assert!(int.engine().emits_bits(1));
        assert!(!int.engine().emits_bits(0));
        assert!(!int.engine().emits_bits(2));
        // fc1's 64 f32s collapse to one u64 word in the traffic model
        assert_eq!(int.activation_bytes() + 4 * 64,
                   packed.activation_bytes() + 8);
        assert!(int.peak_memory_bytes() <= packed.peak_memory_bytes());
        let mut r = Rng::new(91);
        let xs: Vec<Vec<f32>> = (0..16).map(|_| r.normal_vec(256, 1.0)).collect();
        let int = int.calibrate_int_gammas(&xs);
        // calibration replaces the default gamma on the packed nodes
        let thr = int.engine().int_thresholds(2).unwrap();
        assert!(thr.gamma.is_finite() && thr.gamma > 0.0);
        assert_eq!(int.classify_batch(&xs), packed.classify_batch(&xs));
        for x in &xs {
            assert_eq!(int.forward(x).len(), 10);
        }
    }
}
