"""Pallas kernels for tile construction (training forward, Eqs. 1-3 & 9).

Two small kernels used on the training path's forward pass:

* ``tile_construct`` — view the flattened weights as ``(p, q)``, sum over the
  ``p`` replicas and threshold (Eqs. 1-3).  Grid walks ``q`` in blocks; each
  step reduces a ``(p, bq)`` strip, so VMEM holds ``p*bq`` weights at a time
  rather than the whole layer.
* ``tile_alphas`` — per-tile scaling factors (Eq. 9): mean absolute value of
  each length-``q`` segment.  Grid walks the ``p`` tiles in blocks.

Both are lowered with ``interpret=True`` (CPU PJRT); semantics are pinned by
``ref.tile_from_weights`` / ``ref.alphas_from`` and the hypothesis suite in
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _divisor_le(n: int, target: int) -> int:
    best = 1
    for d in range(1, min(n, target) + 1):
        if n % d == 0:
            best = d
    return best


def _construct_kernel(w_ref, t_ref):
    s = w_ref[...].sum(axis=0)                       # (bq,)
    t_ref[...] = jnp.where(s > 0, 1.0, -1.0).astype(t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def tile_construct(w: jnp.ndarray, p: int, interpret: bool = True) -> jnp.ndarray:
    """Eqs. 1-3 as a Pallas kernel: flattened ``w`` -> (q,) binary tile."""
    n = w.size
    assert n % p == 0
    q = n // p
    bq = _divisor_le(q, 512)
    wm = w.reshape(p, q)
    return pl.pallas_call(
        _construct_kernel,
        grid=(q // bq,),
        in_specs=[pl.BlockSpec((p, bq), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bq,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((q,), w.dtype),
        interpret=interpret,
    )(wm)


def _alpha_kernel(a_ref, o_ref):
    o_ref[...] = jnp.abs(a_ref[...]).mean(axis=1)


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def tile_alphas(a: jnp.ndarray, p: int, interpret: bool = True) -> jnp.ndarray:
    """Eq. 9 as a Pallas kernel: flattened ``a`` -> (p,) per-tile alphas."""
    n = a.size
    assert n % p == 0
    q = n // p
    bp = _divisor_le(p, 64)
    am = a.reshape(p, q)
    return pl.pallas_call(
        _alpha_kernel,
        grid=(p // bp,),
        in_specs=[pl.BlockSpec((bp, q), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), a.dtype),
        interpret=interpret,
    )(am)
