//! Table 3: PointNet classification / part segmentation (IoU) on the
//! synthetic point-cloud substrates, plus the analytic columns on the
//! full-size PointNet specs.

use tiledbits::arch;
use tiledbits::bench_util::{bench_dirs, bench_steps, header};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_or_load;
use tiledbits::nn::{lower_arch_spec, Engine, EnginePath, LowerOptions, Node, Nonlin,
                    PackedLayout};
use tiledbits::runtime::Runtime;
use tiledbits::tbn::{compress, AlphaMode, TilingPolicy};
use tiledbits::train::TrainOptions;

fn main() {
    header("Table 3: PointNet (cls + part seg + semantic seg)");

    println!("\n-- analytic columns (full-size PointNet) --");
    for (name, lam) in [("pointnet_cls", 64_000), ("pointnet_part_seg", 64_000),
                        ("pointnet_sem_seg", 64_000)] {
        let a = arch::arch_by_name(name).unwrap();
        println!("{name} ({:.2}M params, {:.0}% FC):",
                 a.total_params() as f64 / 1e6, 100.0 * a.fc_fraction());
        for p in [4usize, 8] {
            let (bw, mbit, sav) = compress::table_row(&a, &TilingPolicy::tbn(p, lam));
            println!("  TBN_{p}: bit-width {bw:.3}  {mbit:.2} M-bit  ({sav:.1}x)");
        }
    }

    // native T-Net lowering: pointnet_cls runs as a branching layer graph
    // (two MatMulFeature joins) on the tile-resident packed engine
    println!("\n-- native T-Net lowering (pointnet_cls, 1024 points) --");
    let spec = arch::pointnet_cls();
    let opts = LowerOptions {
        input: (3, 1024, 1),
        p: 4,
        alpha_mode: AlphaMode::PerTile,
        seed: 3,
    };
    match lower_arch_spec(&spec, &opts) {
        Ok(graph) => {
            let tnets: Vec<(usize, usize)> = graph
                .nodes
                .iter()
                .filter_map(|gn| match gn.node {
                    Node::MatMulFeature { k, positions } => Some((k, positions)),
                    _ => None,
                })
                .collect();
            let n_nodes = graph.len();
            let tile = Engine::with_layout_graph(graph, Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::TileResident)
                .unwrap();
            println!("{n_nodes} nodes, feature transforms {tnets:?}, \
                      {} tile-resident weight bytes",
                     tile.resident_weight_bytes());
        }
        Err(e) => println!("not lowerable: {e}"),
    }

    let (artifacts, runs) = bench_dirs();
    let steps = bench_steps(60);
    let Ok(manifest) = Manifest::load(&artifacts) else {
        println!("\n(artifacts not built; skipping measured half)");
        return;
    };
    let rt = Runtime::new(&artifacts).expect("PJRT");
    let opts = TrainOptions { steps: Some(steps), eval_every: 0, log_every: 10_000, seed: None };

    println!("\n-- measured: classification (SynthModelNet, {steps} steps) --");
    for id in ["pointnet_cls_fp", "pointnet_cls_bwnn", "pointnet_cls_tbn4",
               "pointnet_cls_tbn8"] {
        match run_or_load(&rt, &manifest, id, &opts, &runs) {
            Ok(rec) => println!("{id:24} acc {:5.1}%  bit-width {:.3}",
                                100.0 * rec.metric, rec.bit_width),
            Err(e) => println!("{id:24} FAILED: {e:#}"),
        }
    }
    println!("\n-- measured: part segmentation (SynthShapeNet) --");
    for id in ["pointnet_seg_fp", "pointnet_seg_bwnn", "pointnet_seg_tbn4",
               "pointnet_seg_tbn8"] {
        match run_or_load(&rt, &manifest, id, &opts, &runs) {
            Ok(rec) => println!(
                "{id:24} acc {:5.1}%  inst-IoU {:.3}  class-IoU {:.3}  bit-width {:.3}",
                100.0 * rec.metric,
                rec.instance_iou.unwrap_or(0.0),
                rec.class_iou.unwrap_or(0.0),
                rec.bit_width),
            Err(e) => println!("{id:24} FAILED: {e:#}"),
        }
    }
    println!("\nshape check: TBN_4 on par with BWNN, both below FP; IoU well above chance.");
}
