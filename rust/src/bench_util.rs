//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + repeated timing with mean/std/min reporting, and a
//! small table printer shared by the `rust/benches/*` binaries (all of which
//! are `harness = false`).

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s.max(1e-12)
    }

    /// Items per second when each iteration processes `items` samples
    /// (batched-throughput reporting for the packed-path benches).
    pub fn throughput(&self, items: usize) -> f64 {
        items as f64 * self.per_sec()
    }

    pub fn report(&self) -> String {
        format!("{:40} {:>12} {:>12} {:>12}  ({} iters)",
                self.name,
                fmt_time(self.mean_s),
                fmt_time(self.std_s),
                fmt_time(self.min_s),
                self.iters)
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let (mean, std) = crate::util::mean_std(&times);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: std,
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Print the standard bench header.
pub fn header(title: &str) {
    println!("\n#### {title}");
    println!("{:40} {:>12} {:>12} {:>12}", "benchmark", "mean", "std", "min");
    println!("{}", "-".repeat(84));
}

/// Step-count override for training benches: `TBN_BENCH_STEPS` (default 60)
/// keeps `cargo bench` fast; set higher (or run `tbn run-all`) for the full
/// paper-scale runs recorded in EXPERIMENTS.md.
pub fn bench_steps(default: usize) -> usize {
    std::env::var("TBN_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Lower a spec natively and print one stat line: graph shape (attention /
/// join counts), the expanded-vs-tile-resident packed residency delta, and
/// the time of one packed tile-resident forward — the per-arch treatment
/// the transformer benches (`table4_vit` / `table5_timeseries`) share,
/// mirroring what `table1`/`table3` print for the CNN/PointNet graphs.
pub fn print_native_lowering_stats(spec: &crate::arch::ArchSpec) {
    use crate::nn::{lower_arch_spec, Engine, EnginePath, LowerOptions, Node, Nonlin,
                    PackedLayout};
    use crate::tbn::AlphaMode;
    let Some(input) = spec.native_input() else {
        println!("{:18} (no native input shape)", spec.name);
        return;
    };
    let opts = LowerOptions { input, p: 4, alpha_mode: AlphaMode::PerTile, seed: 3 };
    match lower_arch_spec(spec, &opts) {
        Ok(graph) => {
            let attn = graph
                .nodes
                .iter()
                .filter(|gn| matches!(gn.node, Node::Attention { .. }))
                .count();
            let joins = graph.nodes.iter().filter(|gn| gn.node.is_join()).count();
            let n_nodes = graph.len();
            let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                                     EnginePath::Packed,
                                                     PackedLayout::Expanded)
                .expect("lowered graph builds");
            let tile = Engine::with_layout_graph(graph, Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::TileResident)
                .expect("lowered graph builds");
            let (eb, tb) = (expanded.resident_weight_bytes(),
                            tile.resident_weight_bytes());
            let mut rng = crate::util::Rng::new(4);
            let x = rng.normal_vec(tile.in_len(), 1.0);
            let t0 = Instant::now();
            let y = tile.forward(&x);
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(y);
            println!("{:18} {n_nodes:3} nodes  {attn:2} attention  {joins:2} joins  \
                      packed resident: {eb:>11} B expanded / {tb:>9} B tile \
                      ({:.1}x)  fwd {}",
                     spec.name, eb as f64 / tb.max(1) as f64, fmt_time(dt));
        }
        Err(e) => println!("{:18} not lowerable: {e}", spec.name),
    }
}

/// Shared bench entry boilerplate: artifacts + runs dirs. Defaults resolve
/// upwards (benches run with `rust/` as cwd; assets live at the repo root).
pub fn bench_dirs() -> (String, String) {
    let artifacts = std::env::var("TBN_ARTIFACTS")
        .ok()
        .or_else(|| crate::util::locate_upwards("artifacts"))
        .unwrap_or_else(|| "artifacts".into());
    let runs = std::env::var("TBN_RUNS")
        .ok()
        .or_else(|| crate::util::locate_upwards("runs"))
        .unwrap_or_else(|| "runs".into());
    (artifacts, runs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0;
        let r = bench("noop", 2, 10, || {
            count += 1;
        });
        assert_eq!(count, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(0.002).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
    }

    #[test]
    fn bench_steps_default() {
        std::env::remove_var("TBN_BENCH_STEPS");
        assert_eq!(bench_steps(60), 60);
    }

    #[test]
    fn throughput_scales_per_sec() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            std_s: 0.0,
            min_s: 0.5,
        };
        assert!((r.per_sec() - 2.0).abs() < 1e-9);
        assert!((r.throughput(32) - 64.0).abs() < 1e-6);
    }
}
