"""Pure-jnp reference oracle for the Tiled Bit Network core ops.

This module is the single source of truth for the *semantics* of the paper's
Equations 1-9 (Gorbett et al., CIKM 2024).  Every other implementation — the
Pallas kernels in this package, the training layers in ``compile.layers``, and
the Rust host implementations in ``rust/src/tbn/`` — is tested against these
functions.

Canonical layout convention (used everywhere in this repo):

* A weight tensor ``W`` with ``N`` elements is flattened **row-major** (C
  order) to a vector ``w`` of length ``N = p * q``.
* Eq. 1-2: ``w`` is viewed as a ``p x q`` matrix (each row is one *tile slot*)
  and summed over the ``p`` axis, giving ``s`` of length ``q``.
* Eq. 3: the tile is ``t = sign(s)`` with the paper's convention
  ``t_i = 1 if s_i > 0 else -1`` (zero maps to -1).
* Eq. 4-5: the binary weight is ``b[k] = t[k mod q]``, reshaped back to the
  original tensor shape.  Consequently the alpha of flat element ``k`` is
  ``alpha[k div q]`` in the per-tile setting.

This matches Algorithm 1's pointer arithmetic (the tile index cycles through
the flattened weights, the alpha index increments every ``q`` elements).
"""

from __future__ import annotations

import jax.numpy as jnp


def tile_from_weights(w: jnp.ndarray, p: int) -> jnp.ndarray:
    """Eqs. 1-3: aggregate the flattened weights into a q-length binary tile.

    Args:
      w: weight tensor of any shape whose element count is divisible by ``p``.
      p: compression factor (number of tile replicas in the layer).

    Returns:
      ``t`` of shape ``(q,)`` with values in {-1, +1} (same dtype as ``w``).
    """
    n = w.size
    assert n % p == 0, f"layer size {n} not divisible by p={p}"
    q = n // p
    s = w.reshape(p, q).sum(axis=0)
    return jnp.where(s > 0, 1.0, -1.0).astype(w.dtype)


def alphas_from(a: jnp.ndarray, p: int, per_tile: bool) -> jnp.ndarray:
    """Eqs. 7 & 9: compute the scaling factor(s) for one layer.

    Args:
      a: the tensor used for scaling (either ``W`` itself or the independent
        parameter ``A``), same shape as the layer weight.
      p: compression factor.
      per_tile: if True returns one alpha per tile (shape ``(p,)``, Eq. 9);
        otherwise a single layer-wide alpha (shape ``(1,)``, Eq. 7).

    Returns:
      alphas of shape ``(p,)`` or ``(1,)`` (non-negative).
    """
    n = a.size
    if per_tile:
        q = n // p
        return jnp.abs(a.reshape(p, q)).mean(axis=1)
    return jnp.abs(a).reshape(1, -1).mean(axis=1)


def expand_tile(t: jnp.ndarray, alphas: jnp.ndarray, shape: tuple) -> jnp.ndarray:
    """Eqs. 4-5 plus scaling: reconstruct the full weight tensor B-hat.

    ``b[k] = t[k mod q] * alphas[k // q]`` reshaped to ``shape`` (with a
    single alpha the same scalar covers all tiles).
    """
    n = 1
    for d in shape:
        n *= d
    q = t.shape[0]
    p = n // q
    assert p * q == n, f"tile length {q} does not divide layer size {n}"
    b = jnp.tile(t, p)
    if alphas.shape[0] == 1:
        scale = jnp.broadcast_to(alphas, (n,))
    else:
        assert alphas.shape[0] == p
        scale = jnp.repeat(alphas, q)
    return (b * scale).reshape(shape)


def tiled_dense_ref(
    x: jnp.ndarray, t: jnp.ndarray, alphas: jnp.ndarray, out_features: int, in_features: int
) -> jnp.ndarray:
    """Reference tiled fully-connected forward: ``y = x @ B-hat^T``.

    The weight matrix is ``(out_features, in_features)`` reconstructed from
    the tile; ``x`` is ``(batch, in_features)``.
    """
    bhat = expand_tile(t, alphas, (out_features, in_features))
    return x @ bhat.T


def binarize_bwnn(w: jnp.ndarray) -> tuple:
    """XNOR-Net-style binary-weight baseline: sign(w) with mean-|w| scaling.

    Returns (binary weights in {-1,+1}, scalar alpha of shape (1,)).
    """
    alpha = jnp.abs(w).reshape(1, -1).mean(axis=1)
    b = jnp.where(w > 0, 1.0, -1.0).astype(w.dtype)
    return b, alpha
