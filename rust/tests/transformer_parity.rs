//! Native transformer execution parity (artifact-free): the encoder
//! lowering (pre-LN attention / MLP sub-blocks, mixer token-mixing,
//! pos-embed, mean-pool heads) and the Attention/LayerNorm/Transpose graph
//! nodes, pinned the same three ways as `tests/graph_parity.rs`:
//!
//! * **Reference-graph oracle** — an independent test-side evaluator walks
//!   the lowered graph (per-node `forward_reference`/`forward_join` calls
//!   over an explicit value table) and must agree **bit-exactly** with
//!   `Engine::forward` on the Reference path;
//! * **Layout bit-exactness** — on the Packed path, the tile-resident
//!   layout must agree **bit-exactly** with the expanded layout (single
//!   and batched), across ragged dims (token counts and model dims that
//!   are not multiples of 64 everywhere in the minis);
//! * **Quantized-oracle closeness** — the packed forward tracks the f32
//!   sign/gamma oracle at the argmax level (sign tie-breaks can flip
//!   individual hidden units through deep stacks, as in the other parity
//!   suites).
//!
//! Plus the lowering failure modes (head count not dividing dim,
//! mismatched token counts, missing/mis-ordered Q/K/V/O projections,
//! malformed MLP / token-mixing pairs, `Unsupported` constructs naming
//! Swin/MobileViT), the attention-scratch term of `peak_memory_bytes`,
//! and — in the release-mode `--ignored` tier — full-size
//! `vit_small_imagenet` lowering and full-size ViT/TST/Mixer forwards.
//!
//! Packed engines built "at the default layout" go through
//! `PackedLayout::from_env()`, so the CI matrix re-runs this suite (and
//! the `vit_micro`/`tst_micro`/`mixer_micro` minis) under
//! `TBN_LAYOUT=expanded`.

mod common;

use common::{argmax, count_nodes, handrolled_reference_forward};
use tiledbits::arch::{self, ArchSpec, AttnPart, BlockRole, LayerSpec};
use tiledbits::nn::{
    lower_arch_spec, Engine, EnginePath, Graph, LowerOptions, Node, Nonlin,
    PackedLayout, Scratch, Slot,
};
use tiledbits::tbn::AlphaMode;
use tiledbits::util::Rng;

fn opts(input: (usize, usize, usize), p: usize, seed: u64) -> LowerOptions {
    LowerOptions { input, p, alpha_mode: AlphaMode::PerTile, seed }
}

fn native_opts(spec: &ArchSpec, p: usize, seed: u64) -> LowerOptions {
    opts(spec.native_input().expect("native input shape"), p, seed)
}

/// The shared acceptance sweep body: Reference bit-exact vs the
/// independent evaluator, tile-resident bit-exact vs expanded (single and
/// batched), packed == forward_quantized, argmax tracking of the f32
/// oracle.  Returns `(agree, total)` argmax counts.
fn run_parity(graph: &Graph, samples: usize, seed: u64) -> (usize, usize) {
    let reference =
        Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                         EnginePath::Packed,
                                         PackedLayout::TileResident)
        .unwrap();
    let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                             EnginePath::Packed,
                                             PackedLayout::Expanded)
        .unwrap();
    assert!(tile.resident_weight_bytes() <= expanded.resident_weight_bytes(),
            "tile residency above expanded");
    let mut rng = Rng::new(seed);
    let mut agree = 0usize;
    for s in 0..samples {
        let x = rng.normal_vec(reference.in_len(), 1.0);
        assert_eq!(reference.forward(&x),
                   handrolled_reference_forward(graph, &x, true),
                   "sample {s}: Reference DAG walk not bit-exact");
        let yt = tile.forward(&x);
        assert_eq!(yt, expanded.forward(&x), "sample {s}: layouts disagree");
        assert_eq!(yt, tile.forward_quantized(&x),
                   "sample {s}: packed forward_quantized must coincide");
        if argmax(&reference.forward_quantized(&x)) == argmax(&yt) {
            agree += 1;
        }
    }
    let xs: Vec<Vec<f32>> =
        (0..4).map(|_| rng.normal_vec(tile.in_len(), 1.0)).collect();
    let batch = tile.forward_batch(&xs);
    assert_eq!(batch, expanded.forward_batch(&xs), "batched layouts disagree");
    for (x, y) in xs.iter().zip(&batch) {
        assert_eq!(&tile.forward(x), y, "batch != single");
    }
    (agree, samples)
}

// ---------------------------------------------------------------------------
// The transformer minis, end to end on every path
// ---------------------------------------------------------------------------

#[test]
fn vit_micro_lowers_to_expected_graph_and_runs() {
    let spec = arch::vit_micro();
    let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 900)).unwrap();
    // patch_embed, pos_embed_add, 2 x (LN q k v attn wo add + LN fc1 fc2
    // add), final LN, token mean pool, head
    assert_eq!(graph.len(), 27);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), 2);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::LayerNorm { .. })), 5);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::PosEmbedAdd { .. })), 1);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::TokenMeanPool { .. })), 1);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 4);
    // ragged everywhere: dim 20, tokens 10 -> joins are 200 wide (% 64 != 0)
    for gn in &graph.nodes {
        if let Node::Add { len } = gn.node {
            assert_eq!(len % 64, 8, "join width 200 must be ragged");
        }
    }
    match &graph.nodes[6].node {
        Node::Attention { heads, dim, tokens } => {
            assert_eq!((*heads, *dim, *tokens), (4, 20, 10));
        }
        other => panic!("node 6 should be the first attention, got {}", other.name()),
    }
    // wiring: attention reads the three projections; the residual add reads
    // the O projection and the block entry (the pos-embed output)
    assert_eq!(graph.nodes[6].inputs,
               vec![Slot::Node(3), Slot::Node(4), Slot::Node(5)]);
    assert_eq!(graph.nodes[6].relu, Some(false));
    assert_eq!(graph.nodes[8].inputs, vec![Slot::Node(7), Slot::Node(1)]);
    assert_eq!(graph.nodes[8].relu, Some(false), "transformer joins stay linear");
    assert_eq!(graph.nodes[3].relu, Some(false), "projections stay linear");
    assert_eq!(graph.nodes[10].relu, Some(true), "the MLP hidden layer activates");

    let (agree, total) = run_parity(&graph, 8, 901);
    assert!(agree * 10 >= total * 6, "argmax agreement {agree}/{total}");

    // the attention score scratch is visible in the peak-memory model
    let engine =
        Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let (dim, tokens) = (20usize, 10usize);
    let attn_term = 4 * (3 * dim * tokens + dim * tokens) + 4 * tokens * tokens;
    assert!(engine.peak_memory_bytes() >= attn_term,
            "peak {} must cover the attention node's inputs+output+scores {attn_term}",
            engine.peak_memory_bytes());

    // int8 entry path runs and batches consistently
    let int8 =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::PackedInt8).unwrap();
    let mut rng = Rng::new(902);
    let x = rng.normal_vec(int8.in_len(), 1.0);
    assert!(int8.forward(&x).iter().all(|v| v.is_finite()));
    assert_eq!(int8.forward_batch(&[x.clone()])[0], int8.forward(&x));
}

#[test]
fn tst_micro_lowers_and_runs_end_to_end() {
    let spec = arch::tst_micro();
    let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 910)).unwrap();
    // in_proj, 2 x 11 encoder nodes, final LN + pool + head
    assert_eq!(graph.len(), 26);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), 2);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::PosEmbedAdd { .. })), 0);
    match graph
        .nodes
        .iter()
        .find(|gn| matches!(gn.node, Node::Attention { .. }))
        .map(|gn| &gn.node)
    {
        Some(&Node::Attention { heads, dim, tokens }) => {
            assert_eq!((heads, dim, tokens), (3, 12, 9));
        }
        _ => panic!("no attention node"),
    }
    let (agree, total) = run_parity(&graph, 8, 911);
    assert!(agree * 10 >= total * 6, "argmax agreement {agree}/{total}");
}

#[test]
fn mixer_micro_token_mixing_runs_transposed() {
    let spec = arch::mixer_micro();
    let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 920)).unwrap();
    // patch_embed, 2 x (LN T fc1 fc2 T add + LN fc1 fc2 add), LN pool head
    assert_eq!(graph.len(), 24);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Transpose { .. })), 4);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), 0);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 4);
    // the token-mixing FCs run on the transposed (tokens, dim) view: 1x1
    // convs whose channel count is the token count
    let tok_fcs = graph
        .nodes
        .iter()
        .filter_map(|gn| match &gn.node {
            Node::Conv2d(c) if c.record.name.contains(".tok.") => Some((c.ci, c.co)),
            _ => None,
        })
        .collect::<Vec<_>>();
    assert_eq!(tok_fcs, vec![(9, 12), (12, 9), (9, 12), (12, 9)]);
    let (agree, total) = run_parity(&graph, 8, 921);
    assert!(agree * 10 >= total * 6, "argmax agreement {agree}/{total}");
}

/// The minis at the env-selected default layout — the CI `TBN_LAYOUT`
/// matrix hook: both packed layouts serve batch == single bit-identically.
#[test]
fn minis_run_at_env_default_layout() {
    for spec in [arch::vit_micro(), arch::tst_micro(), arch::mixer_micro()] {
        let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 990))
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let engine = Engine::with_layout_graph(graph, Nonlin::Relu,
                                               EnginePath::Packed,
                                               PackedLayout::from_env())
            .unwrap();
        let mut rng = Rng::new(991);
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| rng.normal_vec(engine.in_len(), 1.0)).collect();
        let batch = engine.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&engine.forward(x), y, "{}: batch != single", spec.name);
        }
    }
}

/// Full-size TST (weather): light enough for the default tier on the
/// packed paths — tile-resident vs expanded stay bit-exact at full depth.
#[test]
fn tst_weather_full_size_packed_layouts_bit_exact() {
    let spec = arch::tst_weather();
    let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 930)).unwrap();
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), 2);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 4);
    let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                         EnginePath::Packed,
                                         PackedLayout::TileResident)
        .unwrap();
    let expanded = Engine::with_layout_graph(graph, Nonlin::Relu, EnginePath::Packed,
                                             PackedLayout::Expanded)
        .unwrap();
    assert_eq!(tile.in_len(), 7 * 96);
    assert_eq!(tile.out_len(), 7);
    assert!(tile.resident_weight_bytes() < expanded.resident_weight_bytes());
    let mut rng = Rng::new(931);
    for s in 0..2 {
        let x = rng.normal_vec(tile.in_len(), 1.0);
        assert_eq!(tile.forward(&x), expanded.forward(&x), "sample {s}");
    }
}

// ---------------------------------------------------------------------------
// Full-size paper specs: graph construction in the default tier
// ---------------------------------------------------------------------------

#[test]
fn full_size_transformers_lower_natively() {
    // (spec, expected attention nodes, expected residual adds)
    let cases = [
        (arch::vit_cifar(), 6usize, 12usize),
        (arch::tst_electricity(), 2, 4),
        (arch::mlpmixer_cifar(), 0, 12),
    ];
    for (spec, attn, adds) in cases {
        let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 940))
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), attn,
                   "{}", spec.name);
        assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), adds,
                   "{}", spec.name);
        assert_eq!(count_nodes(&graph, |n| matches!(n, Node::TokenMeanPool { .. })), 1,
                   "{}", spec.name);
        let engine = Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference)
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let (c, h, w) = spec.native_input().unwrap();
        assert_eq!(engine.in_len(), c * h * w, "{}", spec.name);
    }
    // vit_cifar carries the learned pos-embed
    let graph = lower_arch_spec(&arch::vit_cifar(),
                                &native_opts(&arch::vit_cifar(), 4, 941))
        .unwrap();
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::PosEmbedAdd { .. })), 1);
}

#[test]
fn unsupported_attention_constructs_are_named() {
    let swin = arch::swin_t();
    let err = lower_arch_spec(&swin, &native_opts(&swin, 4, 950)).unwrap_err();
    assert!(err.contains("shifted-window"), "unexpected error: {err}");
    let mv = arch::mobilevit();
    let err = lower_arch_spec(&mv, &native_opts(&mv, 4, 951)).unwrap_err();
    assert!(err.contains("unfold/fold"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------------
// Node-level numerics: max-subtracted softmax and LayerNorm epsilon
// ---------------------------------------------------------------------------

/// Attention's softmax is max-subtracted: scaling Q to produce ~1e30
/// logits must saturate toward the argmax key, never overflow to NaN/inf.
#[test]
fn attention_softmax_is_overflow_stable_and_saturates() {
    let (heads, dim, tokens) = (1usize, 4usize, 3usize);
    let node = Node::Attention { heads, dim, tokens };
    let mut scratch = Scratch::default();
    // token 1's key aligns with every query -> its value dominates
    let q = vec![1.0f32; dim * tokens];
    let mut k = vec![-1.0f32; dim * tokens];
    for d in 0..dim {
        k[d * tokens + 1] = 1.0;
    }
    let v: Vec<f32> = (0..dim * tokens).map(|i| i as f32).collect();
    let big_q: Vec<f32> = q.iter().map(|&x| x * 1.0e15).collect();
    let big_k: Vec<f32> = k.iter().map(|&x| x * 1.0e15).collect();
    let y = node.forward_join(&[&big_q, &big_k, &v], false, &mut scratch);
    assert!(y.iter().all(|o| o.is_finite()), "softmax must not overflow");
    // saturated: every query token attends ~entirely to token 1
    for d in 0..dim {
        for t in 0..tokens {
            let want = v[d * tokens + 1];
            let got = y[d * tokens + t];
            assert!((got - want).abs() < 1e-3, "d={d} t={t}: {got} vs {want}");
        }
    }
}

/// The LayerNorm node normalizes each token across channels; all-constant
/// tokens hit the epsilon floor (exact zeros, no NaN from a 0 variance).
#[test]
fn layer_norm_node_normalizes_tokens_and_eps_guards_zero_variance() {
    let (c, positions) = (3usize, 2usize);
    let node = Node::LayerNorm { c, positions, eps: tiledbits::nn::LN_EPS };
    let mut scratch = Scratch::default();
    // token 0: (1, 2, 3); token 1: constant 5s
    let x = [1.0f32, 5.0, 2.0, 5.0, 3.0, 5.0];
    let y = node.forward_reference(&x, false, &mut scratch);
    assert!(y.iter().all(|v| v.is_finite()));
    // token 0 is zero-mean with unit variance (up to eps)
    let t0: Vec<f32> = (0..c).map(|d| y[d * positions]).collect();
    let mean: f32 = t0.iter().sum::<f32>() / c as f32;
    let var: f32 = t0.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
    assert!(mean.abs() < 1e-6 && (var - 1.0).abs() < 1e-3, "mean {mean} var {var}");
    // token 1: zero variance -> exact zeros via the epsilon guard
    for d in 0..c {
        assert_eq!(y[d * positions + 1], 0.0);
    }
}

// ---------------------------------------------------------------------------
// Lowering failure modes
// ---------------------------------------------------------------------------

fn attn_layer(name: &str, dim: usize, tokens: usize, heads: usize, part: AttnPart)
              -> LayerSpec {
    LayerSpec::fc_tok(name, dim, dim, tokens)
        .in_block(BlockRole::AttnProj { id: "b0.attn".into(), heads, part })
}

#[test]
fn head_count_not_dividing_dim_is_rejected() {
    let (dim, tokens, heads) = (10usize, 6usize, 3usize);
    let spec = ArchSpec {
        name: "bad_heads".into(),
        layers: vec![
            attn_layer("wq", dim, tokens, heads, AttnPart::Q),
            attn_layer("wk", dim, tokens, heads, AttnPart::K),
            attn_layer("wv", dim, tokens, heads, AttnPart::V),
            attn_layer("wo", dim, tokens, heads, AttnPart::O),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 960)).unwrap_err();
    assert!(err.contains("heads do not divide"), "unexpected error: {err}");
}

#[test]
fn mismatched_token_counts_are_rejected() {
    let (dim, tokens) = (8usize, 10usize);
    let spec = ArchSpec {
        name: "bad_tokens".into(),
        layers: vec![
            attn_layer("wq", dim, tokens, 2, AttnPart::Q),
            // wk claims 12 tokens while the block's features carry 10
            attn_layer("wk", dim, 12, 2, AttnPart::K),
            attn_layer("wv", dim, tokens, 2, AttnPart::V),
            attn_layer("wo", dim, tokens, 2, AttnPart::O),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 961)).unwrap_err();
    assert!(err.contains("mismatched token counts"), "unexpected error: {err}");
}

#[test]
fn missing_or_misordered_projections_are_rejected() {
    let (dim, tokens) = (8usize, 10usize);
    // missing the O projection
    let spec = ArchSpec {
        name: "no_o".into(),
        layers: vec![
            attn_layer("wq", dim, tokens, 2, AttnPart::Q),
            attn_layer("wk", dim, tokens, 2, AttnPart::K),
            attn_layer("wv", dim, tokens, 2, AttnPart::V),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 962)).unwrap_err();
    assert!(err.contains("Q, K, V, O"), "unexpected error: {err}");
    // V and K swapped
    let spec = ArchSpec {
        name: "swapped".into(),
        layers: vec![
            attn_layer("wq", dim, tokens, 2, AttnPart::Q),
            attn_layer("wv", dim, tokens, 2, AttnPart::V),
            attn_layer("wk", dim, tokens, 2, AttnPart::K),
            attn_layer("wo", dim, tokens, 2, AttnPart::O),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 963)).unwrap_err();
    assert!(err.contains("in order"), "unexpected error: {err}");
}

#[test]
fn malformed_mlp_and_token_mix_pairs_are_rejected() {
    let (dim, tokens) = (8usize, 10usize);
    let mlp = |l: LayerSpec| l.in_block(BlockRole::MlpBody { id: "b0.mlp".into() });
    // fc2 returns to the wrong width
    let spec = ArchSpec {
        name: "bad_mlp".into(),
        layers: vec![
            mlp(LayerSpec::fc_tok("fc1", dim, 16, tokens)),
            mlp(LayerSpec::fc_tok("fc2", 16, dim + 1, tokens)),
            LayerSpec::fc("head", dim + 1, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 964)).unwrap_err();
    assert!(err.contains("MLP sub-block"), "unexpected error: {err}");
    // token-mixing pair whose fc1 does not read the token axis
    let tok = |l: LayerSpec| l.in_block(BlockRole::TokenMix { id: "b0.tok".into() });
    let spec = ArchSpec {
        name: "bad_tok".into(),
        layers: vec![
            tok(LayerSpec::fc_tok("fc1", dim, 16, tokens)),
            tok(LayerSpec::fc_tok("fc2", 16, dim, tokens)),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((dim, tokens, 1), 4, 965)).unwrap_err();
    assert!(err.contains("token-mixing MLP"), "unexpected error: {err}");
}

/// A pos-embed record that does not match the activation it sits on must
/// fail the lowering, not be silently dropped from the graph.
#[test]
fn mismatched_pos_embed_is_rejected() {
    let (dim, tokens) = (8usize, 10usize);
    let spec = ArchSpec {
        name: "bad_pos".into(),
        layers: vec![
            LayerSpec::fc_tok("patch_embed", 4, dim, tokens),
            // sized for twice the tokens actually present
            LayerSpec::other("pos_embed", dim * tokens * 2),
            LayerSpec::fc("head", dim, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((4, tokens, 1), 4, 966)).unwrap_err();
    assert!(err.contains("pos_embed") && err.contains("cannot lower"),
            "unexpected error: {err}");
}

// ---------------------------------------------------------------------------
// Release-mode tier: full-size lowering and forwards
// ---------------------------------------------------------------------------

/// ~52M synthesized params: release (`--ignored`) tier only.
#[test]
#[ignore]
fn vit_small_imagenet_lowers_full_size() {
    let spec = arch::vit_small_imagenet();
    let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 970)).unwrap();
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Attention { .. })), 6);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 12);
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::PosEmbedAdd { .. })), 1);
    let engine =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference).unwrap();
    assert_eq!(engine.in_len(), 768 * 196);
    assert_eq!(engine.out_len(), 1000);
}

/// Full-size ViT / TST-electricity / Mixer forwards: tile-resident vs
/// expanded bit-exact at full depth (release tier).
#[test]
#[ignore]
fn full_size_transformer_forwards_tile_vs_expanded() {
    for spec in [arch::vit_cifar(), arch::tst_electricity(), arch::mlpmixer_cifar()] {
        let graph = lower_arch_spec(&spec, &native_opts(&spec, 4, 980))
            .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                             EnginePath::Packed,
                                             PackedLayout::TileResident)
            .unwrap();
        let expanded = Engine::with_layout_graph(graph, Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::Expanded)
            .unwrap();
        assert!(tile.resident_weight_bytes() < expanded.resident_weight_bytes(),
                "{}", spec.name);
        let mut rng = Rng::new(981);
        for s in 0..2 {
            let x = rng.normal_vec(tile.in_len(), 1.0);
            assert_eq!(tile.forward(&x), expanded.forward(&x),
                       "{} sample {s}", spec.name);
        }
        let xs: Vec<Vec<f32>> =
            (0..2).map(|_| rng.normal_vec(tile.in_len(), 1.0)).collect();
        assert_eq!(tile.forward_batch(&xs), expanded.forward_batch(&xs),
                   "{} batched", spec.name);
    }
}
