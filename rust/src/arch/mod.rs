//! Exact layer-shape specifications of the *paper's* architectures.
//!
//! These drive every analytic column in the paper's tables: bit-width,
//! #Params (M-bit), savings vs 1-bit, bit-ops (Table 2), conv/FC composition
//! (Figure 2) and the inference memory model (Table 7 / Figure 5).  The
//! scaled-down *trainable* minis live in `python/compile/models`; this module
//! describes the full-size networks so the accounting matches the paper.
//!
//! Param totals are calibrated against the paper's own numbers (#Params
//! M-bit / 32): ResNet18-CIFAR 10.99M, ResNet50-CIFAR 23.45M, VGG-Small
//! 4.57M, ResNet34-ImageNet 21.1M, ViT-CIFAR 9.5M, Swin-t 26.6M, PointNet
//! 3.48M/8.34M/3.53M, TST 4.5M/0.37M.

mod models;

pub use models::*;

/// Layer kind: everything the paper tiles is a conv or an FC weight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// (out_c, in_c, kh, kw)
    Conv { co: usize, ci: usize, kh: usize, kw: usize },
    /// (out_features, in_features)
    Fc { co: usize, ci: usize },
    /// Norm scales, embeddings, ... (never quantized)
    Other,
}

/// Which projection of a transformer encoder attention sub-block a layer is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnPart {
    Q,
    K,
    V,
    /// The output projection applied to the attention context.
    O,
}

/// Block-boundary annotation marking the branching construct a layer belongs
/// to.  Plain sequential layers carry no annotation; `nn::lower_arch_spec`
/// uses consecutive runs of equal `id`s to rebuild the graph edges the flat
/// `Vec<LayerSpec>` cannot express (ResNet skip connections, PointNet T-Net
/// subgraphs, transformer encoder sub-blocks).  The annotations change
/// nothing about the analytic accounting — params/MACs stay per-layer sums.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BlockRole {
    /// Residual-block body layer.  The activation entering the block's first
    /// body layer is the skip operand; the last body layer's output joins it
    /// through an elementwise `Add` (ReLU after the join, per ResNet).
    ResidualBody { id: String },
    /// The block's 1x1 projection shortcut: lowers from the block input and
    /// replaces the identity as the skip operand of the join.
    ResidualDown { id: String },
    /// T-Net subgraph layer (PointNet): the subgraph branches off the
    /// current `(k, points)` features, ends in a `k*k` transform vector, and
    /// the transform left-multiplies the features it branched from
    /// (`MatMulFeature`).
    Tnet { id: String, k: usize },
    /// Transformer encoder attention sub-block projection: the four
    /// consecutive `AttnProj` layers of one `id` (Q, K, V, O in order)
    /// lower pre-LN to `LayerNorm -> Q/K/V token-FCs -> Attention -> O
    /// token-FC -> Add` (residual join, stream stays linear).
    AttnProj { id: String, heads: usize, part: AttnPart },
    /// Transformer / mixer MLP sub-block: two consecutive `MlpBody` layers
    /// (fc1 then fc2) lower pre-LN to `LayerNorm -> fc1 (ReLU) -> fc2 ->
    /// Add`.
    MlpBody { id: String },
    /// MLP-Mixer token-mixing MLP: two consecutive `TokenMix` layers lower
    /// pre-LN and *transposed* to `LayerNorm -> Transpose -> fc1 (ReLU) ->
    /// fc2 -> Transpose -> Add`, so the FCs mix the token axis.
    TokenMix { id: String },
    /// A construct the native engine has no graph node for (Swin shifted
    /// windows, MobileViT unfold/fold): `nn::lower_arch_spec` fails with an
    /// error naming it.
    Unsupported { id: String, construct: String },
}

impl BlockRole {
    /// The block id this annotation groups under.
    pub fn id(&self) -> &str {
        match self {
            BlockRole::ResidualBody { id }
            | BlockRole::ResidualDown { id }
            | BlockRole::Tnet { id, .. }
            | BlockRole::AttnProj { id, .. }
            | BlockRole::MlpBody { id }
            | BlockRole::TokenMix { id }
            | BlockRole::Unsupported { id, .. } => id,
        }
    }
}

/// One weight-bearing layer of a full-size architecture.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: Kind,
    /// Total weight elements.
    pub params: usize,
    /// Multiply-accumulates for one input sample.
    pub macs: u64,
    /// Input activation elements (batch 1).
    pub in_act: usize,
    /// Output activation elements (batch 1).
    pub out_act: usize,
    /// Branching-construct membership (`None` for the sequential trunk).
    pub block: Option<BlockRole>,
}

impl LayerSpec {
    pub fn conv(name: &str, ci: usize, co: usize, k: usize, h_out: usize, w_out: usize,
                h_in: usize, w_in: usize) -> LayerSpec {
        let params = co * ci * k * k;
        LayerSpec {
            name: name.into(),
            kind: Kind::Conv { co, ci, kh: k, kw: k },
            params,
            macs: (co * ci * k * k * h_out * w_out) as u64,
            in_act: ci * h_in * w_in,
            out_act: co * h_out * w_out,
            block: None,
        }
    }

    pub fn fc(name: &str, ci: usize, co: usize) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            kind: Kind::Fc { co, ci },
            params: co * ci,
            macs: (co * ci) as u64,
            in_act: ci,
            out_act: co,
            block: None,
        }
    }

    /// FC applied to `tokens` positions (transformer / PointNet shared MLP).
    pub fn fc_tok(name: &str, ci: usize, co: usize, tokens: usize) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            kind: Kind::Fc { co, ci },
            params: co * ci,
            macs: (co * ci * tokens) as u64,
            in_act: ci * tokens,
            out_act: co * tokens,
            block: None,
        }
    }

    pub fn other(name: &str, params: usize) -> LayerSpec {
        LayerSpec { name: name.into(), kind: Kind::Other, params, macs: 0,
                    in_act: 0, out_act: 0, block: None }
    }

    /// Tag this layer as part of a branching construct (builder-style).
    pub fn in_block(mut self, role: BlockRole) -> LayerSpec {
        self.block = Some(role);
        self
    }

    pub fn is_conv(&self) -> bool {
        matches!(self.kind, Kind::Conv { .. })
    }

    pub fn is_fc(&self) -> bool {
        matches!(self.kind, Kind::Fc { .. })
    }

    /// Per-output-channel weight count (replication granularity, §4.1).
    pub fn per_channel(&self) -> usize {
        match self.kind {
            Kind::Conv { ci, kh, kw, .. } => ci * kh * kw,
            Kind::Fc { ci, .. } => ci,
            Kind::Other => self.params,
        }
    }
}

/// A named full-size architecture.
#[derive(Debug, Clone)]
pub struct ArchSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl ArchSpec {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn conv_params(&self) -> usize {
        self.layers.iter().filter(|l| l.is_conv()).map(|l| l.params).sum()
    }

    pub fn fc_params(&self) -> usize {
        self.layers.iter().filter(|l| l.is_fc()).map(|l| l.params).sum()
    }

    /// Fraction of weight params in FC layers (Figure 2's y-axis).
    pub fn fc_fraction(&self) -> f64 {
        let total = (self.conv_params() + self.fc_params()).max(1);
        self.fc_params() as f64 / total as f64
    }

    /// Native lowering input `(channels, height, width)` implied by the
    /// first weight layer: a conv stem reads a square `ci x s x s` image, a
    /// token FC a channel-major `(ci, tokens, 1)` token map.  `None` when
    /// the first weight layer's input shape cannot be reconstructed (the
    /// benches and `tbn serve --arch` feed this to `nn::LowerOptions`).
    pub fn native_input(&self) -> Option<(usize, usize, usize)> {
        let l = self.layers.iter().find(|l| l.is_conv() || l.is_fc())?;
        match l.kind {
            Kind::Conv { ci, .. } => {
                if ci == 0 || l.in_act % ci != 0 {
                    return None;
                }
                let area = l.in_act / ci;
                let s = (area as f64).sqrt().round() as usize;
                (s * s == area).then_some((ci, s, s))
            }
            Kind::Fc { ci, .. } => {
                if ci == 0 || l.in_act == 0 || l.in_act % ci != 0 {
                    return None;
                }
                Some((ci, l.in_act / ci, 1))
            }
            Kind::Other => None,
        }
    }
}

/// All architectures that appear in the paper's evaluation.
pub fn all_archs() -> Vec<ArchSpec> {
    vec![
        resnet18_cifar(),
        resnet50_cifar(),
        vgg_small_cifar(),
        resnet34_imagenet(),
        vit_cifar(),
        vit_small_imagenet(),
        swin_t(),
        mobilevit(),
        pointnet_cls(),
        pointnet_part_seg(),
        pointnet_sem_seg(),
        mlpmixer_cifar(),
        convmixer_cifar(),
        tst_electricity(),
        tst_weather(),
    ]
}

pub fn arch_by_name(name: &str) -> Option<ArchSpec> {
    all_archs().into_iter().find(|a| a.name == name)
}

/// The native-engine demo minis (not paper architectures; kept out of
/// [`all_archs`] so the analytic tables stay paper-only).
pub fn mini_archs() -> Vec<ArchSpec> {
    vec![
        cnn_micro(),
        pointnet_micro(),
        resnet_micro(),
        pointnet_tnet_micro(),
        vit_micro(),
        tst_micro(),
        mixer_micro(),
    ]
}

/// Look up a paper architecture *or* demo mini by name (what
/// `tbn serve --arch` accepts).
pub fn any_arch_by_name(name: &str) -> Option<ArchSpec> {
    all_archs()
        .into_iter()
        .chain(mini_archs())
        .find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper-calibrated param totals (±3%): Tables 1, 3, 4, 5.
    #[test]
    fn param_totals_match_paper() {
        let cases = [
            ("resnet18_cifar", 10.99e6, 0.03),
            ("resnet50_cifar", 23.45e6, 0.03),
            ("vgg_small_cifar", 4.57e6, 0.03),
            ("resnet34_imagenet", 21.09e6, 0.04),
            ("vit_cifar", 9.49e6, 0.03),
            ("swin_t", 26.6e6, 0.08),
            ("pointnet_cls", 3.48e6, 0.05),
            ("pointnet_part_seg", 8.34e6, 0.08),
            ("pointnet_sem_seg", 3.53e6, 0.05),
            ("tst_electricity", 4.54e6, 0.05),
            ("tst_weather", 0.368e6, 0.10),
        ];
        for (name, want, tol) in cases {
            let arch = arch_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            let got = arch.total_params() as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < tol, "{name}: got {got:.3e}, paper {want:.3e} (rel {rel:.3})");
        }
    }

    /// Figure 2: ResNets are conv-dominated; ViT/Mixer/PointNet FC-dominated.
    #[test]
    fn composition_trends() {
        assert!(resnet18_cifar().fc_fraction() < 0.05);
        assert!(resnet34_imagenet().fc_fraction() < 0.15);
        assert!(vit_cifar().fc_fraction() > 0.95);
        assert!(swin_t().fc_fraction() > 0.90);
        assert!(pointnet_cls().fc_fraction() > 0.95);
        assert!(mlpmixer_cifar().fc_fraction() > 0.95);
        assert!(convmixer_cifar().fc_fraction() < 0.1);
    }

    #[test]
    fn macs_positive_and_consistent() {
        for arch in all_archs() {
            assert!(arch.total_macs() > 0, "{}", arch.name);
            for l in &arch.layers {
                if l.is_conv() || l.is_fc() {
                    assert!(l.params > 0 && l.per_channel() > 0);
                }
            }
        }
    }

    #[test]
    fn layer_constructors() {
        let c = LayerSpec::conv("c", 3, 64, 3, 32, 32, 32, 32);
        assert_eq!(c.params, 64 * 3 * 9);
        assert_eq!(c.macs, (64 * 3 * 9 * 32 * 32) as u64);
        assert_eq!(c.per_channel(), 27);
        assert!(c.block.is_none());
        let f = LayerSpec::fc_tok("f", 512, 512, 64);
        assert_eq!(f.params, 512 * 512);
        assert_eq!(f.macs, (512 * 512 * 64) as u64);
    }

    /// The block-boundary annotations the graph lowering consumes: every
    /// residual body/downsample conv and T-Net layer is tagged, the
    /// sequential trunk is not, and the analytic totals ignore the tags.
    #[test]
    fn branching_annotations_group_blocks() {
        let r18 = resnet18_cifar();
        let bodies = r18
            .layers
            .iter()
            .filter(|l| matches!(&l.block, Some(BlockRole::ResidualBody { .. })))
            .count();
        let downs = r18
            .layers
            .iter()
            .filter(|l| matches!(&l.block, Some(BlockRole::ResidualDown { .. })))
            .count();
        assert_eq!(bodies, 16, "8 basic blocks x 2 convs");
        assert_eq!(downs, 3, "stages 1..3 open with a projection");
        assert!(r18.layers[0].block.is_none(), "stem is trunk");
        assert!(r18.layers.last().unwrap().block.is_none(), "fc head is trunk");

        let pn = pointnet_cls();
        let ks: Vec<usize> = pn
            .layers
            .iter()
            .filter_map(|l| match &l.block {
                Some(BlockRole::Tnet { k, .. }) => Some(*k),
                _ => None,
            })
            .collect();
        assert_eq!(ks.len(), 12, "two 6-layer T-Nets");
        assert!(ks[..6].iter().all(|&k| k == 3));
        assert!(ks[6..].iter().all(|&k| k == 64));
        assert_eq!(pn.layers[0].block.as_ref().unwrap().id(), "tnet3");
    }

    /// Transformer annotations: each ViT/TST encoder block carries Q, K, V,
    /// O attention projections (in order, consistent heads) and an MLP
    /// pair; Swin/MobileViT attention is tagged `Unsupported`; the mixer's
    /// token MLPs are `TokenMix` pairs.
    #[test]
    fn encoder_annotations_group_blocks() {
        for (spec, depth, heads) in [(vit_cifar(), 6usize, 8usize),
                                     (vit_small_imagenet(), 6, 8),
                                     (tst_electricity(), 2, 8),
                                     (tst_weather(), 2, 8),
                                     (vit_micro(), 2, 4),
                                     (tst_micro(), 2, 3)] {
            let parts: Vec<AttnPart> = spec
                .layers
                .iter()
                .filter_map(|l| match &l.block {
                    Some(BlockRole::AttnProj { heads: h, part, .. }) => {
                        assert_eq!(*h, heads, "{}", spec.name);
                        Some(*part)
                    }
                    _ => None,
                })
                .collect();
            assert_eq!(parts.len(), 4 * depth, "{}", spec.name);
            for blk in parts.chunks(4) {
                assert_eq!(blk, [AttnPart::Q, AttnPart::K, AttnPart::V, AttnPart::O],
                           "{}", spec.name);
            }
            let mlps = spec
                .layers
                .iter()
                .filter(|l| matches!(&l.block, Some(BlockRole::MlpBody { .. })))
                .count();
            assert_eq!(mlps, 2 * depth, "{}", spec.name);
            assert!(spec.layers[0].block.is_none(), "{}: embed is trunk", spec.name);
            assert!(spec.layers.last().unwrap().block.is_none(),
                    "{}: head is trunk", spec.name);
        }
        for spec in [swin_t(), mobilevit()] {
            assert!(
                spec.layers.iter().any(|l| matches!(
                    &l.block, Some(BlockRole::Unsupported { .. }))),
                "{}: attention must be tagged unsupported", spec.name
            );
        }
        let mixer = mlpmixer_cifar();
        let tok = mixer
            .layers
            .iter()
            .filter(|l| matches!(&l.block, Some(BlockRole::TokenMix { .. })))
            .count();
        let ch = mixer
            .layers
            .iter()
            .filter(|l| matches!(&l.block, Some(BlockRole::MlpBody { .. })))
            .count();
        assert_eq!((tok, ch), (12, 12), "6 blocks x (2 token + 2 channel) FCs");
    }

    #[test]
    fn native_input_reconstructs_first_layer_shape() {
        let cases = [
            ("resnet18_cifar", resnet18_cifar(), (3, 32, 32)),
            ("vit_cifar", vit_cifar(), (48, 64, 1)),
            ("pointnet_cls", pointnet_cls(), (3, 1024, 1)),
            ("tst_weather", tst_weather(), (7, 96, 1)),
            ("vit_micro", vit_micro(), (12, 10, 1)),
            ("mixer_micro", mixer_micro(), (6, 9, 1)),
        ];
        for (name, spec, want) in cases {
            assert_eq!(spec.native_input(), Some(want), "{name}");
        }
    }
}
