//! Multivariate time-series forecasting (paper Table 5): Transformer
//! encoders on the synthetic electricity/weather series at FP, BWNN and
//! TBN_4, reporting MSE over multiple seeds with std — the paper's protocol.

use anyhow::{anyhow, Result};
use tiledbits::config::Manifest;
use tiledbits::coordinator::run_experiment;
use tiledbits::runtime::Runtime;
use tiledbits::train::TrainOptions;
use tiledbits::util::mean_std;

fn main() -> Result<()> {
    let artifacts = std::env::var("TBN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let steps: usize = std::env::var("TBN_STEPS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(120);
    let seeds: usize = std::env::var("TBN_SEEDS").ok()
        .and_then(|s| s.parse().ok()).unwrap_or(3);
    let manifest = Manifest::load(&artifacts).map_err(|e| anyhow!(e))?;
    let rt = Runtime::new(&artifacts)?;

    println!("== time-series forecasting (Table 5): MSE over {seeds} seeds ==\n");
    for ds in ["elec", "weather"] {
        println!("-- synthetic {ds} --");
        for method in ["fp", "bwnn", "tbn4"] {
            let id = format!("tst_{ds}_{method}");
            let Some(exp) = manifest.by_id(&id) else { continue };
            let mut mses = Vec::new();
            let mut bw = 32.0;
            for seed in 0..seeds {
                let rec = run_experiment(&rt, exp, &TrainOptions {
                    steps: Some(steps), eval_every: 0, log_every: 10_000,
                    seed: Some(100 + seed as u64) })?;
                mses.push(rec.metric);
                bw = rec.bit_width;
            }
            let (m, s) = mean_std(&mses);
            println!("{:16} MSE {m:.4} +- {s:.4}   bit-width {bw:.3}", id);
        }
        println!();
    }
    println!("expected shape (paper): TBN_4 MSE within noise of FP and BWNN on");
    println!("both datasets — compression does not hurt single-step forecasting.");
    Ok(())
}
