//! SIMD-backend bit-exactness (artifact-free).
//!
//! The dispatch contract (`tbn::bitops` module docs): every backend
//! generation of the XNOR-popcount word loop — scalar, the 4-wide u64
//! unroll, the u128 lanes, and the AVX2 Harley–Seal kernels — computes the
//! *identical* signed dot at every width, start phase, and offset phase.
//! The only thing a backend may change is how interior full words are
//! batched into popcounts; every partial boundary word is masked by the
//! same scalar expressions in all of them.  These tests fuzz that contract
//! directly against `xnor_dot_words_range_scalar` (the one-word oracle) and
//! then pin it end to end: engine forwards on the `cnn_micro` conv graph
//! and the `vit_micro` transformer are bit-exact across every
//! backend × layout × thread-count combination.
//!
//! `SimdBackend::Avx2` is safe to request everywhere: off-AVX2 hosts fall
//! back to the u128 path inside the wrapper (and `Engine::with_simd` clamps
//! to the detected best), so this suite passes unchanged on any CPU.

use tiledbits::arch;
use tiledbits::nn::{lower_arch_spec, Engine, EnginePath, LowerOptions, Nonlin,
                    PackedLayout, SimdBackend};
use tiledbits::tbn::bitops::{xnor_dot_words_offset_scalar, xnor_dot_words_offset_with,
                             xnor_dot_words_range_scalar, xnor_dot_words_range_with};
use tiledbits::tbn::AlphaMode;
use tiledbits::util::Rng;

const ALL_BACKENDS: [SimdBackend; 4] = [SimdBackend::Scalar, SimdBackend::U64x4,
                                        SimdBackend::U128, SimdBackend::Avx2];

fn rand_words(rng: &mut Rng, n: usize) -> Vec<u64> {
    (0..n).map(|_| rng.next_u64()).collect()
}

/// Aligned range kernel: every backend vs the scalar oracle over a grid of
/// ragged starts × lens (0, 1, sub-word, %64 != 0 tails, interiors that are
/// not multiples of the 4-word / 64-word vector blocks) plus a randomized
/// sweep of 500 (start, len) pairs.
#[test]
fn every_backend_matches_scalar_range_on_ragged_lens() {
    let mut rng = Rng::new(0x51D0);
    let words = 300usize;
    let a = rand_words(&mut rng, words);
    let b = rand_words(&mut rng, words);
    let lens = [0usize, 1, 2, 63, 64, 65, 100, 127, 128, 129, 191, 255, 256, 257,
                64 * 4 + 1, 64 * 5 - 1, 64 * 63, 64 * 64, 64 * 64 + 17, words * 64];
    let starts = [0usize, 1, 7, 31, 63, 64, 65, 129, 1000];
    for &start in &starts {
        for &len in &lens {
            if start + len > words * 64 {
                continue;
            }
            let want = xnor_dot_words_range_scalar(&a, &b, start, len);
            for backend in ALL_BACKENDS {
                assert_eq!(xnor_dot_words_range_with(backend, &a, &b, start, len),
                           want, "{backend} range start={start} len={len}");
            }
        }
    }
    for _ in 0..500 {
        let start = (rng.next_u64() as usize) % (words * 64);
        let len = (rng.next_u64() as usize) % (words * 64 - start + 1);
        let want = xnor_dot_words_range_scalar(&a, &b, start, len);
        for backend in ALL_BACKENDS {
            assert_eq!(xnor_dot_words_range_with(backend, &a, &b, start, len),
                       want, "{backend} random range start={start} len={len}");
        }
    }
}

/// Misaligned shift-stitch kernel: every backend vs the scalar offset
/// kernel at **all 64 offset phases** (`a_start % 64` from 0 to 63, so both
/// the congruent delegate-to-range case and every carried-word stitch), at
/// congruent and non-congruent `b` phases, across ragged lens.
#[test]
fn every_backend_matches_scalar_offset_at_all_64_phases() {
    let mut rng = Rng::new(0x0FF5E7);
    let words = 200usize;
    let a = rand_words(&mut rng, words);
    let b = rand_words(&mut rng, words);
    let lens = [0usize, 1, 65, 127, 64 * 3, 64 * 5 + 13, 5000];
    for a_phase in 0..64usize {
        // one full word of headroom so every phase reads mid-slice
        let a_start = 64 + a_phase;
        for b_start in [0usize, 1, 37, 63, 64 + a_phase] {
            for &len in &lens {
                if a_start + len > words * 64 || b_start + len > words * 64 {
                    continue;
                }
                let want = xnor_dot_words_offset_scalar(&a, a_start, &b, b_start, len);
                for backend in ALL_BACKENDS {
                    assert_eq!(
                        xnor_dot_words_offset_with(backend, &a, a_start, &b,
                                                   b_start, len),
                        want,
                        "{backend} offset a_start={a_start} b_start={b_start} \
                         len={len}"
                    );
                }
            }
        }
    }
    // randomized sweep across phases and ragged lens
    for _ in 0..500 {
        let a_start = (rng.next_u64() as usize) % (words * 32);
        let b_start = (rng.next_u64() as usize) % (words * 32);
        let room = words * 64 - a_start.max(b_start);
        let len = (rng.next_u64() as usize) % (room + 1);
        let want = xnor_dot_words_offset_scalar(&a, a_start, &b, b_start, len);
        for backend in ALL_BACKENDS {
            assert_eq!(
                xnor_dot_words_offset_with(backend, &a, a_start, &b, b_start, len),
                want,
                "{backend} random offset a_start={a_start} b_start={b_start} len={len}"
            );
        }
    }
}

/// The offset kernel agrees with the aligned range kernel whenever both can
/// express the same dot (`a_start` congruent to `b_start` mod 64), for
/// every backend — the congruent fast path must not drift from the stitch.
#[test]
fn congruent_offsets_agree_with_range_on_every_backend() {
    let mut rng = Rng::new(0xC0FFEE);
    let words = 96usize;
    let a = rand_words(&mut rng, words);
    for phase in [0usize, 1, 17, 63] {
        for words_off in [0usize, 1, 5] {
            let start = words_off * 64 + phase;
            for &len in &[0usize, 1, 64, 129, 64 * 10 + 7] {
                if start + len > words * 64 {
                    continue;
                }
                let want = xnor_dot_words_range_scalar(&a, &a, start, len);
                for backend in ALL_BACKENDS {
                    assert_eq!(
                        xnor_dot_words_offset_with(backend, &a, start, &a, start, len),
                        want, "{backend} congruent start={start} len={len}");
                }
            }
        }
    }
}

fn graph_for(name: &str) -> (tiledbits::nn::Graph, usize) {
    let (spec, input) = match name {
        "cnn_micro" => (arch::cnn_micro(), (3usize, 16usize, 16usize)),
        "vit_micro" => {
            let s = arch::vit_micro();
            let input = s.native_input().expect("vit_micro input shape");
            (s, input)
        }
        other => panic!("unknown spec {other}"),
    };
    let opts = LowerOptions { input, p: 4, alpha_mode: AlphaMode::PerTile, seed: 7 };
    let graph = lower_arch_spec(&spec, &opts).unwrap();
    let in_len = input.0 * input.1 * input.2;
    (graph, in_len)
}

/// End-to-end pin: packed engine forwards (single and batched) on the
/// `cnn_micro` conv graph and the `vit_micro` transformer are bit-exact
/// across every backend × layout × thread count — FC rows, conv im2col and
/// attention projections all ride the dispatched kernels.
#[test]
fn engine_forwards_bit_exact_across_backend_layout_threads() {
    for name in ["cnn_micro", "vit_micro"] {
        let (graph, in_len) = graph_for(name);
        let mut rng = Rng::new(59);
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(in_len, 1.0)).collect();
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let baseline = Engine::with_layout_graph(
                graph.clone(), Nonlin::Relu, EnginePath::Packed, layout)
                .unwrap()
                .with_threads(1)
                .with_simd(SimdBackend::Scalar);
            let singles: Vec<Vec<f32>> = xs.iter().map(|x| baseline.forward(x)).collect();
            let batch = baseline.forward_batch(&xs);
            for backend in ALL_BACKENDS {
                for threads in [1usize, 3] {
                    let engine = Engine::with_layout_graph(
                        graph.clone(), Nonlin::Relu, EnginePath::Packed, layout)
                        .unwrap()
                        .with_threads(threads)
                        .with_simd(backend);
                    for (s, x) in xs.iter().enumerate() {
                        assert_eq!(engine.forward(x), singles[s],
                                   "{name} {layout:?} {backend} threads={threads} \
                                    sample {s}");
                    }
                    assert_eq!(engine.forward_batch(&xs), batch,
                               "{name} {layout:?} {backend} threads={threads} batched");
                }
            }
        }
    }
}

/// `with_simd` clamps impossible requests instead of faulting: asking for
/// AVX2 yields a backend the host can actually run, and the engine still
/// computes the scalar bits.
#[test]
fn unsupported_backend_requests_clamp_to_detected() {
    let (graph, in_len) = graph_for("cnn_micro");
    let mut rng = Rng::new(60);
    let x = rng.normal_vec(in_len, 1.0);
    let engine = Engine::with_layout_graph(
        graph.clone(), Nonlin::Relu, EnginePath::Packed, PackedLayout::TileResident)
        .unwrap()
        .with_simd(SimdBackend::Avx2);
    assert!(engine.simd().supported(), "with_simd must never store an \
             unsupported backend (got {})", engine.simd());
    let scalar = Engine::with_layout_graph(
        graph, Nonlin::Relu, EnginePath::Packed, PackedLayout::TileResident)
        .unwrap()
        .with_simd(SimdBackend::Scalar);
    assert_eq!(engine.forward(&x), scalar.forward(&x));
}
