//! Class-structured synthetic image sets (CIFAR-10 / MNIST stand-ins).

use crate::util::Rng;
use super::{Dataset, Task};

/// SynthMNIST: per-class prototype vectors + Gaussian noise, normalized.
///
/// Each class c has a fixed prototype drawn from a class-seeded stream; a
/// sample is `prototype + sigma * noise`.  sigma is chosen so a linear model
/// separates classes well but single features do not.
pub fn synth_mnist(input: &[usize], classes: usize, n: usize, rng: &mut Rng) -> Dataset {
    let d: usize = input.iter().product();
    let mut protos = Vec::with_capacity(classes);
    for c in 0..classes {
        // prototypes come from a *fixed* stream so train/test agree
        let mut pr = Rng::new(PROTO_SEED ^ (c as u64 + 1).wrapping_mul(0x9E3779B9));
        protos.push(pr.normal_vec(d, 1.0));
    }
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        y.push(c as i32);
        let proto = &protos[c];
        for &pj in proto.iter() {
            x.push(pj + 1.2 * rng.gauss_f32());
        }
    }
    Dataset { n, x_elems: d, x, y_int: y, y_float: vec![], y_elems: 0,
              y_int_elems: 1, task: Task::Cls }
}

/// SynthCIFAR: class-specific 2-D frequency/blob patterns per channel plus
/// colored noise — closer to natural-image statistics than pure prototypes,
/// so convolutional inductive bias helps (CNNs beat linear models here).
pub fn synth_cifar(input: &[usize], classes: usize, n: usize, rng: &mut Rng) -> Dataset {
    assert_eq!(input.len(), 3, "synth_cifar wants [c, h, w]");
    let (c_ch, h, w) = (input[0], input[1], input[2]);
    let d = c_ch * h * w;

    // fixed per-class pattern parameters
    struct Pat {
        fx: f32,
        fy: f32,
        phase: f32,
        blob_x: f32,
        blob_y: f32,
        chan_mix: Vec<f32>,
    }
    let pats: Vec<Pat> = (0..classes)
        .map(|c| {
            let mut pr = Rng::new(0xC1FA ^ (c as u64 + 1).wrapping_mul(0x9E3779B9));
            Pat {
                fx: 0.5 + 2.5 * pr.next_f32(),
                fy: 0.5 + 2.5 * pr.next_f32(),
                phase: std::f32::consts::TAU * pr.next_f32(),
                blob_x: pr.next_f32(),
                blob_y: pr.next_f32(),
                chan_mix: (0..c_ch).map(|_| 0.5 + pr.next_f32()).collect(),
            }
        })
        .collect();

    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        y.push(c as i32);
        let p = &pats[c];
        // small per-sample jitter so samples within a class vary
        let jx = 0.15 * rng.gauss_f32();
        let jy = 0.15 * rng.gauss_f32();
        let amp = 0.8 + 0.4 * rng.next_f32();
        for ch in 0..c_ch {
            let mix = p.chan_mix[ch];
            for iy in 0..h {
                for ix in 0..w {
                    let u = ix as f32 / w as f32;
                    let v = iy as f32 / h as f32;
                    let wave = (std::f32::consts::TAU
                        * (p.fx * (u + jx) + p.fy * (v + jy))
                        + p.phase)
                        .sin();
                    let bx = u - p.blob_x;
                    let by = v - p.blob_y;
                    let blob = (-8.0 * (bx * bx + by * by)).exp();
                    let signal = mix * (0.7 * wave + 1.5 * blob);
                    x.push(amp * signal + 0.6 * rng.gauss_f32());
                }
            }
        }
    }
    Dataset { n, x_elems: d, x, y_int: y, y_float: vec![], y_elems: 0,
              y_int_elems: 1, task: Task::Cls }
}

/// Fixed stream for class prototypes (shared by train and test splits).
const PROTO_SEED: u64 = 0x5397_11AA_02;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_shapes() {
        let mut rng = Rng::new(1);
        let d = synth_mnist(&[256], 10, 12, &mut rng);
        assert_eq!(d.x.len(), 12 * 256);
        assert_eq!(d.y_int.len(), 12);
    }

    #[test]
    fn cifar_within_class_similarity() {
        // two samples of the same class correlate more than across classes
        let mut rng = Rng::new(2);
        let d = synth_cifar(&[3, 16, 16], 10, 400, &mut rng);
        let dim = d.x_elems;
        let corr = |a: &[f32], b: &[f32]| -> f64 {
            let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x * *y) as f64).sum();
            let na: f64 = a.iter().map(|x| (*x * *x) as f64).sum::<f64>().sqrt();
            let nb: f64 = b.iter().map(|x| (*x * *x) as f64).sum::<f64>().sqrt();
            dot / (na * nb)
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..60 {
            for j in (i + 1)..60 {
                let ci = d.y_int[i];
                let cj = d.y_int[j];
                let c = corr(&d.x[i * dim..(i + 1) * dim], &d.x[j * dim..(j + 1) * dim]);
                if ci == cj {
                    same.push(c);
                } else {
                    diff.push(c);
                }
            }
        }
        let ms = same.iter().sum::<f64>() / same.len().max(1) as f64;
        let md = diff.iter().sum::<f64>() / diff.len().max(1) as f64;
        assert!(ms > md + 0.1, "same {ms} vs diff {md}");
    }
}
