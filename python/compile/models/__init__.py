"""Model zoo: every architecture family the paper evaluates, scaled to CPU.

``build_model(model_cfg, tiling)`` dispatches on ``model_cfg["family"]`` and
returns a :class:`compile.layers.ModelDef` (ordered ParamSpecs + apply fn).
All models are bias-free on quantized layers, per the paper ("We do not
consider bias parameters in this work").
"""

from __future__ import annotations

from ..layers import ModelDef, TilingConfig
from . import cnn, mixer, mlp, pointnet, tst, vit

_FAMILIES = {
    "mlp": mlp.build,
    "resnet_mini": cnn.build_resnet_mini,
    "vgg_mini": cnn.build_vgg_mini,
    "vit_tiny": vit.build,
    "pointnet_cls": pointnet.build_cls,
    "pointnet_seg": pointnet.build_seg,
    "tst": tst.build,
    "mlpmixer": mixer.build_mlpmixer,
    "convmixer": mixer.build_convmixer,
}


def build_model(model_cfg: dict, tiling: TilingConfig) -> ModelDef:
    family = model_cfg["family"]
    if family not in _FAMILIES:
        raise ValueError(f"unknown model family {family!r}")
    return _FAMILIES[family](model_cfg, tiling)


def families() -> list:
    return sorted(_FAMILIES)
