"""AOT pipeline tests: graph lowering, manifest consistency, HLO executability.

Uses a temp output dir and a couple of small experiments so the suite stays
fast; the full 48-experiment build is exercised by `make artifacts`.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.layers import TilingConfig, init_params
from compile.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
CONFIG = os.path.join(REPO, "configs", "experiments.json")


@pytest.fixture(scope="module")
def cfg():
    with open(CONFIG) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def mlp_entry(cfg):
    exp = next(e for e in cfg["experiments"] if e["id"] == "mlp_micro_tbn4")
    return aot.build_graphs(exp, cfg["defaults"])


class TestManifest:
    def test_every_experiment_has_unique_id(self, cfg):
        ids = [e["id"] for e in cfg["experiments"]]
        assert len(ids) == len(set(ids))

    def test_every_experiment_references_a_table_or_figure(self, cfg):
        for e in cfg["experiments"]:
            assert e.get("tables"), f"{e['id']} not mapped to any table/figure"

    def test_graph_files_and_roles(self, mlp_entry):
        entry, graphs = mlp_entry
        assert set(graphs) == {"init", "train_step", "eval_step", "forward"}
        roles = {p["role"] for p in entry["params"]}
        assert "weight" in roles and "alpha_src" in roles

    def test_tiled_param_bookkeeping(self, mlp_entry):
        entry, _ = mlp_entry
        tiled = [p for p in entry["params"] if p["quant"] == "tiled"]
        assert tiled
        for p in tiled:
            n = int(np.prod(p["shape"]))
            assert p["p"] * p["q"] == n
        kinds = [ip["kind"] for ip in entry["infer_params"]]
        assert "tile" in kinds and "alphas" in kinds
        # A never ships to inference
        assert not any(ip["name"].endswith(".A") for ip in entry["infer_params"])

    def test_hlo_text_parses_as_hlo(self, mlp_entry):
        _, graphs = mlp_entry
        for name, text in graphs.items():
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text


class TestGraphSemantics:
    """Execute the lowered python functions (pre-lowering) for numerics."""

    def test_init_then_train_step_reduces_loss(self, cfg):
        exp = next(e for e in cfg["experiments"] if e["id"] == "mlp_micro_tbn4")
        tiling = TilingConfig.from_json(exp["tiling"])
        model = build_model(exp["model"], tiling)
        specs = model.specs
        tr = aot.merge_train(cfg["defaults"], exp)
        from compile.optim import apply_update, init_opt_state

        params = init_params(jnp.asarray(exp.get("seed", 1), jnp.int32), specs)
        state = init_opt_state(tr["opt"], params, specs)
        r = np.random.default_rng(0)
        x = jnp.asarray(r.standard_normal((16, 256)), jnp.float32)
        y = jnp.asarray(r.integers(0, 10, 16), jnp.int32)

        from compile.layers import softmax_xent

        def lf(p):
            return softmax_xent(model.apply(p, x), y)

        first = float(lf(params))
        for step in range(1, 16):
            loss, grads = jax.value_and_grad(lf)(params)
            params, state = apply_update(tr["opt"], specs, params, grads, state,
                                         jnp.asarray(0.05, jnp.float32),
                                         jnp.asarray(step, jnp.float32), tr)
        assert float(lf(params)) < first

    def test_io_shapes_cls_seg_forecast(self, cfg):
        by_id = {e["id"]: e for e in cfg["experiments"]}
        io = aot.io_shapes(by_id["mlp_micro_tbn4"], cfg["defaults"], "cls")
        assert io["y_dtype"] == "i32" and len(io["y_train"]) == 1
        io = aot.io_shapes(by_id["pointnet_seg_tbn4"], cfg["defaults"], "seg")
        assert io["y_train"] == [io["train_batch"], 128]
        io = aot.io_shapes(by_id["tst_elec_tbn4"], cfg["defaults"], "forecast")
        assert io["y_dtype"] == "f32" and io["y_train"][1] == 32


class TestBuiltArtifacts:
    """Consistency checks over the real artifacts/ dir when it exists."""

    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(REPO, "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_all_graph_files_exist(self, manifest):
        for e in manifest["experiments"]:
            for g in e["graphs"].values():
                assert os.path.exists(os.path.join(REPO, "artifacts", g["file"]))

    def test_config_and_manifest_agree(self, manifest, cfg):
        assert {e["id"] for e in manifest["experiments"]} == \
               {e["id"] for e in cfg["experiments"]}

    def test_tbn_experiments_have_subbit_width(self, manifest):
        """Bit-width over quantized layers must be < 1 for every TBN config."""
        for e in manifest["experiments"]:
            if e["tiling"]["mode"] != "tbn":
                continue
            bits = 0.0
            n = 0
            for pr in e["params"]:
                if pr["quant"] == "tiled":
                    sz = int(np.prod(pr["shape"]))
                    bits += pr["q"] + 32.0 * pr["n_alphas"]
                    n += sz
            if n:
                assert bits / n < 1.0, e["id"]
