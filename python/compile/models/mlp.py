"""MLP (paper section 5.1): the microcontroller model — 1 hidden layer + ReLU.

Matches Table 6's deployment model: in_dim -> hidden -> classes, fused ReLU,
no biases.  The hidden layer (in_dim*hidden params) is above lambda and gets
tiled; the classification head is small and stays full precision (the paper
notes "Since the classification layer only contains 1280 parameters, it is
not tiled").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import ModelBind, ModelDef, SpecBuilder, TilingConfig


def build(cfg: dict, tiling: TilingConfig) -> ModelDef:
    in_dim = int(cfg["in_dim"])
    hidden = [int(h) for h in cfg["hidden"]]
    classes = int(cfg["classes"])

    b = SpecBuilder(tiling)
    dims = [in_dim] + hidden
    for i in range(len(hidden)):
        b.weight(f"fc{i}", (dims[i + 1], dims[i]))
    b.weight("head", (classes, dims[-1]))
    specs = b.specs

    def apply(params, x):
        m = ModelBind(specs, params)
        h = x.reshape(x.shape[0], -1)
        for i in range(len(hidden)):
            h = jax.nn.relu(m.dense(f"fc{i}", h))
        return m.dense("head", h)

    return ModelDef(specs, apply)
