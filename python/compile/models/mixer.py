"""MLPMixer and ConvMixer for Figure 6 (effect of layer size) and Figure 7.

MLPMixer: per-block token-mixing MLP (across patches) + channel-mixing MLP.
ConvMixer: patch-embedding conv, then depth x [depthwise conv + pointwise
conv] with residual on the depthwise step (Trockman & Kolter).

These are the paper's ablation architectures: ConvMixer's largest layer is
small, so accuracy degrades quickly with p; MLPMixer's channel MLPs are
bigger and degrade more gracefully.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (ModelBind, ModelDef, SpecBuilder, TilingConfig,
                      declare_groupnorm, declare_layernorm)


def build_mlpmixer(cfg: dict, tiling: TilingConfig) -> ModelDef:
    dim = int(cfg["dim"])
    depth = int(cfg["depth"])
    patch = int(cfg["patch"])
    token_mlp = int(cfg["token_mlp"])
    channel_mlp = int(cfg["channel_mlp"])
    classes = int(cfg["classes"])
    img = int(cfg.get("img", 16))
    chans = int(cfg.get("in_channels", 3))
    tokens = (img // patch) ** 2

    b = SpecBuilder(tiling)
    b.weight("patch_embed", (dim, chans * patch * patch))
    for d in range(depth):
        pre = f"blk{d}"
        declare_layernorm(b, f"{pre}.ln1", dim)
        b.weight(f"{pre}.tok.fc1", (token_mlp, tokens))
        b.weight(f"{pre}.tok.fc2", (tokens, token_mlp))
        declare_layernorm(b, f"{pre}.ln2", dim)
        b.weight(f"{pre}.ch.fc1", (channel_mlp, dim))
        b.weight(f"{pre}.ch.fc2", (dim, channel_mlp))
    declare_layernorm(b, "final", dim)
    b.weight("head", (classes, dim))
    specs = b.specs

    def apply(params, x):
        m = ModelBind(specs, params)
        n, c, hh, ww = x.shape
        gh, gw = hh // patch, ww // patch
        xp = x.reshape(n, c, gh, patch, gw, patch)
        xp = xp.transpose(0, 2, 4, 1, 3, 5).reshape(n, gh * gw, c * patch * patch)
        h = m.dense("patch_embed", xp)  # (n, tokens, dim)
        for d in range(depth):
            pre = f"blk{d}"
            # token mixing: transpose to (n, dim, tokens)
            t = m.ln(f"{pre}.ln1", h).transpose(0, 2, 1)
            t = m.dense(f"{pre}.tok.fc2", jax.nn.gelu(m.dense(f"{pre}.tok.fc1", t)))
            h = h + t.transpose(0, 2, 1)
            ch = m.ln(f"{pre}.ln2", h)
            ch = m.dense(f"{pre}.ch.fc2", jax.nn.gelu(m.dense(f"{pre}.ch.fc1", ch)))
            h = h + ch
        h = m.ln("final", h).mean(axis=1)
        return m.dense("head", h)

    return ModelDef(specs, apply)


def build_convmixer(cfg: dict, tiling: TilingConfig) -> ModelDef:
    dim = int(cfg["dim"])
    depth = int(cfg["depth"])
    kernel = int(cfg["kernel"])
    patch = int(cfg["patch"])
    classes = int(cfg["classes"])
    chans = int(cfg.get("in_channels", 3))

    b = SpecBuilder(tiling)
    b.weight("patch_embed", (dim, chans, patch, patch))
    declare_groupnorm(b, "patch_embed", dim)
    for d in range(depth):
        pre = f"blk{d}"
        b.weight(f"{pre}.dw", (dim, 1, kernel, kernel))  # depthwise
        declare_groupnorm(b, f"{pre}.dw", dim)
        b.weight(f"{pre}.pw", (dim, dim, 1, 1))  # pointwise
        declare_groupnorm(b, f"{pre}.pw", dim)
    b.weight("head", (classes, dim))
    specs = b.specs

    def apply(params, x):
        m = ModelBind(specs, params)
        h = jax.nn.gelu(m.gn("patch_embed", m.conv("patch_embed", x, stride=patch, padding="VALID")))
        for d in range(depth):
            pre = f"blk{d}"
            r = h
            h = jax.nn.gelu(m.gn(f"{pre}.dw", m.conv(f"{pre}.dw", h, groups=h.shape[1])))
            h = h + r
            h = jax.nn.gelu(m.gn(f"{pre}.pw", m.conv(f"{pre}.pw", h)))
        h = h.mean(axis=(2, 3))
        return m.dense("head", h)

    return ModelDef(specs, apply)
