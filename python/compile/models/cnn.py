"""CNN families for Table 1/2 and Figures 7/8: ResNet-mini and VGG-mini.

Scaled-down counterparts of the paper's ResNet-18/50 and VGG-Small (DESIGN §7:
the full architectures are reproduced analytically in ``rust/src/arch``; the
minis carry the accuracy-trend claims).  BatchNorm is replaced by GroupNorm
(batch-size independent — keeps train/eval graphs identical; DESIGN §7).

ResNet-mini: stem conv + 2 stages x 2 basic blocks (widths w, 2w), stride-2
downsample between stages, global average pool, FC head.
VGG-mini: [w, w, M, 2w, 2w, M] conv stack + FC head (VGG-Small shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..layers import (ModelBind, ModelDef, SpecBuilder, TilingConfig,
                      declare_groupnorm)


def build_resnet_mini(cfg: dict, tiling: TilingConfig) -> ModelDef:
    w = int(cfg.get("width", 16))
    classes = int(cfg["classes"])
    in_ch = 3

    b = SpecBuilder(tiling)
    b.weight("stem", (w, in_ch, 3, 3))
    declare_groupnorm(b, "stem", w)

    stages = [(w, 1), (2 * w, 2)]  # (channels, first-block stride)
    blocks = 2
    cin = w
    for si, (ch, stride) in enumerate(stages):
        for bi in range(blocks):
            st = stride if bi == 0 else 1
            pre = f"s{si}b{bi}"
            b.weight(f"{pre}.conv1", (ch, cin, 3, 3))
            declare_groupnorm(b, f"{pre}.conv1", ch)
            b.weight(f"{pre}.conv2", (ch, ch, 3, 3))
            declare_groupnorm(b, f"{pre}.conv2", ch)
            if st != 1 or cin != ch:
                b.weight(f"{pre}.down", (ch, cin, 1, 1))
                declare_groupnorm(b, f"{pre}.down", ch)
            cin = ch
    b.weight("head", (classes, cin))
    specs = b.specs
    has = {s.name for s in specs}

    def apply(params, x):
        m = ModelBind(specs, params)
        h = jax.nn.relu(m.gn("stem", m.conv("stem", x)))
        cin_l = w
        for si, (ch, stride) in enumerate(stages):
            for bi in range(blocks):
                st = stride if bi == 0 else 1
                pre = f"s{si}b{bi}"
                r = h
                h2 = jax.nn.relu(m.gn(f"{pre}.conv1", m.conv(f"{pre}.conv1", h, stride=st)))
                h2 = m.gn(f"{pre}.conv2", m.conv(f"{pre}.conv2", h2))
                if f"{pre}.down" in has:
                    r = m.gn(f"{pre}.down", m.conv(f"{pre}.down", r, stride=st))
                h = jax.nn.relu(h2 + r)
                cin_l = ch
        h = h.mean(axis=(2, 3))  # global average pool
        return m.dense("head", h)

    return ModelDef(specs, apply)


def build_vgg_mini(cfg: dict, tiling: TilingConfig) -> ModelDef:
    w = int(cfg.get("width", 32))
    classes = int(cfg["classes"])
    plan = [w, w, "M", 2 * w, 2 * w, "M"]

    b = SpecBuilder(tiling)
    cin = 3
    ci = 0
    for item in plan:
        if item == "M":
            continue
        b.weight(f"conv{ci}", (int(item), cin, 3, 3))
        declare_groupnorm(b, f"conv{ci}", int(item))
        cin = int(item)
        ci += 1
    # input 16x16 -> two 2x2 maxpools -> 4x4 feature map
    b.weight("fc", (4 * w, cin * 4 * 4))
    b.weight("head", (classes, 4 * w))
    specs = b.specs

    def apply(params, x):
        m = ModelBind(specs, params)
        h = x
        ci_l = 0
        for item in plan:
            if item == "M":
                h = jax.lax.reduce_window(
                    h, -jnp.inf, jax.lax.max,
                    window_dimensions=(1, 1, 2, 2),
                    window_strides=(1, 1, 2, 2), padding="VALID")
            else:
                h = jax.nn.relu(m.gn(f"conv{ci_l}", m.conv(f"conv{ci_l}", h)))
                ci_l += 1
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(m.dense("fc", h))
        return m.dense("head", h)

    return ModelDef(specs, apply)
