//! Runtime integration: load real AOT artifacts via PJRT and exercise every
//! graph kind (init / train_step / eval_step / forward) of the micro MLP
//! experiments.  Skips (with a notice) when `make artifacts` hasn't run.

use tiledbits::config::Manifest;
use tiledbits::runtime::{self, Runtime};
use tiledbits::tensor::Tensor;
use tiledbits::train::{Trainer, TrainOptions};

fn setup() -> Option<(Runtime, Manifest)> {
    let Some(artifacts) = tiledbits::util::locate_upwards("artifacts") else {
        eprintln!("skipping runtime tests: artifacts/ not built");
        return None;
    };
    let manifest = match Manifest::load(&artifacts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            return None;
        }
    };
    let rt = match Runtime::new(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime tests: {e:#}");
            return None;
        }
    };
    Some((rt, manifest))
}

#[test]
fn init_is_deterministic_and_shaped() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("mlp_micro_tbn4").expect("mlp_micro_tbn4");
    let trainer = Trainer::new(&rt, exp).unwrap();
    let a = trainer.init_params(7).unwrap();
    let b = trainer.init_params(7).unwrap();
    let c = trainer.init_params(8).unwrap();
    assert_eq!(a.len(), exp.n_params());
    for ((la, lb), info) in a.iter().zip(&b).zip(&exp.params) {
        let ta = runtime::tensor_from_literal(la).unwrap();
        let tb = runtime::tensor_from_literal(lb).unwrap();
        assert_eq!(ta.shape, info.shape, "{}", info.name);
        assert_eq!(ta.data, tb.data, "{} not deterministic", info.name);
    }
    let t0a = runtime::tensor_from_literal(&a[0]).unwrap();
    let t0c = runtime::tensor_from_literal(&c[0]).unwrap();
    assert_ne!(t0a.data, t0c.data, "seed must change init");
}

#[test]
fn train_step_decreases_loss_on_fixed_batch() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("mlp_micro_tbn4").unwrap();
    let trainer = Trainer::new(&rt, exp).unwrap();
    let (result, _) = trainer
        .run(&TrainOptions { steps: Some(30), eval_every: 0, log_every: 1000, seed: Some(3) })
        .unwrap();
    let first = result.train_history.first().unwrap().loss;
    let last = result.train_history.last().unwrap().loss;
    assert!(last < first, "loss {first} -> {last} did not decrease");
}

#[test]
fn eval_metric_consistent_with_task() {
    let Some((rt, manifest)) = setup() else { return };
    for id in ["mlp_micro_fp", "mlp_micro_bwnn", "mlp_micro_tbn4"] {
        let exp = manifest.by_id(id).unwrap();
        let trainer = Trainer::new(&rt, exp).unwrap();
        let params = trainer.init_params(1).unwrap();
        let point = trainer.evaluate(&params, 0).unwrap();
        // untrained model: accuracy near chance, loss near ln(10)
        assert!(point.metric >= 0.0 && point.metric <= 1.0, "{id}: {point:?}");
        assert!(point.loss > 1.0 && point.loss < 6.0, "{id}: loss {}", point.loss);
    }
}

#[test]
fn forward_graph_runs_from_exported_params() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("mlp_micro_tbn4").unwrap();
    let trainer = Trainer::new(&rt, exp).unwrap();
    let (_, model) = trainer
        .run(&TrainOptions { steps: Some(10), eval_every: 0, log_every: 1000, seed: Some(1) })
        .unwrap();
    let exe = rt.load(exp.graph_file("forward").unwrap()).unwrap();
    let batch = exp.io.serve_batch;
    let idxs: Vec<usize> = (0..batch).collect();
    let (x, _, _) = trainer.test_ds.gather(&idxs);
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(&exp.io.x);
    let mut inputs = vec![runtime::literal_f32(&Tensor::new(x_shape, x)).unwrap()];
    inputs.extend(tiledbits::train::export::forward_inputs(exp, &model).unwrap());
    let out = exe.run(&inputs).unwrap();
    let logits = runtime::tensor_from_literal(&out[0]).unwrap();
    assert_eq!(logits.shape, vec![batch, exp.dataset_classes]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
}

#[test]
fn compile_cache_reuses_executables() {
    let Some((rt, manifest)) = setup() else { return };
    let exp = manifest.by_id("mlp_micro_fp").unwrap();
    let file = exp.graph_file("init").unwrap();
    let a = rt.load(file).unwrap();
    let b = rt.load(file).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b), "second load must hit the cache");
}
