"""Build-time compiler package: authors and AOT-lowers all compute graphs.

Never imported at runtime — the Rust binary only consumes the HLO text and
manifest this package emits into ``artifacts/``.
"""
