//! Layer graph of the native engine: typed nodes with per-layer Reference
//! and Packed kernels.
//!
//! The paper applies tiling to "both fully-connected and convolutional
//! layers"; this module is where both meet the native engine.  A [`Node`] is
//! one step of a sequential inference graph:
//!
//! * [`FcLayer`] — a `[m, n]` weight layer served by the Algorithm 1 f32
//!   kernels (Reference) or the XNOR-popcount row kernels (Packed);
//! * [`Conv2dLayer`] — a 2-D convolution lowered to im2col patches that
//!   dispatch into the *same* packed row kernels, so conv and FC share one
//!   inner loop (`tbn::bitops::xnor_dot_words_range`);
//! * `Pool2d` / `GlobalPool` / `Flatten` — weightless shape plumbing that
//!   lets whole CNN specs (`arch::models`) run natively.
//!
//! [`lower_arch_spec`] converts a sequential `arch::ArchSpec` into a node
//! chain, inferring conv stride/padding from the spec's activation shapes
//! and inserting pooling nodes where consecutive specs imply spatial
//! reduction.  Branching specs (ResNet residuals, PointNet T-Nets) are
//! rejected with an error.  `nn::Engine` executes the chain.

mod conv;
mod fc;

pub use conv::Conv2dLayer;
pub use fc::FcLayer;

use super::layer_resident_bytes;
use super::packed::{PackedLayer, PackedLayout};
use crate::arch::{ArchSpec, Kind};
use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord, WeightPayload};
use crate::tensor::BitVec;
use crate::util::Rng;

/// Pooling flavor for the weightless pool nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Avg,
    Max,
}

/// Reusable scratch buffers shared by the packed FC and conv kernels, so a
/// batch (or a serve worker) allocates them once:
///
/// * `words` — packed sign bits of the current activation / im2col patch;
/// * `patch` — f32 im2col staging buffer;
/// * `qi8` / `patch_i8` — layer-0 int8 input and its im2col staging;
/// * `batch_words` / `gammas` / `batch_out` — the batched packed path:
///   `B` packed activation-bit vectors side by side, their XNOR-Net
///   scales, and the per-batch output staging (conv scatters it back into
///   channel-major order).
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    pub words: Vec<u64>,
    pub patch: Vec<f32>,
    pub qi8: Vec<i8>,
    pub patch_i8: Vec<i8>,
    pub batch_words: Vec<u64>,
    pub gammas: Vec<f32>,
    pub batch_out: Vec<f32>,
}

/// One node of the inference layer graph.  Activations flow through as flat
/// f32 vectors; conv/pool nodes interpret them channel-major `(c, h, w)`.
#[derive(Debug, Clone)]
pub enum Node {
    Fc(FcLayer),
    Conv2d(Conv2dLayer),
    /// Square-window pool with window = stride = `f` over a `(c, h, w)` map
    /// (`h` and `w` must be multiples of `f`).
    Pool2d { kind: PoolKind, c: usize, h: usize, w: usize, f: usize },
    /// Pool over all spatial/token positions: `(c, positions)` -> `(c,)`.
    GlobalPool { kind: PoolKind, c: usize, positions: usize },
    /// Shape bookkeeping only: activations are already flat.
    Flatten { len: usize },
}

impl Node {
    pub fn name(&self) -> &str {
        match self {
            Node::Fc(l) => &l.record.name,
            Node::Conv2d(l) => &l.record.name,
            Node::Pool2d { .. } => "pool2d",
            Node::GlobalPool { .. } => "global_pool",
            Node::Flatten { .. } => "flatten",
        }
    }

    pub fn in_len(&self) -> usize {
        match self {
            Node::Fc(l) => l.n,
            Node::Conv2d(l) => l.in_len(),
            Node::Pool2d { c, h, w, .. } => c * h * w,
            Node::GlobalPool { c, positions, .. } => c * positions,
            Node::Flatten { len } => *len,
        }
    }

    pub fn out_len(&self) -> usize {
        match self {
            Node::Fc(l) => l.m,
            Node::Conv2d(l) => l.out_len(),
            Node::Pool2d { c, h, w, f, .. } => c * (h / f) * (w / f),
            Node::GlobalPool { c, .. } => *c,
            Node::Flatten { len } => *len,
        }
    }

    /// Weight-bearing nodes (the ones ReLU and packing apply to).
    pub fn is_weight(&self) -> bool {
        matches!(self, Node::Fc(_) | Node::Conv2d(_))
    }

    /// The TBNZ record behind a weight node.
    pub fn record(&self) -> Option<&LayerRecord> {
        match self {
            Node::Fc(l) => Some(l.record.as_ref()),
            Node::Conv2d(l) => Some(l.record.as_ref()),
            _ => None,
        }
    }

    /// Weight bytes resident on the reference path (sub-bit tiles stay
    /// packed); weightless nodes are free.
    pub fn resident_bytes_reference(&self) -> usize {
        self.record().map(layer_resident_bytes).unwrap_or(0)
    }

    /// Scratch staging bytes this node's *packed* batch-1 forward holds
    /// live on top of weights and in/out activations: a packed conv stages
    /// the whole binarized im2col map (`area` packed patch vectors), its
    /// per-position gammas and a position-major output copy; a packed FC
    /// stages one packed activation vector.  `Engine::peak_memory_bytes`
    /// adds this term for nodes that run packed.
    pub fn packed_scratch_bytes(&self) -> usize {
        match self {
            Node::Fc(l) => 8 * l.n.div_ceil(64).max(1),
            Node::Conv2d(c) => {
                let area = c.h_out * c.w_out;
                let stride = c.patch_len().div_ceil(64).max(1);
                8 * area * stride + 4 * area + 4 * area * (c.co / c.groups)
            }
            _ => 0,
        }
    }

    /// Build the packed per-layer state for a weight node (`None` for
    /// weightless nodes) under the given weight layout.
    pub(crate) fn build_packed(&self, layout: PackedLayout)
                               -> Result<Option<PackedLayer>, String> {
        match self {
            Node::Fc(l) => l.build_packed(layout).map(Some),
            Node::Conv2d(l) => l.build_packed(layout).map(Some),
            _ => Ok(None),
        }
    }

    /// Reference (f32) forward of this node.
    pub fn forward_reference(&self, x: &[f32], relu: bool, scratch: &mut Scratch) -> Vec<f32> {
        match self {
            Node::Fc(l) => l.forward_reference(x, relu),
            Node::Conv2d(l) => l.forward_reference(x, relu, scratch),
            Node::Pool2d { kind, c, h, w, f } => pool2d(*kind, *c, *h, *w, *f, x),
            Node::GlobalPool { kind, c, positions } => global_pool(*kind, *c, *positions, x),
            Node::Flatten { .. } => x.to_vec(),
        }
    }
}

fn pool2d(kind: PoolKind, c: usize, h: usize, w: usize, f: usize, x: &[f32]) -> Vec<f32> {
    debug_assert!(f > 0 && h % f == 0 && w % f == 0);
    debug_assert_eq!(x.len(), c * h * w);
    let (ho, wo) = (h / f, w / f);
    let mut y = vec![0.0f32; c * ho * wo];
    for ch in 0..c {
        let plane = &x[ch * h * w..(ch + 1) * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = match kind {
                    PoolKind::Avg => 0.0f32,
                    PoolKind::Max => f32::NEG_INFINITY,
                };
                for ky in 0..f {
                    for kx in 0..f {
                        let v = plane[(oy * f + ky) * w + ox * f + kx];
                        match kind {
                            PoolKind::Avg => acc += v,
                            PoolKind::Max => acc = acc.max(v),
                        }
                    }
                }
                if kind == PoolKind::Avg {
                    acc /= (f * f) as f32;
                }
                y[(ch * ho + oy) * wo + ox] = acc;
            }
        }
    }
    y
}

fn global_pool(kind: PoolKind, c: usize, positions: usize, x: &[f32]) -> Vec<f32> {
    debug_assert!(positions > 0);
    debug_assert_eq!(x.len(), c * positions);
    (0..c)
        .map(|ch| {
            let plane = &x[ch * positions..(ch + 1) * positions];
            match kind {
                PoolKind::Avg => plane.iter().sum::<f32>() / positions as f32,
                PoolKind::Max => plane.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// ArchSpec lowering
// ---------------------------------------------------------------------------

/// Options for lowering an `arch::ArchSpec` into a native layer graph.
#[derive(Debug, Clone)]
pub struct LowerOptions {
    /// Input tensor as `(channels, height, width)`; use `(c, n, 1)` for
    /// point-cloud / token inputs.
    pub input: (usize, usize, usize),
    /// Tiles per layer for the synthesized Tiled payloads (layers whose
    /// param count `p` does not divide fall back to 1-bit Bwnn, mirroring
    /// the exporter).
    pub p: usize,
    pub alpha_mode: AlphaMode,
    /// Seed for the synthesized weights: the graph structure is exact, the
    /// weights are drawn (no trained conv checkpoints exist natively yet).
    pub seed: u64,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions {
            input: (3, 32, 32),
            p: 4,
            alpha_mode: AlphaMode::PerTile,
            seed: 0,
        }
    }
}

fn isqrt(x: usize) -> usize {
    (x as f64).sqrt().round() as usize
}

/// Synthesize a payload for `params` drawn weights: Tiled at `p` when it
/// divides, else 1-bit Bwnn (the exporter's binarize fallback).
fn synth_payload(params: usize, opts: &LowerOptions, rng: &mut Rng) -> WeightPayload {
    let w = rng.normal_vec(params, 1.0);
    if opts.p > 1 && params % opts.p == 0 {
        WeightPayload::Tiled {
            p: opts.p,
            tile: tile_from_weights(&w, opts.p),
            alphas: alphas_from(&w, opts.p, opts.alpha_mode),
        }
    } else {
        WeightPayload::Bwnn {
            bits: BitVec::from_signs(&w),
            alpha: w.iter().map(|x| x.abs()).sum::<f32>() / params.max(1) as f32,
        }
    }
}

/// Insert pooling so the current `(c, h, w)` activation matches the next
/// layer's expected flat input length `want`.
fn reconcile(
    nodes: &mut Vec<Node>,
    c: &mut usize,
    h: &mut usize,
    w: &mut usize,
    want: usize,
    at: &str,
) -> Result<(), String> {
    let cur = *c * *h * *w;
    if cur == want {
        return Ok(());
    }
    if want == *c && *h * *w > 1 {
        nodes.push(Node::GlobalPool { kind: PoolKind::Avg, c: *c, positions: *h * *w });
        *h = 1;
        *w = 1;
        return Ok(());
    }
    if want % *c == 0 {
        let next_pos = want / *c;
        let cur_pos = *h * *w;
        if next_pos > 0 && cur_pos % next_pos == 0 {
            let factor = cur_pos / next_pos;
            let f = isqrt(factor);
            if f > 1 && f * f == factor && *h % f == 0 && *w % f == 0 {
                nodes.push(Node::Pool2d { kind: PoolKind::Avg, c: *c, h: *h, w: *w, f });
                *h /= f;
                *w /= f;
                return Ok(());
            }
        }
    }
    Err(format!(
        "{at}: cannot reconcile activation ({c} x {h} x {w} = {cur}) with expected \
         input {want} — non-sequential spec (residual/branching) or unsupported pooling"
    ))
}

/// Infer `(stride, pad_lo, pad_hi)` mapping `h_in -> h_out` with kernel `k`
/// under the standard floor conv arithmetic
/// `h_out = (h_in + pad_lo + pad_hi - k) / s + 1`.
fn infer_stride_pad(h_in: usize, h_out: usize, k: usize)
                    -> Option<(usize, usize, usize)> {
    for s in 1..=8usize {
        for pad_lo in 0..=k {
            for pad_hi in [pad_lo, pad_lo + 1] {
                let padded = h_in + pad_lo + pad_hi;
                if padded < k {
                    continue;
                }
                if (padded - k) / s + 1 == h_out {
                    return Some((s, pad_lo, pad_hi));
                }
            }
        }
    }
    None
}

/// Lower a sequential `arch::ArchSpec` into a native layer-graph node chain.
///
/// Supported: plain conv stacks (square spatial maps, symmetric or
/// "same"-style asymmetric padding, grouped/depthwise convs), token-wise FC
/// layers (`fc_tok`, lowered to 1x1 convs over the token axis — PointNet's
/// shared MLPs), FC heads (global/spatial pooling plus a `Flatten` are
/// inserted automatically), and `Kind::Other` records (skipped — they carry
/// no MACs).  Branching specs (ResNet residual/downsample forks, T-Nets)
/// return an error from the shape reconciliation.
pub fn lower_arch_spec(spec: &ArchSpec, opts: &LowerOptions) -> Result<Vec<Node>, String> {
    let mut rng = Rng::new(opts.seed ^ 0x7B1E5);
    let (mut c, mut h, mut w) = opts.input;
    if c * h * w == 0 {
        return Err(format!("{}: empty lowering input", spec.name));
    }
    let mut nodes: Vec<Node> = Vec::new();
    for l in &spec.layers {
        let at = format!("{}::{}", spec.name, l.name);
        match l.kind {
            Kind::Other => continue,
            Kind::Conv { co, ci, kh, kw } => {
                reconcile(&mut nodes, &mut c, &mut h, &mut w, l.in_act, &at)?;
                if ci == 0 || c % ci != 0 {
                    return Err(format!("{at}: weight ci {ci} does not divide {c} channels"));
                }
                let groups = c / ci;
                if co % groups != 0 {
                    return Err(format!("{at}: co {co} not a multiple of {groups} groups"));
                }
                if l.out_act % co != 0 {
                    return Err(format!("{at}: out_act {} not a multiple of co {co}", l.out_act));
                }
                let area = l.out_act / co;
                let (h_out, w_out) = if w == 1 {
                    (area, 1)
                } else {
                    let s = isqrt(area);
                    if s * s != area {
                        return Err(format!("{at}: non-square output area {area}"));
                    }
                    (s, s)
                };
                let (stride, pad_lo, _pad_hi) = infer_stride_pad(h, h_out, kh)
                    .ok_or_else(|| {
                        format!("{at}: no stride/padding maps {h} -> {h_out} with k={kh}")
                    })?;
                let record = LayerRecord {
                    name: l.name.clone(),
                    shape: vec![co, ci, kh, kw],
                    payload: synth_payload(l.params, opts, &mut rng),
                };
                let conv = Conv2dLayer::with_output(
                    record, (c, h, w), stride, pad_lo, (h_out, w_out), groups)?;
                nodes.push(Node::Conv2d(conv));
                c = co;
                h = h_out;
                w = w_out;
            }
            Kind::Fc { co, ci } => {
                if ci == 0 || l.in_act % ci != 0 {
                    return Err(format!("{at}: in_act {} not a multiple of ci {ci}", l.in_act));
                }
                let tokens = l.in_act / ci;
                reconcile(&mut nodes, &mut c, &mut h, &mut w, l.in_act, &at)?;
                let record_payload = synth_payload(l.params, opts, &mut rng);
                if tokens == 1 {
                    // plain FC over the flattened activation
                    if h * w > 1 {
                        nodes.push(Node::Flatten { len: ci });
                    }
                    let record = LayerRecord {
                        name: l.name.clone(),
                        shape: vec![co, ci],
                        payload: record_payload,
                    };
                    nodes.push(Node::Fc(FcLayer::from_record(record)?));
                    c = co;
                    h = 1;
                    w = 1;
                } else {
                    // token-wise shared MLP: a 1x1 conv over the token axis
                    if c != ci || h * w != tokens {
                        return Err(format!(
                            "{at}: token FC expects ({ci} ch x {tokens} pos), have \
                             ({c} x {h} x {w}) — token-mixing layers are unsupported"
                        ));
                    }
                    let record = LayerRecord {
                        name: l.name.clone(),
                        shape: vec![co, ci, 1, 1],
                        payload: record_payload,
                    };
                    let conv = Conv2dLayer::with_output(
                        record, (c, h, w), 1, 0, (h, w), 1)?;
                    nodes.push(Node::Conv2d(conv));
                    c = co;
                }
            }
        }
    }
    if nodes.is_empty() {
        return Err(format!("{}: nothing to lower", spec.name));
    }
    Ok(nodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pool2d_avg_and_max() {
        // one channel, 4x4, f=2
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let avg = pool2d(PoolKind::Avg, 1, 4, 4, 2, &x);
        assert_eq!(avg, vec![2.5, 4.5, 10.5, 12.5]);
        let max = pool2d(PoolKind::Max, 1, 4, 4, 2, &x);
        assert_eq!(max, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn pool2d_channel_major() {
        // two channels pool independently
        let mut x = vec![1.0f32; 4];
        x.extend(vec![3.0f32; 4]);
        let y = pool2d(PoolKind::Avg, 2, 2, 2, 2, &x);
        assert_eq!(y, vec![1.0, 3.0]);
    }

    #[test]
    fn global_pool_avg_and_max() {
        let x = vec![1.0f32, 2.0, 3.0, -1.0, -2.0, -3.0];
        assert_eq!(global_pool(PoolKind::Avg, 2, 3, &x), vec![2.0, -2.0]);
        assert_eq!(global_pool(PoolKind::Max, 2, 3, &x), vec![3.0, -1.0]);
    }

    #[test]
    fn infer_stride_pad_paper_cases() {
        // resnet stem on cifar: 3x3, 32 -> 32 => stride 1 pad 1
        assert_eq!(infer_stride_pad(32, 32, 3), Some((1, 1, 1)));
        // imagenet stem: 7x7, 224 -> 112 => stride 2 (minimal pads: 2 + 3)
        assert_eq!(infer_stride_pad(224, 112, 7), Some((2, 2, 3)));
        // vgg downsampling conv: 3x3, 32 -> 16 => stride 2, trailing pad 1
        assert_eq!(infer_stride_pad(32, 16, 3), Some((2, 0, 1)));
        // 1x1 downsample, 32 -> 16 => stride 2 pad 0
        assert_eq!(infer_stride_pad(32, 16, 1), Some((2, 0, 0)));
        // convmixer depthwise: 8x8 "same" => asymmetric (3, 4)
        assert_eq!(infer_stride_pad(32, 32, 8), Some((1, 3, 4)));
        // impossible mapping: upsampling beyond what padding can reach
        assert_eq!(infer_stride_pad(32, 1, 3), None);
    }

    #[test]
    fn node_shape_bookkeeping() {
        let n = Node::Pool2d { kind: PoolKind::Avg, c: 8, h: 4, w: 4, f: 2 };
        assert_eq!((n.in_len(), n.out_len()), (128, 32));
        assert!(!n.is_weight());
        assert_eq!(n.resident_bytes_reference(), 0);
        let g = Node::GlobalPool { kind: PoolKind::Max, c: 16, positions: 64 };
        assert_eq!((g.in_len(), g.out_len()), (1024, 16));
        let f = Node::Flatten { len: 40 };
        assert_eq!((f.in_len(), f.out_len()), (40, 40));
        let mut s = Scratch::default();
        assert_eq!(f.forward_reference(&[1.0; 40], false, &mut s), vec![1.0; 40]);
    }

    #[test]
    fn synth_payload_tiles_when_divisible() {
        let mut rng = Rng::new(1);
        let opts = LowerOptions::default();
        match synth_payload(64, &opts, &mut rng) {
            WeightPayload::Tiled { p, .. } => assert_eq!(p, 4),
            other => panic!("expected tiled, got {other:?}"),
        }
        match synth_payload(63, &opts, &mut rng) {
            WeightPayload::Bwnn { .. } => {}
            other => panic!("expected bwnn fallback, got {other:?}"),
        }
    }
}
