#!/usr/bin/env python3
"""Recompute runs/<id>.json bit_width under the weight-only convention.

The bit-width column is deterministic given the manifest's parameter table
(independent of training), so records written by an older binary can be
patched in place: tiled -> q + 32*n_alphas bits; bwnn -> n + 32; fp -> 32n,
summed over role=="weight" parameters only.
"""

import json
import os
import sys


def weight_bits(param: dict) -> tuple:
    import math
    n = math.prod(param["shape"])
    q = param.get("q", 0)
    if param["quant"] == "tiled":
        return q + 32 * param.get("n_alphas", 1), n
    if param["quant"] == "bwnn":
        return n + 32, n
    return 32 * n, n


def main(artifacts="artifacts", runs="runs"):
    with open(os.path.join(artifacts, "manifest.json")) as f:
        manifest = json.load(f)
    by_id = {e["id"]: e for e in manifest["experiments"]}
    patched = 0
    for fname in os.listdir(runs):
        if not fname.endswith(".json"):
            continue
        exp_id = fname[:-5]
        if exp_id not in by_id:
            continue
        path = os.path.join(runs, fname)
        with open(path) as f:
            rec = json.load(f)
        bits = 0
        params = 0
        for p in by_id[exp_id]["params"]:
            if p["role"] != "weight":
                continue
            b, n = weight_bits(p)
            bits += b
            params += n
        rec["bit_width"] = bits / max(params, 1)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        patched += 1
    print(f"patched {patched} run records")


if __name__ == "__main__":
    main(*sys.argv[1:])
