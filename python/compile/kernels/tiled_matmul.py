"""Pallas tile-reusing fully-connected kernel (paper section 5.2, TPU-adapted).

The paper's Triton kernel keeps a single ``m x q`` tile resident and wraps the
weight pointer modulo ``q`` while sweeping an ``m x n`` matmul.  On TPU the
analogous resource is VMEM: this kernel's weight-side VMEM footprint is the
``q``-length tile plus ``p`` alpha scalars instead of the full ``N = p*q``
weight matrix.  Each grid step reconstructs its weight block in-register from
the *same* tile ref (constant index_map -> Mosaic keeps one copy resident),
replacing Triton's modular pointer arithmetic with a gather over
``flat_index mod q``.

Must be lowered with ``interpret=True``: the CPU PJRT client (xla_extension
0.5.1) cannot execute Mosaic custom-calls.  Real-TPU efficiency is estimated
analytically in DESIGN.md section 8 / EXPERIMENTS.md section Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_rows(m: int, target: int = 128) -> int:
    """Largest divisor of ``m`` that is <= target (output rows per grid step)."""
    best = 1
    for d in range(1, min(m, target) + 1):
        if m % d == 0:
            best = d
    return best


def _kernel(x_ref, t_ref, a_ref, o_ref, *, n: int, q: int, bm: int, n_alphas: int):
    """One output block: rows [i*bm, (i+1)*bm) of y = x @ B-hat^T.

    The weight block is reconstructed from the tile:
      B-hat[r, c] = t[(r*n + c) mod q] * alpha[(r*n + c) // q]
    """
    i = pl.program_id(0)
    rows = i * bm + jnp.arange(bm, dtype=jnp.int32)            # (bm,)
    cols = jnp.arange(n, dtype=jnp.int32)                      # (n,)
    flat = rows[:, None] * n + cols[None, :]                   # (bm, n)
    tile = t_ref[...]                                          # (q,) - the only weight-side load
    w = jnp.take(tile, flat % q, axis=0)                       # (bm, n) in-register expansion
    if n_alphas == 1:
        w = w * a_ref[0]
    else:
        alphas = a_ref[...]                                    # (p,)
        w = w * jnp.take(alphas, flat // q, axis=0)
    o_ref[...] = jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("out_features", "in_features", "interpret", "block_rows"))
def tiled_matmul(
    x: jnp.ndarray,
    t: jnp.ndarray,
    alphas: jnp.ndarray,
    out_features: int,
    in_features: int,
    interpret: bool = True,
    block_rows: int | None = None,
) -> jnp.ndarray:
    """y = x @ expand(t, alphas)^T without materializing the weight matrix.

    Args:
      x: (batch, in_features) activations.
      t: (q,) binary tile (+-1 floats).
      alphas: (p,) per-tile or (1,) layer-wide scalars.
      out_features/in_features: weight matrix shape (m, n); m*n == p*q.
      interpret: keep True for CPU PJRT (see module docstring).
      block_rows: override the output-row block size (must divide m).

    Returns:
      (batch, out_features) float32.
    """
    m, n = out_features, in_features
    q = t.shape[0]
    n_alphas = alphas.shape[0]
    assert x.shape[-1] == n, f"x last dim {x.shape[-1]} != in_features {n}"
    assert (m * n) % q == 0, f"tile length {q} must divide layer size {m * n}"
    bm = block_rows if block_rows is not None else _block_rows(m)
    assert m % bm == 0
    batch = x.shape[0]

    kernel = functools.partial(_kernel, n=n, q=q, bm=bm, n_alphas=n_alphas)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            # x: whole activation block every step (constant index_map ->
            # resident in VMEM once, not re-fetched per grid step).
            pl.BlockSpec((batch, n), lambda i: (0, 0)),
            # the tile: THE point of the kernel - same (q,) block for every
            # output block; weight-side HBM->VMEM traffic is q elements total.
            pl.BlockSpec((q,), lambda i: (0,)),
            pl.BlockSpec((n_alphas,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((batch, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, m), jnp.float32),
        interpret=interpret,
    )(x, t, alphas)


def vmem_bytes_tiled(batch: int, m: int, n: int, q: int, p: int, bm: int | None = None) -> dict:
    """Analytic VMEM footprint of one grid step of the tiled kernel (f32).

    Used by the performance model (EXPERIMENTS.md section Perf) to compare
    against a standard blocked matmul, which must stream all m*n weights.
    """
    bm = bm if bm is not None else _block_rows(m)
    return {
        "x": batch * n * 4,
        "tile": q * 4,
        "alphas": p * 4,
        "w_block_scratch": bm * n * 4,
        "out": batch * bm * 4,
        "weight_stream_total": q * 4,          # vs m*n*4 for a dense kernel
        "dense_weight_stream_total": m * n * 4,
    }
