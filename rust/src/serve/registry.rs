//! Multi-model registry: one process serves many models, swappable live.
//!
//! The tile-resident packed layout keeps `O(q)` weight bytes resident per
//! tiled layer, so dozens of models fit where one expanded binary model did
//! — the registry is what turns that residency headroom into a serving
//! feature.  Each entry owns a full [`Server`] worker pool (bounded queue,
//! batching, per-model [`ServerStats`]), published behind an `Arc` in an
//! `RwLock`ed map.
//!
//! **Hot swap** is an `Arc` swap: [`ModelRegistry::swap`] replaces the
//! entry's `Arc<Server>` under the write lock and bumps the entry's
//! generation counter.  Readers ([`ModelRegistry::get`]) clone the `Arc`
//! under the read lock and then operate lock-free, so an in-flight request
//! always runs against exactly the server it resolved — a swap can never
//! tear a model mid-request.  The old pool drains gracefully: when its last
//! `Arc` holder finishes, `Server::drop` closes the queue, the workers
//! drain what was accepted, and the threads join.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use super::{Server, ServerStats};

struct Entry {
    server: Arc<Server>,
    /// Bumped on every [`ModelRegistry::swap`]; echoed in `/infer`
    /// responses so clients (and the torn-model test) can attribute an
    /// answer to the exact model version that produced it.
    generation: usize,
}

/// One model's public registry row.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub in_dim: usize,
    pub generation: usize,
}

/// Name -> serving pool map with live (`Arc`-swap) model replacement.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Entry>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Add (or replace) a model under `name`.  Returns the entry's
    /// generation: 0 for a new name, `previous + 1` when replacing — so
    /// `register` on an existing name is exactly a [`swap`](Self::swap).
    pub fn register(&self, name: &str, server: Server) -> usize {
        let mut m = self.models.write().unwrap();
        let generation = m.get(name).map_or(0, |e| e.generation + 1);
        m.insert(name.to_string(), Entry { server: Arc::new(server), generation });
        generation
    }

    /// Hot-swap the model behind `name`.  Errors if the name was never
    /// registered (a swap targets a live model; use
    /// [`register`](Self::register) to introduce one).  In-flight requests
    /// keep the old `Arc<Server>` and complete against it; the old pool
    /// drains and joins when its last holder drops it.
    pub fn swap(&self, name: &str, server: Server) -> Result<usize, String> {
        let mut m = self.models.write().unwrap();
        match m.get_mut(name) {
            Some(e) => {
                e.generation += 1;
                e.server = Arc::new(server);
                Ok(e.generation)
            }
            None => Err(format!("swap: unknown model {name:?}")),
        }
    }

    /// Resolve a model for one request: the returned `Arc` pins the exact
    /// server (and therefore model version) for the request's lifetime.
    pub fn get(&self, name: &str) -> Option<(Arc<Server>, usize)> {
        let m = self.models.read().unwrap();
        m.get(name).map(|e| (e.server.clone(), e.generation))
    }

    /// The single registered model, if exactly one — lets `/infer` omit
    /// the `model` field on single-model servers.
    pub fn sole(&self) -> Option<(String, Arc<Server>, usize)> {
        let m = self.models.read().unwrap();
        if m.len() == 1 {
            m.iter()
                .next()
                .map(|(n, e)| (n.clone(), e.server.clone(), e.generation))
        } else {
            None
        }
    }

    /// Drop a model; its pool drains once in-flight holders release it.
    pub fn remove(&self, name: &str) -> bool {
        self.models.write().unwrap().remove(name).is_some()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registry listing, name-sorted (what `GET /models` serves).
    pub fn infos(&self) -> Vec<ModelInfo> {
        let m = self.models.read().unwrap();
        let mut v: Vec<ModelInfo> = m
            .iter()
            .map(|(n, e)| ModelInfo {
                name: n.clone(),
                in_dim: e.server.in_dim(),
                generation: e.generation,
            })
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Summed served/rejected across every model — the one-line aggregate
    /// for the periodic serve stats line.
    pub fn totals(&self) -> (usize, usize) {
        let m = self.models.read().unwrap();
        m.values().fold((0, 0), |(served, rejected), e| {
            let s = e.server.stats();
            (served + s.served, rejected + s.rejected)
        })
    }

    /// Per-model stats snapshot, name-sorted (the `GET /stats` rows and
    /// the final drain report).
    pub fn stats(&self) -> Vec<(String, usize, ServerStats)> {
        let m = self.models.read().unwrap();
        let mut v: Vec<(String, usize, ServerStats)> = m
            .iter()
            .map(|(n, e)| (n.clone(), e.generation, e.server.stats()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{BatchPolicy, OverflowPolicy, ServePolicy};
    use std::time::Duration;

    struct ConstModel {
        dim: usize,
        v: f32,
    }

    impl crate::serve::BatchModel for ConstModel {
        fn infer_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
            xs.iter().map(|_| vec![self.v, self.v]).collect()
        }

        fn in_dim(&self) -> usize {
            self.dim
        }
    }

    fn pool(v: f32) -> Server {
        Server::start_pool_with(
            Arc::new(ConstModel { dim: 3, v }),
            ServePolicy {
                batch: BatchPolicy { max_batch: 4, window: Duration::from_micros(50) },
                queue_cap: 16,
                on_full: OverflowPolicy::Block,
                ..ServePolicy::default()
            },
            1,
        )
    }

    #[test]
    fn register_get_and_list() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.register("a", pool(1.0)), 0);
        assert_eq!(reg.register("b", pool(2.0)), 0);
        assert_eq!(reg.len(), 2);
        let (srv, generation) = reg.get("a").expect("registered");
        assert_eq!(generation, 0);
        assert_eq!(srv.in_dim(), 3);
        assert_eq!(srv.infer(vec![0.0; 3]).unwrap().y, vec![1.0, 1.0]);
        let names: Vec<String> = reg.infos().into_iter().map(|i| i.name).collect();
        assert_eq!(names, vec!["a", "b"]);
        assert!(reg.get("missing").is_none());
        assert!(reg.sole().is_none(), "two models -> no sole default");
        assert!(reg.remove("b"));
        let (name, _, _) = reg.sole().expect("one model left");
        assert_eq!(name, "a");
    }

    #[test]
    fn swap_bumps_generation_and_old_arc_survives() {
        let reg = ModelRegistry::new();
        reg.register("m", pool(1.0));
        let (old, g0) = reg.get("m").unwrap();
        assert_eq!(g0, 0);
        assert!(reg.swap("missing", pool(9.0)).is_err());
        assert_eq!(reg.swap("m", pool(2.0)).unwrap(), 1);
        // the pinned old Arc still serves the old model (no torn state)
        assert_eq!(old.infer(vec![0.0; 3]).unwrap().y, vec![1.0, 1.0]);
        let (new, g1) = reg.get("m").unwrap();
        assert_eq!(g1, 1);
        assert_eq!(new.infer(vec![0.0; 3]).unwrap().y, vec![2.0, 2.0]);
        // re-register on a live name is a swap too
        assert_eq!(reg.register("m", pool(3.0)), 2);
    }

    #[test]
    fn stats_are_per_model() {
        let reg = ModelRegistry::new();
        reg.register("x", pool(1.0));
        reg.register("y", pool(2.0));
        let (srv, _) = reg.get("x").unwrap();
        for _ in 0..5 {
            srv.infer(vec![0.0; 3]).unwrap();
        }
        let stats = reg.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "x");
        assert_eq!(stats[0].2.served, 5);
        assert_eq!(stats[1].2.served, 0);
    }
}
