//! Bit-packed XNOR-popcount inference fast path (§5 deployment kernels).
//!
//! The reference engine expands each tile lazily and multiplies in f32.  The
//! fast path instead materializes, **once at model-load time**, every FC
//! layer's expanded sign matrix as `u64`-packed rows plus per-row runs of
//! constant alpha, then runs the deployment forward of the BNN literature
//! (Kim & Smaragdis 2016; XNOR-Net):
//!
//! * layer 0 consumes the raw f32 input through the reference Algorithm 1
//!   kernels (first layers stay higher precision, the standard BNN practice);
//! * every later layer sign-binarizes its input activations (`h > 0`, the
//!   crate-wide `BitVec::from_signs` convention) with an XNOR-Net scale
//!   `gamma = mean |h|`, and computes `y = gamma * sum_runs alpha_run *
//!   xnor_popcount(row_bits, x_bits)` — pure word ops plus one multiply per
//!   alpha run.
//!
//! Because hidden activations are quantized, this computes a *different
//! function* from `MlpEngine::forward` on the `Reference` path.  Its oracle
//! is [`forward_quantized_reference`]: the same math in plain f32 over the
//! expanded weights, which `rust/tests/packed_parity.rs` pins the bit
//! kernels against (agreement up to f32 accumulation order and sign
//! tie-breaks at exactly-zero activations).

use crate::tbn::bitops::xnor_dot_words_range;
use crate::tbn::{LayerRecord, TbnzModel, WeightPayload};
use super::{fc_fp_forward, fc_layer_forward};

/// Which implementation serves `MlpEngine::forward`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EnginePath {
    /// Expand-and-multiply f32 path (the oracle; exact Algorithm 1 math).
    #[default]
    Reference,
    /// Bit-packed XNOR-popcount path with sign-binarized hidden activations.
    Packed,
}

/// One run of constant alpha inside a packed row: `[start, start + len)`
/// bits scaled by `alpha`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlphaRun {
    pub start: u32,
    pub len: u32,
    pub alpha: f32,
}

/// Payload of one packed layer.
#[derive(Debug, Clone, PartialEq)]
pub enum PackedPayload {
    /// Binary-weight layer: expanded sign rows packed into `u64` words.
    Bits {
        /// Words per row (`ceil(n / 64)`, at least 1).
        words_per_row: usize,
        /// `m * words_per_row` words; row `i` starts at `i * words_per_row`.
        /// Bits at positions `>= n` within a row are zero.
        row_words: Vec<u64>,
        /// Constant-alpha runs of all rows, concatenated.
        runs: Vec<AlphaRun>,
        /// Row `i` owns `runs[run_offsets[i] .. run_offsets[i + 1]]`.
        run_offsets: Vec<u32>,
    },
    /// Full-precision layer: dense row-major weights (nothing to pack).
    Dense(Vec<f32>),
}

/// One FC layer prepared for the packed forward.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    pub name: String,
    /// Output features.
    pub m: usize,
    /// Input features.
    pub n: usize,
    pub payload: PackedPayload,
}

fn pack_rows<F: Fn(usize) -> bool>(m: usize, n: usize, bit_at_flat: F) -> (usize, Vec<u64>) {
    let wpr = n.div_ceil(64).max(1);
    let mut words = vec![0u64; m * wpr];
    for i in 0..m {
        let base = i * wpr;
        let row_start = i * n;
        for j in 0..n {
            if bit_at_flat(row_start + j) {
                words[base + j / 64] |= 1u64 << (j % 64);
            }
        }
    }
    (wpr, words)
}

impl PackedLayer {
    /// Pack one TBNZ layer record (2-D FC layers only).
    pub fn from_record(l: &LayerRecord) -> Result<PackedLayer, String> {
        if l.shape.len() != 2 {
            return Err(format!("{}: packed engine requires 2-D FC layers", l.name));
        }
        let (m, n) = (l.shape[0], l.shape[1]);
        let payload = match &l.payload {
            WeightPayload::Fp(w) => {
                if w.len() != m * n {
                    return Err(format!("{}: fp payload size mismatch", l.name));
                }
                PackedPayload::Dense(w.clone())
            }
            WeightPayload::Bwnn { bits, alpha } => {
                if bits.len() != m * n {
                    return Err(format!("{}: bwnn payload size mismatch", l.name));
                }
                let (words_per_row, row_words) = pack_rows(m, n, |flat| bits.get_bit(flat));
                let runs = (0..m)
                    .map(|_| AlphaRun { start: 0, len: n as u32, alpha: *alpha })
                    .collect();
                let run_offsets = (0..=m as u32).collect();
                PackedPayload::Bits { words_per_row, row_words, runs, run_offsets }
            }
            WeightPayload::Tiled { tile, alphas, .. } => {
                let q = tile.len();
                if q == 0 || (m * n) % q != 0 || alphas.is_empty() {
                    return Err(format!("{}: invalid tiled payload (q={q})", l.name));
                }
                let (words_per_row, row_words) = pack_rows(m, n, |flat| tile.get_bit(flat % q));
                let single = alphas.len() == 1;
                let mut runs = Vec::new();
                let mut run_offsets = Vec::with_capacity(m + 1);
                run_offsets.push(0u32);
                for i in 0..m {
                    let row_start = i * n;
                    let mut j = 0usize;
                    while j < n {
                        let flat = row_start + j;
                        // run until the tile wraps (alpha can only change there)
                        let len = (q - flat % q).min(n - j);
                        let alpha = if single {
                            alphas[0]
                        } else {
                            alphas[(flat / q) % alphas.len()]
                        };
                        runs.push(AlphaRun { start: j as u32, len: len as u32, alpha });
                        j += len;
                    }
                    run_offsets.push(runs.len() as u32);
                }
                PackedPayload::Bits { words_per_row, row_words, runs, run_offsets }
            }
        };
        Ok(PackedLayer { name: l.name.clone(), m, n, payload })
    }

    /// Weight bytes resident for this layer on the packed path.
    pub fn resident_bytes(&self) -> usize {
        match &self.payload {
            PackedPayload::Bits { row_words, runs, run_offsets, .. } => {
                8 * row_words.len()
                    + std::mem::size_of::<AlphaRun>() * runs.len()
                    + 4 * run_offsets.len()
            }
            PackedPayload::Dense(w) => 4 * w.len(),
        }
    }

    /// Forward this layer over a sign-binarized input: `xw` holds the packed
    /// sign bits of the input activations (bits `>= n` zero) and `gamma` is
    /// their XNOR-Net scale.  The multiply count is one per alpha run.
    pub fn forward_binarized(&self, xw: &[u64], gamma: f32, relu: bool) -> Vec<f32> {
        let mut y = Vec::with_capacity(self.m);
        match &self.payload {
            PackedPayload::Bits { words_per_row, row_words, runs, run_offsets } => {
                for i in 0..self.m {
                    let row = &row_words[i * words_per_row..(i + 1) * words_per_row];
                    let mut acc = 0.0f32;
                    let (lo, hi) = (run_offsets[i] as usize, run_offsets[i + 1] as usize);
                    for run in &runs[lo..hi] {
                        let dot = xnor_dot_words_range(
                            row, xw, run.start as usize, run.len as usize);
                        acc += run.alpha * dot as f32;
                    }
                    let v = gamma * acc;
                    y.push(if relu { v.max(0.0) } else { v });
                }
            }
            PackedPayload::Dense(w) => {
                // fp weights against ±1 inputs: add or subtract each weight
                for i in 0..self.m {
                    let row = &w[i * self.n..(i + 1) * self.n];
                    let mut acc = 0.0f32;
                    for (j, &wj) in row.iter().enumerate() {
                        if (xw[j / 64] >> (j % 64)) & 1 == 1 {
                            acc += wj;
                        } else {
                            acc -= wj;
                        }
                    }
                    let v = gamma * acc;
                    y.push(if relu { v.max(0.0) } else { v });
                }
            }
        }
        y
    }
}

/// Sign-binarize an activation vector into `words` (bit j set iff
/// `h[j] > 0`, the `BitVec::from_signs` convention; tail bits zero) and
/// return the XNOR-Net activation scale `gamma = mean |h|`.
///
/// `words` is a scratch buffer so batch loops can reuse one allocation.
pub fn binarize_activations(h: &[f32], words: &mut Vec<u64>) -> f32 {
    let wpr = h.len().div_ceil(64).max(1);
    words.clear();
    words.resize(wpr, 0);
    let mut sum = 0.0f32;
    for (j, &v) in h.iter().enumerate() {
        sum += v.abs();
        if v > 0.0 {
            words[j / 64] |= 1u64 << (j % 64);
        }
    }
    if h.is_empty() {
        0.0
    } else {
        sum / h.len() as f32
    }
}

/// A whole model prepared for the packed forward. Layer 0 keeps its TBNZ
/// record (it runs on the raw f32 input through the reference kernels);
/// every later layer is bit-packed.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedModel {
    first: LayerRecord,
    rest: Vec<PackedLayer>,
}

impl PackedModel {
    /// Pack every FC layer of a TBNZ model. Fails on non-2-D layers or
    /// malformed payloads; shape-chain validation is `MlpEngine::new`'s job.
    pub fn from_tbnz(model: &TbnzModel) -> Result<PackedModel, String> {
        let Some(first) = model.layers.first() else {
            return Err("packed engine requires at least one layer".to_string());
        };
        if first.shape.len() != 2 {
            return Err(format!("{}: packed engine requires 2-D FC layers", first.name));
        }
        let rest = model.layers[1..]
            .iter()
            .map(PackedLayer::from_record)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(PackedModel { first: first.clone(), rest })
    }

    /// Packed layers after the f32 entry layer.
    pub fn packed_layers(&self) -> &[PackedLayer] {
        &self.rest
    }

    /// Weight bytes resident on the packed path (entry layer at its TBNZ
    /// residency + packed rows for the rest).
    pub fn resident_bytes(&self) -> usize {
        super::layer_resident_bytes(&self.first)
            + self.rest.iter().map(PackedLayer::resident_bytes).sum::<usize>()
    }

    /// Max memory at any layer on the packed path: that layer's resident
    /// weights (packed rows after layer 0) + f32 input/output activation
    /// buffers — the Table 6 "Max Memory Usage" model applied to the fast
    /// path's row storage.
    pub fn peak_memory_bytes(&self) -> usize {
        let first = super::layer_resident_bytes(&self.first)
            + 4 * (self.first.shape[0] + self.first.shape[1]);
        self.rest
            .iter()
            .map(|l| l.resident_bytes() + 4 * (l.m + l.n))
            .fold(first, usize::max)
    }

    /// Quantized deployment forward for one sample (see module docs).
    pub fn forward(&self, x: &[f32], relu_hidden: bool) -> Vec<f32> {
        let mut scratch = Vec::new();
        self.forward_with_scratch(x, relu_hidden, &mut scratch)
    }

    fn forward_with_scratch(&self, x: &[f32], relu_hidden: bool, xw: &mut Vec<u64>)
                            -> Vec<f32> {
        let mut h = fc_layer_forward(&self.first, x, relu_hidden && !self.rest.is_empty());
        for (k, layer) in self.rest.iter().enumerate() {
            let gamma = binarize_activations(&h, xw);
            let relu = relu_hidden && k + 1 < self.rest.len();
            h = layer.forward_binarized(xw, gamma, relu);
        }
        h
    }

    /// Batched quantized forward, layer-major: all samples pass through a
    /// layer before the next layer starts, so one layer's packed rows are
    /// touched consecutively (cache-warm across the batch) and the
    /// bit-packing scratch buffer is allocated once for the whole batch.
    /// Each sample still walks every row; a row-major blocked kernel is a
    /// ROADMAP item.  Results are bit-identical to per-sample [`Self::forward`].
    pub fn forward_batch(&self, xs: &[Vec<f32>], relu_hidden: bool) -> Vec<Vec<f32>> {
        let relu0 = relu_hidden && !self.rest.is_empty();
        let mut hs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| fc_layer_forward(&self.first, x, relu0))
            .collect();
        let mut xw = Vec::new();
        for (k, layer) in self.rest.iter().enumerate() {
            let relu = relu_hidden && k + 1 < self.rest.len();
            for h in hs.iter_mut() {
                let gamma = binarize_activations(h, &mut xw);
                *h = layer.forward_binarized(&xw, gamma, relu);
            }
        }
        hs
    }
}

/// f32 oracle of the quantized deployment forward: identical math to
/// [`PackedModel::forward`] — sign binarization, gamma scaling, expanded
/// dense multiply — with no bit tricks.  `Reference`-path engines serve this
/// from `MlpEngine::forward_quantized`, and the parity suite compares the
/// packed path against it.
pub fn forward_quantized_reference(model: &TbnzModel, x: &[f32], relu_hidden: bool)
                                   -> Vec<f32> {
    assert!(!model.layers.is_empty(), "empty model");
    let last = model.layers.len() - 1;
    let mut h = fc_layer_forward(&model.layers[0], x, relu_hidden && last > 0);
    for (li, layer) in model.layers.iter().enumerate().skip(1) {
        let gamma = if h.is_empty() {
            0.0
        } else {
            h.iter().map(|v| v.abs()).sum::<f32>() / h.len() as f32
        };
        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let w = layer.expand();
        let m = layer.shape[0];
        let mut y = fc_fp_forward(&w, &signs, m, false);
        let relu = relu_hidden && li < last;
        for v in y.iter_mut() {
            let s = gamma * *v;
            *v = if relu { s.max(0.0) } else { s };
        }
        h = y;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tbn::{alphas_from, tile_from_weights, AlphaMode};
    use crate::tensor::BitVec;
    use crate::util::Rng;

    fn tiled_record(name: &str, m: usize, n: usize, p: usize, mode: AlphaMode,
                    rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, mode),
            },
        }
    }

    fn bwnn_record(name: &str, m: usize, n: usize, rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Bwnn { bits: BitVec::from_signs(&w), alpha: 0.4 },
        }
    }

    #[test]
    fn binarize_matches_bitvec_convention() {
        let h = [0.5f32, -0.1, 0.0, 2.0, -3.0];
        let mut words = Vec::new();
        let gamma = binarize_activations(&h, &mut words);
        let v = BitVec::from_signs(&h);
        assert_eq!(&words[..], v.words());
        let want = h.iter().map(|x| x.abs()).sum::<f32>() / h.len() as f32;
        assert!((gamma - want).abs() < 1e-7);
    }

    #[test]
    fn binarize_empty_and_reuse() {
        let mut words = vec![u64::MAX; 4]; // stale scratch must be cleared
        assert_eq!(binarize_activations(&[], &mut words), 0.0);
        assert_eq!(words, vec![0u64]);
        let g = binarize_activations(&[1.0, 1.0], &mut words);
        assert_eq!(words, vec![0b11u64]);
        assert!((g - 1.0).abs() < 1e-7);
    }

    /// A packed Bwnn layer over ±1 inputs must equal the dense computation.
    #[test]
    fn bits_layer_matches_dense_on_signs() {
        let mut rng = Rng::new(31);
        let (m, n) = (7, 70); // non-multiple-of-64 width
        let rec = bwnn_record("l", m, n, &mut rng);
        let packed = PackedLayer::from_record(&rec).unwrap();
        let h = rng.normal_vec(n, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&h, &mut xw);
        let got = packed.forward_binarized(&xw, gamma, false);

        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let w = rec.expand();
        let want = fc_fp_forward(&w, &signs, m, false);
        for i in 0..m {
            assert!((got[i] - gamma * want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                    "row {i}: {} vs {}", got[i], gamma * want[i]);
        }
    }

    /// Tiled rows with per-tile alphas: alpha runs must follow the flat
    /// alpha index `(flat / q) % p` exactly.
    #[test]
    fn tiled_layer_alpha_runs_match_expansion() {
        let mut rng = Rng::new(32);
        // q = m*n/p = 5*12/4 = 15, so runs split mid-row
        let rec = tiled_record("t", 5, 12, 4, AlphaMode::PerTile, &mut rng);
        let packed = PackedLayer::from_record(&rec).unwrap();
        let h = rng.normal_vec(12, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&h, &mut xw);
        let got = packed.forward_binarized(&xw, gamma, false);

        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let want = fc_fp_forward(&rec.expand(), &signs, 5, false);
        for i in 0..5 {
            assert!((got[i] - gamma * want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                    "row {i}");
        }
    }

    #[test]
    fn packed_model_matches_reference_oracle() {
        let mut rng = Rng::new(33);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 48, 70, 4, AlphaMode::PerTile, &mut rng),
                bwnn_record("fc1", 33, 48, &mut rng),
                tiled_record("head", 10, 33, 2, AlphaMode::Single, &mut rng),
            ],
        };
        let packed = PackedModel::from_tbnz(&model).unwrap();
        for s in 0..4 {
            let mut r = Rng::new(100 + s);
            let x = r.normal_vec(70, 1.0);
            let a = packed.forward(&x, true);
            let b = forward_quantized_reference(&model, &x, true);
            assert_eq!(a.len(), b.len());
            for i in 0..a.len() {
                assert!((a[i] - b[i]).abs() < 1e-3 * b[i].abs().max(1.0),
                        "sample {s} out {i}: {} vs {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn forward_batch_equals_per_sample() {
        let mut rng = Rng::new(34);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 32, 65, 4, AlphaMode::PerTile, &mut rng),
                bwnn_record("head", 6, 32, &mut rng),
            ],
        };
        let packed = PackedModel::from_tbnz(&model).unwrap();
        let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(65, 1.0)).collect();
        let batch = packed.forward_batch(&xs, true);
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&packed.forward(x, true), y);
        }
    }

    #[test]
    fn single_layer_model_is_exactly_reference() {
        let mut rng = Rng::new(35);
        let model = TbnzModel {
            layers: vec![tiled_record("only", 9, 20, 4, AlphaMode::PerTile, &mut rng)],
        };
        let packed = PackedModel::from_tbnz(&model).unwrap();
        let x = rng.normal_vec(20, 1.0);
        // one layer: no binarization anywhere, bit-exact against the oracle
        assert_eq!(packed.forward(&x, true),
                   forward_quantized_reference(&model, &x, true));
    }

    #[test]
    fn resident_bytes_scale_with_rows() {
        let mut rng = Rng::new(36);
        let model = TbnzModel {
            layers: vec![
                tiled_record("fc0", 16, 64, 4, AlphaMode::Single, &mut rng),
                bwnn_record("fc1", 64, 16, &mut rng),
            ],
        };
        let packed = PackedModel::from_tbnz(&model).unwrap();
        // fc1 packed rows: 64 rows x 1 word = 512 bytes of words at least
        assert!(packed.resident_bytes() >= 512);
        assert_eq!(packed.packed_layers().len(), 1);
    }

    #[test]
    fn rejects_non_2d_layers() {
        let rec = LayerRecord {
            name: "conv".into(),
            shape: vec![4, 4, 3, 3],
            payload: WeightPayload::Fp(vec![0.0; 144]),
        };
        assert!(PackedLayer::from_record(&rec).is_err());
        assert!(PackedModel::from_tbnz(&TbnzModel { layers: vec![] }).is_err());
    }
}
