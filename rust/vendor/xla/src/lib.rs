//! Offline stub of the `xla` crate (xla_extension / PJRT bindings).
//!
//! The real bindings need the native `xla_extension` runtime, which is not
//! present in this container.  This stub keeps the crate buildable and the
//! artifact-free test tier green:
//!
//! * **Host-side [`Literal`] operations are implemented for real** (packing,
//!   reshape, element access) — the exporter unit tests exercise them without
//!   any runtime.
//! * **PJRT entry points return `Err`** (`PjRtClient::cpu`, `compile`,
//!   `execute`, HLO parsing), so everything that needs real artifacts fails
//!   with a clear message and the artifact-dependent tests skip cleanly.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; implements `std::error::Error` so `?` converts it into
/// `anyhow::Error` exactly like the real crate's error does.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime unavailable in this offline build (stub `xla` crate); \
         graph execution requires the real xla_extension runtime"
    )))
}

/// Element buffer of a literal. Public only so `NativeType` can name it.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn into_data(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }

    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }

    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host tensor value (shape + element buffer), mirroring `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::into_data(v.to_vec()) }
    }

    /// 0-D (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::into_data(vec![v]) }
    }

    /// Total element count (sums over tuple members).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.iter().map(Literal::element_count).sum(),
        }
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// First element (for 0-D literals).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("get_first_element: empty literal".into()))
    }

    /// Decompose a tuple literal into its members.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(t) => Ok(t.clone()),
            _ => Err(Error("to_tuple: literal is not a tuple".into())),
        }
    }

    /// Array shape (dims) of the literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("parse HLO text {path}"))
    }
}

/// A computation ready to compile.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (never constructible in the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

/// Compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }

    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }
}

/// PJRT client handle. `cpu()` always fails in the stub, which is the single
/// gate that turns every runtime-dependent code path into a clean error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_and_first_element() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        assert!(s.get_first_element::<f32>().is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn runtime_paths_fail_cleanly() {
        let e = PjRtClient::cpu().err().expect("stub client must not exist");
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[0.0f32]).to_tuple().is_err());
    }
}
