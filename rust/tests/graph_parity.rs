//! Branching layer-graph parity (artifact-free): the DAG executor and the
//! residual/T-Net lowering, pinned three ways.
//!
//! * **Reference-graph oracle** — an independent test-side evaluator walks
//!   the lowered graph (per-node `forward_reference`/`forward_join` calls
//!   over an explicit value table) and must agree **bit-exactly** with
//!   `Engine::forward` on the Reference path: this pins the engine's
//!   executor (slot fetch, liveness, ReLU placement) against a second
//!   implementation.
//! * **Layout bit-exactness** — on the Packed path, the tile-resident
//!   layout must agree **bit-exactly** with the expanded layout across
//!   randomized branching configs (both accumulate identical integer dots
//!   in identical order), including residual joins whose activation width
//!   is not a multiple of 64, and batched vs single-sample forwards.
//! * **Quantized-oracle closeness** — the packed forward tracks the f32
//!   sign/gamma oracle (`forward_quantized` on a Reference engine) with the
//!   usual f32 tolerance per binarized layer and argmax agreement end to
//!   end (sign tie-breaks can flip individual hidden units through deep
//!   stacks, exactly as in `tests/conv_parity.rs`).
//!
//! Plus the lowering failure modes: mismatched skip shapes (projection and
//! identity), T-Net entry-channel and transform-size mismatches.
//!
//! Packed engines built "at the default layout" go through
//! `PackedLayout::from_env()`, so the CI matrix re-runs this suite under
//! `TBN_LAYOUT=expanded`.

mod common;

use common::{argmax, count_nodes, handrolled_reference_forward};
use tiledbits::arch::{self, ArchSpec, BlockRole, LayerSpec};
use tiledbits::nn::{
    lower_arch_spec, Engine, EnginePath, LowerOptions, Node, Nonlin,
    PackedLayout, Slot,
};
use tiledbits::tbn::AlphaMode;
use tiledbits::util::Rng;

fn opts(input: (usize, usize, usize), p: usize, seed: u64) -> LowerOptions {
    LowerOptions { input, p, alpha_mode: AlphaMode::PerTile, seed }
}

/// Randomized annotated branching spec: either a small residual net (stem +
/// 1..2 blocks, optionally channel-growing with a 1x1 projection skip) or a
/// small T-Net pointnet.  Widths/spatial sizes are drawn so most joins land
/// on activation widths that are not multiples of 64.
fn random_branching_spec(rng: &mut Rng, case: u64)
                         -> (ArchSpec, (usize, usize, usize)) {
    if rng.below(3) < 2 {
        // residual CNN
        let c_in = 1 + rng.below(3);
        let hw = 5 + rng.below(4); // 5..8 -> join widths mostly % 64 != 0
        let w0 = 4 + rng.below(5);
        let mut layers = vec![LayerSpec::conv("stem", c_in, w0, 3, hw, hw, hw, hw)];
        let blocks = 1 + rng.below(2);
        let mut c = w0;
        for b in 0..blocks {
            let id = format!("b{b}");
            let grow = rng.below(2) == 1;
            let co = if grow { c + 1 + rng.below(4) } else { c };
            layers.push(
                LayerSpec::conv(&format!("{id}.conv1"), c, co, 3, hw, hw, hw, hw)
                    .in_block(BlockRole::ResidualBody { id: id.clone() }));
            layers.push(
                LayerSpec::conv(&format!("{id}.conv2"), co, co, 3, hw, hw, hw, hw)
                    .in_block(BlockRole::ResidualBody { id: id.clone() }));
            if grow {
                layers.push(
                    LayerSpec::conv(&format!("{id}.down"), c, co, 1, hw, hw, hw, hw)
                        .in_block(BlockRole::ResidualDown { id: id.clone() }));
            }
            c = co;
        }
        layers.push(LayerSpec::fc("head", c, 4 + rng.below(6)));
        (ArchSpec { name: format!("residual_rand_{case}"), layers }, (c_in, hw, hw))
    } else {
        // T-Net pointnet
        let k = 2 + rng.below(3);
        let points = 9 + rng.below(8); // 9..16 positions
        let mid = 6 + rng.below(6);
        let t = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: "t".into(), k });
        let c2 = 5 + rng.below(6);
        let layers = vec![
            t(LayerSpec::fc_tok("t.conv1", k, mid, points)),
            t(LayerSpec::fc("t.fc1", mid, k * k)),
            LayerSpec::fc_tok("conv1", k, c2, points),
            LayerSpec::fc("head", c2, 4 + rng.below(6)),
        ];
        (ArchSpec { name: format!("tnet_rand_{case}"), layers }, (k, points, 1))
    }
}

// ---------------------------------------------------------------------------
// Randomized branching configs: executor oracle + layout bit-exactness
// ---------------------------------------------------------------------------

/// The acceptance sweep: >= 8 randomized branching configs where (a) the
/// Reference DAG walk is bit-exact against the independent evaluator, (b)
/// the tile-resident packed forward is bit-exact against the expanded
/// layout (single and batched), and (c) the packed forward tracks the
/// quantized f32 oracle at the argmax level.
#[test]
fn branching_configs_layouts_bit_exact_and_track_oracle() {
    let mut ragged_joins = 0usize;
    let mut agree = 0usize;
    let mut total = 0usize;
    // two fixed minis (resnet_micro's first join is 392 wide, 392 % 64 != 0)
    // plus 10 randomized branching specs
    let mut configs: Vec<(ArchSpec, (usize, usize, usize), usize, u64)> = vec![
        (arch::resnet_micro(), (3, 7, 7), 4, 900),
        (arch::pointnet_tnet_micro(), (3, 16, 1), 4, 901),
    ];
    for case in 0..10u64 {
        let mut rng = Rng::new(0xD06E ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        let (spec, input) = random_branching_spec(&mut rng, case);
        let p = [2usize, 4][rng.below(2)];
        configs.push((spec, input, p, 1000 + case));
    }
    for (case, (spec, input, p, seed)) in configs.into_iter().enumerate() {
        let mut rng = Rng::new(0xE4E ^ seed);
        let graph = lower_arch_spec(&spec, &opts(input, p, seed))
            .unwrap_or_else(|e| panic!("case {case} ({}): {e}", spec.name));
        assert!(count_nodes(&graph, Node::is_join) >= 1, "case {case} has no join");
        for gn in &graph.nodes {
            if let Node::Add { len } = gn.node {
                if len % 64 != 0 {
                    ragged_joins += 1;
                }
            }
        }
        let reference =
            Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::Reference)
                .unwrap();
        let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                             EnginePath::Packed,
                                             PackedLayout::TileResident)
            .unwrap();
        let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::Expanded)
            .unwrap();
        assert!(tile.resident_weight_bytes() <= expanded.resident_weight_bytes(),
                "case {case}: tile residency above expanded");
        for s in 0..3 {
            let x = rng.normal_vec(reference.in_len(), 1.0);
            // (a) executor vs the independent reference-graph evaluator
            assert_eq!(reference.forward(&x), handrolled_reference_forward(&graph, &x, true),
                       "case {case} sample {s}: Reference DAG walk not bit-exact");
            // (b) tile-resident vs expanded, bit-exact
            let yt = tile.forward(&x);
            assert_eq!(yt, expanded.forward(&x),
                       "case {case} sample {s}: layouts disagree");
            // (c) argmax tracking of the f32 quantized oracle
            total += 1;
            if argmax(&reference.forward_quantized(&x)) == argmax(&yt) {
                agree += 1;
            }
            // packed forward and forward_quantized coincide on packed engines
            assert_eq!(yt, tile.forward_quantized(&x));
        }
        let xs: Vec<Vec<f32>> =
            (0..4).map(|_| rng.normal_vec(tile.in_len(), 1.0)).collect();
        let batch = tile.forward_batch(&xs);
        assert_eq!(batch, expanded.forward_batch(&xs), "case {case}: batched layouts");
        for (x, y) in xs.iter().zip(&batch) {
            assert_eq!(&tile.forward(x), y, "case {case}: batch != single");
        }
    }
    assert!(ragged_joins >= 1,
            "the sweep must include at least one residual join with n % 64 != 0");
    // sign tie-breaks may flip individual samples; the bulk must agree
    assert!(agree * 10 >= total * 6, "packed/oracle argmax agreement {agree}/{total}");
}

/// Explicit ragged residual: a 5-channel 5x5 block joins 125-element
/// activations (125 % 64 != 0), with a channel-growing projection block on
/// top — the acceptance criterion's named hard case, bit-exact across
/// layouts and batch modes.
#[test]
fn residual_join_with_ragged_width_is_bit_exact_across_layouts() {
    let id0 = || BlockRole::ResidualBody { id: "b0".into() };
    let id1 = || BlockRole::ResidualBody { id: "b1".into() };
    let spec = ArchSpec {
        name: "ragged_residual".into(),
        layers: vec![
            LayerSpec::conv("stem", 2, 5, 3, 5, 5, 5, 5),
            LayerSpec::conv("b0.conv1", 5, 5, 3, 5, 5, 5, 5).in_block(id0()),
            LayerSpec::conv("b0.conv2", 5, 5, 3, 5, 5, 5, 5).in_block(id0()),
            LayerSpec::conv("b1.conv1", 5, 9, 3, 5, 5, 5, 5).in_block(id1()),
            LayerSpec::conv("b1.conv2", 9, 9, 3, 5, 5, 5, 5).in_block(id1()),
            LayerSpec::conv("b1.down", 5, 9, 1, 5, 5, 5, 5)
                .in_block(BlockRole::ResidualDown { id: "b1".into() }),
            LayerSpec::fc("head", 9, 6),
        ],
    };
    let graph = lower_arch_spec(&spec, &opts((2, 5, 5), 5, 77)).unwrap();
    let adds: Vec<usize> = graph
        .nodes
        .iter()
        .filter_map(|gn| match gn.node {
            Node::Add { len } => Some(len),
            _ => None,
        })
        .collect();
    assert_eq!(adds, vec![125, 225], "join widths (125 % 64 = 61, ragged)");
    let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                         EnginePath::Packed,
                                         PackedLayout::TileResident)
        .unwrap();
    let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                             EnginePath::Packed,
                                             PackedLayout::Expanded)
        .unwrap();
    let reference =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference).unwrap();
    let mut rng = Rng::new(78);
    for s in 0..8 {
        let x = rng.normal_vec(tile.in_len(), 1.0);
        assert_eq!(tile.forward(&x), expanded.forward(&x), "sample {s}");
        assert!(reference.forward(&x).iter().all(|v| v.is_finite()));
    }
    let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(tile.in_len(), 1.0)).collect();
    assert_eq!(tile.forward_batch(&xs), expanded.forward_batch(&xs));
    for (x, y) in xs.iter().zip(&tile.forward_batch(&xs)) {
        assert_eq!(&tile.forward(x), y);
    }
}

// ---------------------------------------------------------------------------
// The annotated minis, end to end on every path
// ---------------------------------------------------------------------------

#[test]
fn resnet_micro_lowers_to_expected_graph() {
    let spec = arch::resnet_micro();
    let graph = lower_arch_spec(&spec, &opts((3, 7, 7), 4, 11)).unwrap();
    // stem, b0.conv1, b0.conv2, add, b1.conv1, b1.conv2, b1.down, add,
    // global pool, head
    assert_eq!(graph.len(), 10);
    assert!(matches!(graph.nodes[3].node, Node::Add { len: 392 })); // 8*7*7, ragged
    assert_eq!(graph.nodes[3].inputs, vec![Slot::Node(2), Slot::Node(0)]);
    assert_eq!(graph.nodes[3].relu, Some(true), "ReLU moves after the join");
    assert_eq!(graph.nodes[2].relu, Some(false), "body's last conv stays linear");
    // the projection block: down reads the block entry (the first add) and
    // stays linear — both operands activate only after the join
    assert_eq!(graph.nodes[6].inputs, vec![Slot::Node(3)]);
    assert_eq!(graph.nodes[6].relu, Some(false));
    assert!(matches!(graph.nodes[7].node, Node::Add { len: 192 }));
    assert_eq!(graph.nodes[7].inputs, vec![Slot::Node(5), Slot::Node(6)]);
    assert!(matches!(graph.nodes[8].node, Node::GlobalPool { positions: 16, .. }));
    assert!(matches!(&graph.nodes[9].node, Node::Fc(fc) if fc.m == 10 && fc.n == 12));

    let reference =
        Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let packed = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                           EnginePath::Packed, PackedLayout::from_env())
        .unwrap();
    let int8 =
        Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::PackedInt8).unwrap();
    assert_eq!(reference.in_len(), 3 * 7 * 7);
    assert_eq!(reference.out_len(), 10);
    let mut rng = Rng::new(12);
    let mut agree = 0usize;
    let n_samples = 8usize;
    for _ in 0..n_samples {
        let x = rng.normal_vec(reference.in_len(), 1.0);
        assert_eq!(reference.forward(&x),
                   handrolled_reference_forward(&graph, &x, true));
        let y = packed.forward(&x);
        assert_eq!(y, packed.forward_quantized(&x));
        if argmax(&reference.forward_quantized(&x)) == argmax(&y) {
            agree += 1;
        }
        assert!(int8.forward(&x).iter().all(|v| v.is_finite()));
        assert_eq!(int8.forward_batch(&[x.clone()])[0], int8.forward(&x));
    }
    assert!(agree * 10 >= n_samples * 6, "argmax agreement {agree}/{n_samples}");
    assert!(packed.resident_weight_bytes() < 4 * spec.total_params());
}

#[test]
fn pointnet_tnet_micro_lowers_with_feature_transforms() {
    let spec = arch::pointnet_tnet_micro();
    let graph = lower_arch_spec(&spec, &opts((3, 16, 1), 4, 13)).unwrap();
    // tnet3: conv1, conv2, pool, fc1, fc2, matmul;
    // conv1; tnet8: conv1, pool, fc1, matmul; conv2, pool, head
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::MatMulFeature { .. })), 2);
    let mm_params: Vec<(usize, usize)> = graph
        .nodes
        .iter()
        .filter_map(|gn| match gn.node {
            Node::MatMulFeature { k, positions } => Some((k, positions)),
            _ => None,
        })
        .collect();
    assert_eq!(mm_params, vec![(3, 16), (8, 16)]);
    // the first T-Net branches straight off the source features
    let first_mm = graph
        .nodes
        .iter()
        .find(|gn| matches!(gn.node, Node::MatMulFeature { .. }))
        .unwrap();
    assert_eq!(first_mm.inputs[0], Slot::Source);
    assert_eq!(first_mm.relu, Some(false));

    let reference =
        Engine::from_graph(graph.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                         EnginePath::Packed,
                                         PackedLayout::TileResident)
        .unwrap();
    let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                             EnginePath::Packed,
                                             PackedLayout::Expanded)
        .unwrap();
    assert_eq!(reference.in_len(), 3 * 16);
    assert_eq!(reference.out_len(), 10);
    let mut rng = Rng::new(14);
    for s in 0..6 {
        let x = rng.normal_vec(reference.in_len(), 1.0);
        assert_eq!(reference.forward(&x),
                   handrolled_reference_forward(&graph, &x, true), "sample {s}");
        assert_eq!(tile.forward(&x), expanded.forward(&x), "sample {s}");
    }
    let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(tile.in_len(), 1.0)).collect();
    let batch = tile.forward_batch(&xs);
    for (x, y) in xs.iter().zip(&batch) {
        assert_eq!(&tile.forward(x), y);
    }
}

// ---------------------------------------------------------------------------
// Lowering failure modes
// ---------------------------------------------------------------------------

#[test]
fn mismatched_projection_skip_shape_is_rejected() {
    let b = || BlockRole::ResidualBody { id: "b0".into() };
    let spec = ArchSpec {
        name: "bad_down".into(),
        layers: vec![
            LayerSpec::conv("stem", 3, 8, 3, 6, 6, 6, 6),
            LayerSpec::conv("b0.conv1", 8, 12, 3, 6, 6, 6, 6).in_block(b()),
            LayerSpec::conv("b0.conv2", 12, 12, 3, 6, 6, 6, 6).in_block(b()),
            // projection to 10 channels cannot join the 12-channel body
            LayerSpec::conv("b0.down", 8, 10, 1, 6, 6, 6, 6)
                .in_block(BlockRole::ResidualDown { id: "b0".into() }),
            LayerSpec::fc("head", 12, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((3, 6, 6), 4, 15)).unwrap_err();
    assert!(err.contains("skip shape mismatch"), "unexpected error: {err}");
}

#[test]
fn channel_changing_identity_skip_is_rejected() {
    let b = || BlockRole::ResidualBody { id: "b0".into() };
    let spec = ArchSpec {
        name: "bad_identity".into(),
        layers: vec![
            LayerSpec::conv("stem", 3, 8, 3, 6, 6, 6, 6),
            // body grows 8 -> 12 channels but ships no projection
            LayerSpec::conv("b0.conv1", 8, 12, 3, 6, 6, 6, 6).in_block(b()),
            LayerSpec::conv("b0.conv2", 12, 12, 3, 6, 6, 6, 6).in_block(b()),
            LayerSpec::fc("head", 12, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((3, 6, 6), 4, 16)).unwrap_err();
    assert!(err.contains("skip shape mismatch") && err.contains("downsample projection"),
            "unexpected error: {err}");
}

#[test]
fn tnet_entry_channel_mismatch_is_rejected() {
    // transform claims k = 4, but the features entering it have 3 channels
    let t = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: "t".into(), k: 4 });
    let spec = ArchSpec {
        name: "bad_tnet_entry".into(),
        layers: vec![
            t(LayerSpec::fc_tok("t.conv1", 4, 8, 12)),
            t(LayerSpec::fc("t.fc1", 8, 16)),
            LayerSpec::fc_tok("conv1", 3, 8, 12),
            LayerSpec::fc("head", 8, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((3, 12, 1), 4, 17)).unwrap_err();
    assert!(err.contains("T-Net k mismatch"), "unexpected error: {err}");
}

#[test]
fn tnet_transform_size_mismatch_is_rejected() {
    // subgraph ends in 10 values, not k*k = 9
    let t = |l: LayerSpec| l.in_block(BlockRole::Tnet { id: "t".into(), k: 3 });
    let spec = ArchSpec {
        name: "bad_tnet_head".into(),
        layers: vec![
            t(LayerSpec::fc_tok("t.conv1", 3, 8, 12)),
            t(LayerSpec::fc("t.fc1", 8, 10)),
            LayerSpec::fc_tok("conv1", 3, 8, 12),
            LayerSpec::fc("head", 8, 4),
        ],
    };
    let err = lower_arch_spec(&spec, &opts((3, 12, 1), 4, 18)).unwrap_err();
    assert!(err.contains("T-Net k mismatch"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------------
// Full-size paper specs: graph construction (forwards stay out of the
// default tier — debug-mode full-size forwards take minutes)
// ---------------------------------------------------------------------------

#[test]
fn resnet18_cifar_lowers_with_residual_joins() {
    let spec = arch::resnet18_cifar();
    let graph = lower_arch_spec(&spec, &opts((3, 32, 32), 4, 19)).unwrap();
    // 8 basic blocks -> 8 residual joins; stages 1..3 open with a projection
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 8);
    let downs = graph
        .nodes
        .iter()
        .filter(|gn| gn.node.name().ends_with(".down"))
        .count();
    assert_eq!(downs, 3);
    let engine =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference).unwrap();
    assert_eq!(engine.in_len(), 3 * 32 * 32);
    assert_eq!(engine.out_len(), 10);
}

#[test]
fn pointnet_cls_lowers_with_two_tnets() {
    let spec = arch::pointnet_cls();
    let graph = lower_arch_spec(&spec, &opts((3, 1024, 1), 4, 20)).unwrap();
    let mm_params: Vec<(usize, usize)> = graph
        .nodes
        .iter()
        .filter_map(|gn| match gn.node {
            Node::MatMulFeature { k, positions } => Some((k, positions)),
            _ => None,
        })
        .collect();
    assert_eq!(mm_params, vec![(3, 1024), (64, 1024)]);
    let engine =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference).unwrap();
    assert_eq!(engine.in_len(), 3 * 1024);
    assert_eq!(engine.out_len(), 40);
}

/// ResNet50's bottleneck lowering — 23.5M synthesized params, so it runs in
/// the release-mode `--ignored` tier CI compiles and executes.
#[test]
#[ignore]
fn resnet50_cifar_lowers_with_bottleneck_joins() {
    let spec = arch::resnet50_cifar();
    let graph = lower_arch_spec(&spec, &opts((3, 32, 32), 4, 21)).unwrap();
    // [3, 4, 6, 3] bottleneck blocks -> 16 joins, every stage opens with a
    // projection (stage 0 grows 64 -> 256)
    assert_eq!(count_nodes(&graph, |n| matches!(n, Node::Add { .. })), 16);
    let downs = graph
        .nodes
        .iter()
        .filter(|gn| gn.node.name().ends_with(".down"))
        .count();
    assert_eq!(downs, 4);
    let engine =
        Engine::from_graph(graph, Nonlin::Relu, EnginePath::Reference).unwrap();
    assert_eq!(engine.in_len(), 3 * 32 * 32);
    assert_eq!(engine.out_len(), 10);
}

/// Full forward of the branching minis on the packed tile-resident path vs
/// the expanded layout at full depth — release-tier (`--ignored`) version
/// of the micro checks with more samples.
#[test]
#[ignore]
fn branching_minis_extended_layout_sweep() {
    for (spec, input) in [
        (arch::resnet_micro(), (3usize, 7usize, 7usize)),
        (arch::pointnet_tnet_micro(), (3, 16, 1)),
    ] {
        for p in [2usize, 4, 8] {
            let graph = match lower_arch_spec(&spec, &opts(input, p, 22)) {
                Ok(g) => g,
                Err(e) => panic!("{} p={p}: {e}", spec.name),
            };
            let tile = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::TileResident)
                .unwrap();
            let expanded = Engine::with_layout_graph(graph, Nonlin::Relu,
                                                     EnginePath::Packed,
                                                     PackedLayout::Expanded)
                .unwrap();
            let mut rng = Rng::new(23);
            for s in 0..32 {
                let x = rng.normal_vec(tile.in_len(), 1.0);
                assert_eq!(tile.forward(&x), expanded.forward(&x),
                           "{} p={p} sample {s}", spec.name);
            }
        }
    }
}
