//! Bit-packed XNOR-popcount layer state (§5 deployment kernels).
//!
//! The reference kernels expand each tile lazily and multiply in f32.  The
//! fast path instead prepares, **once at model-load time**, per-layer packed
//! state ([`PackedLayer`]) and runs the deployment forward of the BNN
//! literature (Kim & Smaragdis 2016; XNOR-Net):
//!
//! * the first weight layer consumes the raw f32 input through the reference
//!   Algorithm 1 kernels (first layers stay higher precision, the standard
//!   BNN practice) — or, on [`EnginePath::PackedInt8`], the input quantized
//!   to 8-bit integers ([`quantize_input_i8`], the paper's
//!   microcontroller-style input packing) with pure integer MACs;
//! * every later weight layer sign-binarizes its input activations (`h > 0`,
//!   the crate-wide `BitVec::from_signs` convention) with an XNOR-Net scale
//!   `gamma = mean |h|`, and computes `y = gamma * sum_runs alpha_run *
//!   xnor_popcount(row_bits, x_bits)` — pure word ops plus one multiply per
//!   alpha run.
//!
//! **Tile-resident layout** (the default, [`PackedLayout::TileResident`]):
//! a tiled layer keeps exactly *one* packed tile — `q` bits in `~q/64`
//! `u64` words — plus its alpha scalars resident
//! ([`PackedPayload::Tile`]).  Every row of the expanded `m x n` sign
//! matrix is a window into the endlessly repeated tile stream, so row dots
//! walk the row's constant-alpha runs as *offsets into the tile*:
//! word-aligned views when the tile phase and the activation phase agree
//! mod 64, shift-stitched views otherwise
//! (`tbn::bitops::xnor_dot_words_offset`).  Weight residency and weight
//! traffic per layer drop from `O(m·n)` bits to `O(q)` — the paper's
//! "single tile per layer in memory" inference kernel — and the tile stays
//! L1-resident across all `m` rows and a whole batch.
//! [`PackedLayout::Expanded`] keeps the PR 1 behavior (every row expanded
//! into its own packed words) for A/B measurement; the two layouts are
//! bit-exact against each other because both accumulate the same exact
//! integer dot per alpha run in the same order.  The XNOR-popcount
//! arithmetic itself runs on the runtime-dispatched
//! [`SimdBackend`](crate::tbn::bitops::SimdBackend) (`TBN_SIMD` /
//! `--simd`): the `_simd` kernel variants take the backend explicitly so
//! engines hoist the choice out of the row loops, and backend selection
//! never changes results either — every backend masks partial words
//! identically and leaves the per-run f32 accumulation order untouched.
//!
//! **Threshold-folded integer pipeline** ([`EnginePath::PackedInt`]): on
//! hidden FC-to-FC edges the f32 round trip disappears entirely.  The next
//! binarized layer only consumes the *sign* of
//! `v = gamma · alpha · (2·same − n)` (and ReLU cannot flip it:
//! `relu(v) > 0 ⇔ v > 0`), so for a row whose alpha runs share one value
//! `a` the output bit collapses to an integer popcount compare: with any
//! constant `gamma > 0`, `a > 0` gives `bit = same ≥ T_r` with
//! `T_r = ⌊n/2⌋ + 1`, and `a < 0` **flips** the comparison to
//! `bit = same ≤ ⌊(n−1)/2⌋`; `a = 0` (or a NaN alpha) pins the bit to 0,
//! matching the Packed path's `NaN > 0 == false` convention.  Rows whose
//! runs mix alpha values (per-tile alpha modes) keep the exact per-run f32
//! accumulation and test `acc > 0` — still skipping the gamma reduction
//! and the separate binarize pass.  The thresholds are precomputed once at
//! engine build time ([`IntThresholds::from_layer`]) and the row kernels
//! write the next layer's bit-words directly
//! ([`PackedLayer::forward_batch_bits_mt_simd`]).  The data-dependent
//! XNOR-Net gamma is replaced on this path by a per-layer *calibrated
//! constant* ([`IntThresholds::gamma`]), applied only where f32 values
//! must be emitted (the output layer and boundaries into non-FC
//! consumers) — bit emission is invariant to any positive constant gamma,
//! but PackedInt therefore computes a slightly different function from
//! Packed; `tests/int_pipeline_parity.rs` pins bit-exactness against a
//! plain-Rust integer oracle and argmax agreement against Packed.
//!
//! A `PackedLayer` is a plain `(m, n)` row matrix over the layer's row-major
//! flat weights: FC layers pack their `[m, n]` shape directly, Conv2d layers
//! pack `(co, ci/groups * kh * kw)` rows and feed im2col patches through the
//! same kernels (`nn::layers::Conv2dLayer`).  The graph-level orchestration
//! lives in `nn::Engine`; this module owns only per-layer state and the
//! scalar/bit kernels it runs on.
//!
//! Because hidden activations are quantized, the packed paths compute a
//! *different function* from the `Reference` forward.  The FC-chain oracle
//! is [`forward_quantized_reference`]: the same math in plain f32 over the
//! expanded weights, which `rust/tests/packed_parity.rs` pins the bit
//! kernels against (agreement up to f32 accumulation order and sign
//! tie-breaks at exactly-zero activations).

use super::{fc_fp_forward, fc_layer_forward};
use crate::tbn::bitops::{active_backend, xnor_dot_words_offset_with,
                         xnor_dot_words_range_with, SimdBackend};
use crate::tbn::{LayerRecord, TbnzModel, WeightPayload};

/// Which implementation serves `MlpEngine::forward` / `Engine::forward`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EnginePath {
    /// Expand-and-multiply f32 path (the oracle; exact Algorithm 1 math).
    #[default]
    Reference,
    /// Bit-packed XNOR-popcount path with sign-binarized hidden activations;
    /// the first weight layer runs on the raw f32 input.
    Packed,
    /// `Packed` with the first weight layer's *input* quantized to 8-bit
    /// integers (symmetric, [`quantize_input_i8`]) so layer 0 runs integer
    /// MACs — the paper's microcontroller deployment.  Differs from the
    /// f32 oracle by the input quantization error; `tests/conv_parity.rs`
    /// documents and gates the tolerance.
    PackedInt8,
    /// Threshold-folded integer pipeline: hidden FC-to-FC edges never
    /// materialize f32 activations — each packed FC row emits its output
    /// *bit* straight from the integer XNOR-popcount via a precomputed
    /// per-row threshold ([`IntThresholds`]), and the data-dependent
    /// XNOR-Net gamma is replaced by a per-layer calibrated constant
    /// (`Engine::calibrate_int_gammas`) applied only where f32 values are
    /// emitted.  `Packed` stays the exact XNOR-Net baseline.
    PackedInt,
}

impl EnginePath {
    /// True for every path that builds packed per-layer state.
    pub fn is_packed(&self) -> bool {
        !matches!(self, EnginePath::Reference)
    }
}

/// How tiled layers lay out their packed weight state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PackedLayout {
    /// Keep exactly one packed tile (`q` bits) per tiled layer and compute
    /// row dots as offsets into it — `O(q)` weight residency, the paper's
    /// GPU/microcontroller tile-reuse kernel.  The default.
    #[default]
    TileResident,
    /// Expand every row of the `m x n` sign matrix into its own packed
    /// words (the PR 1 layout) — `O(m·n)` residency, kept behind this
    /// explicit toggle for A/B measurement.
    Expanded,
}

impl PackedLayout {
    /// Layout selected by the `TBN_LAYOUT` environment variable:
    /// `expanded` picks [`PackedLayout::Expanded`], anything else (or
    /// unset) the tile-resident default.  This is the CI A/B hook — the
    /// parity suites build their "default" packed engines through it, and
    /// the workflow runs the test job once per layout.
    pub fn from_env() -> PackedLayout {
        match std::env::var("TBN_LAYOUT") {
            Ok(v) if v.eq_ignore_ascii_case("expanded") => PackedLayout::Expanded,
            _ => PackedLayout::TileResident,
        }
    }
}

/// Kernel thread count selected by the `TBN_THREADS` environment variable
/// (unset, unparsable or `< 1` values fall back to 1 — single-threaded).
/// This is the CI matrix hook mirroring [`PackedLayout::from_env`]: engines
/// built without an explicit `Engine::with_threads` pick it up, so the
/// parity suites exercise the threaded kernels whenever the workflow sets
/// `TBN_THREADS=4`.  Threading never changes results: each thread owns a
/// disjoint slice of the output and runs the unmodified serial per-element
/// math, so any thread count is bit-exact against 1.
pub fn threads_from_env() -> usize {
    match std::env::var("TBN_THREADS") {
        Ok(v) => v.trim().parse::<usize>().unwrap_or(1).max(1),
        Err(_) => 1,
    }
}

/// Split `items` into at most `threads` contiguous non-empty `(lo, hi)`
/// ranges covering `0..items` — the static partition behind every threaded
/// kernel.  Remainder items go to the leading ranges, so range sizes differ
/// by at most one.  Empty when `items == 0`.
pub(crate) fn split_ranges(items: usize, threads: usize) -> Vec<(usize, usize)> {
    if items == 0 {
        return Vec::new();
    }
    let t = threads.clamp(1, items);
    let (base, rem) = (items / t, items % t);
    let mut ranges = Vec::with_capacity(t);
    let mut lo = 0usize;
    for k in 0..t {
        let len = base + usize::from(k < rem);
        ranges.push((lo, lo + len));
        lo += len;
    }
    ranges
}

/// Partition a buffer of `inner`-element blocks into per-range strided
/// views: `parts[r][blk]` is block `blk`'s `ranges[r]` sub-slice.  The
/// slices are pairwise disjoint, so one scoped thread can own range `r`'s
/// views across every block — disjoint writes with no aliasing and no
/// `unsafe`.  `ranges` must be the sorted cover produced by
/// [`split_ranges`] over `0..inner`.  Generic over the element type: the
/// f32 kernels split activation blocks, the integer pipeline splits `u64`
/// bit-word blocks with the same machinery.
pub(crate) fn partition_strided<'a, T>(
    buf: &'a mut [T],
    inner: usize,
    ranges: &[(usize, usize)],
) -> Vec<Vec<&'a mut [T]>> {
    let mut parts: Vec<Vec<&'a mut [T]>> =
        ranges.iter().map(|_| Vec::with_capacity(buf.len() / inner.max(1))).collect();
    for block in buf.chunks_mut(inner) {
        let mut rest = block;
        for (r, &(lo, hi)) in ranges.iter().enumerate() {
            let (mid, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
            parts[r].push(mid);
            rest = tail;
        }
    }
    parts
}

/// One run of constant alpha inside a packed row: `[start, start + len)`
/// bits scaled by `alpha`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlphaRun {
    pub start: u32,
    pub len: u32,
    pub alpha: f32,
}

/// Payload of one packed layer.
#[derive(Debug, Clone, PartialEq)]
pub enum PackedPayload {
    /// Binary-weight layer: expanded sign rows packed into `u64` words.
    Bits {
        /// Words per row (`ceil(n / 64)`, at least 1).
        words_per_row: usize,
        /// `m * words_per_row` words; row `i` starts at `i * words_per_row`.
        /// Bits at positions `>= n` within a row are zero.
        row_words: Vec<u64>,
        /// Constant-alpha runs of all rows, concatenated.
        runs: Vec<AlphaRun>,
        /// Row `i` owns `runs[run_offsets[i] .. run_offsets[i + 1]]`.
        run_offsets: Vec<u32>,
    },
    /// Tiled layer, tile-resident: one packed `q`-bit tile shared by every
    /// row.  Row `i`'s weight bit at column `j` is
    /// `tile[(i*n + j) % q]` and its alpha is
    /// `alphas[((i*n + j) / q) % alphas.len()]`, so the per-row alpha runs
    /// are derived arithmetically — no per-row metadata is stored at all.
    Tile {
        /// Tile length in bits.
        q: usize,
        /// `ceil(q / 64)` packed words, LSB-first, tail bits zero.
        tile_words: Vec<u64>,
        /// 1 (layer-wide) or p (per-tile) scalars.
        alphas: Vec<f32>,
    },
    /// Full-precision layer: dense row-major weights (nothing to pack).
    Dense(Vec<f32>),
}

/// One weight layer prepared for the packed forward: an `(m, n)` row matrix
/// over the layer's row-major flat weights.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    pub name: String,
    /// Rows (output features / conv output channels).
    pub m: usize,
    /// Row length (input features / im2col patch length).
    pub n: usize,
    pub payload: PackedPayload,
}

fn pack_rows<F: Fn(usize) -> bool>(m: usize, n: usize, bit_at_flat: F) -> (usize, Vec<u64>) {
    let wpr = n.div_ceil(64).max(1);
    let mut words = vec![0u64; m * wpr];
    for i in 0..m {
        let base = i * wpr;
        let row_start = i * n;
        for j in 0..n {
            if bit_at_flat(row_start + j) {
                words[base + j / 64] |= 1u64 << (j % 64);
            }
        }
    }
    (wpr, words)
}

impl PackedLayer {
    /// Pack one TBNZ layer record (2-D FC layers; conv layers use
    /// [`PackedLayer::from_record_mn`] with their im2col row view).
    pub fn from_record(l: &LayerRecord) -> Result<PackedLayer, String> {
        if l.shape.len() != 2 {
            return Err(format!("{}: packed FC view requires a 2-D shape", l.name));
        }
        PackedLayer::from_record_mn(l, l.shape[0], l.shape[1])
    }

    /// [`PackedLayer::from_record_mn_layout`] with the default
    /// (tile-resident) layout.
    pub fn from_record_mn(l: &LayerRecord, m: usize, n: usize) -> Result<PackedLayer, String> {
        PackedLayer::from_record_mn_layout(l, m, n, PackedLayout::default())
    }

    /// Pack any payload viewed as an `(m, n)` row matrix over its row-major
    /// flat weights.  FC layers pass their shape directly; Conv2d passes
    /// `(co, ci/groups * kh * kw)` so each row is one output channel's
    /// im2col filter.  `layout` selects the weight layout for tiled
    /// payloads (Bwnn and Fp payloads are unaffected).
    pub fn from_record_mn_layout(l: &LayerRecord, m: usize, n: usize,
                                 layout: PackedLayout) -> Result<PackedLayer, String> {
        if m * n != l.n() {
            return Err(format!(
                "{}: {m}x{n} row view does not cover {} params",
                l.name,
                l.n()
            ));
        }
        let payload = match &l.payload {
            WeightPayload::Fp(w) => {
                if w.len() != m * n {
                    return Err(format!("{}: fp payload size mismatch", l.name));
                }
                PackedPayload::Dense(w.clone())
            }
            WeightPayload::Bwnn { bits, alpha } => {
                if bits.len() != m * n {
                    return Err(format!("{}: bwnn payload size mismatch", l.name));
                }
                let (words_per_row, row_words) = pack_rows(m, n, |flat| bits.get_bit(flat));
                let runs = (0..m)
                    .map(|_| AlphaRun { start: 0, len: n as u32, alpha: *alpha })
                    .collect();
                let run_offsets = (0..=m as u32).collect();
                PackedPayload::Bits { words_per_row, row_words, runs, run_offsets }
            }
            WeightPayload::Tiled { tile, alphas, .. } => {
                let q = tile.len();
                if q == 0 || (m * n) % q != 0 || alphas.is_empty() {
                    return Err(format!("{}: invalid tiled payload (q={q})", l.name));
                }
                match layout {
                    PackedLayout::TileResident => PackedPayload::Tile {
                        q,
                        tile_words: tile.words().to_vec(),
                        alphas: alphas.clone(),
                    },
                    PackedLayout::Expanded => {
                        let (words_per_row, row_words) =
                            pack_rows(m, n, |flat| tile.get_bit(flat % q));
                        let single = alphas.len() == 1;
                        let mut runs = Vec::new();
                        let mut run_offsets = Vec::with_capacity(m + 1);
                        run_offsets.push(0u32);
                        for i in 0..m {
                            let row_start = i * n;
                            let mut j = 0usize;
                            while j < n {
                                let flat = row_start + j;
                                // run until the tile wraps (alpha can only
                                // change there)
                                let len = (q - flat % q).min(n - j);
                                let alpha = if single {
                                    alphas[0]
                                } else {
                                    alphas[(flat / q) % alphas.len()]
                                };
                                runs.push(AlphaRun {
                                    start: j as u32,
                                    len: len as u32,
                                    alpha,
                                });
                                j += len;
                            }
                            run_offsets.push(runs.len() as u32);
                        }
                        PackedPayload::Bits { words_per_row, row_words, runs, run_offsets }
                    }
                }
            }
        };
        Ok(PackedLayer { name: l.name.clone(), m, n, payload })
    }

    /// Weight bytes resident for this layer on the packed path.  A
    /// tile-resident layer reports the true sub-bit number: the packed
    /// tile words plus the alpha table, independent of `m` and `n`.
    pub fn resident_bytes(&self) -> usize {
        match &self.payload {
            PackedPayload::Bits { row_words, runs, run_offsets, .. } => {
                8 * row_words.len()
                    + std::mem::size_of::<AlphaRun>() * runs.len()
                    + 4 * run_offsets.len()
            }
            PackedPayload::Tile { tile_words, alphas, .. } => {
                8 * tile_words.len() + 4 * alphas.len()
            }
            PackedPayload::Dense(w) => 4 * w.len(),
        }
    }

    /// Resident `u64` weight words behind this layer's packed bit state
    /// (what the inner loops stream from; 0 for dense fp payloads, which
    /// keep f32 weights instead).  `benches/fig5_memtrace.rs` traces this
    /// per layer.
    pub fn weight_words(&self) -> usize {
        match &self.payload {
            PackedPayload::Bits { row_words, .. } => row_words.len(),
            PackedPayload::Tile { tile_words, .. } => tile_words.len(),
            PackedPayload::Dense(_) => 0,
        }
    }

    /// Binarized dot of row `i` against the packed input bits `xw` (no gamma
    /// scale or nonlinearity applied): `sum_runs alpha_run *
    /// xnor_popcount(row, xw)` for bit rows; add/subtract per weight for
    /// dense rows.  The shared inner kernel of the packed FC *and* conv
    /// forwards.
    ///
    /// On the tile-resident layout the row never materializes: each
    /// constant-alpha run is a dot of the activation bits `[j, j+len)`
    /// against the tile bits `[ti, ti+len)` at the row's tile phase
    /// `ti = (i*n + j) % q`, via the misaligned shift-stitch kernel.  Runs
    /// are derived arithmetically (a run ends where the tile wraps), so
    /// the two layouts accumulate the same exact integer dots in the same
    /// order — bit-exact agreement.
    pub fn row_dot_binarized(&self, i: usize, xw: &[u64]) -> f32 {
        self.row_dot_binarized_simd(i, xw, active_backend())
    }

    /// [`PackedLayer::row_dot_binarized`] on an explicit XNOR-popcount
    /// backend.  The backend changes only how the interior full words of
    /// each run batch their popcounts — every backend computes the same
    /// exact integer dot per alpha run, and the f32 accumulation order is
    /// untouched — so any backend choice is **bit-exact** against any
    /// other (and composes with the threading contract the same way).
    pub fn row_dot_binarized_simd(&self, i: usize, xw: &[u64],
                                  simd: SimdBackend) -> f32 {
        match &self.payload {
            PackedPayload::Bits { words_per_row, row_words, runs, run_offsets } => {
                let row = &row_words[i * words_per_row..(i + 1) * words_per_row];
                let (lo, hi) = (run_offsets[i] as usize, run_offsets[i + 1] as usize);
                let mut acc = 0.0f32;
                for run in &runs[lo..hi] {
                    let dot = xnor_dot_words_range_with(simd, row, xw,
                                                        run.start as usize,
                                                        run.len as usize);
                    acc += run.alpha * dot as f32;
                }
                acc
            }
            PackedPayload::Tile { q, tile_words, alphas } => {
                let q = *q;
                let single = alphas.len() == 1;
                let row_start = i * self.n;
                let mut acc = 0.0f32;
                let mut j = 0usize;
                while j < self.n {
                    let flat = row_start + j;
                    let ti = flat % q;
                    // run until the tile wraps (alpha can only change there)
                    let len = (q - ti).min(self.n - j);
                    let alpha =
                        if single { alphas[0] } else { alphas[(flat / q) % alphas.len()] };
                    let dot = xnor_dot_words_offset_with(simd, tile_words, ti, xw, j, len);
                    acc += alpha * dot as f32;
                    j += len;
                }
                acc
            }
            PackedPayload::Dense(w) => {
                // fp weights against ±1 inputs: add or subtract each weight
                let row = &w[i * self.n..(i + 1) * self.n];
                let mut acc = 0.0f32;
                for (j, &wj) in row.iter().enumerate() {
                    if (xw[j / 64] >> (j % 64)) & 1 == 1 {
                        acc += wj;
                    } else {
                        acc -= wj;
                    }
                }
                acc
            }
        }
    }

    /// Forward all rows over a sign-binarized input: `xw` holds the packed
    /// sign bits of the input activations (bits `>= n` zero) and `gamma` is
    /// their XNOR-Net scale.  The multiply count is one per alpha run.
    pub fn forward_binarized(&self, xw: &[u64], gamma: f32, relu: bool) -> Vec<f32> {
        self.forward_binarized_simd(xw, gamma, relu, active_backend())
    }

    /// [`PackedLayer::forward_binarized`] on an explicit backend (see
    /// [`PackedLayer::row_dot_binarized_simd`] for the bit-exactness
    /// contract).
    pub fn forward_binarized_simd(&self, xw: &[u64], gamma: f32, relu: bool,
                                  simd: SimdBackend) -> Vec<f32> {
        (0..self.m)
            .map(|i| {
                let v = gamma * self.row_dot_binarized_simd(i, xw, simd);
                if relu { v.max(0.0) } else { v }
            })
            .collect()
    }

    /// Batched binarized forward of rows `[row_lo, row_hi)` over `B` packed
    /// inputs: `xws` holds `B` activation-bit vectors of `stride` words
    /// each (input `b` at `xws[b*stride .. (b+1)*stride]`, bits `>= n`
    /// zero), `gammas` their XNOR-Net scales (`B = gammas.len()`).
    ///
    /// Row-major loop order: each row's weight state — its packed words and
    /// alpha runs, or the one shared tile — is walked while hot across the
    /// whole batch, which is where the batched path earns its keep (the
    /// tile-resident layout keeps `O(q)` weight bytes hot across all rows
    /// *and* all samples).  Outputs land at
    /// `out[b * (row_hi - row_lo) + (i - row_lo)]`, each exactly equal to
    /// the single-sample path: `gamma_b * row_dot_binarized(i, xw_b)`
    /// (+ ReLU).
    ///
    /// FC layers pass all rows and one vector per batch sample; Conv2d
    /// passes one group's row range and one vector per output position.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_binarized_rows(&self, row_lo: usize, row_hi: usize,
                                        xws: &[u64], stride: usize, gammas: &[f32],
                                        relu: bool, out: &mut [f32]) {
        self.forward_batch_binarized_rows_simd(row_lo, row_hi, xws, stride, gammas,
                                               relu, out, active_backend())
    }

    /// [`PackedLayer::forward_batch_binarized_rows`] on an explicit
    /// backend — the form the engine layers call, with the backend hoisted
    /// out of the row loop (see [`PackedLayer::row_dot_binarized_simd`]).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_binarized_rows_simd(&self, row_lo: usize, row_hi: usize,
                                             xws: &[u64], stride: usize,
                                             gammas: &[f32], relu: bool,
                                             out: &mut [f32], simd: SimdBackend) {
        let bsz = gammas.len();
        debug_assert!(row_lo <= row_hi && row_hi <= self.m);
        debug_assert!(xws.len() >= bsz * stride);
        let nrows = row_hi - row_lo;
        debug_assert!(out.len() >= bsz * nrows);
        for i in row_lo..row_hi {
            for b in 0..bsz {
                let xw = &xws[b * stride..(b + 1) * stride];
                let v = gammas[b] * self.row_dot_binarized_simd(i, xw, simd);
                out[b * nrows + (i - row_lo)] = if relu { v.max(0.0) } else { v };
            }
        }
    }

    /// Multi-threaded [`PackedLayer::forward_batch_binarized_rows`]: splits
    /// the output-row loop across at most `threads` scoped std threads
    /// (`std::thread::scope` — no pool state, no new deps).  Each thread
    /// computes one contiguous row range and writes only its own strided,
    /// pairwise-disjoint sub-slices of `out`; every output element is still
    /// produced by the unmodified serial expression
    /// `gamma_b * row_dot_binarized(i, xw_b)` with the same per-run f32
    /// accumulation order, so the result is **bit-exact at any thread
    /// count**, on both packed layouts.  `threads <= 1`, a single row, or
    /// an empty batch run the serial kernel inline with zero spawn
    /// overhead.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_binarized_rows_mt(&self, row_lo: usize, row_hi: usize,
                                           xws: &[u64], stride: usize,
                                           gammas: &[f32], relu: bool,
                                           out: &mut [f32], threads: usize) {
        self.forward_batch_binarized_rows_mt_simd(row_lo, row_hi, xws, stride, gammas,
                                                  relu, out, threads, active_backend())
    }

    /// [`PackedLayer::forward_batch_binarized_rows_mt`] on an explicit
    /// backend: every worker thread runs the dispatched kernel, so the
    /// intra-op threading and the SIMD backend compose — and stay
    /// bit-exact — in both directions.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_binarized_rows_mt_simd(&self, row_lo: usize, row_hi: usize,
                                                xws: &[u64], stride: usize,
                                                gammas: &[f32], relu: bool,
                                                out: &mut [f32], threads: usize,
                                                simd: SimdBackend) {
        let bsz = gammas.len();
        debug_assert!(row_lo <= row_hi && row_hi <= self.m);
        let nrows = row_hi - row_lo;
        let t = threads.min(nrows).max(1);
        if t <= 1 || bsz == 0 {
            return self.forward_batch_binarized_rows_simd(row_lo, row_hi, xws, stride,
                                                          gammas, relu, out, simd);
        }
        debug_assert!(xws.len() >= bsz * stride);
        debug_assert!(out.len() >= bsz * nrows);
        let ranges = split_ranges(nrows, t);
        let parts = partition_strided(&mut out[..bsz * nrows], nrows, &ranges);
        std::thread::scope(|scope| {
            for (&(lo, hi), mut slices) in ranges.iter().zip(parts) {
                scope.spawn(move || {
                    for i in (row_lo + lo)..(row_lo + hi) {
                        for (b, dst) in slices.iter_mut().enumerate() {
                            let xw = &xws[b * stride..(b + 1) * stride];
                            let v = gammas[b] * self.row_dot_binarized_simd(i, xw, simd);
                            dst[i - row_lo - lo] = if relu { v.max(0.0) } else { v };
                        }
                    }
                });
            }
        });
    }

    /// Walk row `i`'s constant-alpha runs in kernel order, calling
    /// `f(start, len, alpha)` per run.  `Bits` rows replay their stored
    /// runs, `Tile` rows derive them arithmetically (exactly like
    /// [`PackedLayer::row_dot_binarized_simd`]), `Dense` rows have none.
    /// Shared by the threshold precompute and the plain-Rust oracles.
    pub fn for_each_run<F: FnMut(usize, usize, f32)>(&self, i: usize, mut f: F) {
        match &self.payload {
            PackedPayload::Bits { runs, run_offsets, .. } => {
                let (lo, hi) = (run_offsets[i] as usize, run_offsets[i + 1] as usize);
                for run in &runs[lo..hi] {
                    f(run.start as usize, run.len as usize, run.alpha);
                }
            }
            PackedPayload::Tile { q, alphas, .. } => {
                let q = *q;
                let single = alphas.len() == 1;
                let row_start = i * self.n;
                let mut j = 0usize;
                while j < self.n {
                    let flat = row_start + j;
                    let len = (q - flat % q).min(self.n - j);
                    let alpha =
                        if single { alphas[0] } else { alphas[(flat / q) % alphas.len()] };
                    f(j, len, alpha);
                    j += len;
                }
            }
            PackedPayload::Dense(_) => {}
        }
    }

    /// Weight sign bit of row `i`, column `j` (binary payloads only; panics
    /// on `Dense`).  Scalar single-bit reads — the plain-Rust oracle's view
    /// of the weights, independent of the popcount kernels.
    pub fn weight_bit(&self, i: usize, j: usize) -> bool {
        match &self.payload {
            PackedPayload::Bits { words_per_row, row_words, .. } => {
                let w = row_words[i * words_per_row + j / 64];
                (w >> (j % 64)) & 1 == 1
            }
            PackedPayload::Tile { q, tile_words, .. } => {
                let t = (i * self.n + j) % q;
                (tile_words[t / 64] >> (t % 64)) & 1 == 1
            }
            PackedPayload::Dense(_) => panic!("dense rows have no weight bits"),
        }
    }

    /// Raw integer XNOR-popcount dot of row `i` against the packed input
    /// bits: `2·same − n` with no alpha and no gamma — the quantity the
    /// folded thresholds compare against.  Only meaningful for rows whose
    /// alpha runs share one value (see [`IntRowRule`]); `Dense` rows have
    /// no integer dot and panic.
    pub fn row_int_dot_simd(&self, i: usize, xw: &[u64], simd: SimdBackend) -> i64 {
        match &self.payload {
            PackedPayload::Bits { words_per_row, row_words, .. } => {
                let row = &row_words[i * words_per_row..(i + 1) * words_per_row];
                xnor_dot_words_range_with(simd, row, xw, 0, self.n)
            }
            PackedPayload::Tile { q, tile_words, .. } => {
                let q = *q;
                let row_start = i * self.n;
                let mut acc = 0i64;
                let mut j = 0usize;
                while j < self.n {
                    let ti = (row_start + j) % q;
                    let len = (q - ti).min(self.n - j);
                    acc += xnor_dot_words_offset_with(simd, tile_words, ti, xw, j, len);
                    j += len;
                }
                acc
            }
            PackedPayload::Dense(_) => panic!("dense rows have no integer dot"),
        }
    }

    /// Output bit of row `i` under its folded rule — the integer-pipeline
    /// row kernel.  `Pos`/`Neg` rows stay entirely in the integer domain
    /// (one popcount dot, one compare); `Mixed` rows accumulate the exact
    /// per-run f32 sum and test its sign; `Zero` rows are constant.  Every
    /// backend computes the same integer dots, so the emitted bit is
    /// bit-exact across `SimdBackend`s and (word-split) thread counts.
    pub fn row_rule_bit_simd(&self, rule: IntRowRule, i: usize, xw: &[u64],
                             simd: SimdBackend) -> bool {
        match rule {
            IntRowRule::Zero => false,
            IntRowRule::Mixed => self.row_dot_binarized_simd(i, xw, simd) > 0.0,
            IntRowRule::Pos { t } => {
                self.row_int_dot_simd(i, xw, simd) >= 2 * t as i64 - self.n as i64
            }
            IntRowRule::Neg { t } => {
                self.row_int_dot_simd(i, xw, simd) <= 2 * t as i64 - self.n as i64
            }
        }
    }

    /// Batched bit-emitting forward: for each of `bsz` packed inputs
    /// (`xws[b*stride ..]`, bits `>= n` zero) compute every row's folded
    /// output bit and write it straight into `out[b*stride_out ..]` as the
    /// *next* layer's activation words (bit `i` of sample `b`; tail bits
    /// zero).  No f32 buffer, no binarize pass, no gamma reduction.  Rows
    /// stay the outer loop so each row's weight state is walked while hot
    /// across the whole batch, like the f32 batch kernel.
    /// `stride_out >= ceil(m/64)` words per sample, fully overwritten.
    pub fn forward_batch_bits_simd(&self, thr: &IntThresholds, xws: &[u64],
                                   stride: usize, bsz: usize, out: &mut [u64],
                                   stride_out: usize, simd: SimdBackend) {
        debug_assert_eq!(thr.rules.len(), self.m);
        debug_assert!(xws.len() >= bsz * stride);
        debug_assert!(stride_out * 64 >= self.m && out.len() >= bsz * stride_out);
        for w in out[..bsz * stride_out].iter_mut() {
            *w = 0;
        }
        for i in 0..self.m {
            let rule = thr.rules[i];
            for b in 0..bsz {
                let xw = &xws[b * stride..(b + 1) * stride];
                if self.row_rule_bit_simd(rule, i, xw, simd) {
                    out[b * stride_out + i / 64] |= 1u64 << (i % 64);
                }
            }
        }
    }

    /// Multi-threaded [`PackedLayer::forward_batch_bits_simd`].  Output
    /// bits of different rows share `u64` words, so the split is by
    /// contiguous *word* ranges: each thread owns rows
    /// `[64·w_lo, min(64·w_hi, m))` and therefore whole words of every
    /// sample's output — pairwise-disjoint writes with no atomics, via the
    /// same strided partition as the f32 kernels.  Each bit is still
    /// produced by the unmodified serial row kernel, so any thread count
    /// is bit-exact against 1.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_batch_bits_mt_simd(&self, thr: &IntThresholds, xws: &[u64],
                                      stride: usize, bsz: usize, out: &mut [u64],
                                      stride_out: usize, threads: usize,
                                      simd: SimdBackend) {
        let wcount = self.m.div_ceil(64).max(1);
        let t = threads.min(wcount).max(1);
        if t <= 1 || bsz == 0 {
            return self.forward_batch_bits_simd(thr, xws, stride, bsz, out,
                                                stride_out, simd);
        }
        debug_assert_eq!(thr.rules.len(), self.m);
        debug_assert!(xws.len() >= bsz * stride);
        debug_assert!(stride_out >= wcount && out.len() >= bsz * stride_out);
        for w in out[..bsz * stride_out].iter_mut() {
            *w = 0;
        }
        let ranges = split_ranges(wcount, t);
        let parts = partition_strided(&mut out[..bsz * stride_out], stride_out,
                                      &ranges);
        std::thread::scope(|scope| {
            for (&(wlo, whi), mut slices) in ranges.iter().zip(parts) {
                scope.spawn(move || {
                    for i in (wlo * 64)..(whi * 64).min(self.m) {
                        let rule = thr.rules[i];
                        for (b, dst) in slices.iter_mut().enumerate() {
                            let xw = &xws[b * stride..(b + 1) * stride];
                            if self.row_rule_bit_simd(rule, i, xw, simd) {
                                dst[i / 64 - wlo] |= 1u64 << (i % 64);
                            }
                        }
                    }
                });
            }
        });
    }
}

/// One row's folded integer decision rule on the [`EnginePath::PackedInt`]
/// path, in the *same-count* domain of `same = popcount(xnor(row, x))`
/// (so the raw dot `2·same − n` compares against `2t − n`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IntRowRule {
    /// Uniform positive alpha: `bit = same ≥ t`, `t = ⌊n/2⌋ + 1`
    /// (⇔ `2·same − n > 0`).
    Pos { t: i32 },
    /// Uniform negative alpha flips the comparison: `bit = same ≤ t`,
    /// `t = ⌊(n−1)/2⌋` (⇔ `2·same − n < 0`).
    Neg { t: i32 },
    /// Alpha 0 or NaN (or an empty row): the pre-activation can never be
    /// `> 0` on the Packed path, so the bit is constant 0.
    Zero,
    /// Runs mix alpha values (per-tile alpha modes, dense fp rows): no
    /// single integer threshold exists; the kernel keeps the exact per-run
    /// f32 accumulation and tests `acc > 0`.
    Mixed,
}

/// Per-row folded thresholds plus the per-layer calibrated gamma constant
/// for one packed layer — the [`EnginePath::PackedInt`] build-time state.
///
/// `gamma` defaults to 1.0 and is only *observable* where the layer must
/// emit f32 values (the output layer, or a boundary into a non-FC
/// consumer): hidden bit emission is invariant to any positive constant
/// scale.  `Engine::calibrate_int_gammas` replaces it with the mean
/// XNOR-Net gamma observed over a calibration set.
#[derive(Debug, Clone, PartialEq)]
pub struct IntThresholds {
    /// One rule per output row (`rules.len() == m`).
    pub rules: Vec<IntRowRule>,
    /// Per-layer constant replacing the data-dependent XNOR-Net scale on
    /// f32-emitting boundaries.  Positive and finite.
    pub gamma: f32,
}

impl IntThresholds {
    /// Classify every row of `layer` at build time.  A row is `Pos`/`Neg`
    /// when all its alpha runs share one finite non-zero value (Bwnn rows,
    /// single-alpha tiled rows, and any per-tile row that happens to be
    /// covered by one run), `Zero` when that shared value is 0 or NaN, and
    /// `Mixed` otherwise (including every dense fp row).
    pub fn from_layer(layer: &PackedLayer) -> IntThresholds {
        let n = layer.n;
        let rules = (0..layer.m)
            .map(|i| {
                if matches!(layer.payload, PackedPayload::Dense(_)) {
                    return IntRowRule::Mixed;
                }
                let mut first: Option<f32> = None;
                let mut uniform = true;
                layer.for_each_run(i, |_, _, a| match first {
                    None => first = Some(a),
                    // NaN != NaN keeps a NaN-alpha multi-run row Mixed,
                    // where the f32 kernel reproduces Packed's NaN > 0
                    // == false; a single NaN run classifies Zero below.
                    Some(f) if f != a => uniform = false,
                    Some(_) => {}
                });
                match first {
                    _ if !uniform => IntRowRule::Mixed,
                    None => IntRowRule::Zero, // empty row: dot is always 0
                    Some(a) if a > 0.0 => IntRowRule::Pos { t: (n / 2 + 1) as i32 },
                    Some(a) if a < 0.0 => {
                        IntRowRule::Neg { t: (n.saturating_sub(1) / 2) as i32 }
                    }
                    Some(_) => IntRowRule::Zero, // ±0.0 or NaN alpha
                }
            })
            .collect();
        IntThresholds { rules, gamma: 1.0 }
    }

    /// The microcontroller export encoding: one `i32` per row.
    /// `Pos { t }` → `t` (always ≥ 1), `Neg { t }` → `−t − 1` (always
    /// ≤ −1, decodes as `t = −v − 1`), `Zero` → `i32::MAX` (an
    /// unreachable same-count), `Mixed` → `i32::MIN` (sentinel: the row
    /// needs the weighted-run evaluation, no single threshold exists).
    pub fn export_i32(&self) -> Vec<i32> {
        self.rules
            .iter()
            .map(|r| match *r {
                IntRowRule::Pos { t } => t,
                IntRowRule::Neg { t } => -t - 1,
                IntRowRule::Zero => i32::MAX,
                IntRowRule::Mixed => i32::MIN,
            })
            .collect()
    }
}

/// Sign-binarize an activation vector into `words` (bit j set iff
/// `h[j] > 0`, the `BitVec::from_signs` convention; tail bits zero) and
/// return the XNOR-Net activation scale `gamma = mean |h|`.
///
/// `words` is a scratch buffer so batch loops can reuse one allocation.
pub fn binarize_activations(h: &[f32], words: &mut Vec<u64>) -> f32 {
    let wpr = h.len().div_ceil(64).max(1);
    words.clear();
    words.resize(wpr, 0);
    binarize_activations_into(h, words)
}

/// [`binarize_activations`] into a caller-placed word slice (at least
/// `ceil(len/64)` words; fully overwritten, tail bits zeroed).  Batch loops
/// pack `B` inputs side by side in one buffer through this entry point.
///
/// Non-finite activations are handled deterministically, mirroring the
/// [`quantize_input_i8`] guard: the sign bit follows the crate-wide
/// `v > 0.0` convention (NaN and `-inf` read −1, `+inf` reads +1), but
/// only *finite* magnitudes feed the gamma mean — a single NaN or infinity
/// must not turn the XNOR-Net scale non-finite and poison every downstream
/// layer.  If the finite sum itself overflows f32, gamma collapses to 0.
pub fn binarize_activations_into(h: &[f32], words: &mut [u64]) -> f32 {
    debug_assert!(words.len() * 64 >= h.len());
    for w in words.iter_mut() {
        *w = 0;
    }
    let mut sum = 0.0f32;
    for (j, &v) in h.iter().enumerate() {
        if v.is_finite() {
            sum += v.abs();
        }
        if v > 0.0 {
            words[j / 64] |= 1u64 << (j % 64);
        }
    }
    if h.is_empty() {
        0.0
    } else {
        finite_or_zero(sum / h.len() as f32)
    }
}

/// Sign-binarize with **no** gamma reduction — the integer pipeline's
/// boundary entry point (an f32 value crossing into a bit-consuming layer
/// only needs its signs; the folded thresholds replace the scale).  Same
/// bit convention as [`binarize_activations_into`]: bit j set iff
/// `h[j] > 0.0` (NaN and `-inf` read 0), tail bits zeroed.
pub fn binarize_signs_into(h: &[f32], words: &mut [u64]) {
    debug_assert!(words.len() * 64 >= h.len());
    for w in words.iter_mut() {
        *w = 0;
    }
    for (j, &v) in h.iter().enumerate() {
        if v > 0.0 {
            words[j / 64] |= 1u64 << (j % 64);
        }
    }
}

/// [`binarize_signs_into`] with a resizing scratch `Vec` (at least one
/// word, like [`binarize_activations`]).
pub fn binarize_signs(h: &[f32], words: &mut Vec<u64>) {
    let wpr = h.len().div_ceil(64).max(1);
    words.clear();
    words.resize(wpr, 0);
    binarize_signs_into(h, words);
}

/// The XNOR-Net activation scale `gamma = mean |h|` with the same
/// non-finite guard as [`binarize_activations_into`]: non-finite elements
/// are skipped, and a non-finite mean collapses to 0.  The f32 oracles
/// (`forward_quantized_reference` and the layer `forward_quantized_oracle`s)
/// share this so packed-vs-oracle parity holds on poisoned inputs too.
pub fn activation_gamma(h: &[f32]) -> f32 {
    if h.is_empty() {
        return 0.0;
    }
    let sum: f32 = h.iter().filter(|v| v.is_finite()).map(|v| v.abs()).sum();
    finite_or_zero(sum / h.len() as f32)
}

fn finite_or_zero(v: f32) -> f32 {
    if v.is_finite() { v } else { 0.0 }
}

/// Symmetric 8-bit input quantization (the paper's microcontroller input
/// packing): `scale = max|x| / 127`, `xq[j] = round(x[j] / scale)` clamped
/// to `[-127, 127]`.  Returns the scale.  The degenerate guard returns
/// scale 0.0 with `out` all zeros in **two** cases: an all-zero input
/// (`max|x| == 0`), and any input whose `max|x|` is non-finite — a NaN or
/// ±inf element makes no symmetric scale meaningful, so the whole sample
/// collapses to zeros rather than poisoning the integer kernels.  This is
/// the same convention as the integer hidden pipeline's gamma guards
/// ([`activation_gamma`] / `Engine::calibrate_int_gammas`): non-finite
/// inputs deterministically degrade to zero, never to NaN.  `out` is a
/// scratch buffer reused across samples.
///
/// Per-element quantization error is at most `scale / 2`, so a dot with a
/// weight row `w` is off by at most `scale / 2 * sum_j |w_j|` — the bound
/// `tests/conv_parity.rs` gates the int8 kernels against.
pub fn quantize_input_i8(x: &[f32], out: &mut Vec<i8>) -> f32 {
    out.clear();
    let maxabs = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if maxabs == 0.0 || !maxabs.is_finite() {
        out.resize(x.len(), 0);
        return 0.0;
    }
    let scale = maxabs / 127.0;
    out.extend(x.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8));
    scale
}

/// One row of the layer-0 int8 kernel: dot of the row's weights (flat offset
/// `flat_start`, spanning `xq.len()` elements) with the quantized input,
/// rescaled by `scale`.  Binary payloads accumulate in i32 — pure integer
/// MACs, the microcontroller inner loop — and apply alpha/scale once per
/// run; fp payloads dequantize on the fly.
pub fn payload_row_dot_i8(
    payload: &WeightPayload,
    flat_start: usize,
    xq: &[i8],
    scale: f32,
) -> f32 {
    match payload {
        WeightPayload::Fp(w) => {
            let row = &w[flat_start..flat_start + xq.len()];
            scale * row.iter().zip(xq).map(|(wj, &q)| wj * q as f32).sum::<f32>()
        }
        WeightPayload::Bwnn { bits, alpha } => {
            let mut acc = 0i32;
            for (j, &q) in xq.iter().enumerate() {
                if bits.get_bit(flat_start + j) {
                    acc += q as i32;
                } else {
                    acc -= q as i32;
                }
            }
            alpha * scale * acc as f32
        }
        WeightPayload::Tiled { tile, alphas, .. } => {
            let qlen = tile.len();
            let single = alphas.len() == 1;
            let mut total = 0.0f32;
            let mut j = 0usize;
            while j < xq.len() {
                let flat = flat_start + j;
                let ti = flat % qlen;
                let seg = (qlen - ti).min(xq.len() - j);
                let a = if single { alphas[0] } else { alphas[(flat / qlen) % alphas.len()] };
                let mut acc = 0i32;
                for k in 0..seg {
                    if tile.get_bit(ti + k) {
                        acc += xq[j + k] as i32;
                    } else {
                        acc -= xq[j + k] as i32;
                    }
                }
                total += a * acc as f32;
                j += seg;
            }
            scale * total
        }
    }
}

/// f32 oracle of the quantized deployment forward over an FC chain:
/// identical math to the packed path — sign binarization, gamma scaling,
/// expanded dense multiply — with no bit tricks.  `Reference`-path engines
/// serve this from `MlpEngine::forward_quantized`, and the parity suite
/// compares the packed path against it.
pub fn forward_quantized_reference(model: &TbnzModel, x: &[f32], relu_hidden: bool)
                                   -> Vec<f32> {
    assert!(!model.layers.is_empty(), "empty model");
    let last = model.layers.len() - 1;
    let mut h = fc_layer_forward(&model.layers[0], x, relu_hidden && last > 0);
    for (li, layer) in model.layers.iter().enumerate().skip(1) {
        let gamma = activation_gamma(&h);
        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let w = layer.expand();
        let m = layer.shape[0];
        let mut y = fc_fp_forward(&w, &signs, m, false);
        let relu = relu_hidden && li < last;
        for v in y.iter_mut() {
            let s = gamma * *v;
            *v = if relu { s.max(0.0) } else { s };
        }
        h = y;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tbn::{alphas_from, tile_from_weights, AlphaMode};
    use crate::tensor::BitVec;
    use crate::util::Rng;

    fn tiled_record(name: &str, m: usize, n: usize, p: usize, mode: AlphaMode,
                    rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, mode),
            },
        }
    }

    fn bwnn_record(name: &str, m: usize, n: usize, rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Bwnn { bits: BitVec::from_signs(&w), alpha: 0.4 },
        }
    }

    #[test]
    fn binarize_matches_bitvec_convention() {
        let h = [0.5f32, -0.1, 0.0, 2.0, -3.0];
        let mut words = Vec::new();
        let gamma = binarize_activations(&h, &mut words);
        let v = BitVec::from_signs(&h);
        assert_eq!(&words[..], v.words());
        let want = h.iter().map(|x| x.abs()).sum::<f32>() / h.len() as f32;
        assert!((gamma - want).abs() < 1e-7);
    }

    #[test]
    fn binarize_empty_and_reuse() {
        let mut words = vec![u64::MAX; 4]; // stale scratch must be cleared
        assert_eq!(binarize_activations(&[], &mut words), 0.0);
        assert_eq!(words, vec![0u64]);
        let g = binarize_activations(&[1.0, 1.0], &mut words);
        assert_eq!(words, vec![0b11u64]);
        assert!((g - 1.0).abs() < 1e-7);
    }

    /// A packed Bwnn layer over ±1 inputs must equal the dense computation.
    #[test]
    fn bits_layer_matches_dense_on_signs() {
        let mut rng = Rng::new(31);
        let (m, n) = (7, 70); // non-multiple-of-64 width
        let rec = bwnn_record("l", m, n, &mut rng);
        let packed = PackedLayer::from_record(&rec).unwrap();
        let h = rng.normal_vec(n, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&h, &mut xw);
        let got = packed.forward_binarized(&xw, gamma, false);

        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let w = rec.expand();
        let want = fc_fp_forward(&w, &signs, m, false);
        for i in 0..m {
            assert!((got[i] - gamma * want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                    "row {i}: {} vs {}", got[i], gamma * want[i]);
        }
    }

    /// Tiled rows with per-tile alphas: alpha runs must follow the flat
    /// alpha index `(flat / q) % p` exactly.
    #[test]
    fn tiled_layer_alpha_runs_match_expansion() {
        let mut rng = Rng::new(32);
        // q = m*n/p = 5*12/4 = 15, so runs split mid-row
        let rec = tiled_record("t", 5, 12, 4, AlphaMode::PerTile, &mut rng);
        let packed = PackedLayer::from_record(&rec).unwrap();
        let h = rng.normal_vec(12, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&h, &mut xw);
        let got = packed.forward_binarized(&xw, gamma, false);

        let signs: Vec<f32> = h.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let want = fc_fp_forward(&rec.expand(), &signs, 5, false);
        for i in 0..5 {
            assert!((got[i] - gamma * want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                    "row {i}");
        }
    }

    /// `row_dot_binarized` is the kernel `forward_binarized` sums from.
    #[test]
    fn row_dot_consistent_with_forward() {
        let mut rng = Rng::new(37);
        let rec = tiled_record("t", 6, 40, 4, AlphaMode::PerTile, &mut rng);
        let packed = PackedLayer::from_record(&rec).unwrap();
        let h = rng.normal_vec(40, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&h, &mut xw);
        let fwd = packed.forward_binarized(&xw, gamma, false);
        for i in 0..6 {
            assert_eq!(fwd[i], gamma * packed.row_dot_binarized(i, &xw), "row {i}");
        }
    }

    /// A 4-D conv record packs through the `(m, n)` view: each row is one
    /// output channel's filter, and alpha runs follow the same flat index.
    #[test]
    fn conv_record_packs_via_mn_view() {
        let mut rng = Rng::new(38);
        let (co, cig, kh, kw) = (4usize, 3usize, 3usize, 3usize);
        let w = rng.normal_vec(co * cig * kh * kw, 1.0);
        let rec = LayerRecord {
            name: "conv".into(),
            shape: vec![co, cig, kh, kw],
            payload: WeightPayload::Tiled {
                p: 4,
                tile: tile_from_weights(&w, 4),
                alphas: alphas_from(&w, 4, AlphaMode::PerTile),
            },
        };
        // 2-D constructor refuses; the explicit row view packs
        assert!(PackedLayer::from_record(&rec).is_err());
        let n = cig * kh * kw;
        let packed = PackedLayer::from_record_mn(&rec, co, n).unwrap();
        assert_eq!((packed.m, packed.n), (co, n));
        // parity against the expanded dense rows over a ±1 patch
        let patch = rng.normal_vec(n, 1.0);
        let mut xw = Vec::new();
        let gamma = binarize_activations(&patch, &mut xw);
        let signs: Vec<f32> =
            patch.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
        let dense = rec.expand();
        for o in 0..co {
            let want: f32 =
                dense[o * n..(o + 1) * n].iter().zip(&signs).map(|(a, b)| a * b).sum();
            let got = gamma * packed.row_dot_binarized(o, &xw);
            assert!((got - gamma * want).abs() < 1e-3 * want.abs().max(1.0), "row {o}");
        }
        // a wrong row view is rejected
        assert!(PackedLayer::from_record_mn(&rec, co, n + 1).is_err());
    }

    /// The tile-resident layout is bit-exact against the expanded layout:
    /// same integer dots per run, same f32 accumulation order — across
    /// ragged widths (n % 64 != 0), mid-row alpha splits and q % 64 != 0
    /// tiles (the shift-stitched cases).
    #[test]
    fn tile_resident_matches_expanded_bit_exact() {
        let mut rng = Rng::new(41);
        for (m, n, p) in [(7, 70, 7), (5, 12, 4), (16, 64, 4), (13, 33, 3),
                          (6, 130, 4), (9, 65, 5), (4, 100, 8)] {
            if (m * n) % p != 0 {
                panic!("bad test shape {m}x{n} p={p}");
            }
            for mode in [AlphaMode::Single, AlphaMode::PerTile] {
                let rec = tiled_record("t", m, n, p, mode, &mut rng);
                let expanded = PackedLayer::from_record_mn_layout(
                    &rec, m, n, PackedLayout::Expanded).unwrap();
                let tile = PackedLayer::from_record_mn_layout(
                    &rec, m, n, PackedLayout::TileResident).unwrap();
                assert!(matches!(expanded.payload, PackedPayload::Bits { .. }));
                assert!(matches!(tile.payload, PackedPayload::Tile { .. }));
                let h = rng.normal_vec(n, 1.0);
                let mut xw = Vec::new();
                let gamma = binarize_activations(&h, &mut xw);
                assert_eq!(
                    tile.forward_binarized(&xw, gamma, false),
                    expanded.forward_binarized(&xw, gamma, false),
                    "m={m} n={n} p={p} mode={mode:?}"
                );
            }
        }
    }

    /// Tile-resident residency is the sub-bit number — q bits + alphas —
    /// and at least 8x below the expanded rows once m*n/q >= 8.
    #[test]
    fn tile_resident_residency_is_o_q() {
        let mut rng = Rng::new(43);
        let (m, n, p) = (64usize, 96usize, 8usize); // q = 768, m*n/q = 8
        let rec = tiled_record("t", m, n, p, AlphaMode::PerTile, &mut rng);
        let q = m * n / p;
        let tile = PackedLayer::from_record_mn_layout(
            &rec, m, n, PackedLayout::TileResident).unwrap();
        let expanded = PackedLayer::from_record_mn_layout(
            &rec, m, n, PackedLayout::Expanded).unwrap();
        assert_eq!(tile.resident_bytes(), 8 * q.div_ceil(64) + 4 * p);
        assert!(tile.resident_bytes() <= q / 8 + 8 + 4 * p,
                "tile residency {} vs q/8 = {}", tile.resident_bytes(), q / 8);
        assert!(expanded.resident_bytes() >= 8 * tile.resident_bytes(),
                "expanded {} vs tile {}", expanded.resident_bytes(),
                tile.resident_bytes());
        assert_eq!(tile.weight_words(), q.div_ceil(64));
        assert_eq!(expanded.weight_words(), m * n.div_ceil(64));
    }

    /// The batched row kernel is exactly the single-sample kernel in a
    /// different loop order.
    #[test]
    fn batch_binarized_rows_match_single_path() {
        let mut rng = Rng::new(44);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let (m, n) = (11usize, 70usize);
            let rec = tiled_record("t", m, n, 7, AlphaMode::PerTile, &mut rng);
            let packed = PackedLayer::from_record_mn_layout(&rec, m, n, layout).unwrap();
            let stride = n.div_ceil(64).max(1);
            let bsz = 5usize;
            let mut xws = vec![0u64; bsz * stride];
            let mut gammas = Vec::with_capacity(bsz);
            let mut singles = Vec::with_capacity(bsz);
            for b in 0..bsz {
                let h = rng.normal_vec(n, 1.0);
                let g = binarize_activations_into(
                    &h, &mut xws[b * stride..(b + 1) * stride]);
                gammas.push(g);
                singles.push(packed.forward_binarized(
                    &xws[b * stride..(b + 1) * stride], g, true));
            }
            let mut out = vec![0.0f32; bsz * m];
            packed.forward_batch_binarized_rows(0, m, &xws, stride, &gammas, true,
                                                &mut out);
            for b in 0..bsz {
                assert_eq!(&out[b * m..(b + 1) * m], &singles[b][..],
                           "{layout:?} sample {b}");
            }
            // a row sub-range lands at the same values, re-based
            let (lo, hi) = (3usize, 8usize);
            let mut sub = vec![0.0f32; bsz * (hi - lo)];
            packed.forward_batch_binarized_rows(lo, hi, &xws, stride, &gammas, true,
                                                &mut sub);
            for b in 0..bsz {
                assert_eq!(&sub[b * (hi - lo)..(b + 1) * (hi - lo)],
                           &singles[b][lo..hi], "{layout:?} rows {lo}..{hi}");
            }
        }
    }

    #[test]
    fn binarize_into_matches_vec_entry_point() {
        let mut rng = Rng::new(45);
        let h = rng.normal_vec(130, 1.0);
        let mut words = Vec::new();
        let g1 = binarize_activations(&h, &mut words);
        let mut slice = vec![u64::MAX; 3]; // stale bits must be cleared
        let g2 = binarize_activations_into(&h, &mut slice);
        assert_eq!(g1, g2);
        assert_eq!(&words[..], &slice[..]);
    }

    #[test]
    fn quantize_i8_bounds_and_zero() {
        let mut out = Vec::new();
        assert_eq!(quantize_input_i8(&[0.0, 0.0], &mut out), 0.0);
        assert_eq!(out, vec![0i8, 0]);

        let x = [1.0f32, -2.0, 0.5, 2.0];
        let scale = quantize_input_i8(&x, &mut out);
        assert!((scale - 2.0 / 127.0).abs() < 1e-7);
        // extremes map to ±127, everything reconstructs within scale/2
        assert_eq!(out[1], -127);
        assert_eq!(out[3], 127);
        for (j, &v) in x.iter().enumerate() {
            assert!((out[j] as f32 * scale - v).abs() <= scale / 2.0 + 1e-6, "elem {j}");
        }
    }

    /// The documented degenerate guard: a non-finite `max|x|` (any NaN or
    /// ±inf element) behaves exactly like the all-zero input — scale 0.0,
    /// `out` all zeros — never a NaN scale.
    #[test]
    fn quantize_i8_non_finite_collapses_to_zero() {
        let mut out = Vec::new();
        for bad in [
            vec![1.0f32, f32::NAN, -2.0],
            vec![f32::INFINITY, 0.5],
            vec![-1.0, f32::NEG_INFINITY],
            vec![f32::NAN],
        ] {
            let scale = quantize_input_i8(&bad, &mut out);
            assert_eq!(scale, 0.0, "input {bad:?}");
            assert!(scale.is_finite());
            assert_eq!(out, vec![0i8; bad.len()], "input {bad:?}");
        }
        // stale scratch from a previous sample is fully replaced
        let s = quantize_input_i8(&[2.0, -2.0], &mut out);
        assert!(s > 0.0);
        assert_eq!(quantize_input_i8(&[f32::NAN, 1.0, 1.0], &mut out), 0.0);
        assert_eq!(out, vec![0i8; 3]);
    }

    /// The int8 row kernel is within the documented quantization bound of
    /// the exact f32 row dot: `scale/2 * sum_j |w_j|` plus f32 slack.
    #[test]
    fn int8_row_dot_within_quantization_bound() {
        let mut rng = Rng::new(39);
        for rec in [
            tiled_record("t", 8, 50, 4, AlphaMode::PerTile, &mut rng),
            bwnn_record("b", 8, 50, &mut rng),
            LayerRecord {
                name: "fp".into(),
                shape: vec![8, 50],
                payload: WeightPayload::Fp(rng.normal_vec(400, 1.0)),
            },
        ] {
            let x = rng.normal_vec(50, 1.0);
            let mut xq = Vec::new();
            let scale = quantize_input_i8(&x, &mut xq);
            let dense = rec.expand();
            for i in 0..8 {
                let row = &dense[i * 50..(i + 1) * 50];
                let exact: f32 = row.iter().zip(&x).map(|(w, v)| w * v).sum();
                let got = payload_row_dot_i8(&rec.payload, i * 50, &xq, scale);
                let bound =
                    0.5 * scale * row.iter().map(|w| w.abs()).sum::<f32>() * 1.05 + 1e-4;
                assert!((got - exact).abs() <= bound,
                        "{} row {i}: {got} vs {exact} (bound {bound})", rec.name);
            }
        }
    }

    /// `split_ranges` always yields a contiguous, non-empty cover of
    /// `0..items` with at most `threads` pieces.
    #[test]
    fn split_ranges_covers_and_balances() {
        for items in [0usize, 1, 2, 3, 7, 8, 64, 65] {
            for threads in [1usize, 2, 3, 4, 8, 100] {
                let ranges = split_ranges(items, threads);
                if items == 0 {
                    assert!(ranges.is_empty());
                    continue;
                }
                assert!(ranges.len() <= threads.min(items));
                assert_eq!(ranges[0].0, 0);
                assert_eq!(ranges.last().unwrap().1, items);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous {items}/{threads}");
                }
                let (min, max) = ranges.iter().fold((usize::MAX, 0), |(mn, mx), r| {
                    (mn.min(r.1 - r.0), mx.max(r.1 - r.0))
                });
                assert!(min >= 1 && max - min <= 1, "balanced {items}/{threads}");
            }
        }
    }

    /// The threaded batched row kernel is bit-exact against the serial one
    /// at every thread count, on both layouts — including threads > rows,
    /// a batch that doesn't divide across threads, and a row sub-range.
    #[test]
    fn batch_rows_mt_bit_exact_vs_serial() {
        let mut rng = Rng::new(46);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let (m, n) = (11usize, 70usize);
            let rec = tiled_record("t", m, n, 7, AlphaMode::PerTile, &mut rng);
            let packed = PackedLayer::from_record_mn_layout(&rec, m, n, layout).unwrap();
            let stride = n.div_ceil(64).max(1);
            let bsz = 5usize; // does not divide 2/4/8 threads
            let mut xws = vec![0u64; bsz * stride];
            let mut gammas = Vec::with_capacity(bsz);
            for b in 0..bsz {
                let h = rng.normal_vec(n, 1.0);
                gammas.push(binarize_activations_into(
                    &h, &mut xws[b * stride..(b + 1) * stride]));
            }
            let mut want = vec![0.0f32; bsz * m];
            packed.forward_batch_binarized_rows(0, m, &xws, stride, &gammas, true,
                                                &mut want);
            for threads in [1usize, 2, 3, 4, 8, 64] {
                let mut got = vec![0.0f32; bsz * m];
                packed.forward_batch_binarized_rows_mt(
                    0, m, &xws, stride, &gammas, true, &mut got, threads);
                assert_eq!(got, want, "{layout:?} threads={threads}");
                // row sub-range, re-based like the serial kernel
                let (lo, hi) = (3usize, 8usize);
                let mut sub = vec![0.0f32; bsz * (hi - lo)];
                packed.forward_batch_binarized_rows_mt(
                    lo, hi, &xws, stride, &gammas, true, &mut sub, threads);
                for b in 0..bsz {
                    assert_eq!(&sub[b * (hi - lo)..(b + 1) * (hi - lo)],
                               &want[b * m + lo..b * m + hi],
                               "{layout:?} threads={threads} rows {lo}..{hi}");
                }
            }
        }
    }

    /// Every XNOR-popcount backend produces bit-identical packed forwards
    /// on both layouts, serial and threaded — the engine-level face of the
    /// kernel parity `tests/simd_parity.rs` sweeps.
    #[test]
    fn batch_rows_bit_exact_across_simd_backends() {
        let mut rng = Rng::new(47);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let (m, n) = (11usize, 70usize);
            let rec = tiled_record("t", m, n, 7, AlphaMode::PerTile, &mut rng);
            let packed = PackedLayer::from_record_mn_layout(&rec, m, n, layout).unwrap();
            let stride = n.div_ceil(64).max(1);
            let bsz = 5usize;
            let mut xws = vec![0u64; bsz * stride];
            let mut gammas = Vec::with_capacity(bsz);
            for b in 0..bsz {
                let h = rng.normal_vec(n, 1.0);
                gammas.push(binarize_activations_into(
                    &h, &mut xws[b * stride..(b + 1) * stride]));
            }
            let mut want = vec![0.0f32; bsz * m];
            packed.forward_batch_binarized_rows_simd(0, m, &xws, stride, &gammas, true,
                                                     &mut want, SimdBackend::Scalar);
            for simd in [SimdBackend::Scalar, SimdBackend::U64x4, SimdBackend::U128,
                         SimdBackend::Avx2] {
                for threads in [1usize, 3, 8] {
                    let mut got = vec![0.0f32; bsz * m];
                    packed.forward_batch_binarized_rows_mt_simd(
                        0, m, &xws, stride, &gammas, true, &mut got, threads, simd);
                    assert_eq!(got, want, "{layout:?} {simd} threads={threads}");
                }
                let single = packed.forward_binarized_simd(
                    &xws[..stride], gammas[0], true, simd);
                assert_eq!(&single[..], &want[..m], "{layout:?} {simd} single");
            }
        }
    }

    /// Non-finite activations must not poison gamma: signs stay on the
    /// `v > 0.0` convention (NaN/−inf → 0-bit, +inf → 1-bit) and gamma
    /// averages the finite magnitudes only.
    #[test]
    fn binarize_guards_non_finite_activations() {
        let h = [1.0f32, f32::NAN, -2.0, f32::INFINITY, f32::NEG_INFINITY, 3.0];
        let mut words = Vec::new();
        let gamma = binarize_activations(&h, &mut words);
        assert!(gamma.is_finite());
        // mean over all 6 slots of the finite |h| values: (1 + 2 + 3) / 6
        assert!((gamma - 1.0).abs() < 1e-7, "gamma {gamma}");
        // bits: 1.0 -> 1, NaN -> 0, -2 -> 0, +inf -> 1, -inf -> 0, 3 -> 1
        assert_eq!(words, vec![0b101001u64]);
        assert_eq!(activation_gamma(&h), gamma);
        // an all-non-finite vector yields gamma 0, like the i8 guard
        let bad = [f32::NAN, f32::INFINITY];
        assert_eq!(binarize_activations(&bad, &mut words), 0.0);
        assert_eq!(activation_gamma(&bad), 0.0);
        // finite-sum overflow collapses to 0 instead of +inf
        let huge = [f32::MAX, f32::MAX, f32::MAX];
        assert_eq!(binarize_activations(&huge, &mut words), 0.0);
    }

    #[test]
    fn threads_from_env_parses_and_clamps() {
        // Avoid mutating the process env (tests run in parallel); the
        // parse rule itself is what matters: junk and 0 fall back to 1.
        let parse = |v: &str| v.trim().parse::<usize>().unwrap_or(1).max(1);
        assert_eq!(parse("4"), 4);
        assert_eq!(parse(" 8 "), 8);
        assert_eq!(parse("0"), 1);
        assert_eq!(parse("nope"), 1);
        assert!(threads_from_env() >= 1);
    }

    #[test]
    fn rejects_non_2d_layers_and_bad_views() {
        let rec = LayerRecord {
            name: "conv".into(),
            shape: vec![4, 4, 3, 3],
            payload: WeightPayload::Fp(vec![0.0; 144]),
        };
        assert!(PackedLayer::from_record(&rec).is_err());
        assert!(PackedLayer::from_record_mn(&rec, 4, 4).is_err());
        assert!(PackedLayer::from_record_mn(&rec, 4, 36).is_ok());
    }

    fn tiled_record_alphas(name: &str, m: usize, n: usize, p: usize,
                           alphas: Vec<f32>, rng: &mut Rng) -> LayerRecord {
        let w = rng.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled { p, tile: tile_from_weights(&w, p), alphas },
        }
    }

    #[test]
    fn binarize_signs_matches_gamma_variant_bits() {
        let mut rng = Rng::new(51);
        let h = rng.normal_vec(130, 1.0);
        let mut with_gamma = Vec::new();
        binarize_activations(&h, &mut with_gamma);
        let mut signs_only = vec![u64::MAX; 3]; // stale bits must be cleared
        binarize_signs_into(&h, &mut signs_only);
        assert_eq!(&with_gamma[..], &signs_only[..]);
        let mut v = vec![u64::MAX; 7];
        binarize_signs(&h, &mut v);
        assert_eq!(with_gamma, v);
        binarize_signs(&[], &mut v);
        assert_eq!(v, vec![0u64]);
    }

    /// Threshold classification: uniform positive alpha folds to `Pos`
    /// with `t = n/2 + 1`, uniform negative flips to `Neg` with
    /// `t = (n-1)/2`, zero/NaN alphas pin to `Zero`, per-tile alpha mixes
    /// and dense fp rows stay `Mixed` — and the export encoding is stable.
    #[test]
    fn int_thresholds_classify_rows() {
        let mut rng = Rng::new(52);
        let (m, n, p) = (6usize, 40usize, 4usize);
        let pos = PackedLayer::from_record(
            &tiled_record_alphas("pos", m, n, p, vec![0.5], &mut rng)).unwrap();
        let thr = IntThresholds::from_layer(&pos);
        assert_eq!(thr.gamma, 1.0);
        assert!(thr.rules.iter().all(|r| *r == IntRowRule::Pos { t: 21 }));
        assert_eq!(thr.export_i32(), vec![21; m]);

        let neg = PackedLayer::from_record(
            &tiled_record_alphas("neg", m, n, p, vec![-0.5], &mut rng)).unwrap();
        let thr = IntThresholds::from_layer(&neg);
        assert!(thr.rules.iter().all(|r| *r == IntRowRule::Neg { t: 19 }));
        assert_eq!(thr.export_i32(), vec![-20; m]);

        for a in [0.0f32, -0.0, f32::NAN] {
            let z = PackedLayer::from_record(
                &tiled_record_alphas("z", m, n, p, vec![a], &mut rng)).unwrap();
            let thr = IntThresholds::from_layer(&z);
            assert!(thr.rules.iter().all(|r| *r == IntRowRule::Zero), "alpha {a}");
            assert_eq!(thr.export_i32(), vec![i32::MAX; m], "alpha {a}");
        }

        // per-tile alphas split rows mid-way (q = 60 < n*2): Mixed rows
        let mixed = PackedLayer::from_record(
            &tiled_record("mix", m, n, p, AlphaMode::PerTile, &mut rng)).unwrap();
        let thr = IntThresholds::from_layer(&mixed);
        assert!(thr.rules.contains(&IntRowRule::Mixed));
        assert!(thr.export_i32().contains(&i32::MIN));

        let dense = PackedLayer::from_record(&LayerRecord {
            name: "fp".into(),
            shape: vec![2, 8],
            payload: WeightPayload::Fp(rng.normal_vec(16, 1.0)),
        })
        .unwrap();
        let thr = IntThresholds::from_layer(&dense);
        assert_eq!(thr.rules, vec![IntRowRule::Mixed; 2]);
    }

    /// Each folded row rule emits exactly the Packed path's bit: for any
    /// positive constant gamma, `bit == (gamma * row_dot_binarized > 0)` —
    /// across positive, negative, zero and NaN alphas, both layouts, even
    /// and odd widths.
    #[test]
    fn row_rule_bit_matches_packed_sign() {
        let mut rng = Rng::new(53);
        for (n, p) in [(40usize, 4usize), (33, 3), (70, 7)] {
            let m = 6usize;
            for alphas in [vec![0.5f32], vec![-0.5], vec![0.0], vec![f32::NAN]] {
                let rec = tiled_record_alphas("t", m, n, p, alphas.clone(), &mut rng);
                for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
                    let packed =
                        PackedLayer::from_record_mn_layout(&rec, m, n, layout).unwrap();
                    let thr = IntThresholds::from_layer(&packed);
                    let h = rng.normal_vec(n, 1.0);
                    let mut xw = Vec::new();
                    binarize_signs(&h, &mut xw);
                    for i in 0..m {
                        let want = 1.7f32 * packed.row_dot_binarized(i, &xw) > 0.0;
                        let got = packed.row_rule_bit_simd(thr.rules[i], i, &xw,
                                                           SimdBackend::Scalar);
                        assert_eq!(got, want,
                                   "n={n} alphas={alphas:?} {layout:?} row {i}");
                    }
                }
            }
        }
    }

    /// The bit-emitting batch kernel writes exactly the per-row rule bits
    /// (tail bits zero), and the word-split threaded variant is bit-exact
    /// against it at every thread count and SIMD backend, on both layouts —
    /// with m > 64 so the output spans multiple words.
    #[test]
    fn batch_bits_mt_bit_exact_vs_serial() {
        let mut rng = Rng::new(54);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            for mode in [AlphaMode::Single, AlphaMode::PerTile] {
                let (m, n, p) = (70usize, 70usize, 7usize);
                let rec = tiled_record("t", m, n, p, mode, &mut rng);
                let packed =
                    PackedLayer::from_record_mn_layout(&rec, m, n, layout).unwrap();
                let thr = IntThresholds::from_layer(&packed);
                let stride = n.div_ceil(64).max(1);
                let stride_out = m.div_ceil(64).max(1);
                let bsz = 5usize;
                let mut xws = vec![0u64; bsz * stride];
                for b in 0..bsz {
                    let h = rng.normal_vec(n, 1.0);
                    binarize_signs_into(&h, &mut xws[b * stride..(b + 1) * stride]);
                }
                let mut want = vec![u64::MAX; bsz * stride_out]; // stale bits cleared
                packed.forward_batch_bits_simd(&thr, &xws, stride, bsz, &mut want,
                                               stride_out, SimdBackend::Scalar);
                for b in 0..bsz {
                    let xw = &xws[b * stride..(b + 1) * stride];
                    for i in 0..m {
                        let bit = (want[b * stride_out + i / 64] >> (i % 64)) & 1 == 1;
                        assert_eq!(bit,
                                   packed.row_rule_bit_simd(thr.rules[i], i, xw,
                                                            SimdBackend::Scalar),
                                   "{layout:?} {mode:?} sample {b} row {i}");
                    }
                    for tail in m..stride_out * 64 {
                        assert_eq!((want[b * stride_out + tail / 64] >> (tail % 64)) & 1,
                                   0, "tail bit {tail}");
                    }
                }
                for simd in [SimdBackend::Scalar, SimdBackend::U64x4, SimdBackend::U128,
                             SimdBackend::Avx2] {
                    for threads in [1usize, 2, 3, 8, 64] {
                        let mut got = vec![u64::MAX; bsz * stride_out];
                        packed.forward_batch_bits_mt_simd(&thr, &xws, stride, bsz,
                                                          &mut got, stride_out,
                                                          threads, simd);
                        assert_eq!(got, want,
                                   "{layout:?} {mode:?} {simd} threads={threads}");
                    }
                }
            }
        }
    }
}
