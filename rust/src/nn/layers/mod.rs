//! Layer graph of the native engine: typed nodes with per-layer Reference
//! and Packed kernels, wired into a small DAG.
//!
//! The paper applies tiling to "both fully-connected and convolutional
//! layers"; this module is where both meet the native engine.  A [`Node`] is
//! one step of an inference graph:
//!
//! * [`FcLayer`] — a `[m, n]` weight layer served by the Algorithm 1 f32
//!   kernels (Reference) or the XNOR-popcount row kernels (Packed);
//! * [`Conv2dLayer`] — a 2-D convolution lowered to im2col patches that
//!   dispatch into the *same* packed row kernels, so conv and FC share one
//!   inner loop (`tbn::bitops::xnor_dot_words_range`);
//! * `Pool2d` / `GlobalPool` / `Flatten` — weightless shape plumbing that
//!   lets whole CNN specs (`arch::models`) run natively;
//! * `LayerNorm` / `TokenMeanPool` / `Transpose` / `PosEmbedAdd` — the
//!   transformer plumbing: per-token epsilon-stable normalization, the
//!   encoder head's token mean pool, the mixer's token<->channel
//!   transpose, and the learned positional-embedding add (an f32
//!   parameter node);
//! * `Add` / `MatMulFeature` / `Attention` — the multi-input **join**
//!   nodes: an elementwise residual join (ResNet skips, transformer
//!   residuals), the PointNet T-Net feature-transform apply (a `k x k`
//!   matrix from one branch multiplying the `(k, positions)` features of
//!   the other), and multi-head self-attention consuming Q/K/V slots
//!   (max-subtracted softmax over `QK^T / sqrt(d_h)` in f32).
//!
//! Nodes are wired into a [`Graph`]: each [`GraphNode`] names where every
//! input slot reads from ([`Slot::Source`] for the engine input,
//! [`Slot::Node`] for an earlier node's output), so activations are
//! addressable by node id and branches/skips are ordinary edges.  A linear
//! chain is the special case [`Graph::sequential`].
//!
//! [`lower_arch_spec`] converts an `arch::ArchSpec` into a graph, inferring
//! conv stride/padding from the spec's activation shapes and inserting
//! pooling nodes where consecutive specs imply spatial reduction.  Branching
//! constructs are rebuilt from the spec's `arch::BlockRole` annotations:
//! residual blocks (identity or 1x1-downsample skips, ReLU after the join),
//! T-Net subgraphs (transform head kept linear, then a `MatMulFeature`
//! join), and the transformer encoder sub-blocks (pre-LN attention and MLP
//! residuals, mixer token-mixing MLPs between transposes) — so ViT, TST
//! *and* MLP-Mixer specs run natively.  `nn::Engine` executes the graph
//! with a value-table walker.

mod conv;
mod fc;

pub use conv::Conv2dLayer;
pub use fc::FcLayer;

use std::sync::Arc;

use super::layer_resident_bytes;
use super::packed::{PackedLayer, PackedLayout};
use crate::arch::{ArchSpec, AttnPart, BlockRole, Kind, LayerSpec};
use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord, WeightPayload};
use crate::tensor::BitVec;
use crate::util::Rng;

/// Epsilon of the native `LayerNorm` node (torch's LayerNorm default): the
/// variance is stabilized as `1 / sqrt(var + eps)`, so all-constant tokens
/// normalize to exact zeros instead of dividing by zero.
pub const LN_EPS: f32 = 1e-5;

/// Pooling flavor for the weightless pool nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Avg,
    Max,
}

/// Reusable scratch buffers shared by the packed FC and conv kernels, so a
/// batch (or a serve worker) allocates them once:
///
/// * `words` — packed sign bits of the current activation / im2col patch;
/// * `patch` — f32 im2col staging buffer;
/// * `qi8` / `patch_i8` — layer-0 int8 input and its im2col staging;
/// * `batch_words` / `gammas` / `batch_out` — the batched packed path:
///   `B` packed activation-bit vectors side by side, their XNOR-Net
///   scales, and the per-batch output staging (conv scatters it back into
///   channel-major order);
/// * `attn` — the attention score matrix (`tokens x tokens` f32, reused
///   across heads and samples).
///
/// Under intra-op threading (`Engine::with_threads` / `TBN_THREADS`) the
/// threaded kernels hand each scoped thread a *disjoint chunk* of these
/// buffers (conv: its position range of `batch_words`/`gammas`/`batch_out`)
/// plus a small private patch buffer allocated once per call — the
/// per-thread scratch that keeps the inner loops zero-alloc without any
/// shared mutable state.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    pub words: Vec<u64>,
    pub patch: Vec<f32>,
    pub qi8: Vec<i8>,
    pub patch_i8: Vec<i8>,
    pub batch_words: Vec<u64>,
    pub gammas: Vec<f32>,
    pub batch_out: Vec<f32>,
    pub attn: Vec<f32>,
}

/// One node of the inference layer graph.  Activations flow through as flat
/// f32 vectors; conv/pool nodes interpret them channel-major `(c, h, w)`,
/// the transformer nodes channel-major `(dim, tokens)`.
///
/// `Add`, `MatMulFeature` and `Attention` are the multi-input **join**
/// nodes: they take two (`Add`/`MatMulFeature`) or three (`Attention`)
/// input slots (see [`GraphNode`]) and run through [`Node::forward_join`]
/// instead of [`Node::forward_reference`].  Joins are weightless and run in
/// f32 on every `EnginePath` — the packed paths binarize only weight-layer
/// inputs, so joins are exactly shared between the paths.
#[derive(Debug, Clone)]
pub enum Node {
    Fc(FcLayer),
    Conv2d(Conv2dLayer),
    /// Square-window pool with window = stride = `f` over a `(c, h, w)` map
    /// (`h` and `w` must be multiples of `f`).
    Pool2d { kind: PoolKind, c: usize, h: usize, w: usize, f: usize },
    /// Pool over all spatial/token positions: `(c, positions)` -> `(c,)`.
    GlobalPool { kind: PoolKind, c: usize, positions: usize },
    /// Shape bookkeeping only: activations are already flat.
    Flatten { len: usize },
    /// Elementwise residual join of two equal-length activations (slot 0:
    /// block body, slot 1: skip).  ResNet applies ReLU *after* the join, so
    /// the lowering forces the body's last conv linear and activates here;
    /// transformer residual joins stay linear.
    Add { len: usize },
    /// T-Net feature-transform apply: slot 0 carries `(k, positions)`
    /// channel-major features, slot 1 a row-major `k x k` transform matrix;
    /// the output is the transformed `(k, positions)` map
    /// `y[c', pos] = sum_c T[c', c] * x[c, pos]`.
    MatMulFeature { k: usize, positions: usize },
    /// Per-token layer normalization over a channel-major `(c, positions)`
    /// map: each token (position) is normalized across its `c` channels
    /// with the epsilon-stabilized variance ([`LN_EPS`]).  Weightless
    /// (unit gain, zero bias — norm scales are never quantized and the
    /// native weights are synthesized anyway).
    LayerNorm { c: usize, positions: usize, eps: f32 },
    /// Multi-head self-attention over channel-major `(dim, tokens)` maps:
    /// slots `[Q, K, V]` each carry a projected `(dim, tokens)` map, and
    /// the output is `softmax(Q_h^T K_h / sqrt(dim/heads)) V_h^T` per head
    /// `h`, concatenated back to `(dim, tokens)`.  Softmax rows are
    /// max-subtracted before exponentiation (overflow-stable); the whole
    /// node runs in f32 on every path.
    Attention { heads: usize, dim: usize, tokens: usize },
    /// Mean over the token axis of a `(c, positions)` map -> `(c,)`: the
    /// transformer classification/forecast head's pooling (same math as an
    /// average [`Node::GlobalPool`], kept distinct so transformer graphs
    /// and their stats read as such).
    TokenMeanPool { c: usize, positions: usize },
    /// Channel-major transpose `(c, positions)` -> `(positions, c)`:
    /// `y[t * c + d] = x[d * positions + t]`.  The mixer token-mixing MLPs
    /// run between a transpose pair so their FCs mix the token axis.
    Transpose { c: usize, positions: usize },
    /// Learned positional-embedding add: `y = x + emb` elementwise.  The
    /// table is an f32 parameter (never quantized, matching the paper's
    /// treatment of embeddings) shared behind an `Arc`.
    PosEmbedAdd { emb: Arc<Vec<f32>> },
}

impl Node {
    pub fn name(&self) -> &str {
        match self {
            Node::Fc(l) => &l.record.name,
            Node::Conv2d(l) => &l.record.name,
            Node::Pool2d { .. } => "pool2d",
            Node::GlobalPool { .. } => "global_pool",
            Node::Flatten { .. } => "flatten",
            Node::Add { .. } => "add",
            Node::MatMulFeature { .. } => "matmul_feature",
            Node::LayerNorm { .. } => "layer_norm",
            Node::Attention { .. } => "attention",
            Node::TokenMeanPool { .. } => "token_mean_pool",
            Node::Transpose { .. } => "transpose",
            Node::PosEmbedAdd { .. } => "pos_embed_add",
        }
    }

    pub fn in_len(&self) -> usize {
        match self {
            Node::Fc(l) => l.n,
            Node::Conv2d(l) => l.in_len(),
            Node::Pool2d { c, h, w, .. } => c * h * w,
            Node::GlobalPool { c, positions, .. } => c * positions,
            Node::Flatten { len } => *len,
            Node::Add { len } => *len,
            Node::MatMulFeature { k, positions } => k * positions,
            Node::LayerNorm { c, positions, .. } => c * positions,
            Node::Attention { dim, tokens, .. } => dim * tokens,
            Node::TokenMeanPool { c, positions } => c * positions,
            Node::Transpose { c, positions } => c * positions,
            Node::PosEmbedAdd { emb } => emb.len(),
        }
    }

    pub fn out_len(&self) -> usize {
        match self {
            Node::Fc(l) => l.m,
            Node::Conv2d(l) => l.out_len(),
            Node::Pool2d { c, h, w, f, .. } => c * (h / f) * (w / f),
            Node::GlobalPool { c, .. } => *c,
            Node::Flatten { len } => *len,
            Node::Add { len } => *len,
            Node::MatMulFeature { k, positions } => k * positions,
            Node::LayerNorm { c, positions, .. } => c * positions,
            Node::Attention { dim, tokens, .. } => dim * tokens,
            Node::TokenMeanPool { c, .. } => *c,
            Node::Transpose { c, positions } => c * positions,
            Node::PosEmbedAdd { emb } => emb.len(),
        }
    }

    /// Number of input slots: 1 for the chain nodes, 2 for `Add` /
    /// `MatMulFeature`, 3 for `Attention` (Q, K, V).
    pub fn arity(&self) -> usize {
        match self {
            Node::Add { .. } | Node::MatMulFeature { .. } => 2,
            Node::Attention { .. } => 3,
            _ => 1,
        }
    }

    /// True for the multi-input join nodes (`Add` / `MatMulFeature` /
    /// `Attention`).
    pub fn is_join(&self) -> bool {
        self.arity() > 1
    }

    /// Expected input length of slot `slot` (join nodes have per-slot
    /// shapes; unary nodes answer [`Node::in_len`] for slot 0).
    pub fn slot_in_len(&self, slot: usize) -> usize {
        match self {
            Node::MatMulFeature { k, positions } if slot == 0 => k * positions,
            Node::MatMulFeature { k, .. } => k * k,
            _ => self.in_len(),
        }
    }

    /// Weight-bearing nodes (the ones ReLU and packing apply to).
    pub fn is_weight(&self) -> bool {
        matches!(self, Node::Fc(_) | Node::Conv2d(_))
    }

    /// The TBNZ record behind a weight node.
    pub fn record(&self) -> Option<&LayerRecord> {
        match self {
            Node::Fc(l) => Some(l.record.as_ref()),
            Node::Conv2d(l) => Some(l.record.as_ref()),
            _ => None,
        }
    }

    /// Weight bytes resident on the reference path (sub-bit tiles stay
    /// packed); the pos-embed table is a resident f32 parameter on every
    /// path; weightless nodes are free.
    pub fn resident_bytes_reference(&self) -> usize {
        match self {
            Node::PosEmbedAdd { emb } => 4 * emb.len(),
            _ => self.record().map(layer_resident_bytes).unwrap_or(0),
        }
    }

    /// Serialized parameter bits carried outside a `LayerRecord` (the
    /// learned pos-embedding table: fp32, never quantized).
    pub fn extra_param_bits(&self) -> usize {
        match self {
            Node::PosEmbedAdd { emb } => 32 * emb.len(),
            _ => 0,
        }
    }

    /// f32 scratch this node's forward stages on *every* path: the
    /// attention score matrix (`tokens x tokens`, reused across heads); 0
    /// for everything else.  `Engine::peak_memory_bytes` charges this term
    /// unconditionally — the context accumulator is the node's output
    /// buffer, which the peak model already counts.
    pub fn f32_scratch_bytes(&self) -> usize {
        match self {
            Node::Attention { tokens, .. } => 4 * tokens * tokens,
            _ => 0,
        }
    }

    /// Scratch staging bytes this node's *packed* batch-1 forward holds
    /// live on top of weights and in/out activations: a packed conv stages
    /// the whole binarized im2col map (`area` packed patch vectors), its
    /// per-position gammas and a position-major output copy; a packed FC
    /// stages one packed activation vector.  `Engine::peak_memory_bytes`
    /// adds this term for nodes that run packed.
    pub fn packed_scratch_bytes(&self) -> usize {
        match self {
            Node::Fc(l) => 8 * l.n.div_ceil(64).max(1),
            Node::Conv2d(c) => {
                let area = c.h_out * c.w_out;
                let stride = c.patch_len().div_ceil(64).max(1);
                8 * area * stride + 4 * area + 4 * area * (c.co / c.groups)
            }
            _ => 0,
        }
    }

    /// Build the packed per-layer state for a weight node (`None` for
    /// weightless nodes) under the given weight layout.
    pub(crate) fn build_packed(&self, layout: PackedLayout)
                               -> Result<Option<PackedLayer>, String> {
        match self {
            Node::Fc(l) => l.build_packed(layout).map(Some),
            Node::Conv2d(l) => l.build_packed(layout).map(Some),
            _ => Ok(None),
        }
    }

    /// Reference (f32) forward of this node.  Join nodes take multiple
    /// inputs and run through [`Node::forward_join`] instead.
    pub fn forward_reference(&self, x: &[f32], relu: bool, scratch: &mut Scratch) -> Vec<f32> {
        match self {
            Node::Fc(l) => l.forward_reference(x, relu),
            Node::Conv2d(l) => l.forward_reference(x, relu, scratch),
            Node::Pool2d { kind, c, h, w, f } => pool2d(*kind, *c, *h, *w, *f, x),
            Node::GlobalPool { kind, c, positions } => global_pool(*kind, *c, *positions, x),
            Node::Flatten { .. } => x.to_vec(),
            Node::LayerNorm { c, positions, eps } => layer_norm(*c, *positions, *eps, x),
            Node::TokenMeanPool { c, positions } => {
                global_pool(PoolKind::Avg, *c, *positions, x)
            }
            Node::Transpose { c, positions } => transpose_cp(*c, *positions, x),
            Node::PosEmbedAdd { emb } => {
                debug_assert_eq!(x.len(), emb.len());
                x.iter()
                    .zip(emb.iter())
                    .map(|(v, e)| {
                        let s = v + e;
                        if relu { s.max(0.0) } else { s }
                    })
                    .collect()
            }
            Node::Add { .. } | Node::MatMulFeature { .. } | Node::Attention { .. } => {
                unreachable!("join nodes take multiple inputs; use Node::forward_join")
            }
        }
    }

    /// Forward of a multi-input join node (`inputs` holds one slice per
    /// slot, `self.arity()` of them).  Identical on every `EnginePath`:
    /// joins are weightless, so there is nothing to binarize or pack.
    pub fn forward_join(&self, inputs: &[&[f32]], relu: bool,
                        scratch: &mut Scratch) -> Vec<f32> {
        debug_assert_eq!(inputs.len(), self.arity());
        match self {
            Node::Add { len } => {
                let (a, b) = (inputs[0], inputs[1]);
                debug_assert_eq!(a.len(), *len);
                debug_assert_eq!(b.len(), *len);
                a.iter()
                    .zip(b)
                    .map(|(u, v)| {
                        let s = u + v;
                        if relu { s.max(0.0) } else { s }
                    })
                    .collect()
            }
            Node::MatMulFeature { k, positions } => {
                let (k, positions) = (*k, *positions);
                let (a, b) = (inputs[0], inputs[1]);
                debug_assert_eq!(a.len(), k * positions);
                debug_assert_eq!(b.len(), k * k);
                let mut y = vec![0.0f32; k * positions];
                for co in 0..k {
                    let row = &b[co * k..(co + 1) * k];
                    let out = &mut y[co * positions..(co + 1) * positions];
                    for (ci, &t) in row.iter().enumerate() {
                        let plane = &a[ci * positions..(ci + 1) * positions];
                        for (o, &v) in out.iter_mut().zip(plane) {
                            *o += t * v;
                        }
                    }
                    if relu {
                        for o in out.iter_mut() {
                            *o = o.max(0.0);
                        }
                    }
                }
                y
            }
            Node::Attention { heads, dim, tokens } => {
                let (heads, dim, tokens) = (*heads, *dim, *tokens);
                let (q, k, v) = (inputs[0], inputs[1], inputs[2]);
                debug_assert!(heads > 0 && dim % heads == 0);
                debug_assert_eq!(q.len(), dim * tokens);
                debug_assert_eq!(k.len(), dim * tokens);
                debug_assert_eq!(v.len(), dim * tokens);
                let dh = dim / heads;
                let scale = 1.0 / (dh as f32).sqrt();
                scratch.attn.clear();
                scratch.attn.resize(tokens * tokens, 0.0);
                let mut y = vec![0.0f32; dim * tokens];
                for h in 0..heads {
                    let d0 = h * dh;
                    // raw scores: s[t1, t2] = <Q[:, t1], K[:, t2]> over the
                    // head's channels (channel-outer walk keeps the token
                    // rows contiguous)
                    for s in scratch.attn.iter_mut() {
                        *s = 0.0;
                    }
                    for d in d0..d0 + dh {
                        let qrow = &q[d * tokens..(d + 1) * tokens];
                        let krow = &k[d * tokens..(d + 1) * tokens];
                        for (t1, &qv) in qrow.iter().enumerate() {
                            let srow =
                                &mut scratch.attn[t1 * tokens..(t1 + 1) * tokens];
                            for (s, &kv) in srow.iter_mut().zip(krow) {
                                *s += qv * kv;
                            }
                        }
                    }
                    // scale + stable softmax per query row
                    for t1 in 0..tokens {
                        let srow = &mut scratch.attn[t1 * tokens..(t1 + 1) * tokens];
                        for s in srow.iter_mut() {
                            *s *= scale;
                        }
                        softmax_inplace(srow);
                    }
                    // context: y[d, t1] = sum_t2 p[t1, t2] * V[d, t2]
                    for d in d0..d0 + dh {
                        let vrow = &v[d * tokens..(d + 1) * tokens];
                        let yrow = &mut y[d * tokens..(d + 1) * tokens];
                        for (t1, yv) in yrow.iter_mut().enumerate() {
                            let prow = &scratch.attn[t1 * tokens..(t1 + 1) * tokens];
                            let mut acc = 0.0f32;
                            for (&p, &vv) in prow.iter().zip(vrow) {
                                acc += p * vv;
                            }
                            *yv = acc;
                        }
                    }
                }
                if relu {
                    for o in y.iter_mut() {
                        *o = o.max(0.0);
                    }
                }
                y
            }
            _ => unreachable!("forward_join is only defined for join nodes"),
        }
    }
}

// ---------------------------------------------------------------------------
// Graph wiring
// ---------------------------------------------------------------------------

/// Where a graph node reads one input slot from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// The engine's input sample.
    Source,
    /// The output of graph node `id` (which must precede the consumer).
    Node(usize),
}

/// One node of a layer DAG: the compute [`Node`] plus where each of its
/// input slots reads from and an optional ReLU override.
#[derive(Debug, Clone)]
pub struct GraphNode {
    pub node: Node,
    /// One entry per input slot (`node.arity()` of them; for
    /// `MatMulFeature`: `[features, transform]`, for `Add`:
    /// `[body, skip]`).
    pub inputs: Vec<Slot>,
    /// ReLU policy: `None` follows the engine default (activate after every
    /// weight node except the final weight layer); `Some(true)` activates
    /// here (still gated on the engine's nonlinearity); `Some(false)`
    /// forces the node linear (e.g. a residual body's last conv, whose
    /// activation moves after the join).
    pub relu: Option<bool>,
}

/// A layer DAG in topological order: node `i` may only read `Slot::Node(j)`
/// with `j < i`; the last node's output is the graph output.  `nn::Engine`
/// validates the wiring and executes the graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<GraphNode>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph { nodes: Vec::new() }
    }

    /// Wrap a linear chain: node 0 reads the source, node `i` reads node
    /// `i - 1` — the sequential special case every pre-DAG engine ran.
    pub fn sequential(nodes: Vec<Node>) -> Graph {
        let nodes = nodes
            .into_iter()
            .enumerate()
            .map(|(i, node)| GraphNode {
                node,
                inputs: vec![if i == 0 { Slot::Source } else { Slot::Node(i - 1) }],
                relu: None,
            })
            .collect();
        Graph { nodes }
    }

    /// Append a node reading `inputs` under the default ReLU policy;
    /// returns the new node's output slot.
    pub fn push(&mut self, node: Node, inputs: Vec<Slot>) -> Slot {
        self.push_with_relu(node, inputs, None)
    }

    /// [`Graph::push`] with an explicit ReLU override.
    pub fn push_with_relu(&mut self, node: Node, inputs: Vec<Slot>,
                          relu: Option<bool>) -> Slot {
        self.nodes.push(GraphNode { node, inputs, relu });
        Slot::Node(self.nodes.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

fn pool2d(kind: PoolKind, c: usize, h: usize, w: usize, f: usize, x: &[f32]) -> Vec<f32> {
    debug_assert!(f > 0 && h % f == 0 && w % f == 0);
    debug_assert_eq!(x.len(), c * h * w);
    let (ho, wo) = (h / f, w / f);
    let mut y = vec![0.0f32; c * ho * wo];
    for ch in 0..c {
        let plane = &x[ch * h * w..(ch + 1) * h * w];
        for oy in 0..ho {
            for ox in 0..wo {
                let mut acc = match kind {
                    PoolKind::Avg => 0.0f32,
                    PoolKind::Max => f32::NEG_INFINITY,
                };
                for ky in 0..f {
                    for kx in 0..f {
                        let v = plane[(oy * f + ky) * w + ox * f + kx];
                        match kind {
                            PoolKind::Avg => acc += v,
                            PoolKind::Max => acc = acc.max(v),
                        }
                    }
                }
                if kind == PoolKind::Avg {
                    acc /= (f * f) as f32;
                }
                y[(ch * ho + oy) * wo + ox] = acc;
            }
        }
    }
    y
}

/// Numerically stable softmax over `row` in place: max-subtracted before
/// exponentiation, so huge logits cannot overflow (`exp(x - max) <= 1` and
/// the denominator is at least 1 — the max element contributes `exp(0)`).
fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut denom = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        denom += *v;
    }
    for v in row.iter_mut() {
        *v /= denom;
    }
}

/// Per-token layer normalization over a channel-major `(c, positions)`
/// map: token `t` is normalized across its `c` channel values, with the
/// biased variance stabilized by `eps` (all-constant tokens normalize to
/// exact zeros instead of dividing by zero).
fn layer_norm(c: usize, positions: usize, eps: f32, x: &[f32]) -> Vec<f32> {
    debug_assert!(c > 0 && positions > 0);
    debug_assert_eq!(x.len(), c * positions);
    let mut y = vec![0.0f32; x.len()];
    for t in 0..positions {
        let mut mean = 0.0f32;
        for d in 0..c {
            mean += x[d * positions + t];
        }
        mean /= c as f32;
        let mut var = 0.0f32;
        for d in 0..c {
            let dv = x[d * positions + t] - mean;
            var += dv * dv;
        }
        var /= c as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for d in 0..c {
            y[d * positions + t] = (x[d * positions + t] - mean) * inv;
        }
    }
    y
}

/// Channel-major transpose `(c, positions)` -> `(positions, c)`:
/// `y[t * c + d] = x[d * positions + t]`.
fn transpose_cp(c: usize, positions: usize, x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), c * positions);
    let mut y = vec![0.0f32; x.len()];
    for d in 0..c {
        let plane = &x[d * positions..(d + 1) * positions];
        for (t, &v) in plane.iter().enumerate() {
            y[t * c + d] = v;
        }
    }
    y
}

fn global_pool(kind: PoolKind, c: usize, positions: usize, x: &[f32]) -> Vec<f32> {
    debug_assert!(positions > 0);
    debug_assert_eq!(x.len(), c * positions);
    (0..c)
        .map(|ch| {
            let plane = &x[ch * positions..(ch + 1) * positions];
            match kind {
                PoolKind::Avg => plane.iter().sum::<f32>() / positions as f32,
                PoolKind::Max => plane.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// ArchSpec lowering
// ---------------------------------------------------------------------------

/// Options for lowering an `arch::ArchSpec` into a native layer graph.
#[derive(Debug, Clone)]
pub struct LowerOptions {
    /// Input tensor as `(channels, height, width)`; use `(c, n, 1)` for
    /// point-cloud / token inputs.
    pub input: (usize, usize, usize),
    /// Tiles per layer for the synthesized Tiled payloads (layers whose
    /// param count `p` does not divide fall back to 1-bit Bwnn, mirroring
    /// the exporter).
    pub p: usize,
    pub alpha_mode: AlphaMode,
    /// Seed for the synthesized weights: the graph structure is exact, the
    /// weights are drawn (no trained conv checkpoints exist natively yet).
    pub seed: u64,
}

impl Default for LowerOptions {
    fn default() -> Self {
        LowerOptions {
            input: (3, 32, 32),
            p: 4,
            alpha_mode: AlphaMode::PerTile,
            seed: 0,
        }
    }
}

fn isqrt(x: usize) -> usize {
    (x as f64).sqrt().round() as usize
}

/// Synthesize a payload for `params` drawn weights: Tiled at `p` when it
/// divides, else 1-bit Bwnn (the exporter's binarize fallback).
fn synth_payload(params: usize, opts: &LowerOptions, rng: &mut Rng) -> WeightPayload {
    let w = rng.normal_vec(params, 1.0);
    if opts.p > 1 && params % opts.p == 0 {
        WeightPayload::Tiled {
            p: opts.p,
            tile: tile_from_weights(&w, opts.p),
            alphas: alphas_from(&w, opts.p, opts.alpha_mode),
        }
    } else {
        WeightPayload::Bwnn {
            bits: BitVec::from_signs(&w),
            alpha: w.iter().map(|x| x.abs()).sum::<f32>() / params.max(1) as f32,
        }
    }
}

/// Shape-tracking cursor of the lowering: the slot holding the current
/// activation and its `(c, h, w)` interpretation.  Branch lowering clones
/// the cursor at a block entry and walks each branch independently.
#[derive(Debug, Clone, Copy)]
struct Cursor {
    slot: Slot,
    c: usize,
    h: usize,
    w: usize,
}

impl Cursor {
    fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }
}

/// Insert pooling so the cursor's `(c, h, w)` activation matches the next
/// layer's expected flat input length `want`.
fn reconcile(graph: &mut Graph, cur: &mut Cursor, want: usize, at: &str)
             -> Result<(), String> {
    if cur.len() == want {
        return Ok(());
    }
    if want == cur.c && cur.h * cur.w > 1 {
        cur.slot = graph.push(
            Node::GlobalPool { kind: PoolKind::Avg, c: cur.c, positions: cur.h * cur.w },
            vec![cur.slot]);
        cur.h = 1;
        cur.w = 1;
        return Ok(());
    }
    if want % cur.c == 0 {
        let next_pos = want / cur.c;
        let cur_pos = cur.h * cur.w;
        if next_pos > 0 && cur_pos % next_pos == 0 {
            let factor = cur_pos / next_pos;
            let f = isqrt(factor);
            if f > 1 && f * f == factor && cur.h % f == 0 && cur.w % f == 0 {
                cur.slot = graph.push(
                    Node::Pool2d { kind: PoolKind::Avg, c: cur.c, h: cur.h, w: cur.w, f },
                    vec![cur.slot]);
                cur.h /= f;
                cur.w /= f;
                return Ok(());
            }
        }
    }
    Err(format!(
        "{at}: cannot reconcile activation ({} x {} x {} = {}) with expected \
         input {want} — unannotated non-sequential spec or unsupported pooling",
        cur.c, cur.h, cur.w, cur.len()
    ))
}

/// Infer `(stride, pad_lo, pad_hi)` mapping `h_in -> h_out` with kernel `k`
/// under the standard floor conv arithmetic
/// `h_out = (h_in + pad_lo + pad_hi - k) / s + 1`.
fn infer_stride_pad(h_in: usize, h_out: usize, k: usize)
                    -> Option<(usize, usize, usize)> {
    for s in 1..=8usize {
        for pad_lo in 0..=k {
            for pad_hi in [pad_lo, pad_lo + 1] {
                let padded = h_in + pad_lo + pad_hi;
                if padded < k {
                    continue;
                }
                if (padded - k) / s + 1 == h_out {
                    return Some((s, pad_lo, pad_hi));
                }
            }
        }
    }
    None
}

/// Lower one weight layer (plus any implied pooling/flatten plumbing) onto
/// the cursor's branch.
fn lower_layer(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions, cur: &mut Cursor,
               spec_name: &str, l: &LayerSpec) -> Result<(), String> {
    let at = format!("{spec_name}::{}", l.name);
    match l.kind {
        Kind::Other => {
            // a learned positional embedding lowers to a PosEmbedAdd
            // parameter node (drawn at ViT's 0.02 init scale); one that
            // does not match the current activation fails loudly — a
            // mis-placed/mis-sized pos_embed must not be silently dropped
            // from the graph.  Every other `Other` record (norm scales,
            // ...) carries no MACs and is skipped as before.
            if l.name.ends_with("pos_embed") {
                if l.params != cur.len() || l.params == 0 {
                    return Err(format!(
                        "{at}: pos_embed carries {} params but the activation here \
                         is {} x {} x {} = {} elements — cannot lower the \
                         positional embedding",
                        l.params, cur.c, cur.h, cur.w, cur.len()
                    ));
                }
                let emb = Arc::new(rng.normal_vec(l.params, 0.02));
                cur.slot = graph.push(Node::PosEmbedAdd { emb }, vec![cur.slot]);
            }
            Ok(())
        }
        Kind::Conv { co, ci, kh, kw } => {
            reconcile(graph, cur, l.in_act, &at)?;
            if ci == 0 || cur.c % ci != 0 {
                return Err(format!("{at}: weight ci {ci} does not divide {} channels", cur.c));
            }
            let groups = cur.c / ci;
            if co % groups != 0 {
                return Err(format!("{at}: co {co} not a multiple of {groups} groups"));
            }
            if l.out_act % co != 0 {
                return Err(format!("{at}: out_act {} not a multiple of co {co}", l.out_act));
            }
            let area = l.out_act / co;
            let (h_out, w_out) = if cur.w == 1 {
                (area, 1)
            } else {
                let s = isqrt(area);
                if s * s != area {
                    return Err(format!("{at}: non-square output area {area}"));
                }
                (s, s)
            };
            let (stride, pad_lo, _pad_hi) = infer_stride_pad(cur.h, h_out, kh)
                .ok_or_else(|| {
                    format!("{at}: no stride/padding maps {} -> {h_out} with k={kh}", cur.h)
                })?;
            let record = LayerRecord {
                name: l.name.clone(),
                shape: vec![co, ci, kh, kw],
                payload: synth_payload(l.params, opts, rng),
            };
            let conv = Conv2dLayer::with_output(
                record, cur.shape(), stride, pad_lo, (h_out, w_out), groups)?;
            cur.slot = graph.push(Node::Conv2d(conv), vec![cur.slot]);
            cur.c = co;
            cur.h = h_out;
            cur.w = w_out;
            Ok(())
        }
        Kind::Fc { co, ci } => {
            if ci == 0 || l.in_act % ci != 0 {
                return Err(format!("{at}: in_act {} not a multiple of ci {ci}", l.in_act));
            }
            let tokens = l.in_act / ci;
            reconcile(graph, cur, l.in_act, &at)?;
            let record_payload = synth_payload(l.params, opts, rng);
            if tokens == 1 {
                // plain FC over the flattened activation
                if cur.h * cur.w > 1 {
                    cur.slot = graph.push(Node::Flatten { len: ci }, vec![cur.slot]);
                }
                let record = LayerRecord {
                    name: l.name.clone(),
                    shape: vec![co, ci],
                    payload: record_payload,
                };
                cur.slot = graph.push(Node::Fc(FcLayer::from_record(record)?),
                                      vec![cur.slot]);
                cur.c = co;
                cur.h = 1;
                cur.w = 1;
            } else {
                // token-wise shared MLP: a 1x1 conv over the token axis
                if cur.c != ci || cur.h * cur.w != tokens {
                    return Err(format!(
                        "{at}: token FC expects ({ci} ch x {tokens} pos), have \
                         ({} x {} x {}) — unannotated token-mixing layers are \
                         unsupported (tag them BlockRole::TokenMix)",
                        cur.c, cur.h, cur.w
                    ));
                }
                let record = LayerRecord {
                    name: l.name.clone(),
                    shape: vec![co, ci, 1, 1],
                    payload: record_payload,
                };
                let conv = Conv2dLayer::with_output(
                    record, cur.shape(), 1, 0, (cur.h, cur.w), 1)?;
                cur.slot = graph.push(Node::Conv2d(conv), vec![cur.slot]);
                cur.c = co;
            }
            Ok(())
        }
    }
}

/// Force the last weight node pushed at-or-after `start` linear (its
/// activation moves after a join), returning whether one was found.
fn suppress_relu_after_last_weight(graph: &mut Graph, start: usize) -> bool {
    for gn in graph.nodes[start..].iter_mut().rev() {
        if gn.node.is_weight() {
            gn.relu = Some(false);
            return true;
        }
    }
    false
}

/// Lower one residual block: the body chains from the block entry, the
/// optional downsample projection branches from the same entry, and an
/// `Add` joins the two (ReLU after the join, body's last conv linear — the
/// standard ResNet placement).
#[allow(clippy::too_many_arguments)]
fn lower_residual_block(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions,
                        cur: &mut Cursor, spec_name: &str, id: &str,
                        body: &[&LayerSpec], downsample: Option<&LayerSpec>)
                        -> Result<(), String> {
    let entry = *cur;
    let body_start = graph.len();
    for &l in body {
        lower_layer(graph, rng, opts, cur, spec_name, l)?;
    }
    if !suppress_relu_after_last_weight(graph, body_start) {
        return Err(format!("{spec_name}::{id}: residual block has no weight layers"));
    }
    let skip = match downsample {
        Some(l) => {
            let mut dcur = entry;
            let down_start = graph.len();
            lower_layer(graph, rng, opts, &mut dcur, spec_name, l)?;
            // the projection shortcut is linear too: both join operands
            // activate only after the Add (standard ResNet placement)
            suppress_relu_after_last_weight(graph, down_start);
            if dcur.shape() != cur.shape() {
                return Err(format!(
                    "{spec_name}::{id}: skip shape mismatch — downsample produced \
                     {}x{}x{}, body {}x{}x{}",
                    dcur.c, dcur.h, dcur.w, cur.c, cur.h, cur.w
                ));
            }
            dcur.slot
        }
        None => {
            if entry.shape() != cur.shape() {
                return Err(format!(
                    "{spec_name}::{id}: skip shape mismatch — identity skip is \
                     {}x{}x{} but the body produces {}x{}x{} (the block needs a \
                     downsample projection)",
                    entry.c, entry.h, entry.w, cur.c, cur.h, cur.w
                ));
            }
            entry.slot
        }
    };
    cur.slot = graph.push_with_relu(Node::Add { len: cur.len() },
                                    vec![cur.slot, skip], Some(true));
    Ok(())
}

/// Lower one T-Net: the subgraph branches off the current `(k, positions)`
/// features, must end in a `k*k` transform vector (its head kept linear),
/// and a `MatMulFeature` applies the transform to the entry features.
#[allow(clippy::too_many_arguments)]
fn lower_tnet(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions, cur: &mut Cursor,
              spec_name: &str, id: &str, k: usize, body: &[&LayerSpec])
              -> Result<(), String> {
    let entry = *cur;
    if entry.c != k {
        return Err(format!(
            "{spec_name}::{id}: T-Net k mismatch — transform is {k}x{k} but the \
             features entering the subgraph have {} channels",
            entry.c
        ));
    }
    let positions = entry.h * entry.w;
    let body_start = graph.len();
    let mut tcur = entry;
    for &l in body {
        lower_layer(graph, rng, opts, &mut tcur, spec_name, l)?;
    }
    if !suppress_relu_after_last_weight(graph, body_start) {
        return Err(format!("{spec_name}::{id}: T-Net subgraph has no weight layers"));
    }
    if tcur.len() != k * k {
        return Err(format!(
            "{spec_name}::{id}: T-Net k mismatch — the subgraph ends in {} values \
             but a {k}x{k} transform needs {}",
            tcur.len(),
            k * k
        ));
    }
    cur.slot = graph.push_with_relu(Node::MatMulFeature { k, positions },
                                    vec![entry.slot, tcur.slot], Some(false));
    cur.c = k;
    cur.h = entry.h;
    cur.w = entry.w;
    Ok(())
}

/// Build one token-wise FC — a 1x1 conv over the token axis at input shape
/// `(ci, h, w)` — with a synthesized payload: the shared projection
/// constructor of the encoder lowering.  On the packed paths the conv
/// batches every token through `PackedLayer::forward_batch_binarized_rows`,
/// so all tokens hit the (shift-stitched, tile-resident) row kernel in one
/// call.
fn token_fc_node(rng: &mut Rng, opts: &LowerOptions, l: &LayerSpec, co: usize,
                 ci: usize, h: usize, w: usize) -> Result<Node, String> {
    let record = LayerRecord {
        name: l.name.clone(),
        shape: vec![co, ci, 1, 1],
        payload: synth_payload(l.params, opts, rng),
    };
    let conv = Conv2dLayer::with_output(record, (ci, h, w), 1, 0, (h, w), 1)?;
    Ok(Node::Conv2d(conv))
}

/// Extract `(co, ci)` of an FC-kind layer spec inside an encoder sub-block.
fn fc_dims(spec_name: &str, l: &LayerSpec) -> Result<(usize, usize), String> {
    match l.kind {
        Kind::Fc { co, ci } => Ok((co, ci)),
        _ => Err(format!(
            "{spec_name}::{}: encoder sub-block layers must be FC projections",
            l.name
        )),
    }
}

/// Lower one pre-LN attention sub-block: `LayerNorm -> Q/K/V token-FCs
/// (all reading the normalized features) -> Attention -> O token-FC ->
/// Add` with the block entry as the residual operand.  Every projection
/// stays linear and the join stays linear (the transformer stream carries
/// no ReLU; the MLP sub-block activates its hidden layer instead).
#[allow(clippy::too_many_arguments)]
fn lower_attention_block(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions,
                         cur: &mut Cursor, spec_name: &str, id: &str, heads: usize,
                         parts: &[(&LayerSpec, AttnPart)]) -> Result<(), String> {
    let (dim, tokens) = (cur.c, cur.h * cur.w);
    if heads == 0 || dim % heads != 0 {
        return Err(format!(
            "{spec_name}::{id}: {heads} heads do not divide dim {dim}"
        ));
    }
    let got: Vec<AttnPart> = parts.iter().map(|(_, p)| *p).collect();
    if got != [AttnPart::Q, AttnPart::K, AttnPart::V, AttnPart::O] {
        return Err(format!(
            "{spec_name}::{id}: attention sub-block needs exactly the Q, K, V, O \
             projections in order, got {got:?}"
        ));
    }
    for (l, part) in parts {
        let (co, ci) = fc_dims(spec_name, l)?;
        if ci != dim || co != dim || l.in_act != dim * tokens {
            return Err(format!(
                "{spec_name}::{}: {part:?} projection is {co}x{ci} over {} input \
                 activations, but the block's features are dim {dim} x {tokens} \
                 tokens (mismatched token counts?)",
                l.name, l.in_act
            ));
        }
    }
    let entry = *cur;
    let ln = graph.push(
        Node::LayerNorm { c: dim, positions: tokens, eps: LN_EPS }, vec![entry.slot]);
    let mut qkv = Vec::with_capacity(3);
    for (l, _) in &parts[..3] {
        let node = token_fc_node(rng, opts, l, dim, dim, entry.h, entry.w)?;
        qkv.push(graph.push_with_relu(node, vec![ln], Some(false)));
    }
    let attn = graph.push_with_relu(Node::Attention { heads, dim, tokens },
                                    vec![qkv[0], qkv[1], qkv[2]], Some(false));
    let o_node = token_fc_node(rng, opts, parts[3].0, dim, dim, entry.h, entry.w)?;
    let o = graph.push_with_relu(o_node, vec![attn], Some(false));
    cur.slot = graph.push_with_relu(Node::Add { len: dim * tokens },
                                    vec![o, entry.slot], Some(false));
    Ok(())
}

/// Lower one pre-LN MLP sub-block (transformer MLP / mixer channel MLP):
/// `LayerNorm -> fc1 (ReLU) -> fc2 -> Add` with the block entry as the
/// residual operand (fc2 and the join stay linear).
fn lower_mlp_block(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions,
                   cur: &mut Cursor, spec_name: &str, id: &str,
                   body: &[&LayerSpec]) -> Result<(), String> {
    let (dim, tokens) = (cur.c, cur.h * cur.w);
    if body.len() != 2 {
        return Err(format!(
            "{spec_name}::{id}: MLP sub-block needs exactly fc1 and fc2, got {} layers",
            body.len()
        ));
    }
    let (h1, d1) = fc_dims(spec_name, body[0])?;
    let (d2, h2) = fc_dims(spec_name, body[1])?;
    if d1 != dim || d2 != dim || h1 != h2 || body[0].in_act != dim * tokens {
        return Err(format!(
            "{spec_name}::{id}: MLP sub-block must map dim {dim} -> hidden -> dim \
             over {tokens} tokens, got {h1}x{d1} then {d2}x{h2} over {} input \
             activations",
            body[0].in_act
        ));
    }
    let entry = *cur;
    let ln = graph.push(
        Node::LayerNorm { c: dim, positions: tokens, eps: LN_EPS }, vec![entry.slot]);
    let fc1 = token_fc_node(rng, opts, body[0], h1, dim, entry.h, entry.w)?;
    let hidden = graph.push_with_relu(fc1, vec![ln], Some(true));
    let fc2 = token_fc_node(rng, opts, body[1], dim, h1, entry.h, entry.w)?;
    let out = graph.push_with_relu(fc2, vec![hidden], Some(false));
    cur.slot = graph.push_with_relu(Node::Add { len: dim * tokens },
                                    vec![out, entry.slot], Some(false));
    Ok(())
}

/// Lower one mixer token-mixing MLP sub-block: the same pre-LN MLP shape,
/// but run *transposed* so the FCs mix the token axis — `LayerNorm ->
/// Transpose -> fc1 (ReLU) -> fc2 -> Transpose -> Add`.
fn lower_token_mix_block(graph: &mut Graph, rng: &mut Rng, opts: &LowerOptions,
                         cur: &mut Cursor, spec_name: &str, id: &str,
                         body: &[&LayerSpec]) -> Result<(), String> {
    let (dim, tokens) = (cur.c, cur.h * cur.w);
    if body.len() != 2 {
        return Err(format!(
            "{spec_name}::{id}: token-mixing MLP needs exactly fc1 and fc2, got {} \
             layers",
            body.len()
        ));
    }
    let (h1, t1) = fc_dims(spec_name, body[0])?;
    let (t2, h2) = fc_dims(spec_name, body[1])?;
    if t1 != tokens || t2 != tokens || h1 != h2 || body[0].in_act != dim * tokens {
        return Err(format!(
            "{spec_name}::{id}: token-mixing MLP must map {tokens} tokens -> hidden \
             -> {tokens} tokens across dim {dim}, got {h1}x{t1} then {t2}x{h2} over \
             {} input activations (mismatched token counts?)",
            body[0].in_act
        ));
    }
    let entry = *cur;
    let ln = graph.push(
        Node::LayerNorm { c: dim, positions: tokens, eps: LN_EPS }, vec![entry.slot]);
    let t = graph.push(Node::Transpose { c: dim, positions: tokens }, vec![ln]);
    // transposed view: (tokens, dim) channel-major — token FCs over dim
    // positions
    let fc1 = token_fc_node(rng, opts, body[0], h1, tokens, dim, 1)?;
    let hidden = graph.push_with_relu(fc1, vec![t], Some(true));
    let fc2 = token_fc_node(rng, opts, body[1], tokens, h1, dim, 1)?;
    let mixed = graph.push_with_relu(fc2, vec![hidden], Some(false));
    let back = graph.push(Node::Transpose { c: tokens, positions: dim }, vec![mixed]);
    cur.slot = graph.push_with_relu(Node::Add { len: dim * tokens },
                                    vec![back, entry.slot], Some(false));
    Ok(())
}

/// Lower an `arch::ArchSpec` into a native layer [`Graph`].
///
/// Supported: plain conv stacks (square spatial maps, symmetric or
/// "same"-style asymmetric padding, grouped/depthwise convs), token-wise FC
/// layers (`fc_tok`, lowered to 1x1 convs over the token axis — PointNet's
/// shared MLPs), FC heads (global/spatial pooling plus a `Flatten` are
/// inserted automatically), `Kind::Other` records (a `pos_embed` sized to
/// the current activation lowers to a learned [`Node::PosEmbedAdd`]; every
/// other `Other` record is skipped — they carry no MACs), and the annotated
/// branching constructs (`arch::BlockRole`):
///
/// * **residual blocks** — consecutive `ResidualBody` layers chain from the
///   block entry; a `ResidualDown` layer (if present) lowers the 1x1
///   projection from the same entry; an `Add` node joins body and skip with
///   ReLU after the join (the body's final conv stays linear);
/// * **T-Nets** — consecutive `Tnet` layers form a subgraph from the
///   current `(k, positions)` features, ending in a linear `k*k` transform
///   that a `MatMulFeature` node applies back onto the entry features;
/// * **encoder attention sub-blocks** — four consecutive `AttnProj` layers
///   (Q, K, V, O) lower pre-LN to `LayerNorm -> Q/K/V token-FCs ->
///   Attention -> O token-FC -> Add` (everything linear: the transformer
///   stream carries no ReLU);
/// * **encoder / mixer MLP sub-blocks** — two consecutive `MlpBody` layers
///   lower to `LayerNorm -> fc1 (ReLU) -> fc2 -> Add`;
/// * **mixer token-mixing MLPs** — two consecutive `TokenMix` layers run
///   the same MLP shape between a [`Node::Transpose`] pair, so the FCs mix
///   the token axis.
///
/// A trunk FC head that follows encoder output gets the standard pre-LN
/// transformer treatment: a final `LayerNorm` + [`Node::TokenMeanPool`]
/// ahead of the projection.  So `vit_cifar` / `vit_small_imagenet` /
/// `tst_electricity` / `tst_weather` / `mlpmixer_cifar` (and the
/// `vit_micro` / `tst_micro` / `mixer_micro` minis) lower natively.
///
/// Mis-annotated specs fail with shape errors (mismatched skip shapes,
/// transform size != `k*k`, head count not dividing dim, mismatched token
/// counts, missing/mis-ordered Q/K/V/O projections); `Unsupported`-tagged
/// constructs (Swin shifted windows, MobileViT unfold/fold) fail naming
/// the construct, and unannotated branching (e.g. segmentation-head
/// feature concats) still fails at the shape reconciliation.
pub fn lower_arch_spec(spec: &ArchSpec, opts: &LowerOptions) -> Result<Graph, String> {
    let mut rng = Rng::new(opts.seed ^ 0x7B1E5);
    let (c, h, w) = opts.input;
    if c * h * w == 0 {
        return Err(format!("{}: empty lowering input", spec.name));
    }
    let mut graph = Graph::new();
    let mut cur = Cursor { slot: Slot::Source, c, h, w };
    let layers = &spec.layers;
    let mut i = 0usize;
    // true while the cursor carries encoder-block output: the next trunk FC
    // head gets the standard pre-LN transformer treatment (final LayerNorm
    // + TokenMeanPool) instead of the generic pooling reconciliation
    let mut encoder_tail = false;
    while i < layers.len() {
        match &layers[i].block {
            None => {
                let l = &layers[i];
                if encoder_tail {
                    if let Kind::Fc { ci, .. } = l.kind {
                        if l.in_act == ci && cur.c == ci && cur.h * cur.w > 1 {
                            let positions = cur.h * cur.w;
                            cur.slot = graph.push(
                                Node::LayerNorm { c: ci, positions, eps: LN_EPS },
                                vec![cur.slot]);
                            cur.slot = graph.push(
                                Node::TokenMeanPool { c: ci, positions },
                                vec![cur.slot]);
                            cur.h = 1;
                            cur.w = 1;
                        }
                    }
                }
                lower_layer(&mut graph, &mut rng, opts, &mut cur, &spec.name, l)?;
                if !matches!(l.kind, Kind::Other) {
                    encoder_tail = false;
                }
                i += 1;
            }
            Some(BlockRole::ResidualBody { id }) | Some(BlockRole::ResidualDown { id }) => {
                let id = id.clone();
                let mut body: Vec<&LayerSpec> = Vec::new();
                let mut downsample: Option<&LayerSpec> = None;
                while i < layers.len() {
                    match &layers[i].block {
                        Some(BlockRole::ResidualBody { id: j }) if *j == id => {
                            body.push(&layers[i]);
                            i += 1;
                        }
                        Some(BlockRole::ResidualDown { id: j }) if *j == id => {
                            if downsample.replace(&layers[i]).is_some() {
                                return Err(format!(
                                    "{}::{id}: residual block has two downsample layers",
                                    spec.name
                                ));
                            }
                            i += 1;
                        }
                        _ => break,
                    }
                }
                lower_residual_block(&mut graph, &mut rng, opts, &mut cur, &spec.name,
                                     &id, &body, downsample)?;
                encoder_tail = false;
            }
            Some(BlockRole::Tnet { id, k }) => {
                let (id, k) = (id.clone(), *k);
                let mut body: Vec<&LayerSpec> = Vec::new();
                while i < layers.len() {
                    match &layers[i].block {
                        Some(BlockRole::Tnet { id: j, k: kj }) if *j == id && *kj == k => {
                            body.push(&layers[i]);
                            i += 1;
                        }
                        _ => break,
                    }
                }
                lower_tnet(&mut graph, &mut rng, opts, &mut cur, &spec.name, &id, k,
                           &body)?;
                encoder_tail = false;
            }
            Some(BlockRole::AttnProj { id, heads, .. }) => {
                let (id, heads) = (id.clone(), *heads);
                let mut parts: Vec<(&LayerSpec, AttnPart)> = Vec::new();
                while i < layers.len() {
                    match &layers[i].block {
                        Some(BlockRole::AttnProj { id: j, heads: hj, part })
                            if *j == id && *hj == heads =>
                        {
                            parts.push((&layers[i], *part));
                            i += 1;
                        }
                        _ => break,
                    }
                }
                lower_attention_block(&mut graph, &mut rng, opts, &mut cur,
                                      &spec.name, &id, heads, &parts)?;
                encoder_tail = true;
            }
            Some(BlockRole::MlpBody { id }) => {
                let id = id.clone();
                let mut body: Vec<&LayerSpec> = Vec::new();
                while i < layers.len() {
                    match &layers[i].block {
                        Some(BlockRole::MlpBody { id: j }) if *j == id => {
                            body.push(&layers[i]);
                            i += 1;
                        }
                        _ => break,
                    }
                }
                lower_mlp_block(&mut graph, &mut rng, opts, &mut cur, &spec.name, &id,
                                &body)?;
                encoder_tail = true;
            }
            Some(BlockRole::TokenMix { id }) => {
                let id = id.clone();
                let mut body: Vec<&LayerSpec> = Vec::new();
                while i < layers.len() {
                    match &layers[i].block {
                        Some(BlockRole::TokenMix { id: j }) if *j == id => {
                            body.push(&layers[i]);
                            i += 1;
                        }
                        _ => break,
                    }
                }
                lower_token_mix_block(&mut graph, &mut rng, opts, &mut cur,
                                      &spec.name, &id, &body)?;
                encoder_tail = true;
            }
            Some(BlockRole::Unsupported { id, construct }) => {
                return Err(format!(
                    "{}::{id}: {construct} is not lowerable — the native engine has \
                     no graph node for it",
                    spec.name
                ));
            }
        }
    }
    if graph.is_empty() {
        return Err(format!("{}: nothing to lower", spec.name));
    }
    Ok(graph)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn pool2d_avg_and_max() {
        // one channel, 4x4, f=2
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let avg = pool2d(PoolKind::Avg, 1, 4, 4, 2, &x);
        assert_eq!(avg, vec![2.5, 4.5, 10.5, 12.5]);
        let max = pool2d(PoolKind::Max, 1, 4, 4, 2, &x);
        assert_eq!(max, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn pool2d_channel_major() {
        // two channels pool independently
        let mut x = vec![1.0f32; 4];
        x.extend(vec![3.0f32; 4]);
        let y = pool2d(PoolKind::Avg, 2, 2, 2, 2, &x);
        assert_eq!(y, vec![1.0, 3.0]);
    }

    #[test]
    fn global_pool_avg_and_max() {
        let x = vec![1.0f32, 2.0, 3.0, -1.0, -2.0, -3.0];
        assert_eq!(global_pool(PoolKind::Avg, 2, 3, &x), vec![2.0, -2.0]);
        assert_eq!(global_pool(PoolKind::Max, 2, 3, &x), vec![3.0, -1.0]);
    }

    #[test]
    fn infer_stride_pad_paper_cases() {
        // resnet stem on cifar: 3x3, 32 -> 32 => stride 1 pad 1
        assert_eq!(infer_stride_pad(32, 32, 3), Some((1, 1, 1)));
        // imagenet stem: 7x7, 224 -> 112 => stride 2 (minimal pads: 2 + 3)
        assert_eq!(infer_stride_pad(224, 112, 7), Some((2, 2, 3)));
        // vgg downsampling conv: 3x3, 32 -> 16 => stride 2, trailing pad 1
        assert_eq!(infer_stride_pad(32, 16, 3), Some((2, 0, 1)));
        // 1x1 downsample, 32 -> 16 => stride 2 pad 0
        assert_eq!(infer_stride_pad(32, 16, 1), Some((2, 0, 0)));
        // convmixer depthwise: 8x8 "same" => asymmetric (3, 4)
        assert_eq!(infer_stride_pad(32, 32, 8), Some((1, 3, 4)));
        // impossible mapping: upsampling beyond what padding can reach
        assert_eq!(infer_stride_pad(32, 1, 3), None);
    }

    #[test]
    fn node_shape_bookkeeping() {
        let n = Node::Pool2d { kind: PoolKind::Avg, c: 8, h: 4, w: 4, f: 2 };
        assert_eq!((n.in_len(), n.out_len()), (128, 32));
        assert!(!n.is_weight());
        assert_eq!(n.resident_bytes_reference(), 0);
        let g = Node::GlobalPool { kind: PoolKind::Max, c: 16, positions: 64 };
        assert_eq!((g.in_len(), g.out_len()), (1024, 16));
        let f = Node::Flatten { len: 40 };
        assert_eq!((f.in_len(), f.out_len()), (40, 40));
        let mut s = Scratch::default();
        assert_eq!(f.forward_reference(&[1.0; 40], false, &mut s), vec![1.0; 40]);
    }

    #[test]
    fn synth_payload_tiles_when_divisible() {
        let mut rng = Rng::new(1);
        let opts = LowerOptions::default();
        match synth_payload(64, &opts, &mut rng) {
            WeightPayload::Tiled { p, .. } => assert_eq!(p, 4),
            other => panic!("expected tiled, got {other:?}"),
        }
        match synth_payload(63, &opts, &mut rng) {
            WeightPayload::Bwnn { .. } => {}
            other => panic!("expected bwnn fallback, got {other:?}"),
        }
    }

    #[test]
    fn add_join_math_and_shape() {
        let add = Node::Add { len: 4 };
        assert_eq!(add.arity(), 2);
        assert!(add.is_join() && !add.is_weight());
        assert_eq!((add.in_len(), add.out_len()), (4, 4));
        assert_eq!((add.slot_in_len(0), add.slot_in_len(1)), (4, 4));
        assert_eq!(add.resident_bytes_reference(), 0);
        assert_eq!(add.packed_scratch_bytes(), 0);
        let mut s = Scratch::default();
        let a = [1.0f32, -2.0, 3.0, 0.5];
        let b = [1.0f32, 1.0, -4.0, 0.5];
        assert_eq!(add.forward_join(&[&a, &b], false, &mut s),
                   vec![2.0, -1.0, -1.0, 1.0]);
        assert_eq!(add.forward_join(&[&a, &b], true, &mut s),
                   vec![2.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn matmul_feature_applies_transform_per_position() {
        // 2x2 transform over 3 positions: y[c', p] = sum_c T[c', c] x[c, p]
        let mm = Node::MatMulFeature { k: 2, positions: 3 };
        assert_eq!(mm.arity(), 2);
        assert_eq!((mm.slot_in_len(0), mm.slot_in_len(1)), (6, 4));
        assert_eq!((mm.in_len(), mm.out_len()), (6, 6));
        let mut s = Scratch::default();
        let x = [1.0f32, 2.0, 3.0, // channel 0
                 4.0, 5.0, 6.0]; // channel 1
        let t = [1.0f32, 0.0, // row 0: identity on channel 0
                 1.0, 1.0]; // row 1: channel 0 + channel 1
        assert_eq!(mm.forward_join(&[&x, &t], false, &mut s),
                   vec![1.0, 2.0, 3.0, 5.0, 7.0, 9.0]);
        let neg_t = [-1.0f32, 0.0, 0.0, -1.0];
        let y = mm.forward_join(&[&x, &neg_t], true, &mut s);
        assert!(y.iter().all(|&v| v == 0.0), "relu clamps the negated map");
    }

    #[test]
    fn softmax_is_max_subtracted_and_normalized() {
        // huge logits must not overflow: exp(x - max) <= 1 by construction
        let mut row = [1.0e30f32, 1.0e30, -1.0e30];
        softmax_inplace(&mut row);
        assert!(row.iter().all(|v| v.is_finite()));
        assert!((row[0] - 0.5).abs() < 1e-6 && (row[1] - 0.5).abs() < 1e-6);
        assert_eq!(row[2], 0.0);
        // shift invariance: softmax(x + c) == softmax(x)
        let mut a = [0.3f32, -1.2, 2.5, 0.0];
        let mut b = [100.3f32, 98.8, 102.5, 100.0];
        softmax_inplace(&mut a);
        softmax_inplace(&mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
        let sum: f32 = a.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn layer_norm_normalizes_each_token_and_eps_stabilizes() {
        // two channels, three tokens, channel-major
        let x = [1.0f32, 5.0, 7.0, // channel 0
                 3.0, 5.0, 7.0]; // channel 1 (token 1 and 2 are constant)
        let y = layer_norm(2, 3, LN_EPS, &x);
        // token 0: mean 2, var 1 -> ±1/sqrt(1 + eps)
        let g = 1.0 / (1.0f32 + LN_EPS).sqrt();
        assert!((y[0] + g).abs() < 1e-5 && (y[3] - g).abs() < 1e-5);
        // constant tokens: variance 0 -> exact zeros, no NaN/inf (epsilon)
        assert_eq!(y[1], 0.0);
        assert_eq!(y[4], 0.0);
        assert_eq!(y[2], 0.0);
        assert_eq!(y[5], 0.0);
        // every token ends up zero-mean / unit-variance (up to eps)
        let mut rng = Rng::new(71);
        let x = rng.normal_vec(16 * 9, 2.0);
        let y = layer_norm(16, 9, LN_EPS, &x);
        for t in 0..9 {
            let vals: Vec<f32> = (0..16).map(|d| y[d * 9 + t]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / 16.0;
            let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-4, "token {t} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "token {t} var {var}");
        }
    }

    #[test]
    fn transpose_roundtrips_and_relocates() {
        // (2, 3) channel-major -> (3, 2)
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let t = transpose_cp(2, 3, &x);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(transpose_cp(3, 2, &t), x.to_vec());
        let node = Node::Transpose { c: 2, positions: 3 };
        let mut s = Scratch::default();
        assert_eq!(node.forward_reference(&x, false, &mut s), t);
        assert_eq!((node.in_len(), node.out_len()), (6, 6));
    }

    #[test]
    fn token_mean_pool_matches_global_avg_pool() {
        let mut rng = Rng::new(72);
        let x = rng.normal_vec(5 * 7, 1.0);
        let pool = Node::TokenMeanPool { c: 5, positions: 7 };
        let gp = Node::GlobalPool { kind: PoolKind::Avg, c: 5, positions: 7 };
        let mut s = Scratch::default();
        assert_eq!(pool.forward_reference(&x, false, &mut s),
                   gp.forward_reference(&x, false, &mut s));
        assert_eq!((pool.in_len(), pool.out_len()), (35, 5));
    }

    #[test]
    fn pos_embed_add_is_elementwise_and_counts_as_parameters() {
        let emb = Arc::new(vec![0.5f32, -1.0, 0.0, 2.0]);
        let node = Node::PosEmbedAdd { emb };
        assert!(!node.is_weight() && !node.is_join());
        assert_eq!((node.in_len(), node.out_len()), (4, 4));
        assert_eq!(node.resident_bytes_reference(), 16);
        assert_eq!(node.extra_param_bits(), 128);
        let mut s = Scratch::default();
        let y = node.forward_reference(&[1.0, 1.0, 1.0, -3.0], false, &mut s);
        assert_eq!(y, vec![1.5, 0.0, 1.0, -1.0]);
    }

    /// The attention node must equal a naive per-head implementation, and
    /// stay finite under huge-magnitude inputs (the max-subtracted
    /// softmax).
    #[test]
    fn attention_matches_naive_reference() {
        let (heads, dim, tokens) = (2usize, 6usize, 5usize);
        let node = Node::Attention { heads, dim, tokens };
        assert_eq!(node.arity(), 3);
        assert!(node.is_join() && !node.is_weight());
        assert_eq!(node.in_len(), 30);
        assert_eq!(node.slot_in_len(2), 30);
        assert_eq!(node.f32_scratch_bytes(), 4 * tokens * tokens);
        let mut rng = Rng::new(73);
        let q = rng.normal_vec(dim * tokens, 1.0);
        let k = rng.normal_vec(dim * tokens, 1.0);
        let v = rng.normal_vec(dim * tokens, 1.0);
        let mut s = Scratch::default();
        let got = node.forward_join(&[&q, &k, &v], false, &mut s);
        // naive: per head, per query token, softmax over all key tokens
        let dh = dim / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let mut want = vec![0.0f32; dim * tokens];
        for h in 0..heads {
            for t1 in 0..tokens {
                let mut scores = vec![0.0f32; tokens];
                for (t2, sc) in scores.iter_mut().enumerate() {
                    for d in h * dh..(h + 1) * dh {
                        *sc += q[d * tokens + t1] * k[d * tokens + t2];
                    }
                    *sc *= scale;
                }
                let max = scores.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let exps: Vec<f32> = scores.iter().map(|&v| (v - max).exp()).collect();
                let denom: f32 = exps.iter().sum();
                for d in h * dh..(h + 1) * dh {
                    let mut acc = 0.0f32;
                    for t2 in 0..tokens {
                        acc += exps[t2] / denom * v[d * tokens + t2];
                    }
                    want[d * tokens + t1] = acc;
                }
            }
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4 * w.abs().max(1.0), "out {i}: {g} vs {w}");
        }
        // huge scores (~1e30 logits): softmax must saturate, never overflow
        let big: Vec<f32> = q.iter().map(|&v| v * 1.0e15).collect();
        let y = node.forward_join(&[&big, &big, &v], false, &mut s);
        assert!(y.iter().all(|o| o.is_finite()), "attention must be overflow-stable");
    }

    /// With a single token the attention weights are exactly 1, so the node
    /// passes V through untouched — a closed-form anchor.
    #[test]
    fn attention_single_token_passes_v_through() {
        let node = Node::Attention { heads: 2, dim: 4, tokens: 1 };
        let q = [5.0f32, -2.0, 0.0, 1.0];
        let k = [1.0f32, 1.0, 1.0, 1.0];
        let v = [0.25f32, -0.5, 3.0, 4.0];
        let mut s = Scratch::default();
        assert_eq!(node.forward_join(&[&q, &k, &v], false, &mut s), v.to_vec());
    }

    #[test]
    fn graph_sequential_wires_a_chain() {
        let g = Graph::sequential(vec![
            Node::Flatten { len: 8 },
            Node::GlobalPool { kind: PoolKind::Avg, c: 4, positions: 2 },
        ]);
        assert_eq!(g.len(), 2);
        assert_eq!(g.nodes[0].inputs, vec![Slot::Source]);
        assert_eq!(g.nodes[1].inputs, vec![Slot::Node(0)]);
        assert!(g.nodes.iter().all(|gn| gn.relu.is_none()));
    }

    #[test]
    fn graph_push_returns_addressable_slots() {
        let mut g = Graph::new();
        let a = g.push(Node::Flatten { len: 6 }, vec![Slot::Source]);
        let b = g.push(Node::Flatten { len: 6 }, vec![a]);
        let j = g.push_with_relu(Node::Add { len: 6 }, vec![b, a], Some(true));
        assert_eq!(a, Slot::Node(0));
        assert_eq!(j, Slot::Node(2));
        assert_eq!(g.nodes[2].inputs, vec![Slot::Node(1), Slot::Node(0)]);
        assert_eq!(g.nodes[2].relu, Some(true));
    }
}
