#!/usr/bin/env python3
"""Generate configs/experiments.json — the single source of truth shared by
python/compile/aot.py (graph lowering) and the Rust coordinator/benches.

The grid covers every paper table/figure with trained experiments behind it
(T1, T3, T4, T5, T6, F6, F7, F8) at mini scale, with the id naming scheme the
benches expect (`<family>_<variant>`, plus the Fig-7/8 hyperparameter
ablation suffixes `_global`, `_wonly`, `_single_alpha`).

Deterministic: re-running produces byte-identical output.
"""

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "configs", "experiments.json")


def tiling(mode, p=1, lam=0, alpha="per_tile", alpha_src="A"):
    return {"mode": mode, "p": p, "lambda": lam, "alpha": alpha,
            "alpha_src": alpha_src}


def variants(base_lambda, ps=(4, 8)):
    """Standard fp / bwnn / tbn_p tiling variants for one family."""
    out = {"fp": tiling("fp"), "bwnn": tiling("bwnn")}
    for p in ps:
        out[f"tbn{p}"] = tiling("tbn", p, base_lambda)
    return out


def ablations(base_lambda, p=4):
    """Fig 7/8 hyperparameter ablations of the default tbn_p config."""
    return {
        f"tbn{p}_global": tiling("tbn", p, 0),
        f"tbn{p}_wonly": tiling("tbn", p, base_lambda, alpha_src="W"),
        f"tbn{p}_single_alpha": tiling("tbn", p, base_lambda, alpha="single"),
    }


def exp(eid, tables, model, dataset, til, train=None):
    e = {"id": eid, "tables": tables, "model": model, "dataset": dataset,
         "tiling": til}
    if train:
        e["train"] = train
    return e


def family(eid_prefix, tables, model, dataset, tilings, train=None):
    return [exp(f"{eid_prefix}_{v}", tables, model, dataset, t, train)
            for v, t in tilings.items()]


def build():
    exps = []

    # ---- T6/F7: deployment micro MLP (the Table 6 model) ------------------
    mlp_model = {"family": "mlp", "in_dim": 256, "hidden": [128], "classes": 10}
    mlp_ds = {"kind": "synth_mnist", "input": [256], "classes": 10,
              "n_train": 1024, "n_test": 256}
    mlp_tilings = variants(2048, ps=(2, 4, 8))
    exps += family("mlp_micro", ["T6", "F7"], mlp_model, mlp_ds, mlp_tilings)

    # ---- T1/F7/F8: CNN minis on SynthCIFAR --------------------------------
    cifar_ds = {"kind": "synth_cifar", "input": [3, 16, 16], "classes": 10,
                "n_train": 1024, "n_test": 256}
    resnet_model = {"family": "resnet_mini", "width": 16, "classes": 10}
    resnet_tilings = variants(1024, ps=(4, 8, 16))
    resnet_tilings.update(ablations(1024))
    exps += family("resnet_mini", ["T1", "F7", "F8"], resnet_model, cifar_ds,
                   resnet_tilings)

    vgg_model = {"family": "vgg_mini", "width": 16, "classes": 10}
    exps += family("vgg_mini", ["T1"], vgg_model, cifar_ds, variants(1024))

    # ---- T4: ViT-tiny on SynthCIFAR ---------------------------------------
    vit_model = {"family": "vit_tiny", "dim": 64, "depth": 2, "heads": 4,
                 "mlp_dim": 128, "patch": 4, "classes": 10, "img": 16,
                 "in_channels": 3}
    exps += family("vit_tiny", ["T4"], vit_model, cifar_ds, variants(2048))

    # ---- T3: PointNet cls + part seg --------------------------------------
    pn_cls_model = {"family": "pointnet_cls", "classes": 8}
    pn_cls_ds = {"kind": "synth_modelnet", "input": [64, 3], "classes": 8,
                 "n_train": 1024, "n_test": 256}
    exps += family("pointnet_cls", ["T3"], pn_cls_model, pn_cls_ds,
                   variants(4096))

    pn_seg_model = {"family": "pointnet_seg", "classes": 4}
    pn_seg_ds = {"kind": "synth_shapenet", "input": [64, 3], "classes": 4,
                 "n_train": 512, "n_test": 128}
    exps += family("pointnet_seg", ["T3"], pn_seg_model, pn_seg_ds,
                   variants(4096))

    # ---- T5: time-series transformers -------------------------------------
    tst_train = {"steps": 300, "lr": 0.01}
    elec_model = {"family": "tst", "dim": 64, "depth": 2, "heads": 4,
                  "mlp_dim": 128, "seq": 48, "channels": 32}
    elec_ds = {"kind": "synth_electricity", "input": [48, 32], "channels": 32,
               "n_train": 1024, "n_test": 256}
    exps += family("tst_elec", ["T5"], elec_model, elec_ds,
                   variants(2048, ps=(4,)), train=tst_train)

    weather_model = {"family": "tst", "dim": 32, "depth": 2, "heads": 4,
                     "mlp_dim": 64, "seq": 48, "channels": 8}
    weather_ds = {"kind": "synth_weather", "input": [48, 8], "channels": 8,
                  "n_train": 1024, "n_test": 256}
    exps += family("tst_weather", ["T5"], weather_model, weather_ds,
                   variants(1024, ps=(4,)), train=tst_train)

    # ---- F6/F7: mixers (accuracy-vs-compression sweeps) -------------------
    mixer_model = {"family": "mlpmixer", "dim": 64, "depth": 2, "patch": 4,
                   "token_mlp": 32, "channel_mlp": 128, "classes": 10,
                   "img": 16, "in_channels": 3}
    mixer_tilings = {"fp": tiling("fp")}
    for p in (2, 4, 8, 16, 32):
        mixer_tilings[f"tbn{p}"] = tiling("tbn", p, 2048)
    mixer_tilings.update(ablations(2048))
    exps += family("mlpmixer", ["F6", "F7"], mixer_model, cifar_ds,
                   mixer_tilings)

    convmixer_model = {"family": "convmixer", "dim": 32, "depth": 2,
                       "kernel": 3, "patch": 2, "classes": 10, "img": 16,
                       "in_channels": 3}
    conv_tilings = {"fp": tiling("fp")}
    for p in (2, 4, 8, 16):
        conv_tilings[f"tbn{p}"] = tiling("tbn", p, 512)
    exps += family("convmixer", ["F6"], convmixer_model, cifar_ds,
                   conv_tilings)

    return {
        "defaults": {
            "train": {"batch": 32, "steps": 400, "lr": 0.05, "warmup": 5,
                      "schedule": "cosine", "opt": "sgd"},
            "eval_batch": 128,
            "serve_batch": 32,
        },
        "experiments": exps,
    }


def main():
    cfg = build()
    ids = [e["id"] for e in cfg["experiments"]]
    assert len(ids) == len(set(ids)), "duplicate experiment ids"
    assert len(ids) >= 40, f"grid too small: {len(ids)}"
    covered = {t for e in cfg["experiments"] for t in e["tables"]}
    for t in ["T1", "T3", "T4", "T5", "T6", "F6", "F7", "F8"]:
        assert t in covered, f"table {t} uncovered"
    for e in cfg["experiments"]:
        m = e["tiling"]["mode"]
        assert m in ("fp", "bwnn", "tbn")
        if m == "tbn":
            assert e["tiling"]["p"] >= 2
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(cfg, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"wrote {OUT}: {len(ids)} experiments, tables {sorted(covered)}")


if __name__ == "__main__":
    main()
