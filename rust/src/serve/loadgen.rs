//! Open-loop load generator for the network serving front end.
//!
//! "Heavy traffic" is a number, not a vibe: this module offers requests to
//! a running [`net::NetServer`](super::net::NetServer) at a configured
//! arrival rate and measures what comes back.  The arrival process is
//! open-loop Poisson-ish: each client connection draws exponential
//! inter-arrival gaps (rate `rate_rps / conns` per connection) and fires on
//! that schedule *regardless of completions*.  When the server (or the
//! connection) falls behind, the next request goes out late — and its
//! latency is measured **from the scheduled arrival time**, not from the
//! send, so queueing the client was forced into is charged to the server
//! (the standard correction for coordinated omission; a closed-loop
//! measurement would silently pace itself to the server and report
//! flattering tails).
//!
//! Each report carries completed/rejected/error counts, per-connection
//! reconnect totals, nearest-rank p50/p95/p99/p99.9 latency, and achieved
//! throughput.  [`sweep`] runs a rate ladder, [`sweep_grid`] crosses it
//! with a connection-count ladder (how the mux front end's latency-vs-
//! #conns tables are measured), and [`saturation_rps`] reads off the
//! knee: the highest achieved throughput across offered rates — the
//! saturation number `tbn loadgen` and `benches/table_serve.rs` report
//! and `BENCH_serve.json` records.
//!
//! The HTTP client side is the mirror of `net.rs`'s server framing: one
//! keep-alive connection per client thread, `POST /infer` with a
//! single-line JSON body, status + `Content-Length` response parsing.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use crate::util::{Json, Rng};

/// One load-generation run's shape.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, `host:port`.
    pub addr: String,
    /// Model to target; empty targets the server's sole model.
    pub model: String,
    /// Offered arrival rate, requests/s across all connections.
    pub rate_rps: f64,
    /// How long to offer load.
    pub duration: Duration,
    /// Client connections (each is one serial keep-alive HTTP client).
    pub conns: usize,
    /// RNG seed for arrival gaps and request payloads.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            model: String::new(),
            rate_rps: 200.0,
            duration: Duration::from_secs(2),
            conns: 4,
            seed: 1,
        }
    }
}

/// What one run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub model: String,
    pub offered_rps: f64,
    /// Requests actually fired (schedule slots that fit in the window).
    pub sent: usize,
    /// `200` answers.
    pub completed: usize,
    /// `503` sheds (the server's load shedding working as intended).
    pub rejected: usize,
    /// Transport/HTTP failures (connect refused, truncated responses, 4xx).
    pub errors: usize,
    /// Client connections the load was offered over.
    pub conns: usize,
    /// Connection rebuilds after the initial connect, summed over clients
    /// (a healthy keep-alive server holds this at 0).
    pub reconnects: usize,
    pub elapsed_s: f64,
    /// Completed requests per second of wall time.
    pub achieved_rps: f64,
    /// Nearest-rank percentiles over completed requests' latencies,
    /// measured from the *scheduled* arrival (µs).  Zero when nothing
    /// completed.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
}

impl LoadgenReport {
    /// The one-line machine-greppable summary `tbn loadgen` prints.
    pub fn summary(&self) -> String {
        format!(
            "loadgen model={} offered_rps={:.0} conns={} sent={} completed={} \
             rejected={} errors={} reconnects={} achieved_rps={:.1} p50_us={} \
             p95_us={} p99_us={} p999_us={} max_us={}",
            self.model, self.offered_rps, self.conns, self.sent, self.completed,
            self.rejected, self.errors, self.reconnects, self.achieved_rps,
            self.p50_us, self.p95_us, self.p99_us, self.p999_us, self.max_us
        )
    }

    /// One `BENCH_serve.json` row.
    pub fn to_json(&self, name: &str) -> Json {
        Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("model", Json::Str(self.model.clone())),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("conns", Json::Num(self.conns as f64)),
            ("sent", Json::Num(self.sent as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("reconnects", Json::Num(self.reconnects as f64)),
            ("achieved_rps", Json::Num(self.achieved_rps)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p95_us", Json::Num(self.p95_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("p999_us", Json::Num(self.p999_us as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Minimal HTTP/1.1 client (the mirror of net.rs's server framing)
// ---------------------------------------------------------------------------

/// One keep-alive client connection with its pipelining leftover buffer.
struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl HttpClient {
    fn connect(addr: &str) -> Result<HttpClient, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .map_err(|e| format!("set_read_timeout: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(HttpClient { stream, buf: Vec::new() })
    }

    /// One request/response round trip; returns `(status code, body)`.
    fn request(&mut self, method: &str, path: &str, body: Option<&Json>)
               -> Result<(u16, Json), String> {
        let body = body.map(Json::to_string).unwrap_or_default();
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: tbn\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n",
            body.len()
        );
        self.stream
            .write_all(head.as_bytes())
            .and_then(|()| self.stream.write_all(body.as_bytes()))
            .map_err(|e| format!("send: {e}"))?;
        self.read_response()
    }

    fn read_response(&mut self) -> Result<(u16, Json), String> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(h) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let (status, content_length) = parse_response_header(&self.buf[..h])?;
                let total = h + 4 + content_length;
                while self.buf.len() < total {
                    match self.stream.read(&mut tmp) {
                        Ok(0) => return Err("truncated response body".into()),
                        Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                        Err(e) => return Err(format!("recv: {e}")),
                    }
                }
                let text = std::str::from_utf8(&self.buf[h + 4..total])
                    .map_err(|_| "non-utf8 response".to_string())?
                    .to_string();
                self.buf.drain(..total);
                let json = Json::parse(&text)?;
                return Ok((status, json));
            }
            match self.stream.read(&mut tmp) {
                Ok(0) => return Err("connection closed mid-response".into()),
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => return Err(format!("recv: {e}")),
            }
        }
    }
}

/// `HTTP/1.1 200 OK` + headers -> (200, content-length).
fn parse_response_header(block: &[u8]) -> Result<(u16, usize), String> {
    let text = std::str::from_utf8(block).map_err(|_| "non-utf8 header".to_string())?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.split_whitespace();
    let version = parts.next().unwrap_or("");
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| format!("bad status line {status_line:?}"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(format!("bad status line {status_line:?}"));
    }
    let mut content_length = 0usize;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        if name.eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("bad content-length {value:?}"))?;
        }
    }
    Ok((status, content_length))
}

/// Query `GET /models`; returns `(name, in_dim)` rows.
pub fn probe_models(addr: &str) -> Result<Vec<(String, usize)>, String> {
    let mut client = HttpClient::connect(addr)?;
    let (status, body) = client.request("GET", "/models", None)?;
    if status != 200 {
        return Err(format!("GET /models -> {status}"));
    }
    let rows = body.get("models").and_then(Json::as_arr).unwrap_or(&[]);
    Ok(rows
        .iter()
        .map(|m| (m.str_or("name", "").to_string(), m.usize_or("in_dim", 0)))
        .collect())
}

/// Resolve the target model and its input width: the named model, or the
/// server's sole model when `model` is empty.
fn resolve_model(addr: &str, model: &str) -> Result<(String, usize), String> {
    let models = probe_models(addr)?;
    if model.is_empty() {
        match models.as_slice() {
            [one] => Ok(one.clone()),
            [] => Err("server has no models".into()),
            _ => Err(format!(
                "server has {} models — pass --model (one of: {})",
                models.len(),
                models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
            )),
        }
    } else {
        models
            .iter()
            .find(|(n, _)| n == model)
            .cloned()
            .ok_or_else(|| format!("model {model:?} not served"))
    }
}

struct ClientTally {
    sent: usize,
    completed: usize,
    rejected: usize,
    errors: usize,
    reconnects: usize,
    latencies_us: Vec<u64>,
}

/// One client thread: fire `POST /infer` on an exponential-gap schedule at
/// `rate` until `deadline`, measuring sojourn from the scheduled arrival.
fn client_loop(addr: &str, model: &str, in_dim: usize, rate: f64, start: Instant,
               deadline: Instant, mut rng: Rng) -> ClientTally {
    let mut tally = ClientTally {
        sent: 0,
        completed: 0,
        rejected: 0,
        errors: 0,
        reconnects: 0,
        latencies_us: Vec::new(),
    };
    let mut client = HttpClient::connect(addr).ok();
    // first arrival one gap into the window, like every later one
    let mut scheduled = start + exp_gap(&mut rng, rate);
    while scheduled < deadline {
        let now = Instant::now();
        if now < scheduled {
            thread::sleep(scheduled - now);
        }
        let x: Vec<Json> =
            (0..in_dim).map(|_| Json::Num(rng.gauss_f32() as f64)).collect();
        let body = Json::obj(vec![
            ("model", Json::Str(model.to_string())),
            ("x", Json::Arr(x)),
        ]);
        // (re)connect lazily: one failed connect marks this slot an error
        // and the next slot retries, so a draining server doesn't wedge us
        if client.is_none() {
            client = HttpClient::connect(addr).ok();
            tally.reconnects += 1;
        }
        tally.sent += 1;
        match client.as_mut().map(|c| c.request("POST", "/infer", Some(&body))) {
            Some(Ok((200, _))) => {
                tally.completed += 1;
                tally.latencies_us.push(scheduled.elapsed().as_micros() as u64);
            }
            Some(Ok((503, _))) => tally.rejected += 1,
            Some(Ok(_)) => tally.errors += 1,
            Some(Err(_)) => {
                tally.errors += 1;
                client = None; // broken connection: rebuild on next slot
            }
            None => tally.errors += 1,
        }
        scheduled += exp_gap(&mut rng, rate);
    }
    tally
}

/// Exponential inter-arrival gap at `rate` req/s (capped at 1s so a tiny
/// rate still makes progress through the deadline check).
fn exp_gap(rng: &mut Rng, rate: f64) -> Duration {
    let u = rng.next_f64(); // [0, 1)
    let gap_s = -(1.0 - u).ln() / rate.max(1e-9);
    Duration::from_secs_f64(gap_s.clamp(0.0, 1.0))
}

/// Nearest-rank percentile over a sorted slice (the same convention as
/// `ServerStats::latency_percentiles`).
fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Run one open-loop load generation pass.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport, String> {
    let (model, in_dim) = resolve_model(&cfg.addr, &cfg.model)?;
    if in_dim == 0 {
        return Err(format!("model {model:?} reports input width 0"));
    }
    let conns = cfg.conns.max(1);
    let per_conn_rate = cfg.rate_rps / conns as f64;
    let start = Instant::now();
    let deadline = start + cfg.duration;
    let tallies: Vec<ClientTally> = thread::scope(|scope| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let addr = cfg.addr.clone();
                let model = model.clone();
                let rng = Rng::new(cfg.seed.wrapping_add(c as u64).wrapping_mul(0x9E37));
                scope.spawn(move || {
                    client_loop(&addr, &model, in_dim, per_conn_rate, start, deadline, rng)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    let elapsed_s = start.elapsed().as_secs_f64();
    let mut latencies: Vec<u64> = Vec::new();
    let (mut sent, mut completed, mut rejected, mut errors, mut reconnects) = (0, 0, 0, 0, 0);
    for t in tallies {
        sent += t.sent;
        completed += t.completed;
        rejected += t.rejected;
        errors += t.errors;
        reconnects += t.reconnects;
        latencies.extend(t.latencies_us);
    }
    latencies.sort_unstable();
    Ok(LoadgenReport {
        model,
        offered_rps: cfg.rate_rps,
        sent,
        completed,
        rejected,
        errors,
        conns,
        reconnects,
        elapsed_s,
        achieved_rps: completed as f64 / elapsed_s.max(1e-9),
        p50_us: percentile(&latencies, 0.50),
        p95_us: percentile(&latencies, 0.95),
        p99_us: percentile(&latencies, 0.99),
        p999_us: percentile(&latencies, 0.999),
        max_us: latencies.last().copied().unwrap_or(0),
    })
}

/// Run a rate ladder (one [`run`] per offered rate, same duration/conns).
pub fn sweep(base: &LoadgenConfig, rates: &[f64]) -> Result<Vec<LoadgenReport>, String> {
    let mut out = Vec::with_capacity(rates.len());
    for (i, &r) in rates.iter().enumerate() {
        let cfg = LoadgenConfig {
            rate_rps: r,
            seed: base.seed.wrapping_add(i as u64),
            ..base.clone()
        };
        out.push(run(&cfg)?);
    }
    Ok(out)
}

/// Run a rate × connection-count grid: one [`run`] per `(conns, rate)`
/// pair, in connection-ladder-major order (how `tbn loadgen --conns 1,64,512`
/// and the bench's latency-vs-#conns tables are produced).
pub fn sweep_grid(
    base: &LoadgenConfig,
    rates: &[f64],
    conns_list: &[usize],
) -> Result<Vec<LoadgenReport>, String> {
    let mut out = Vec::with_capacity(rates.len() * conns_list.len());
    for (j, &conns) in conns_list.iter().enumerate() {
        for (i, &r) in rates.iter().enumerate() {
            let cfg = LoadgenConfig {
                rate_rps: r,
                conns,
                seed: base.seed.wrapping_add((j * rates.len() + i) as u64),
                ..base.clone()
            };
            out.push(run(&cfg)?);
        }
    }
    Ok(out)
}

/// Saturation throughput: the highest achieved rate across a sweep — past
/// the knee, offering more only grows rejects and tails, not completions.
pub fn saturation_rps(reports: &[LoadgenReport]) -> f64 {
    reports.iter().map(|r| r.achieved_rps).fold(0.0, f64::max)
}

/// Rows for one sweep: one per report (named `rate{R}_conns{C}`, or
/// `"{net_model} rate{R} conns{C}"` when tagged) plus the group's
/// saturation-throughput row.
fn report_rows(reports: &[LoadgenReport], net_model: Option<&str>) -> Vec<Json> {
    let mut runs: Vec<Json> = reports
        .iter()
        .map(|r| {
            let name = match net_model {
                Some(m) => format!("{m} rate{:.0} conns{}", r.offered_rps, r.conns),
                None => format!("rate{:.0}_conns{}", r.offered_rps, r.conns),
            };
            let mut row = r.to_json(&name);
            if let Some(m) = net_model {
                row.set("net_model", Json::Str(m.to_string()));
            }
            row
        })
        .collect();
    let mut sat = Json::obj(vec![
        (
            "name",
            Json::Str(match net_model {
                Some(m) => format!("saturation_{m}"),
                None => "saturation".to_string(),
            }),
        ),
        ("model", Json::Str(reports.first().map(|r| r.model.clone()).unwrap_or_default())),
        ("saturation_rps", Json::Num(saturation_rps(reports))),
    ]);
    if let Some(m) = net_model {
        sat.set("net_model", Json::Str(m.to_string()));
    }
    runs.push(sat);
    runs
}

/// The `BENCH_serve.json` document for a sweep: one row per run plus the
/// saturation-throughput row.
pub fn sweep_to_json(reports: &[LoadgenReport]) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("table_serve".to_string())),
        ("runs", Json::Arr(report_rows(reports, None))),
    ])
}

/// The `BENCH_serve.json` document for an A/B grid: each group is one net
/// model's sweep; its rows carry a `net_model` field and a per-model
/// saturation row.
pub fn grid_to_json(groups: &[(String, Vec<LoadgenReport>)]) -> Json {
    let mut runs = Vec::new();
    for (net_model, reports) in groups {
        runs.extend(report_rows(reports, Some(net_model)));
    }
    Json::obj(vec![
        ("bench", Json::Str("table_serve".to_string())),
        ("runs", Json::Arr(runs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_gaps_have_the_right_mean() {
        let mut rng = Rng::new(7);
        let rate = 500.0;
        let n = 20_000;
        let total: f64 = (0..n).map(|_| exp_gap(&mut rng, rate).as_secs_f64()).sum();
        let mean = total / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.1 / rate, "mean gap {mean}");
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[10, 20, 30, 40], 0.50), 20);
        assert_eq!(percentile(&[10, 20, 30, 40], 0.99), 40);
    }

    #[test]
    fn response_header_parses_and_rejects() {
        let (s, l) =
            parse_response_header(b"HTTP/1.1 503 Service Unavailable\r\nContent-Length: 9")
                .unwrap();
        assert_eq!((s, l), (503, 9));
        assert!(parse_response_header(b"ICY 200 OK").is_err());
        assert!(parse_response_header(b"HTTP/1.1 abc").is_err());
    }

    fn report(rate: f64, conns: usize, achieved: f64) -> LoadgenReport {
        LoadgenReport {
            model: "m".into(),
            offered_rps: rate,
            sent: 10,
            completed: 9,
            rejected: 1,
            errors: 0,
            conns,
            reconnects: 0,
            elapsed_s: 1.0,
            achieved_rps: achieved,
            p50_us: 5,
            p95_us: 9,
            p99_us: 9,
            p999_us: 9,
            max_us: 9,
        }
    }

    #[test]
    fn sweep_json_has_rate_and_saturation_rows() {
        let doc = sweep_to_json(&[report(100.0, 4, 9.0)]);
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].str_or("name", ""), "rate100_conns4");
        assert_eq!(runs[0].usize_or("completed", 0), 9);
        assert_eq!(runs[0].usize_or("conns", 0), 4);
        assert_eq!(runs[0].usize_or("reconnects", 99), 0);
        assert_eq!(runs[0].usize_or("p999_us", 0), 9);
        assert_eq!(runs[1].str_or("name", ""), "saturation");
        assert!((runs[1].f64_or("saturation_rps", 0.0) - 9.0).abs() < 1e-9);
        assert_eq!(doc.str_or("bench", ""), "table_serve");
    }

    #[test]
    fn grid_json_tags_rows_with_net_model() {
        let doc = grid_to_json(&[
            ("mux".to_string(), vec![report(2000.0, 512, 1800.0)]),
            ("threads".to_string(), vec![report(2000.0, 4, 1500.0)]),
        ]);
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[0].str_or("name", ""), "mux rate2000 conns512");
        assert_eq!(runs[0].str_or("net_model", ""), "mux");
        assert_eq!(runs[1].str_or("name", ""), "saturation_mux");
        assert!((runs[1].f64_or("saturation_rps", 0.0) - 1800.0).abs() < 1e-9);
        assert_eq!(runs[2].str_or("net_model", ""), "threads");
        assert_eq!(runs[3].str_or("name", ""), "saturation_threads");
    }
}
