//! Helpers shared by the graph-parity test binaries
//! (`tests/graph_parity.rs`, `tests/transformer_parity.rs`).

use tiledbits::nn::{Graph, Node, Scratch, Slot};

/// Independent reference-graph evaluator: walk the graph with an explicit
/// value table, calling the per-node Reference kernels directly (n-ary
/// joins fetch every input slot).  ReLU placement mirrors the engine
/// contract — weight nodes except the last weight node, overrides win —
/// so `Engine::forward` on the Reference path must agree bit-exactly.
pub fn handrolled_reference_forward(graph: &Graph, x: &[f32], relu_on: bool)
                                    -> Vec<f32> {
    fn fetch<'a>(slot: Slot, x: &'a [f32], values: &'a [Vec<f32>]) -> &'a [f32] {
        match slot {
            Slot::Source => x,
            Slot::Node(j) => &values[j],
        }
    }
    let last_weight = graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, gn)| gn.node.is_weight())
        .map(|(i, _)| i)
        .last();
    let mut values: Vec<Vec<f32>> = Vec::with_capacity(graph.len());
    let mut scratch = Scratch::default();
    for (i, gn) in graph.nodes.iter().enumerate() {
        let default = gn.node.is_weight() && Some(i) != last_weight;
        let relu = gn.relu.unwrap_or(default) && relu_on;
        let out = if gn.node.is_join() {
            let ins: Vec<&[f32]> =
                gn.inputs.iter().map(|&s| fetch(s, x, &values)).collect();
            gn.node.forward_join(&ins, relu, &mut scratch)
        } else {
            gn.node.forward_reference(fetch(gn.inputs[0], x, &values), relu,
                                      &mut scratch)
        };
        values.push(out);
    }
    values.pop().unwrap()
}

pub fn argmax(y: &[f32]) -> usize {
    y.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

pub fn count_nodes(graph: &Graph, pred: impl Fn(&Node) -> bool) -> usize {
    graph.nodes.iter().filter(|gn| pred(&gn.node)).count()
}
