//! Inference memory model + allocator trace (Table 7 and Figure 5).
//!
//! Mirrors the allocation pattern the paper profiles on GPU: the runtime
//! keeps *all* layer weights resident for the whole forward pass, and
//! allocates/deallocates activations layer by layer.  The tiled kernel
//! changes only the weight term: a tiled layer keeps just its tile (f32 or
//! bit-packed) and alphas resident instead of the expanded matrix.
//!
//! Since PR 3 the `TbnPacked` row is no longer only a model: the native
//! engine's tile-resident layout (`nn::PackedLayout::TileResident`,
//! `nn::PackedLayer::resident_bytes`) keeps exactly the `q`-bit tile +
//! alpha table this accounting predicts, up to `u64`-word rounding —
//! pinned by `analytic_model_matches_native_tile_residency` below and
//! measured per architecture in `benches/table7_memory.rs`.

use crate::arch::{ArchSpec, Kind};
use super::policy::{decide, Quant, TilingPolicy};

/// Which §5.2 kernel variant the model is served with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// 32-bit weights, standard kernel (weights fully materialized).
    FpStandard,
    /// 32-bit weights but tiled layers keep only the f32 tile resident.
    FpTiled,
    /// 1-bit packed weights, standard kernel (BWNN row).
    BwnnPacked,
    /// 1-bit packed tiles reused in-kernel (TBN row).
    TbnPacked,
}

/// Weight-resident bytes for one layer under a kernel variant.
pub fn layer_weight_bytes(n: usize, per_channel: usize, quant: Quant,
                          policy: &TilingPolicy, kernel: KernelKind) -> f64 {
    let _ = per_channel;
    let fp = 4.0 * n as f64;
    let packed = (n as f64 / 8.0).ceil() + 4.0; // bits -> bytes + alpha
    match kernel {
        KernelKind::FpStandard => fp,
        KernelKind::FpTiled => match quant {
            Quant::Tiled { p } => {
                let q = n / p;
                4.0 * q as f64 + 4.0 * policy.alpha.count(p) as f64
            }
            _ => fp,
        },
        KernelKind::BwnnPacked => match quant {
            Quant::Fp => fp,
            _ => packed,
        },
        KernelKind::TbnPacked => match quant {
            Quant::Tiled { p } => {
                let q = n / p;
                (q as f64 / 8.0).ceil() + 4.0 * policy.alpha.count(p) as f64
            }
            Quant::Bwnn => packed,
            Quant::Fp => fp,
        },
    }
}

/// Full memory report for one (arch, policy, kernel) triple.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub arch: String,
    pub kernel: KernelKind,
    /// Bytes occupied by weights for the whole pass.
    pub param_bytes: f64,
    /// Peak total = params + worst-case transient activations.
    pub peak_bytes: f64,
    /// Per-layer running-total trace (layer name, bytes) — Figure 5's curve.
    pub trace: Vec<(String, f64)>,
}

impl MemoryReport {
    pub fn param_fraction(&self) -> f64 {
        self.param_bytes / self.peak_bytes.max(1.0)
    }
}

/// Simulate one forward pass at batch 1 (the paper profiles single-image
/// inference).  Activations are f32; a layer holds input + output live
/// simultaneously, the input is freed afterwards.
pub fn simulate(arch: &ArchSpec, policy: &TilingPolicy, kernel: KernelKind) -> MemoryReport {
    let mut param_bytes = 0.0;
    for l in &arch.layers {
        let quant = match l.kind {
            Kind::Conv { .. } | Kind::Fc { .. } => decide(policy, l.params),
            Kind::Other => Quant::Fp,
        };
        param_bytes += layer_weight_bytes(l.params, l.per_channel(), quant, policy, kernel);
    }

    let mut peak = param_bytes;
    let mut trace = Vec::with_capacity(arch.layers.len());
    for l in &arch.layers {
        if l.macs == 0 {
            continue;
        }
        let act = 4.0 * (l.in_act + l.out_act) as f64;
        let current = param_bytes + act;
        peak = peak.max(current);
        trace.push((l.name.clone(), current));
    }
    MemoryReport { arch: arch.name.clone(), kernel, param_bytes, peak_bytes: peak, trace }
}

/// Table 7's four rows for an architecture at compression p.
pub fn table7_rows(arch: &ArchSpec, p: usize, lambda: usize)
                   -> Vec<(&'static str, MemoryReport)> {
    let tbn = TilingPolicy::tbn(p, lambda);
    let bwnn = TilingPolicy::bwnn(lambda);
    let fp = TilingPolicy::fp();
    vec![
        ("Full Precision", simulate(arch, &fp, KernelKind::FpStandard)),
        ("FP, Tiled", simulate(arch, &tbn, KernelKind::FpTiled)),
        ("BWNN", simulate(arch, &bwnn, KernelKind::BwnnPacked)),
        ("TBN", simulate(arch, &tbn, KernelKind::TbnPacked)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;

    fn vit() -> arch::ArchSpec {
        arch::vit_small_imagenet()
    }

    #[test]
    fn fp_param_bytes_is_4n() {
        let a = vit();
        let r = simulate(&a, &TilingPolicy::fp(), KernelKind::FpStandard);
        assert!((r.param_bytes - 4.0 * a.total_params() as f64).abs() < 1.0);
    }

    /// Table 7 structure: FP ~208MB params, FP-tiled ~4x less, TBN params
    /// tiny; peak ordering FP > FP-tiled > BWNN > TBN.
    #[test]
    fn table7_shape_holds() {
        let rows = table7_rows(&vit(), 4, 150_000);
        let by_name: std::collections::HashMap<_, _> =
            rows.iter().map(|(n, r)| (*n, r)).collect();
        let fp = by_name["Full Precision"];
        let fpt = by_name["FP, Tiled"];
        let bw = by_name["BWNN"];
        let tbn = by_name["TBN"];
        // paper: 208MB FP params
        assert!(fp.param_bytes > 190e6 && fp.param_bytes < 230e6,
                "fp params {}", fp.param_bytes);
        // ~4x param reduction from tiling fp weights (paper: 208 -> 52)
        let red = fp.param_bytes / fpt.param_bytes;
        assert!(red > 3.0 && red < 4.5, "fp tiled reduction {red}");
        // ~4x for packed tiles vs packed bwnn (paper: 6.5 -> 1.6)
        let redb = bw.param_bytes / tbn.param_bytes;
        assert!(redb > 3.0 && redb < 4.6, "bwnn->tbn reduction {redb}");
        // peak ordering
        assert!(fp.peak_bytes > fpt.peak_bytes);
        assert!(fpt.peak_bytes > bw.peak_bytes);
        assert!(bw.peak_bytes > tbn.peak_bytes);
        // param fraction: paper 93.5% for FP, 11.9% for TBN.  Our activation
        // model only counts layer in/out buffers (no attention temporaries),
        // so the TBN fraction is higher than the paper's but the gap holds.
        assert!(fp.param_fraction() > 0.85);
        assert!(tbn.param_fraction() < 0.5);
        assert!(fp.param_fraction() > tbn.param_fraction() + 0.4);
    }

    #[test]
    fn trace_has_one_point_per_compute_layer() {
        let a = vit();
        let r = simulate(&a, &TilingPolicy::fp(), KernelKind::FpStandard);
        let compute_layers = a.layers.iter().filter(|l| l.macs > 0).count();
        assert_eq!(r.trace.len(), compute_layers);
        assert!(r.trace.iter().all(|(_, b)| *b >= r.param_bytes));
    }

    #[test]
    fn peak_at_least_params_plus_largest_act() {
        let a = arch::pointnet_cls();
        let r = simulate(&a, &TilingPolicy::fp(), KernelKind::FpStandard);
        let max_act = a.layers.iter().map(|l| 4.0 * (l.in_act + l.out_act) as f64)
            .fold(0.0, f64::max);
        assert!((r.peak_bytes - (r.param_bytes + max_act)).abs() < 1.0);
    }

    /// The Table 7 `TbnPacked` weight term is what the native tile-resident
    /// packed layer actually keeps resident, up to u64-word rounding of the
    /// tile bits.
    #[test]
    fn analytic_model_matches_native_tile_residency() {
        use crate::nn::{PackedLayer, PackedLayout};
        use crate::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                         WeightPayload};
        use crate::util::Rng;

        let (m, n, p) = (96usize, 200usize, 4usize); // q = 4800
        let mut rng = Rng::new(70);
        let w = rng.normal_vec(m * n, 1.0);
        let rec = LayerRecord {
            name: "fc".into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        };
        let native = PackedLayer::from_record_mn_layout(
            &rec, m, n, PackedLayout::TileResident).unwrap();
        let policy = TilingPolicy::tbn(p, 0);
        let quant = decide(&policy, m * n);
        assert_eq!(quant, Quant::Tiled { p });
        let analytic =
            layer_weight_bytes(m * n, n, quant, &policy, KernelKind::TbnPacked);
        let diff = native.resident_bytes() as f64 - analytic;
        assert!(diff.abs() <= 8.0,
                "native {} vs analytic {analytic} (word rounding only)",
                native.resident_bytes());
    }

    #[test]
    fn bwnn_packs_to_eighth() {
        let a = vit();
        let fp = simulate(&a, &TilingPolicy::fp(), KernelKind::FpStandard);
        let bw = simulate(&a, &TilingPolicy::bwnn(0), KernelKind::BwnnPacked);
        let ratio = fp.param_bytes / bw.param_bytes;
        assert!(ratio > 25.0 && ratio < 33.0, "ratio {ratio}");
    }
}
