//! 2-D convolution graph node, lowered to im2col patches over the FC bit
//! kernels.
//!
//! A conv layer with weights `[co, ci/groups, kh, kw]` is, per output
//! position, an FC layer of shape `(co/groups, ci/groups * kh * kw)` applied
//! to the im2col patch at that position — so the Packed path reuses the
//! exact `PackedLayer` row state and `tbn::bitops` XNOR-popcount kernels the
//! FC path runs on (SNN / XNOR-Net lowering).  Patches are staged in the
//! shared [`Scratch`] buffers; zero padding stays exact across the f32, ±1
//! and int8 domains (0 quantizes to 0).
//!
//! Per-patch binarization uses one XNOR-Net scale `gamma = mean |patch|`
//! per position/group (the scalar simplification of XNOR-Net's K matrix);
//! the f32 oracle in [`Conv2dLayer::forward_quantized_oracle`] mirrors this
//! exactly, and `tests/conv_parity.rs` pins the two against each other and
//! against a naive nested-loop convolution.
//!
//! In a branching [`super::Graph`] (ResNet blocks), a conv node is an
//! ordinary unary node: the residual body's final conv is forced linear by
//! the lowering and the activation moves after the `Add` join — the conv
//! kernels themselves are branch-agnostic.

use std::sync::Arc;

use super::Scratch;
use crate::nn::packed::{
    activation_gamma, binarize_activations_into, binarize_signs_into,
    partition_strided, payload_row_dot_i8, quantize_input_i8, split_ranges,
    IntThresholds, PackedLayer, PackedLayout, PackedPayload,
};
use crate::nn::payload_row_dot;
use crate::tbn::bitops::SimdBackend;
use crate::tbn::LayerRecord;

/// A 2-D convolution over a channel-major `(c, h, w)` activation map.
///
/// The record is held behind an `Arc` so a node and any model-level owner
/// share one payload copy instead of duplicating it.
#[derive(Debug, Clone)]
pub struct Conv2dLayer {
    /// Weight record with shape `[co, ci/groups, kh, kw]` (row-major).
    pub record: Arc<LayerRecord>,
    pub co: usize,
    /// Total input channels (across all groups).
    pub ci: usize,
    pub kh: usize,
    pub kw: usize,
    /// Channel groups: 1 = dense conv, `ci` = depthwise.
    pub groups: usize,
    pub stride: usize,
    /// Leading (top/left) zero padding; the trailing pad is implied by
    /// `h_out`/`w_out` and may differ by one ("same" padding of even
    /// kernels).
    pub pad: usize,
    pub h_in: usize,
    pub w_in: usize,
    pub h_out: usize,
    pub w_out: usize,
}

impl Conv2dLayer {
    /// Conv with symmetric padding: output size follows the standard floor
    /// arithmetic `h_out = (h_in + 2*pad - kh) / stride + 1`.
    pub fn new(record: LayerRecord, input: (usize, usize, usize), stride: usize,
               pad: usize, groups: usize) -> Result<Conv2dLayer, String> {
        let (_, h_in, w_in) = input;
        if record.shape.len() != 4 {
            return Err(format!(
                "{}: Conv2d requires a 4-D [co, ci/g, kh, kw] shape", record.name));
        }
        let (kh, kw) = (record.shape[2], record.shape[3]);
        if stride == 0 {
            return Err(format!("{}: stride must be positive", record.name));
        }
        if h_in + 2 * pad < kh || w_in + 2 * pad < kw {
            return Err(format!(
                "{}: kernel {kh}x{kw} larger than padded input", record.name));
        }
        let h_out = (h_in + 2 * pad - kh) / stride + 1;
        let w_out = (w_in + 2 * pad - kw) / stride + 1;
        Conv2dLayer::with_output(record, input, stride, pad, (h_out, w_out), groups)
    }

    /// Conv with an explicit output size (asymmetric "same" padding of even
    /// kernels: the trailing pad is whatever `h_out` implies).
    pub fn with_output(record: LayerRecord, input: (usize, usize, usize), stride: usize,
                       pad: usize, out: (usize, usize), groups: usize)
                       -> Result<Conv2dLayer, String> {
        let (ci, h_in, w_in) = input;
        if record.shape.len() != 4 {
            return Err(format!(
                "{}: Conv2d requires a 4-D [co, ci/g, kh, kw] shape", record.name));
        }
        let (co, cig, kh, kw) = (
            record.shape[0], record.shape[1], record.shape[2], record.shape[3]);
        let (h_out, w_out) = out;
        if groups == 0 || ci % groups != 0 || co % groups != 0 {
            return Err(format!(
                "{}: groups {groups} must divide channels ({ci} in, {co} out)",
                record.name));
        }
        if cig != ci / groups {
            return Err(format!(
                "{}: weight ci/g {cig} != {} ({ci} ch / {groups} groups)",
                record.name, ci / groups));
        }
        if stride == 0 || h_in == 0 || w_in == 0 || h_out == 0 || w_out == 0 {
            return Err(format!("{}: degenerate conv geometry", record.name));
        }
        // every patch must start inside the padded input (the trailing pad
        // absorbs at most one extra position for even "same" kernels)
        if (h_out - 1) * stride > h_in + 2 * pad || (w_out - 1) * stride > w_in + 2 * pad {
            return Err(format!(
                "{}: output {h_out}x{w_out} does not fit input {h_in}x{w_in} \
                 (stride {stride}, pad {pad})", record.name));
        }
        Ok(Conv2dLayer {
            record: Arc::new(record),
            co, ci, kh, kw, groups, stride, pad, h_in, w_in, h_out, w_out,
        })
    }

    pub fn in_len(&self) -> usize {
        self.ci * self.h_in * self.w_in
    }

    pub fn out_len(&self) -> usize {
        self.co * self.h_out * self.w_out
    }

    /// im2col row length: weights per output channel.
    pub fn patch_len(&self) -> usize {
        (self.ci / self.groups) * self.kh * self.kw
    }

    pub(crate) fn build_packed(&self, layout: PackedLayout) -> Result<PackedLayer, String> {
        PackedLayer::from_record_mn_layout(&self.record, self.co, self.patch_len(), layout)
    }

    /// Stage the im2col patch of group `g` at output position `(oy, ox)`
    /// into `patch` (length `patch_len`); out-of-bounds taps are zero.
    fn extract_patch(&self, x: &[f32], g: usize, oy: usize, ox: usize,
                     patch: &mut [f32]) {
        let cig = self.ci / self.groups;
        let y0 = (oy * self.stride) as isize - self.pad as isize;
        let x0 = (ox * self.stride) as isize - self.pad as isize;
        let mut idx = 0usize;
        for c in g * cig..(g + 1) * cig {
            let plane = &x[c * self.h_in * self.w_in..(c + 1) * self.h_in * self.w_in];
            for ky in 0..self.kh {
                let yy = y0 + ky as isize;
                let row_ok = yy >= 0 && (yy as usize) < self.h_in;
                for kx in 0..self.kw {
                    let xx = x0 + kx as isize;
                    patch[idx] = if row_ok && xx >= 0 && (xx as usize) < self.w_in {
                        plane[yy as usize * self.w_in + xx as usize]
                    } else {
                        0.0
                    };
                    idx += 1;
                }
            }
        }
    }

    /// Int8 twin of [`Conv2dLayer::extract_patch`] (padding is exact 0).
    fn extract_patch_i8(&self, xq: &[i8], g: usize, oy: usize, ox: usize,
                        patch: &mut [i8]) {
        let cig = self.ci / self.groups;
        let y0 = (oy * self.stride) as isize - self.pad as isize;
        let x0 = (ox * self.stride) as isize - self.pad as isize;
        let mut idx = 0usize;
        for c in g * cig..(g + 1) * cig {
            let plane = &xq[c * self.h_in * self.w_in..(c + 1) * self.h_in * self.w_in];
            for ky in 0..self.kh {
                let yy = y0 + ky as isize;
                let row_ok = yy >= 0 && (yy as usize) < self.h_in;
                for kx in 0..self.kw {
                    let xx = x0 + kx as isize;
                    patch[idx] = if row_ok && xx >= 0 && (xx as usize) < self.w_in {
                        plane[yy as usize * self.w_in + xx as usize]
                    } else {
                        0
                    };
                    idx += 1;
                }
            }
        }
    }

    /// f32 reference forward: per-position im2col patches against the
    /// payload's row dots (tile reuse — the full weight matrix never
    /// materializes).
    pub fn forward_reference(&self, x: &[f32], relu: bool, scratch: &mut Scratch)
                             -> Vec<f32> {
        debug_assert_eq!(x.len(), self.in_len());
        let n = self.patch_len();
        scratch.patch.clear();
        scratch.patch.resize(n, 0.0);
        let cog = self.co / self.groups;
        let area = self.h_out * self.w_out;
        let mut y = vec![0.0f32; self.co * area];
        for oy in 0..self.h_out {
            for ox in 0..self.w_out {
                for g in 0..self.groups {
                    self.extract_patch(x, g, oy, ox, &mut scratch.patch);
                    for oc in 0..cog {
                        let o = g * cog + oc;
                        let v = payload_row_dot(
                            &self.record.payload, o * n, &scratch.patch);
                        y[(o * self.h_out + oy) * self.w_out + ox] =
                            if relu { v.max(0.0) } else { v };
                    }
                }
            }
        }
        y
    }

    /// Packed forward: binarize each patch with its XNOR-Net scale, then
    /// XNOR-popcount the packed filter rows — the same kernels as packed FC.
    ///
    /// All of a group's output positions are packed side by side and run as
    /// one batch through `PackedLayer::forward_batch_binarized_rows`
    /// (rows outer, positions inner), so each filter row's weight state —
    /// and on the tile-resident layout the one shared tile — is walked
    /// while hot across the whole spatial map.  Outputs are bit-identical
    /// to the per-position form `gamma * row_dot_binarized`.
    ///
    /// With `threads > 1` the output-position loop splits across scoped std
    /// threads: each thread owns a contiguous position range and, for that
    /// range, disjoint chunks of the staging buffers (`batch_words`,
    /// `gammas`, `batch_out`) plus a private im2col patch buffer (its
    /// per-thread scratch) — it binarizes its own positions and runs the
    /// unmodified serial batched row kernel over them, no barrier, no
    /// shared writes.  Per-element math and accumulation order are exactly
    /// the serial kernel's, so any thread count is bit-exact against 1.
    pub fn forward_packed(&self, packed: &PackedLayer, x: &[f32], relu: bool,
                          scratch: &mut Scratch, threads: usize,
                          simd: SimdBackend) -> Vec<f32> {
        self.forward_packed_impl(packed, x, relu, scratch, threads, simd, None)
    }

    /// Integer-pipeline conv forward ([`crate::nn::EnginePath::PackedInt`]):
    /// identical to [`Conv2dLayer::forward_packed`] except every patch's
    /// data-dependent XNOR-Net gamma reduction is replaced by the layer's
    /// *calibrated constant* `thr.gamma` — patches are sign-binarized only
    /// (`binarize_signs_into`), dropping one `mean |patch|` pass per output
    /// position per group.  Conv stays an f32-in / f32-out node on the
    /// integer path (its spatial output feeds pools/flattens, not packed
    /// bit consumers); the whole-map constant replaces *per-patch* scales,
    /// so this computes a different function from Packed — argmax
    /// agreement is gated in `tests/int_pipeline_parity.rs`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_int(&self, packed: &PackedLayer, thr: &IntThresholds, x: &[f32],
                       relu: bool, scratch: &mut Scratch, threads: usize,
                       simd: SimdBackend) -> Vec<f32> {
        self.forward_packed_impl(packed, x, relu, scratch, threads, simd,
                                 Some(thr.gamma))
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_packed_impl(&self, packed: &PackedLayer, x: &[f32], relu: bool,
                           scratch: &mut Scratch, threads: usize,
                           simd: SimdBackend, const_gamma: Option<f32>) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.in_len());
        let n = self.patch_len();
        let stride = n.div_ceil(64).max(1);
        let cog = self.co / self.groups;
        let area = self.h_out * self.w_out;
        scratch.batch_words.clear();
        scratch.batch_words.resize(area * stride, 0);
        scratch.gammas.clear();
        scratch.gammas.resize(area, 0.0);
        scratch.batch_out.clear();
        scratch.batch_out.resize(area * cog, 0.0);
        let mut y = vec![0.0f32; self.co * area];
        let t = threads.min(area).max(1);
        let ranges = if t > 1 { split_ranges(area, t) } else { Vec::new() };
        for g in 0..self.groups {
            if t <= 1 {
                scratch.patch.clear();
                scratch.patch.resize(n, 0.0);
                for oy in 0..self.h_out {
                    for ox in 0..self.w_out {
                        let pos = oy * self.w_out + ox;
                        self.extract_patch(x, g, oy, ox, &mut scratch.patch);
                        let words =
                            &mut scratch.batch_words[pos * stride..(pos + 1) * stride];
                        scratch.gammas[pos] = match const_gamma {
                            Some(gamma) => {
                                binarize_signs_into(&scratch.patch, words);
                                gamma
                            }
                            None => binarize_activations_into(&scratch.patch, words),
                        };
                    }
                }
                packed.forward_batch_binarized_rows_simd(g * cog, (g + 1) * cog,
                                                         &scratch.batch_words,
                                                         stride,
                                                         &scratch.gammas, relu,
                                                         &mut scratch.batch_out,
                                                         simd);
            } else {
                // Contiguous per-range chunks of the position-major staging
                // buffers: range (lo, hi) owns words[lo*stride..hi*stride],
                // gammas[lo..hi] and batch_out[lo*cog..hi*cog].
                let mut wchunks = Vec::with_capacity(ranges.len());
                let mut gchunks = Vec::with_capacity(ranges.len());
                let mut ochunks = Vec::with_capacity(ranges.len());
                let mut wrest = &mut scratch.batch_words[..];
                let mut grest = &mut scratch.gammas[..];
                let mut orest = &mut scratch.batch_out[..];
                for &(lo, hi) in &ranges {
                    let len = hi - lo;
                    let (wc, wt) = std::mem::take(&mut wrest).split_at_mut(len * stride);
                    let (gc, gt) = std::mem::take(&mut grest).split_at_mut(len);
                    let (oc, ot) = std::mem::take(&mut orest).split_at_mut(len * cog);
                    wchunks.push(wc);
                    gchunks.push(gc);
                    ochunks.push(oc);
                    wrest = wt;
                    grest = gt;
                    orest = ot;
                }
                std::thread::scope(|scope| {
                    for (((&(lo, hi), wc), gc), oc) in ranges
                        .iter()
                        .zip(wchunks)
                        .zip(gchunks)
                        .zip(ochunks)
                    {
                        scope.spawn(move || {
                            let mut patch = vec![0.0f32; n];
                            for (k, pos) in (lo..hi).enumerate() {
                                let (oy, ox) = (pos / self.w_out, pos % self.w_out);
                                self.extract_patch(x, g, oy, ox, &mut patch);
                                let words = &mut wc[k * stride..(k + 1) * stride];
                                gc[k] = match const_gamma {
                                    Some(gamma) => {
                                        binarize_signs_into(&patch, words);
                                        gamma
                                    }
                                    None => binarize_activations_into(&patch, words),
                                };
                            }
                            packed.forward_batch_binarized_rows_simd(
                                g * cog, (g + 1) * cog, wc, stride, gc, relu,
                                oc, simd);
                        });
                    }
                });
            }
            for pos in 0..area {
                for oc in 0..cog {
                    y[(g * cog + oc) * area + pos] = scratch.batch_out[pos * cog + oc];
                }
            }
        }
        y
    }

    /// Layer-0 forward on the `PackedInt8` path: quantize the whole input
    /// map once, then run integer row dots over int8 im2col patches.
    ///
    /// With `threads > 1` output positions split across scoped std threads;
    /// each thread owns the channel-strided, pairwise-disjoint `y` slices
    /// of its position range plus a private int8 patch buffer, so results
    /// stay bit-exact against the serial loop.
    pub fn forward_int8(&self, x: &[f32], relu: bool, scratch: &mut Scratch,
                        threads: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.in_len());
        let scale = quantize_input_i8(x, &mut scratch.qi8);
        let n = self.patch_len();
        let cog = self.co / self.groups;
        let area = self.h_out * self.w_out;
        let mut y = vec![0.0f32; self.co * area];
        let t = threads.min(area).max(1);
        if t <= 1 {
            scratch.patch_i8.clear();
            scratch.patch_i8.resize(n, 0);
            for oy in 0..self.h_out {
                for ox in 0..self.w_out {
                    for g in 0..self.groups {
                        self.extract_patch_i8(&scratch.qi8, g, oy, ox,
                                              &mut scratch.patch_i8);
                        for oc in 0..cog {
                            let o = g * cog + oc;
                            let v = payload_row_dot_i8(
                                &self.record.payload, o * n, &scratch.patch_i8, scale);
                            y[(o * self.h_out + oy) * self.w_out + ox] =
                                if relu { v.max(0.0) } else { v };
                        }
                    }
                }
            }
            return y;
        }
        let qi8: &[i8] = &scratch.qi8;
        let ranges = split_ranges(area, t);
        // planes[o] is this thread's positions within output channel o
        // (y is channel-major: y[o * area + pos]).
        let parts = partition_strided(&mut y, area, &ranges);
        std::thread::scope(|scope| {
            for (&(lo, hi), mut planes) in ranges.iter().zip(parts) {
                scope.spawn(move || {
                    let mut patch = vec![0i8; n];
                    for pos in lo..hi {
                        let (oy, ox) = (pos / self.w_out, pos % self.w_out);
                        for g in 0..self.groups {
                            self.extract_patch_i8(qi8, g, oy, ox, &mut patch);
                            for oc in 0..cog {
                                let o = g * cog + oc;
                                let v = payload_row_dot_i8(
                                    &self.record.payload, o * n, &patch, scale);
                                planes[o][pos - lo] = if relu { v.max(0.0) } else { v };
                            }
                        }
                    }
                });
            }
        });
        y
    }

    /// Plain-Rust oracle of [`Conv2dLayer::forward_int`]: per patch,
    /// sign-binarize with scalar compares, accumulate each filter row's
    /// constant-alpha runs as exact integer same-counts (scalar bit reads,
    /// no popcount words), scale by the calibrated constant `thr.gamma` —
    /// the same per-run f32 accumulation order as the kernels, so the two
    /// are **bit-exact**.
    pub fn forward_int_oracle(&self, packed: &PackedLayer, thr: &IntThresholds,
                              x: &[f32], relu: bool, scratch: &mut Scratch)
                              -> Vec<f32> {
        debug_assert_eq!(x.len(), self.in_len());
        let n = self.patch_len();
        scratch.patch.clear();
        scratch.patch.resize(n, 0.0);
        let cog = self.co / self.groups;
        let area = self.h_out * self.w_out;
        let mut y = vec![0.0f32; self.co * area];
        let mut pos_bits = vec![false; n];
        for oy in 0..self.h_out {
            for ox in 0..self.w_out {
                for g in 0..self.groups {
                    self.extract_patch(x, g, oy, ox, &mut scratch.patch);
                    for (b, &v) in pos_bits.iter_mut().zip(scratch.patch.iter()) {
                        *b = v > 0.0;
                    }
                    for oc in 0..cog {
                        let o = g * cog + oc;
                        let mut acc = 0.0f32;
                        if let PackedPayload::Dense(w) = &packed.payload {
                            for (j, &wj) in w[o * n..(o + 1) * n].iter().enumerate() {
                                if pos_bits[j] { acc += wj } else { acc -= wj }
                            }
                        } else {
                            packed.for_each_run(o, |start, len, alpha| {
                                let same = (start..start + len)
                                    .filter(|&j| packed.weight_bit(o, j) == pos_bits[j])
                                    .count() as i64;
                                acc += alpha * (2 * same - len as i64) as f32;
                            });
                        }
                        let v = thr.gamma * acc;
                        y[(o * self.h_out + oy) * self.w_out + ox] =
                            if relu { v.max(0.0) } else { v };
                    }
                }
            }
        }
        y
    }

    /// f32 oracle of [`Conv2dLayer::forward_packed`]: per-patch sign/gamma
    /// math over the expanded weights, no bit tricks.
    pub fn forward_quantized_oracle(&self, x: &[f32], relu: bool, scratch: &mut Scratch)
                                    -> Vec<f32> {
        debug_assert_eq!(x.len(), self.in_len());
        let n = self.patch_len();
        scratch.patch.clear();
        scratch.patch.resize(n, 0.0);
        let dense = self.record.expand();
        let cog = self.co / self.groups;
        let area = self.h_out * self.w_out;
        let mut y = vec![0.0f32; self.co * area];
        let mut signs = vec![0.0f32; n];
        for oy in 0..self.h_out {
            for ox in 0..self.w_out {
                for g in 0..self.groups {
                    self.extract_patch(x, g, oy, ox, &mut scratch.patch);
                    // same non-finite guard as the packed path's
                    // `binarize_activations_into`, so parity holds on
                    // poisoned inputs
                    let gamma = activation_gamma(&scratch.patch);
                    for (s, &v) in signs.iter_mut().zip(scratch.patch.iter()) {
                        *s = if v > 0.0 { 1.0 } else { -1.0 };
                    }
                    for oc in 0..cog {
                        let o = g * cog + oc;
                        let row = &dense[o * n..(o + 1) * n];
                        let dot: f32 = row.iter().zip(&signs).map(|(a, b)| a * b).sum();
                        let v = gamma * dot;
                        y[(o * self.h_out + oy) * self.w_out + ox] =
                            if relu { v.max(0.0) } else { v };
                    }
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tbn::WeightPayload;
    use crate::util::Rng;

    fn fp_conv(co: usize, ci: usize, k: usize, input: (usize, usize, usize),
               stride: usize, pad: usize, groups: usize, seed: u64)
               -> Conv2dLayer {
        let mut rng = Rng::new(seed);
        let cig = ci / groups;
        let record = LayerRecord {
            name: "conv".into(),
            shape: vec![co, cig, k, k],
            payload: WeightPayload::Fp(rng.normal_vec(co * cig * k * k, 1.0)),
        };
        Conv2dLayer::new(record, input, stride, pad, groups).unwrap()
    }

    #[test]
    fn geometry_follows_floor_arithmetic() {
        let c = fp_conv(4, 3, 3, (3, 8, 8), 1, 1, 1, 1);
        assert_eq!((c.h_out, c.w_out), (8, 8));
        assert_eq!(c.in_len(), 3 * 64);
        assert_eq!(c.out_len(), 4 * 64);
        assert_eq!(c.patch_len(), 27);
        let s = fp_conv(4, 3, 3, (3, 9, 9), 2, 0, 1, 2);
        assert_eq!((s.h_out, s.w_out), (4, 4));
    }

    #[test]
    fn identity_1x1_conv_passes_values_through() {
        // co = ci = 1, weight 1.0, k=1: output == input
        let record = LayerRecord {
            name: "id".into(),
            shape: vec![1, 1, 1, 1],
            payload: WeightPayload::Fp(vec![1.0]),
        };
        let conv = Conv2dLayer::new(record, (1, 3, 3), 1, 0, 1).unwrap();
        let x: Vec<f32> = (0..9).map(|i| i as f32 - 4.0).collect();
        let mut s = Scratch::default();
        assert_eq!(conv.forward_reference(&x, false, &mut s), x);
        let y = conv.forward_reference(&x, true, &mut s);
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn depthwise_groups_partition_channels() {
        // 2 channels, depthwise 1x1 with weights [2.0, 3.0]: scales per channel
        let record = LayerRecord {
            name: "dw".into(),
            shape: vec![2, 1, 1, 1],
            payload: WeightPayload::Fp(vec![2.0, 3.0]),
        };
        let conv = Conv2dLayer::new(record, (2, 2, 2), 1, 0, 2).unwrap();
        let x = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let mut s = Scratch::default();
        let y = conv.forward_reference(&x, false, &mut s);
        assert_eq!(y, vec![2.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0]);
    }

    #[test]
    fn padding_zero_fills() {
        // 1x1 input, 3x3 kernel, pad 1: only the center tap lands on data
        let mut rng = Rng::new(5);
        let w = rng.normal_vec(9, 1.0);
        let record = LayerRecord {
            name: "p".into(),
            shape: vec![1, 1, 3, 3],
            payload: WeightPayload::Fp(w.clone()),
        };
        let conv = Conv2dLayer::new(record, (1, 1, 1), 1, 1, 1).unwrap();
        let mut s = Scratch::default();
        let y = conv.forward_reference(&[2.0], false, &mut s);
        assert_eq!(y.len(), 1);
        assert!((y[0] - 2.0 * w[4]).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_geometry() {
        let record = LayerRecord {
            name: "bad".into(),
            shape: vec![4, 3, 3, 3],
            payload: WeightPayload::Fp(vec![0.0; 108]),
        };
        // kernel larger than padded input
        assert!(Conv2dLayer::new(record.clone(), (3, 2, 2), 1, 0, 1).is_err());
        // groups not dividing channels
        assert!(Conv2dLayer::new(record.clone(), (3, 8, 8), 1, 1, 2).is_err());
        // zero stride
        assert!(Conv2dLayer::new(record.clone(), (3, 8, 8), 0, 1, 1).is_err());
        // 2-D record
        let fc = LayerRecord {
            name: "fc".into(),
            shape: vec![4, 27],
            payload: WeightPayload::Fp(vec![0.0; 108]),
        };
        assert!(Conv2dLayer::new(fc, (3, 8, 8), 1, 1, 1).is_err());
    }

    /// The batched packed forward's staging (binarized im2col map, gammas,
    /// position-major output copy) is what `Node::packed_scratch_bytes`
    /// charges to the Table 6 peak.
    #[test]
    fn packed_scratch_bytes_cover_batched_staging() {
        let conv = fp_conv(4, 3, 3, (3, 8, 8), 1, 1, 1, 30);
        // area 64, patch_len 27 -> 1 word/patch, cog 4
        let node = crate::nn::layers::Node::Conv2d(conv);
        assert_eq!(node.packed_scratch_bytes(), 8 * 64 + 4 * 64 + 4 * 64 * 4);
        let fc = crate::nn::layers::Node::Flatten { len: 9 };
        assert_eq!(fc.packed_scratch_bytes(), 0);
    }

    #[test]
    fn packed_matches_oracle_on_one_layer() {
        let mut rng = Rng::new(21);
        let conv = fp_conv(5, 3, 3, (3, 6, 6), 1, 1, 1, 22);
        let x = rng.normal_vec(conv.in_len(), 1.0);
        let mut s = Scratch::default();
        let want = conv.forward_quantized_oracle(&x, false, &mut s);
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let packed = conv.build_packed(layout).unwrap();
            let got = conv.forward_packed(&packed, &x, false, &mut s, 1,
                                          SimdBackend::default());
            assert_eq!(got.len(), want.len());
            for i in 0..got.len() {
                assert!((got[i] - want[i]).abs() < 1e-3 * want[i].abs().max(1.0),
                        "{layout:?} out {i}: {} vs {}", got[i], want[i]);
            }
        }
    }

    /// A tiled conv under both weight layouts is bit-exact — including a
    /// grouped conv, whose batch runs cover row sub-ranges.
    #[test]
    fn tile_resident_conv_matches_expanded_bit_exact() {
        let mut rng = Rng::new(23);
        // grouped: ci=4, groups=2, co=6 -> cog=3; patch_len = 2*3*3 = 18
        let (co, ci, k, groups) = (6usize, 4usize, 3usize, 2usize);
        let cig = ci / groups;
        let params = co * cig * k * k; // 108 -> p=4 divides, q=27
        let w = rng.normal_vec(params, 1.0);
        let record = LayerRecord {
            name: "gc".into(),
            shape: vec![co, cig, k, k],
            payload: crate::tbn::WeightPayload::Tiled {
                p: 4,
                tile: crate::tbn::tile_from_weights(&w, 4),
                alphas: crate::tbn::alphas_from(&w, 4, crate::tbn::AlphaMode::PerTile),
            },
        };
        let conv = Conv2dLayer::new(record, (ci, 7, 7), 1, 1, groups).unwrap();
        let tile = conv.build_packed(PackedLayout::TileResident).unwrap();
        let expanded = conv.build_packed(PackedLayout::Expanded).unwrap();
        assert!(tile.resident_bytes() < expanded.resident_bytes());
        let mut s = Scratch::default();
        let x = rng.normal_vec(conv.in_len(), 1.0);
        let a = conv.forward_packed(&tile, &x, true, &mut s, 1,
                                    SimdBackend::default());
        let b = conv.forward_packed(&expanded, &x, true, &mut s, 1,
                                    SimdBackend::default());
        assert_eq!(a, b, "layouts must agree bit-exactly");

        // the threaded position split is bit-exact on both layouts, at any
        // thread count (including threads > positions: area = 49)
        for threads in [2usize, 3, 4, 8, 64] {
            assert_eq!(conv.forward_packed(&tile, &x, true, &mut s, threads,
                                           SimdBackend::default()),
                       a, "tile threads={threads}");
            assert_eq!(conv.forward_packed(&expanded, &x, true, &mut s, threads,
                                           SimdBackend::default()),
                       b, "expanded threads={threads}");
        }
    }

    /// The integer-pipeline conv forward (constant calibrated gamma, sign
    /// only binarize) is bit-exact against its plain-Rust oracle, on both
    /// layouts and at any thread count — including a grouped conv.
    #[test]
    fn int_conv_matches_oracle_bit_exact() {
        let mut rng = Rng::new(26);
        let (co, ci, k, groups) = (6usize, 4usize, 3usize, 2usize);
        let cig = ci / groups;
        let w = rng.normal_vec(co * cig * k * k, 1.0);
        let record = LayerRecord {
            name: "gc".into(),
            shape: vec![co, cig, k, k],
            payload: crate::tbn::WeightPayload::Tiled {
                p: 4,
                tile: crate::tbn::tile_from_weights(&w, 4),
                alphas: crate::tbn::alphas_from(&w, 4, crate::tbn::AlphaMode::PerTile),
            },
        };
        let conv = Conv2dLayer::new(record, (ci, 7, 7), 1, 1, groups).unwrap();
        let x = rng.normal_vec(conv.in_len(), 1.0);
        let mut s = Scratch::default();
        for layout in [PackedLayout::TileResident, PackedLayout::Expanded] {
            let packed = conv.build_packed(layout).unwrap();
            let mut thr = IntThresholds::from_layer(&packed);
            thr.gamma = 0.37; // calibrated constants must flow through
            let want = conv.forward_int_oracle(&packed, &thr, &x, true, &mut s);
            for threads in [1usize, 2, 4, 64] {
                assert_eq!(conv.forward_int(&packed, &thr, &x, true, &mut s, threads,
                                            SimdBackend::default()),
                           want, "{layout:?} threads={threads}");
            }
        }
    }

    /// The threaded int8 conv forward is bit-exact against the serial one.
    #[test]
    fn int8_threaded_matches_serial_bit_exact() {
        let mut rng = Rng::new(24);
        let conv = fp_conv(5, 3, 3, (3, 6, 6), 1, 1, 1, 25);
        let x = rng.normal_vec(conv.in_len(), 1.0);
        let mut s = Scratch::default();
        let want = conv.forward_int8(&x, true, &mut s, 1);
        for threads in [2usize, 4, 8, 64] {
            assert_eq!(conv.forward_int8(&x, true, &mut s, threads), want,
                       "threads={threads}");
        }
    }
}
