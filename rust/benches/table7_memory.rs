//! Table 7: inference memory on the ImageNet ViT — peak memory, parameter
//! memory and %-of-peak for the four kernel variants, from the allocator
//! model, plus measured host-side weight residency on the native engine:
//! per layer record, and expanded-vs-tile-resident packed layouts across
//! the natively-lowered paper architectures (the tentpole A/B).

use tiledbits::arch;
use tiledbits::bench_util::header;
use tiledbits::coordinator::report;
use tiledbits::nn::{layer_resident_bytes, lower_arch_spec, Engine, EnginePath,
                    LowerOptions, Nonlin, PackedLayout};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TilingPolicy, WeightPayload};
use tiledbits::tbn::memory::{simulate, KernelKind};
use tiledbits::tensor::BitVec;
use tiledbits::util::Rng;

fn main() {
    header("Table 7: inference memory, ImageNet ViT");
    print!("{}", report::memory_table(4).render());
    println!("paper: FP 222.5/208.0 (93.5%), FP-Tiled 78.5/52.0, BWNN 18.4/6.5,");
    println!("       TBN_4 13.4/1.6 (11.9%)\n");

    // host-measured residency of one real 8.3M-param ViT block layer
    let (m, n, p) = (832usize, 3328usize, 4usize);
    let mut rng = Rng::new(7);
    let w = rng.normal_vec(m * n, 1.0);
    let variants = [
        ("fp", LayerRecord { name: "mlp.fc1".into(), shape: vec![m, n],
                             payload: WeightPayload::Fp(w.clone()) }),
        ("bwnn", LayerRecord { name: "mlp.fc1".into(), shape: vec![m, n],
                               payload: WeightPayload::Bwnn {
                                   bits: BitVec::from_signs(&w), alpha: 0.5 } }),
        ("tbn4", LayerRecord { name: "mlp.fc1".into(), shape: vec![m, n],
                               payload: WeightPayload::Tiled {
                                   p,
                                   tile: tile_from_weights(&w, p),
                                   alphas: alphas_from(&w, p, AlphaMode::PerTile) } }),
    ];
    println!("-- measured bytes resident for one {m}x{n} FC layer --");
    let fp_bytes = layer_resident_bytes(&variants[0].1) as f64;
    for (name, rec) in &variants {
        let b = layer_resident_bytes(rec);
        println!("{name:6} {:>12} bytes  ({:.1}x vs fp)", b, fp_bytes / b as f64);
    }

    // peak sensitivity to p
    println!("\n-- TBN peak memory vs p (allocator model) --");
    let a = arch::vit_small_imagenet();
    for p in [2usize, 4, 8, 16] {
        let r = simulate(&a, &TilingPolicy::tbn(p, 150_000), KernelKind::TbnPacked);
        println!("p={p:<2} peak {:7.2} MB  params {:6.2} MB  ({:.1}% of peak)",
                 r.peak_bytes / 1e6, r.param_bytes / 1e6, 100.0 * r.param_fraction());
    }

    // measured packed-engine residency: expanded rows vs the tile-resident
    // layout on the natively-lowered paper architectures (binarized layers
    // only differ; the entry layer stays a reference tile on both).  Since
    // the DAG lowering, the list includes the branching Table 1 / Table 3
    // architectures — ResNet18/50 (residual joins) and PointNet-cls
    // (T-Nets) — and, since the transformer nodes, the Table 4/5 encoders
    // (ViT, TST, MLP-Mixer): attention/LayerNorm run weightless f32, so
    // the residency delta is carried entirely by the tiled projections.
    println!("\n-- packed weight residency: expanded vs tile-resident (measured) --");
    println!("{:22} {:>14} {:>14} {:>8}", "architecture", "expanded B",
             "tile-resident B", "ratio");
    let specs: [(&str, arch::ArchSpec); 11] = [
        ("cnn_micro", arch::cnn_micro()),
        ("pointnet_micro", arch::pointnet_micro()),
        ("vgg_small_cifar", arch::vgg_small_cifar()),
        ("convmixer_cifar", arch::convmixer_cifar()),
        ("resnet18_cifar", arch::resnet18_cifar()),
        ("resnet50_cifar", arch::resnet50_cifar()),
        ("pointnet_cls", arch::pointnet_cls()),
        ("vit_cifar", arch::vit_cifar()),
        ("tst_electricity", arch::tst_electricity()),
        ("tst_weather", arch::tst_weather()),
        ("mlpmixer_cifar", arch::mlpmixer_cifar()),
    ];
    for (name, spec) in specs {
        // input shape derived from the spec itself, so the list cannot
        // drift if a spec's tokens/patch geometry changes
        let input = spec.native_input().expect("first-layer input shape");
        let opts = LowerOptions { input, p: 4, alpha_mode: AlphaMode::PerTile, seed: 9 };
        let graph = match lower_arch_spec(&spec, &opts) {
            Ok(g) => g,
            Err(e) => {
                println!("{name:22} (not lowerable: {e})");
                continue;
            }
        };
        let joins = graph.nodes.iter().filter(|gn| gn.node.is_join()).count();
        let expanded = Engine::with_layout_graph(graph.clone(), Nonlin::Relu,
                                                 EnginePath::Packed,
                                                 PackedLayout::Expanded)
            .unwrap();
        let tile = Engine::with_layout_graph(graph, Nonlin::Relu, EnginePath::Packed,
                                             PackedLayout::TileResident)
            .unwrap();
        let (eb, tb) = (expanded.resident_weight_bytes(), tile.resident_weight_bytes());
        println!("{name:22} {eb:>14} {tb:>14} {:>7.1}x  ({joins} joins)",
                 eb as f64 / tb as f64);
    }
    println!("(tile-resident keeps q bits + alphas per tiled layer: the paper's");
    println!(" 'single tile per layer in memory' deployment kernel)");
}
