//! Table 6 companion: the bit-packed XNOR-popcount fast path vs the f32
//! reference engine on the deployment micro MLP (256 -> 128 -> 10, the
//! Table 6 model shape), plus the Table 7-style weight-residency numbers for
//! both paths.
//!
//! Artifact-free: models are built from a seeded RNG exactly like the engine
//! unit tests, so this bench runs on a bare checkout
//! (`cargo bench --bench table6_packed`).  `--json` additionally writes the
//! machine-readable `BENCH_table6.json` (backend, threads, samples/s; wide
//! rows also carry `activation_bytes`) so the packed-path perf trajectory is
//! tracked in-repo.  Both fast paths appear: `Packed` (exact XNOR-Net
//! baseline) and `PackedInt` (the threshold-folded integer pipeline — the
//! hidden 512-wide layer emits bit-words directly, no f32 round trip).

use tiledbits::bench_util::{bench, header};
use tiledbits::nn::{EnginePath, MlpEngine, Nonlin, SimdBackend};
use tiledbits::tbn::{alphas_from, tile_from_weights, AlphaMode, LayerRecord,
                     TbnzModel, WeightPayload};
use tiledbits::tensor::BitVec;
use tiledbits::util::{Json, Rng};

/// The paper's deployment MLP: 256 -> 128 tiled at p, 128 -> 10 stored 1-bit.
fn micro_model(p: usize) -> TbnzModel {
    let mut r = Rng::new(42);
    let w1: Vec<f32> = r.normal_vec(128 * 256, 1.0);
    let w2: Vec<f32> = r.normal_vec(10 * 128, 1.0);
    TbnzModel {
        layers: vec![
            LayerRecord {
                name: "fc0".into(),
                shape: vec![128, 256],
                payload: WeightPayload::Tiled {
                    p,
                    tile: tile_from_weights(&w1, p),
                    alphas: alphas_from(&w1, p, AlphaMode::PerTile),
                },
            },
            LayerRecord {
                name: "head".into(),
                shape: vec![10, 128],
                payload: WeightPayload::Bwnn {
                    bits: BitVec::from_signs(&w2),
                    alpha: w2.iter().map(|x| x.abs()).sum::<f32>() / w2.len() as f32,
                },
            },
        ],
    }
}

/// A wider 512 -> 512 -> 512 -> 10 tiled MLP for the intra-op thread-scaling
/// curve: the packed hidden layer has 512 output rows to split across cores
/// (the micro model's sole packed layer is the 10-row head).
fn wide_model(p: usize) -> TbnzModel {
    let mut r = Rng::new(43);
    let mk = |name: &str, m: usize, n: usize, r: &mut Rng| {
        let w: Vec<f32> = r.normal_vec(m * n, 1.0);
        LayerRecord {
            name: name.into(),
            shape: vec![m, n],
            payload: WeightPayload::Tiled {
                p,
                tile: tile_from_weights(&w, p),
                alphas: alphas_from(&w, p, AlphaMode::PerTile),
            },
        }
    };
    TbnzModel {
        layers: vec![
            mk("fc0", 512, 512, &mut r),
            mk("fc1", 512, 512, &mut r),
            mk("head", 10, 512, &mut r),
        ],
    }
}

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let simd = SimdBackend::default();
    header("Table 6 companion: packed XNOR path vs f32 reference (micro MLP)");
    println!("packed kernels run the {simd} xnor-popcount backend");

    let p = 4usize;
    let model = micro_model(p);
    let reference =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Reference).unwrap();
    let packed =
        MlpEngine::with_path(model.clone(), Nonlin::Relu, EnginePath::Packed).unwrap();

    let mut r = Rng::new(7);
    let x = r.normal_vec(256, 1.0);
    let batch: Vec<Vec<f32>> = (0..32).map(|_| r.normal_vec(256, 1.0)).collect();
    // the threshold-folded integer pipeline, gammas calibrated on the bench
    // batch (calibration only moves f32 boundaries; bits are invariant)
    let int = MlpEngine::with_path(model, Nonlin::Relu, EnginePath::PackedInt)
        .unwrap()
        .calibrate_int_gammas(&batch);

    // single-sample latency
    let r_ref = bench("reference forward (1 sample)", 20, 200, || {
        std::hint::black_box(reference.forward(&x));
    });
    let r_refq = bench("reference quantized oracle (1 sample)", 20, 200, || {
        std::hint::black_box(reference.forward_quantized(&x));
    });
    let r_pkd = bench("packed xnor forward (1 sample)", 20, 200, || {
        std::hint::black_box(packed.forward(&x));
    });
    let r_int = bench("packed-int threshold forward (1 sample)", 20, 200, || {
        std::hint::black_box(int.forward(&x));
    });

    // batched throughput (the serving path)
    let b_ref = bench("reference forward_batch (32)", 5, 60, || {
        std::hint::black_box(reference.forward_batch(&batch));
    });
    let b_pkd = bench("packed forward_batch (32)", 5, 60, || {
        std::hint::black_box(packed.forward_batch(&batch));
    });
    let b_int = bench("packed-int forward_batch (32)", 5, 60, || {
        std::hint::black_box(int.forward_batch(&batch));
    });

    for r in [&r_ref, &r_refq, &r_pkd, &r_int, &b_ref, &b_pkd, &b_int] {
        println!("{}", r.report());
    }

    println!("\n-- throughput (samples/s) --");
    println!("reference single: {:>12.0}", r_ref.per_sec());
    println!("packed single:    {:>12.0}  ({:.2}x vs reference quantized oracle)",
             r_pkd.per_sec(), r_pkd.per_sec() / r_refq.per_sec());
    println!("packed-int single:{:>12.0}  ({:.2}x vs packed)",
             r_int.per_sec(), r_int.per_sec() / r_pkd.per_sec());
    println!("reference batch:  {:>12.0}", b_ref.throughput(batch.len()));
    println!("packed batch:     {:>12.0}", b_pkd.throughput(batch.len()));
    println!("packed-int batch: {:>12.0}  ({:.2}x vs packed)",
             b_int.throughput(batch.len()),
             b_int.throughput(batch.len()) / b_pkd.throughput(batch.len()));

    // intra-op thread scaling on a wider hidden layer (the micro MLP's only
    // packed layer has 10 rows — too few to split): 512 -> 512 tiled hidden
    // layer behind an f32 entry layer, batch of 32, threads 1/2/4/8.
    println!("\n-- intra-op kernel-thread scaling (512-wide hidden, batch 32) --");
    println!("{:>8} {:>12} {:>14} {:>8} {:>14} {:>8}", "threads", "path",
             "samples/s", "speedup", "act bytes", "vs pkd");
    let wide = wide_model(p);
    let wbatch: Vec<Vec<f32>> = (0..32).map(|_| r.normal_vec(512, 1.0)).collect();
    let mut base = 0.0f64;
    let mut thread_rows: Vec<(&str, usize, f64, usize)> = Vec::new();
    let mut packed_act = 0usize;
    for t in [1usize, 2, 4, 8] {
        for path in [EnginePath::Packed, EnginePath::PackedInt] {
            let tag = if path == EnginePath::Packed { "packed" } else { "int" };
            let engine = MlpEngine::with_path(wide.clone(), Nonlin::Relu, path)
                .unwrap()
                .with_threads(t)
                .with_simd(simd)
                .calibrate_int_gammas(&wbatch[..4]);
            let act = engine.activation_bytes();
            if path == EnginePath::Packed {
                packed_act = act;
            }
            let res = bench(&format!("{tag} forward_batch(32) threads={t}"), 3, 40,
                            || {
                                std::hint::black_box(engine.forward_batch(&wbatch));
                            });
            let sps = res.throughput(wbatch.len());
            if t == 1 && path == EnginePath::Packed {
                base = sps;
            }
            thread_rows.push((tag, t, sps, act));
            println!("{t:>8} {tag:>12} {sps:>14.0} {:>7.2}x {act:>14} {:>7.2}x",
                     sps / base, packed_act as f64 / act as f64);
        }
    }

    if json_mode {
        let entry = |name: &str, threads: usize, sps: f64| {
            Json::obj(vec![
                ("name", Json::Str(name.to_string())),
                ("backend", Json::Str(simd.as_str().to_string())),
                ("threads", Json::Num(threads as f64)),
                ("samples_per_s", Json::Num(sps)),
            ])
        };
        let mut runs = vec![
            entry("micro reference single", 1, r_ref.per_sec()),
            entry("micro packed single", 1, r_pkd.per_sec()),
            entry("micro packed-int single", 1, r_int.per_sec()),
            entry("micro reference batch32", 1, b_ref.throughput(batch.len())),
            entry("micro packed batch32", 1, b_pkd.throughput(batch.len())),
            entry("micro packed-int batch32", 1, b_int.throughput(batch.len())),
        ];
        for &(tag, t, sps, act) in &thread_rows {
            let name = if tag == "int" { "wide packed-int batch32" }
                       else { "wide packed batch32" };
            let mut e = entry(name, t, sps);
            e.set("activation_bytes", Json::Num(act as f64));
            runs.push(e);
        }
        let doc = Json::obj(vec![
            ("bench", Json::Str("table6_packed".to_string())),
            ("backend", Json::Str(simd.as_str().to_string())),
            ("runs", Json::Arr(runs)),
        ]);
        let path = "BENCH_table6.json";
        std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_table6.json");
        println!("\nwrote {path}");
    }

    println!("\n-- Table 6/7-style memory (bytes) --");
    println!("{:28} {:>12} {:>12} {:>12}", "engine", "resident W", "peak mem",
             "storage");
    for (name, e) in [("reference (sub-bit tiles)", &reference),
                      ("packed (tile-resident)", &packed)] {
        println!("{:28} {:>12} {:>12} {:>12}", name, e.resident_weight_bytes(),
                 e.peak_memory_bytes(), e.storage_bytes());
    }
    println!("\nnote: the packed path keeps one q-bit tile (plus alphas) resident per");
    println!("binarized tiled layer (PackedLayout::TileResident; this model's only");
    println!("tiled layer is the f32 entry layer, which stays a reference tile).");
    println!("benches/table7_memory.rs carries the expanded-vs-tile-resident A/B;");
    println!("storage on disk (TBNZ) is unchanged.");
}
